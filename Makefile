PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-sanitize lint zipalint docs-check quickstart \
	bench bench-kernels bench-concurrency bench-trend install-dev

# tier-1 verify (ROADMAP.md). Local default is fail-fast; CI overrides
# PYTEST_ARGS (e.g. --junitxml=...) and drops -x so junit reports are
# complete.
PYTEST_ARGS ?= -x
test:
	$(PYTHON) -m pytest -q $(PYTEST_ARGS)

# tier-1 with the whole-engine runtime sanitizer armed: every step is
# followed by a full state audit (queues, pools, refcounts, qwin
# ownership — docs/ANALYSIS.md). Slower; CI runs a slice of it.
test-sanitize:
	ZIPAGE_SANITIZE=1 $(PYTHON) -m pytest -q $(PYTEST_ARGS)

# correctness lint (ruff config in pyproject.toml; pip install ruff)
lint:
	$(PYTHON) -m ruff check src tests benchmarks examples

# repo-specific architectural static analysis (stdlib-only; zero
# findings is the gate — docs/ANALYSIS.md lists the rules and waivers)
zipalint:
	$(PYTHON) tools/zipalint.py

# docs gate (run in CI): intra-repo markdown links resolve. Config-field
# coverage moved into zipalint (rule ZPL004).
docs-check:
	$(PYTHON) tools/docs_check.py

# quick signal: facade + engine + scheduler + block manager only
test-fast:
	$(PYTHON) -m pytest -q tests/test_api.py tests/test_engine.py tests/test_scheduler.py tests/test_block_manager.py

quickstart:
	$(PYTHON) examples/quickstart.py

bench:
	$(PYTHON) -m benchmarks.run

# kernel micro-bench JSON — this exact target is what CI's bench-smoke job
# uploads; run benchmarks.bench_kernels without --smoke for full shapes
bench-kernels:
	$(PYTHON) -m benchmarks.bench_kernels --smoke --out bench-kernels-smoke.json

# end-to-end serving smoke (zipage vs nano-vLLM baseline, plus the
# oversubscribed recompute-vs-swap-vs-auto preemption-mode comparison) —
# CI uploads the JSON as the per-PR concurrency trajectory artifact
bench-concurrency:
	$(PYTHON) -m benchmarks.bench_concurrency --smoke --oversubscribe --prefix-heavy --out bench-concurrency-smoke.json

# accumulate bench-smoke artifacts (oldest first) into BENCH_TREND.md and
# fail on a >25% decode-throughput regression (zipage, and swap-mode once
# oversubscribed points exist) vs the previous point. CI seeds
# bench-history/ from the last successful main run's artifact; locally,
# drop downloaded per-PR artifacts there to grow the trajectory.
BENCH_TREND_FILES ?= $(sort $(wildcard bench-history/*.json)) bench-concurrency-smoke.json bench-kernels-smoke.json
bench-trend:
	$(PYTHON) tools/bench_trend.py $(BENCH_TREND_FILES) --out BENCH_TREND.md

install-dev:
	pip install -r requirements-dev.txt
