PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast quickstart bench install-dev

# tier-1 verify (ROADMAP.md)
test:
	$(PYTHON) -m pytest -x -q

# quick signal: facade + engine + block manager only
test-fast:
	$(PYTHON) -m pytest -q tests/test_api.py tests/test_engine.py tests/test_block_manager.py

quickstart:
	$(PYTHON) examples/quickstart.py

bench:
	$(PYTHON) -m benchmarks.run

install-dev:
	pip install -r requirements-dev.txt
