PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-sanitize test-soak test-serve lint zipalint \
	docs-check quickstart bench bench-kernels bench-concurrency \
	bench-quality bench-serving bench-trend eval-smoke install-dev serve

# tier-1 verify (ROADMAP.md). Local default is fail-fast; CI overrides
# PYTEST_ARGS (e.g. --junitxml=...) and drops -x so junit reports are
# complete.
PYTEST_ARGS ?= -x
test:
	$(PYTHON) -m pytest -q $(PYTEST_ARGS)

# tier-1 with the whole-engine runtime sanitizer armed: every step is
# followed by a full state audit (queues, pools, refcounts, qwin
# ownership — docs/ANALYSIS.md). Slower; CI runs a slice of it.
test-sanitize:
	ZIPAGE_SANITIZE=1 $(PYTHON) -m pytest -q $(PYTEST_ARGS)

# correctness lint (ruff config in pyproject.toml; pip install ruff)
lint:
	$(PYTHON) -m ruff check src tests benchmarks examples

# repo-specific architectural static analysis (stdlib-only; zero
# findings is the gate — docs/ANALYSIS.md lists the rules and waivers)
zipalint:
	$(PYTHON) tools/zipalint.py

# docs gate (run in CI): intra-repo markdown links resolve. Config-field
# coverage moved into zipalint (rule ZPL004).
docs-check:
	$(PYTHON) tools/docs_check.py

# quick signal: facade + engine + scheduler + block manager only
test-fast:
	$(PYTHON) -m pytest -q tests/test_api.py tests/test_engine.py tests/test_scheduler.py tests/test_block_manager.py

# serving tier (docs/SERVING.md): async facade + HTTP protocol + the
# disconnect/backpressure/drain races, with the runtime sanitizer armed
# — CI's serve-smoke job runs exactly this
test-serve:
	ZIPAGE_SANITIZE=1 $(PYTHON) -m pytest -q $(PYTEST_ARGS) tests/test_aio.py tests/test_serve.py

# randomized engine soak: seeded fuzz workloads across the scheduler
# policy x preemption-mode x fused-horizon matrix with ZIPAGE_SANITIZE=1
# armed (the tests arm it themselves), plus the prefix-cache property
# tests (hypothesis when installed, seeded soak otherwise)
test-soak:
	$(PYTHON) -m pytest -q $(PYTEST_ARGS) tests/test_soak.py tests/test_prefix_cache_prop.py

quickstart:
	$(PYTHON) examples/quickstart.py

bench:
	$(PYTHON) -m benchmarks.run

# kernel micro-bench JSON — this exact target is what CI's bench-smoke job
# uploads; run benchmarks.bench_kernels without --smoke for full shapes
bench-kernels:
	$(PYTHON) -m benchmarks.bench_kernels --smoke --out bench-kernels-smoke.json

# end-to-end serving smoke (zipage vs nano-vLLM baseline, plus the
# oversubscribed recompute-vs-swap-vs-auto preemption-mode comparison) —
# CI uploads the JSON as the per-PR concurrency trajectory artifact
bench-concurrency:
	$(PYTHON) -m benchmarks.bench_concurrency --smoke --oversubscribe --prefix-heavy --out bench-concurrency-smoke.json

# accumulate bench-smoke artifacts (oldest first) into BENCH_TREND.md and
# fail on a >25% decode-throughput regression (zipage, and swap-mode once
# oversubscribed points exist) vs the previous point. CI seeds
# bench-history/ from the last successful main run's artifact; locally,
# drop downloaded per-PR artifacts there to grow the trajectory.
BENCH_TREND_FILES ?= $(sort $(wildcard bench-history/*.json)) bench-concurrency-smoke.json bench-kernels-smoke.json $(wildcard eval-smoke.json) $(wildcard bench-quality-smoke.json) $(wildcard bench-serving-smoke.json)
bench-trend:
	$(PYTHON) tools/bench_trend.py $(BENCH_TREND_FILES) --out BENCH_TREND.md

# scoring-ablation quality proxy (top-1 agreement vs full-KV) — CI
# uploads the JSON next to the eval report (docs/EVAL.md)
bench-quality:
	$(PYTHON) -m benchmarks.bench_quality_proxy --smoke --out bench-quality-smoke.json

# serving-tier latency smoke (docs/SERVING.md): Poisson arrivals through
# the in-process ASGI app — p50/p99 TTFT, inter-token latency, sustained
# tok/s as the zipage-bench-serving/v1 point bench-trend gates
bench-serving:
	$(PYTHON) -m benchmarks.bench_serving --smoke --out bench-serving-smoke.json

# run the OpenAI-compatible server on the tiny model (docs/SERVING.md)
serve:
	$(PYTHON) -m repro.serve --model tiny-lm

# seeded reasoning eval across compression budgets (docs/EVAL.md): tiny-lm
# trained on the task distribution, accuracy scored vs Full-KV, emitted as
# the byte-deterministic zipage-eval/v1 JSON CI gates via bench-trend
eval-smoke:
	$(PYTHON) -m repro.eval --smoke --out eval-smoke.json

install-dev:
	pip install -r requirements-dev.txt
