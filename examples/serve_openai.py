"""The OpenAI-compatible serving tier end-to-end (docs/SERVING.md):
unary + SSE-streamed completions, per-client fairness, and graceful
drain, driven through the in-process ASGI client — no sockets, so it
runs anywhere the tests run. For a real HTTP server use `make serve`
(python -m repro.serve) and point any OpenAI client at it.

  PYTHONPATH=src python examples/serve_openai.py
"""
import asyncio

import numpy as np

from repro.serve import ServeConfig, create_app
from repro.serve.protocol import render_text
from repro.serve.testing import ASGIClient

app = create_app(ServeConfig(model="tiny-lm", max_queued_requests=32))
client = ASGIClient(app)

rng = np.random.default_rng(0)
PROMPT = rng.integers(0, app.state.vocab_size, size=10).tolist()


async def main():
    # unary completion — OpenAI response shape, token-id codec in `text`
    r = await client.request("POST", "/v1/completions", json={
        "prompt": render_text(PROMPT), "max_tokens": 24,
        "temperature": 0.8, "seed": 7})
    body = r.json()
    print(f"unary: finish={body['choices'][0]['finish_reason']} "
          f"usage={body['usage']}")
    print(f"  text: {body['choices'][0]['text']}")

    # SSE stream — chunks arrive as the engine steps; two clients run
    # concurrently and continuous-batch inside the one engine
    async def stream_one(cid):
        toks = []
        async with client.stream("POST", "/v1/chat/completions", json={
                "messages": [{"role": "user",
                              "content": render_text(PROMPT)}],
                "max_tokens": 32, "stream": True},
                headers={"x-client-id": cid}) as h:
            async for event in h.events():
                if event == "[DONE]" or not event["choices"]:
                    continue
                toks += event["choices"][0]["delta"].get("token_ids", [])
        return cid, toks

    for cid, toks in await asyncio.gather(stream_one("alice"),
                                          stream_one("bob")):
        print(f"stream[{cid}]: {len(toks)} tokens: "
              f"{render_text(toks[:8])} ...")

    health = (await client.request("GET", "/health")).json()
    print(f"health: backlog={health['backlog']} "
          f"steps={health['step_count']} "
          f"free_blocks={health['free_blocks']}")

    # graceful drain: intake closes, running work finishes, loop exits
    await app.state.drain()
    assert (await client.request("GET", "/health")).status == 503
    print("drained: intake closed, engine idle")


asyncio.run(main())
