"""Quickstart: serve a tiny LM with Compressed PagedAttention.

  PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

import jax

from repro.configs import get_config
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.models import lm

cfg = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
params = lm.init(cfg, jax.random.key(0))

engine = ZipageEngine(cfg, params, EngineOptions(
    block_size=8,            # page size b
    n_total_blocks=64,       # KV pool
    max_batch=4,             # decode slots
    m_qslots=4,              # paper's M: query-slot concurrency
    n_max=3,                 # block cap => KV budget = (n_max-1)*b = 16
    window=4,                # observation window w
    compress=CompressOptions(window=4, redundancy="lightning",
                             alpha=0.8, lam=0.2, tau=0.4),
    scheduling="hybrid",
    async_compression=True,
    max_model_len=128,
    temperature=0.0,
))

prompts = [[1, 2, 3, 4, 5], [9, 8, 7, 6], [20, 21, 22]]
rids = [engine.submit(p, max_new_tokens=40) for p in prompts]
done = engine.run()

for rid, p in zip(rids, prompts):
    r = done[rid]
    print(f"req {rid}: prompt {p} -> {len(r.output)} tokens, "
          f"first 10 = {r.output[:10]}")
n_comp = sum(m["n_compressing"] for m in engine.metrics)
print(f"steps: {engine.step_count}, compressions: {n_comp}, "
      f"all blocks returned: {engine.bm.num_free == 64}")
