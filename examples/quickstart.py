"""Quickstart: serve a tiny LM through the `Zipage` facade.

One line brings the engine up; requests carry their own SamplingParams
(temperature / top-k / top-p / seed / stop sequences), tokens stream back
as CompletionChunks while the continuous batch runs, and abort() cancels a
request mid-flight with its blocks returned to the pool.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.api import SamplingParams, Zipage

z = Zipage.from_config(
    "tiny-lm",
    block_size=8,            # page size b          (CacheConfig)
    n_total_blocks=64,       # KV pool              (CacheConfig)
    n_max=3,                 # block cap => KV budget = (n_max-1)*b = 16
    window=4,                # observation window w (CacheConfig)
    max_model_len=128,
    max_batch=4,             # decode slots         (SchedulerConfig)
    m_qslots=4,              # paper's M            (SchedulerConfig)
    scheduling="hybrid",
    async_compression=True,
    prefill_rows=4,          # prefill bucket       (ModelRunnerConfig)
    prefill_len=64,
)

# --- batch mode: one call, per-request sampling -----------------------
outs = z.generate(
    [[1, 2, 3, 4, 5], [9, 8, 7, 6], [20, 21, 22]],
    [SamplingParams(max_new_tokens=24),                       # greedy
     SamplingParams(temperature=0.8, seed=7, max_new_tokens=24),
     SamplingParams(temperature=1.2, top_k=40, seed=1, max_new_tokens=24,
                    logprobs=True)])
for o in outs:
    print(f"req {o.request_id}: {o.usage.completion_tokens} tokens "
          f"(finish={o.finish_reason}), first 8 = {o.token_ids[:8]}")

# --- streaming mode: add_request / step, with a mid-flight abort ------
# Two requests at different temperatures AND seeds decode in the SAME
# continuous batch; chunks arrive as tokens land.
r_greedy = z.add_request([1, 2, 3, 4, 5],
                         SamplingParams(max_new_tokens=40))
r_warm = z.add_request([9, 8, 7, 6],
                       SamplingParams(temperature=0.9, seed=123,
                                      max_new_tokens=40))
streamed = {r_greedy: [], r_warm: []}
aborted = None
while z.has_unfinished():
    for out in z.step():
        if out.chunk and out.chunk.token_ids:
            streamed[out.request_id] += out.chunk.token_ids
            print(f"  step {z.step_count:3d} req {out.request_id}: "
                  f"+{len(out.chunk.token_ids)} -> {len(out.token_ids)}")
    if aborted is None and len(streamed[r_warm]) >= 10:
        aborted = z.abort(r_warm)     # cancel mid-flight; blocks returned
        print(f"  aborted req {r_warm} at {aborted.usage.completion_tokens} tokens "
              f"(finish={aborted.finish_reason})")

n_comp = sum(m["n_compressing"] for m in z.metrics)
print(f"steps: {z.step_count}, compressions: {n_comp}, "
      f"all blocks returned: {z.num_free_blocks == 64}")
assert z.num_free_blocks == 64
