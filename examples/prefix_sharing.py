"""Shared-prefix serving (paper §4.4): many requests share a long system
prompt; prefix caching skips re-prefilling it, and compression redirects into
target blocks so sharing survives.

  PYTHONPATH=src python examples/prefix_sharing.py
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.models import lm

cfg = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
params = lm.init(cfg, jax.random.key(0))

SYSTEM_PROMPT = list(range(1, 33))          # 8 full blocks of 4


def run(prefix_caching):
    eng = ZipageEngine(cfg, params, EngineOptions(
        block_size=4, n_total_blocks=128, max_batch=8, m_qslots=8,
        n_max=4, window=2, compress=CompressOptions(window=2),
        prefix_caching=prefix_caching, max_model_len=256,
        prefill_rows=4, prefill_len=64, temperature=0.0))
    rids = [eng.submit(SYSTEM_PROMPT + [100 + i], 30) for i in range(8)]
    done = eng.run(max_steps=2000)
    cached = [done[r].n_cached for r in rids]
    eng.bm.check_invariants()
    assert eng.bm.num_free == 128
    return eng.step_count, cached


steps_pc, cached_pc = run(True)
steps_no, cached_no = run(False)
print(f"with prefix cache:    steps={steps_pc}, cached tokens/request="
      f"{cached_pc}")
print(f"without prefix cache: steps={steps_no}, cached tokens/request="
      f"{cached_no}")
print("prefix cache preserved through compression; block accounting clean.")
