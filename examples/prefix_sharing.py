"""Shared-prefix serving (paper §4.4): many requests share a long system
prompt; prefix caching skips re-prefilling it, and compression redirects into
target blocks so sharing survives.

  PYTHONPATH=src python examples/prefix_sharing.py
"""
from repro.api import CacheConfig, SamplingParams, Zipage
from repro.core.compression import CompressOptions

SYSTEM_PROMPT = list(range(1, 33))          # 8 full blocks of 4


def run(prefix_caching):
    z = Zipage.from_config(
        "tiny-lm",
        cache=CacheConfig(block_size=4, n_total_blocks=128, n_max=4,
                          window=2, compress=CompressOptions(window=2),
                          prefix_caching=prefix_caching, max_model_len=256),
        max_batch=8, m_qslots=8, prefill_rows=4, prefill_len=64)
    outs = z.generate([SYSTEM_PROMPT + [100 + i] for i in range(8)],
                      SamplingParams(max_new_tokens=30))
    cached = [o.metrics.n_cached_prompt_tokens for o in outs]
    z.bm.check_invariants()
    assert z.num_free_blocks == 128
    return z.step_count, cached


steps_pc, cached_pc = run(True)
steps_no, cached_no = run(False)
print(f"with prefix cache:    steps={steps_pc}, cached tokens/request="
      f"{cached_pc}")
print(f"without prefix cache: steps={steps_no}, cached tokens/request="
      f"{cached_no}")
print("prefix cache preserved through compression; block accounting clean.")
