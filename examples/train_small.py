"""Train a small LM for a few hundred steps with the full substrate
(AdamW + ZeRO-1 shardings + chunked-vocab loss + checkpoint/restart).

  PYTHONPATH=src python examples/train_small.py [--steps 200]

Use --arch llama3-8b --reduced (or any assigned arch) to train that family's
reduced config; on a TPU pod the same launcher takes --mesh pod1/pod2.
"""
import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:] or [
        "--arch", "tiny-lm", "--steps", "200", "--seq-len", "64",
        "--global-batch", "8", "--ckpt-dir", "/tmp/repro_train_small",
        "--ckpt-every", "100", "--log-every", "20",
    ]
    main(argv)
