"""End-to-end driver — the paper's headline experiment shape (§5.2/§5.4):
a reasoning workload (short prompts, long outputs) served by Zipage vs the
full-KV PagedAttention baseline (nano-vLLM equivalent) on the same pool.

Zipage's bounded per-request memory sustains higher concurrency; the
baseline preempts/queues once the pool fills. Prints TPS + speedup.

  PYTHONPATH=src python examples/serve_reasoning.py
"""
import time

import numpy as np

from repro.api import SamplingParams, Zipage
from repro.configs import get_config

rng = np.random.default_rng(0)
VOCAB = get_config("tiny-lm").vocab_size
# reasoning shape: short prompts, LONG outputs; demand (32 reqs × ~17 blocks)
# far exceeds the 72-block pool => the pool, not the batch, is the limiter —
# exactly the regime of the paper's Figure 7/8.
PROMPTS = [rng.integers(0, VOCAB, size=12).tolist() for _ in range(32)]
PARAMS = SamplingParams(max_new_tokens=120)


def run(n_max, label):
    z = Zipage.from_config(
        "tiny-lm",
        block_size=8, n_total_blocks=72, n_max=n_max, window=4,
        max_model_len=256,
        max_batch=32, m_qslots=16, scheduling="hybrid",
        async_compression=True,
        prefill_rows=4, prefill_len=64)
    t0 = time.monotonic()
    outs = z.generate(PROMPTS, PARAMS)
    dt = time.monotonic() - t0
    toks = sum(o.usage.completion_tokens for o in outs)
    mean_run = np.mean([m["n_running"] for m in z.metrics])
    preempts = sum(o.metrics.preempt_count for o in outs)
    print(f"{label:22s} steps={z.step_count:5d} tokens={toks:5d} "
          f"tokens/step={toks / z.step_count:5.1f} "
          f"mean_concurrency={mean_run:5.1f} "
          f"preempts={preempts} wall={dt:.1f}s")
    return z.step_count, toks


steps_zip, toks = run(4, "Zipage (budget=24)")
steps_full, _ = run(None, "Full-KV (nano-vllm)")
print(f"\ndevice-step speedup: {steps_full / steps_zip:.2f}x "
      "(hardware-neutral: on an accelerator, a decode step at batch 16 "
      "costs ~the same as at batch 6 — both are weight/KV-bandwidth bound — "
      "so fewer steps IS the throughput gain. The paper reports >2.1x; "
      "wall-clock on this 1-core CPU instead scales with total work, "
      "which is why we report steps.)")
