"""End-to-end driver — the paper's headline experiment shape (§5.2/§5.4):
a reasoning workload (short prompts, long outputs) served by Zipage vs the
full-KV PagedAttention baseline (nano-vLLM equivalent) on the same pool.

Zipage's bounded per-request memory sustains higher concurrency; the
baseline preempts/queues once the pool fills. Prints TPS + speedup.

  PYTHONPATH=src python examples/serve_reasoning.py
"""
import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.models import lm

cfg = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
params = lm.init(cfg, jax.random.key(0))
rng = np.random.default_rng(0)
# reasoning shape: short prompts, LONG outputs; demand (32 reqs × ~17 blocks)
# far exceeds the 72-block pool => the pool, not the batch, is the limiter —
# exactly the regime of the paper's Figure 7/8.
REQS = [(rng.integers(0, cfg.vocab_size, size=12).tolist(), 120)
        for _ in range(32)]


def run(n_max, label):
    eng = ZipageEngine(cfg, params, EngineOptions(
        block_size=8, n_total_blocks=72, max_batch=32, m_qslots=16,
        n_max=n_max, window=4, compress=CompressOptions(window=4),
        scheduling="hybrid", async_compression=True,
        max_model_len=256, prefill_rows=4, prefill_len=64,
        temperature=0.0))
    rids = [eng.submit(p, o) for p, o in REQS]
    t0 = time.monotonic()
    done = eng.run(max_steps=6000)
    dt = time.monotonic() - t0
    toks = sum(len(done[r].output) for r in rids)
    mean_run = np.mean([m["n_running"] for m in eng.metrics])
    print(f"{label:22s} steps={eng.step_count:5d} tokens={toks:5d} "
          f"tokens/step={toks / eng.step_count:5.1f} "
          f"mean_concurrency={mean_run:5.1f} "
          f"preempts={sum(r.preempt_count for r in done.values())} "
          f"wall={dt:.1f}s")
    return eng.step_count, toks


steps_zip, toks = run(4, "Zipage (budget=24)")
steps_full, _ = run(None, "Full-KV (nano-vllm)")
print(f"\ndevice-step speedup: {steps_full / steps_zip:.2f}x "
      "(hardware-neutral: on an accelerator, a decode step at batch 16 "
      "costs ~the same as at batch 6 — both are weight/KV-bandwidth bound — "
      "so fewer steps IS the throughput gain. The paper reports >2.1x; "
      "wall-clock on this 1-core CPU instead scales with total work, "
      "which is why we report steps.)")
