#!/usr/bin/env python
"""Accumulate per-PR bench-smoke JSON artifacts into a markdown trend
table and gate on decode-throughput regressions (``make bench-trend``).

CI uploads ``bench-concurrency-smoke.json`` (schema
``zipage-bench-concurrency/v1..v4``) and ``bench-kernels-smoke.json``
(``zipage-bench-kernels/v1..v2``) for every PR (ROADMAP "Multi-backend
bench trajectory"). v2 kernels points also gate the ragged decode
kernel: the newest point's ragged-vs-dense long-context speedup ratio
must not drop more than ``--max-regression`` below the previous
point's (same-point ratios, so host-speed noise between runs cancels). Feed this tool those artifacts **in chronological order**
(oldest first — e.g. a ``bench-history/`` directory of downloaded
artifacts plus the freshly produced smoke JSON):

    python tools/bench_trend.py bench-history/*.json \\
        bench-concurrency-smoke.json --out BENCH_TREND.md

CI also uploads ``eval-smoke.json`` (``zipage-eval/v1``, the seeded
reasoning eval — docs/EVAL.md) and ``bench-quality-smoke.json``
(``zipage-bench-quality/v1``, top-1 agreement of the scoring ablations);
both land in the reasoning-quality trajectory table.

``bench-serving-smoke.json`` (``zipage-bench-serving/v1``,
benchmarks/bench_serving.py — Poisson arrivals through the in-process
ASGI serving tier, docs/SERVING.md) lands in its own latency table and
adds two gates on the newest vs previous serving point: sustained tok/s
may not drop more than ``--max-regression`` below the previous point,
and p99 TTFT may not grow more than ``--max-ttft-growth`` (default 1.0,
i.e. 2x — client-visible latency on a shared CI box is noisy) above it.

Output: a markdown trajectory table per benchmark kind. Exit status: 1 if
the newest concurrency point's zipage decode throughput (``tps``) — or,
once oversubscribed points exist (schema v3), the swap-mode decode
throughput (``oversub_swap``) — dropped more than ``--max-regression``
(default 0.25, i.e. 25%) below the previous point's, **or** the newest
eval point's accuracy (Full-KV or the headline ``n4_w4`` budget) dropped
more than ``--max-accuracy-drop`` (default 0.02, i.e. 2 points) below
the previous eval point's; 0 otherwise (a single point trivially
passes). Stdlib only — safe to run anywhere CI can run python.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

CONCURRENCY_SCHEMAS = ("zipage-bench-concurrency/v1",
                       "zipage-bench-concurrency/v2",
                       "zipage-bench-concurrency/v3",
                       "zipage-bench-concurrency/v4")
KERNELS_SCHEMAS = ("zipage-bench-kernels/v1",
                   "zipage-bench-kernels/v2")
EVAL_SCHEMAS = ("zipage-eval/v1",)
QUALITY_SCHEMAS = ("zipage-bench-quality/v1",)
SERVING_SCHEMAS = ("zipage-bench-serving/v1",)

#: (result name, human label) series the regression gate watches; a
#: series only gates between consecutive points that both report it, so
#: pre-v3 history mixes fine with v3 points
GATED_SERIES = (("zipage", "zipage"), ("oversub_swap", "swap-mode"))

#: eval budget rows whose accuracy the quality gate watches (the Full-KV
#: anchor and the paper's headline "~95% of Full-KV" budget)
GATED_EVAL_SERIES = (("full_kv", "full-KV accuracy"),
                     ("n4_w4", "n4 accuracy"))

#: kernel speedup series the ragged-decode gate watches: (dense row,
#: ragged row, backend, label). v2 kernels points carry the 4k+
#: mixed-length long-context pair; v1 history lacks it and passes
#: trivially
KERNEL_SPEEDUP_SERIES = (
    ("paged_attention_long", "ragged_attention_long", "jnp",
     "ragged-vs-dense (long, jnp)"),
    ("paged_attention_long", "ragged_attention_long", "pallas-interpret",
     "ragged-vs-dense (long, interpret)"),
)


def load_points(paths):
    """Split the input files into (concurrency, kernels, evals, quality,
    serving) point lists, keeping argument order (= chronological
    order)."""
    concurrency, kernels, evals = [], [], []
    quality, serving, skipped = [], [], []
    for p in paths:
        path = Path(p)
        try:
            data = json.loads(path.read_text())
        except (OSError, ValueError) as e:
            skipped.append(f"{p}: unreadable ({e})")
            continue
        schema = data.get("schema")
        point = {"label": path.stem, "data": data}
        if schema in CONCURRENCY_SCHEMAS:
            concurrency.append(point)
        elif schema in KERNELS_SCHEMAS:
            kernels.append(point)
        elif schema in EVAL_SCHEMAS:
            evals.append(point)
        elif schema in QUALITY_SCHEMAS:
            quality.append(point)
        elif schema in SERVING_SCHEMAS:
            serving.append(point)
        else:
            skipped.append(f"{p}: unknown schema {schema!r}")
    return concurrency, kernels, evals, quality, serving, skipped


def _result(data, name):
    for r in data.get("results", []):
        if r.get("name") == name:
            return r
    return {}


def concurrency_table(points):
    lines = [
        "## Decode throughput trajectory (bench_concurrency)",
        "",
        "| point | zipage tok/s | nano tok/s | speedup | tok/step "
        "| t_host ms | t_device ms | horizon | swap tok/s "
        "| swap/recompute (step) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for pt in points:
        d = pt["data"]
        z = _result(d, "zipage")
        n = _result(d, "nano_vllm")
        sw = _result(d, "oversub_swap")       # v3 oversubscribed scenario
        fmt = lambda v: "-" if v is None else f"{v}"  # noqa: E731
        lines.append(
            f"| {pt['label']} | {fmt(z.get('tps'))} | {fmt(n.get('tps'))} "
            f"| {fmt(d.get('speedup_tps_zipage_vs_nano'))} "
            f"| {fmt(z.get('tokens_per_step'))} "
            f"| {fmt(z.get('t_host_ms'))} | {fmt(z.get('t_device_ms'))} "
            f"| {fmt(z.get('mean_decode_horizon'))} "
            f"| {fmt(sw.get('tps'))} "
            f"| {fmt(d.get('oversub_speedup_step_swap_vs_recompute'))} |")
    return lines


def prefix_table(points):
    """v4 ``--prefix-heavy`` rows: radix+cache-aware vs flat+FCFS on the
    multi-turn prefix-sharing workload (docs/CACHING.md). Only emitted
    when at least one point carries the rows."""
    pts = [pt for pt in points
           if _result(pt["data"], "prefix_radix_cache_aware")]
    if not pts:
        return []
    lines = [
        "## Prefix-cache trajectory (bench_concurrency --prefix-heavy)",
        "",
        "| point | radix tok/s | flat tok/s | speedup | step speedup "
        "| warm ttft ratio | radix hit rate | flat hit rate | evictions "
        "| seg hits | cached tok/blk |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for pt in pts:
        d = pt["data"]
        radix = _result(d, "prefix_radix_cache_aware")
        flat = _result(d, "prefix_flat_fcfs")
        comp = _result(d, "prefix_radix_compressed")
        fmt = lambda v: "-" if v is None else f"{v}"  # noqa: E731
        lines.append(
            f"| {pt['label']} | {fmt(radix.get('tps'))} "
            f"| {fmt(flat.get('tps'))} "
            f"| {fmt(d.get('prefix_speedup_tps_radix_vs_flat'))} "
            f"| {fmt(d.get('prefix_speedup_step_radix_vs_flat'))} "
            f"| {fmt(d.get('prefix_warm_ttft_ratio_radix_vs_flat'))} "
            f"| {fmt(radix.get('prefix_hit_rate'))} "
            f"| {fmt(flat.get('prefix_hit_rate'))} "
            f"| {fmt(radix.get('prefix_evictions'))} "
            f"| {fmt(comp.get('prefix_segment_hits'))} "
            f"| {fmt(comp.get('cached_tokens_per_block'))} |")
    return lines


def kernels_table(points):
    names = []
    for pt in points:
        for r in pt["data"].get("results", []):
            key = (r.get("name"), r.get("backend"))
            if key not in names:
                names.append(key)
    lines = [
        "## Kernel micro-bench trajectory (bench_kernels, us/call)",
        "",
        "| kernel/backend | " + " | ".join(pt["label"] for pt in points)
        + " |",
        "|---|" + "---|" * len(points),
    ]
    for name, backend in names:
        row = [f"| {name}/{backend}"]
        for pt in points:
            us = _kernel_us(pt["data"], name, backend)
            row.append(f" {'-' if us is None else us}")
        lines.append(" |".join(row) + " |")
    # derived ragged-vs-dense speedup columns (v2 long-context pair):
    # dense us / ragged us per point, '-' where the point lacks the rows
    for dense, ragged, backend, label in KERNEL_SPEEDUP_SERIES:
        vals = [_kernel_speedup(pt["data"], dense, ragged, backend)
                for pt in points]
        if not any(v is not None for v in vals):
            continue
        lines.append(
            "| " + label + " |" +
            "|".join(f" {'-' if v is None else round(v, 2)}x "
                     if v is not None else " - " for v in vals) + "|")
    return lines


def _kernel_us(data, name, backend):
    for r in data.get("results", []):
        if r.get("name") == name and r.get("backend") == backend:
            return r.get("us_per_call")
    return None


def _kernel_speedup(data, dense_name, ragged_name, backend):
    """dense/ragged us ratio for one point, None when either row (or a
    sane ragged time) is missing."""
    dense = _kernel_us(data, dense_name, backend)
    ragged = _kernel_us(data, ragged_name, backend)
    if not dense or not ragged:
        return None
    return dense / ragged


def serving_table(points):
    """Client-visible serving latency trajectory
    (``zipage-bench-serving/v1``, benchmarks/bench_serving.py)."""
    lines = [
        "## Serving latency trajectory (bench_serving, in-process ASGI)",
        "",
        "| point | tok/s | ttft p50 ms | ttft p99 ms | itl p50 ms "
        "| itl p99 ms | ok/total | rejected | wall s |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for pt in points:
        r = _result(pt["data"], "serving_poisson")
        fmt = lambda v: "-" if v is None else f"{v}"  # noqa: E731
        lines.append(
            f"| {pt['label']} | {fmt(r.get('tps'))} "
            f"| {fmt(r.get('ttft_p50_ms'))} | {fmt(r.get('ttft_p99_ms'))} "
            f"| {fmt(r.get('itl_p50_ms'))} | {fmt(r.get('itl_p99_ms'))} "
            f"| {fmt(r.get('n_ok'))}/{fmt(r.get('n_requests'))} "
            f"| {fmt(r.get('n_rejected'))} | {fmt(r.get('wall_s'))} |")
    return lines


def check_serving(points, max_regression, max_ttft_growth):
    """(ok, message) for the newest vs previous serving point: sustained
    tok/s gates like decode throughput (floor ``(1-max_regression)*prev``)
    and p99 TTFT gates as a ceiling (``(1+max_ttft_growth)*prev`` — the
    wide default absorbs shared-CI wall-clock noise while still catching
    an event-loop or fan-out stall that multiplies first-token latency)."""
    ok, msgs = True, []
    rows = [(pt["label"], _result(pt["data"], "serving_poisson"))
            for pt in points]
    tps = [(lbl, r.get("tps")) for lbl, r in rows if r.get("tps")]
    if len(tps) < 2:
        msgs.append("serving tok/s: <2 points, trivially OK")
    else:
        (prev_label, prev), (cur_label, cur) = tps[-2], tps[-1]
        floor = (1.0 - max_regression) * prev
        msgs.append(f"serving tok/s: {cur_label} {cur} vs {prev_label} "
                    f"{prev} (floor {floor:.2f})")
        ok = ok and cur >= floor
    ttft = [(lbl, r.get("ttft_p99_ms")) for lbl, r in rows
            if r.get("ttft_p99_ms")]
    if len(ttft) < 2:
        msgs.append("p99 TTFT: <2 points, trivially OK")
    else:
        (prev_label, prev), (cur_label, cur) = ttft[-2], ttft[-1]
        ceiling = (1.0 + max_ttft_growth) * prev
        msgs.append(f"p99 TTFT: {cur_label} {cur}ms vs {prev_label} "
                    f"{prev}ms (ceiling {ceiling:.1f}ms)")
        ok = ok and cur <= ceiling
    return ok, "serving gate: " + "; ".join(msgs)


def quality_table(eval_points, quality_points):
    """Reasoning-quality trajectory: eval accuracy per budget
    (``zipage-eval/v1``, docs/EVAL.md) plus the top-1 agreement of the
    paper's scoring config from ``zipage-bench-quality/v1`` points with a
    matching position in history (quality column '-' when absent)."""
    if not eval_points and not quality_points:
        return []
    lines = [
        "## Reasoning-quality trajectory (repro.eval + "
        "bench_quality_proxy)",
        "",
        "| point | full-KV acc | n2 acc | n3 acc | n4 acc | n3+qa acc "
        "| n4 vs full | n3 agree | paper_c8 top-1 |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    fmt = lambda v: "-" if v is None else f"{v}"  # noqa: E731
    n_rows = max(len(eval_points), len(quality_points))
    for i in range(n_rows):
        ev = eval_points[i] if i < len(eval_points) else None
        qp = quality_points[i] if i < len(quality_points) else None
        label = (ev or qp)["label"]
        row = {}
        if ev is not None:
            row = {r.get("name"): r
                   for r in ev["data"].get("results", [])}
        n4 = row.get("n4_w4", {})
        agr = None
        if qp is not None:
            agr = _result(qp["data"], "paper_c8").get("top1_agreement")
        lines.append(
            f"| {label} "
            f"| {fmt(row.get('full_kv', {}).get('accuracy'))} "
            f"| {fmt(row.get('n2_w4', {}).get('accuracy'))} "
            f"| {fmt(row.get('n3_w4', {}).get('accuracy'))} "
            f"| {fmt(n4.get('accuracy'))} "
            f"| {fmt(row.get('n3_w4_qa', {}).get('accuracy'))} "
            f"| {fmt(n4.get('accuracy_vs_full'))} "
            f"| {fmt(row.get('n3_w4', {}).get('agreement_vs_full'))} "
            f"| {fmt(agr)} |")
    return lines


def check_accuracy(eval_points, max_accuracy_drop):
    """(ok, message) for the newest vs previous eval accuracy per gated
    budget row — fails when accuracy drops by more than
    ``max_accuracy_drop`` (absolute points, default 0.02: the ISSUE's
    '>2-point drop') below the previous history point."""
    ok, msgs = True, []
    for result_name, label in GATED_EVAL_SERIES:
        acc = [(pt["label"],
                _result(pt["data"], result_name).get("accuracy"))
               for pt in eval_points]
        acc = [(lbl, a) for lbl, a in acc if a is not None]
        if len(acc) < 2:
            msgs.append(f"{label}: <2 points, trivially OK")
            continue
        (prev_label, prev), (cur_label, cur) = acc[-2], acc[-1]
        floor = prev - max_accuracy_drop
        msgs.append(f"{label}: {cur_label} {cur} vs {prev_label} {prev} "
                    f"(floor {floor:.3f})")
        ok = ok and cur >= floor
    return ok, "accuracy gate: " + "; ".join(msgs)


def check_kernels(points, max_regression):
    """(ok, message) for the ragged decode kernel's long-context speedup
    over the dense kernel, newest vs previous kernels point. Gating on
    the same-point *ratio* (not raw us/call) keeps the gate robust to
    host-speed noise between CI runs; points without the v2 long-context
    rows (all v1 history) pass trivially."""
    ok, msgs = True, []
    for dense, ragged, backend, label in KERNEL_SPEEDUP_SERIES:
        sp = [(pt["label"],
               _kernel_speedup(pt["data"], dense, ragged, backend))
              for pt in points]
        sp = [(lbl, s) for lbl, s in sp if s is not None]
        if len(sp) < 2:
            msgs.append(f"{label}: <2 points, trivially OK")
            continue
        (prev_label, prev), (cur_label, cur) = sp[-2], sp[-1]
        floor = (1.0 - max_regression) * prev
        msgs.append(f"{label}: {cur_label} {cur:.2f}x vs "
                    f"{prev_label} {prev:.2f}x (floor {floor:.2f}x)")
        ok = ok and cur >= floor
    return ok, "kernel gate: " + "; ".join(msgs)


def check_regression(points, max_regression):
    """(ok, message) for the newest vs previous decode tps, across every
    gated series (plain zipage + v3's swap-mode oversubscribed run). Each
    series compares its own two newest points, so older history without a
    series never blocks a newer one from gating."""
    ok, msgs = True, []
    for result_name, label in GATED_SERIES:
        tps = [(pt["label"], _result(pt["data"], result_name).get("tps"))
               for pt in points]
        tps = [(lbl, t) for lbl, t in tps if t]
        if len(tps) < 2:
            msgs.append(f"{label}: <2 points, trivially OK")
            continue
        (prev_label, prev), (cur_label, cur) = tps[-2], tps[-1]
        floor = (1.0 - max_regression) * prev
        msgs.append(f"{label}: {cur_label} {cur} tok/s vs "
                    f"{prev_label} {prev} tok/s (floor {floor:.2f})")
        ok = ok and cur >= floor
    return ok, "regression gate: " + "; ".join(msgs)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("files", nargs="+",
                    help="bench-*-smoke.json artifacts, oldest first")
    ap.add_argument("--out", default=None, metavar="FILE.md",
                    help="write the markdown table here (default: stdout)")
    ap.add_argument("--max-regression", type=float, default=0.25,
                    help="fail when the newest zipage tps drops more than "
                         "this fraction below the previous point "
                         "(default: 0.25)")
    ap.add_argument("--max-accuracy-drop", type=float, default=0.02,
                    help="fail when the newest eval point's accuracy "
                         "(full-KV or n4 budget) drops more than this "
                         "many absolute points below the previous one "
                         "(default: 0.02)")
    ap.add_argument("--max-ttft-growth", type=float, default=1.0,
                    help="fail when the newest serving point's p99 TTFT "
                         "grows more than this fraction above the "
                         "previous point's (default: 1.0, i.e. 2x)")
    args = ap.parse_args(argv)

    (concurrency, kernels, evals, quality, serving,
     skipped) = load_points(args.files)
    lines = ["# Bench trajectory", ""]
    if concurrency:
        lines += concurrency_table(concurrency) + [""]
        pfx = prefix_table(concurrency)
        if pfx:
            lines += pfx + [""]
    if kernels:
        lines += kernels_table(kernels) + [""]
    if serving:
        lines += serving_table(serving) + [""]
    qt = quality_table(evals, quality)
    if qt:
        lines += qt + [""]
    ok, gate_msg = check_regression(concurrency, args.max_regression)
    acc_ok, acc_msg = check_accuracy(evals, args.max_accuracy_drop)
    kern_ok, kern_msg = check_kernels(kernels, args.max_regression)
    srv_ok, srv_msg = check_serving(serving, args.max_regression,
                                    args.max_ttft_growth)
    lines += [f"_{gate_msg}_", "", f"_{acc_msg}_", "", f"_{kern_msg}_",
              "", f"_{srv_msg}_", ""]
    text = "\n".join(lines)
    if args.out:
        Path(args.out).write_text(text)
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    for s in skipped:
        print(f"bench-trend: skipped {s}", file=sys.stderr)
    if not any((concurrency, kernels, evals, quality, serving)):
        print("bench-trend: no recognised bench JSONs", file=sys.stderr)
        return 2
    if not ok or not acc_ok or not kern_ok or not srv_ok:
        failed = "; ".join(m for okk, m in
                           ((ok, gate_msg), (acc_ok, acc_msg),
                            (kern_ok, kern_msg), (srv_ok, srv_msg))
                           if not okk)
        print(f"bench-trend: FAIL — {failed}", file=sys.stderr)
        return 1
    print(f"bench-trend: OK — {gate_msg}; {acc_msg}; {kern_msg}; "
          f"{srv_msg}", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
