#!/usr/bin/env python
"""Documentation consistency checks (``make docs-check``, run in CI).

Two gates:

  1. every intra-repo markdown link in README.md / ROADMAP.md / docs/*.md
     resolves to an existing file (anchors are stripped; external URLs and
     the OWNER/REPO badge placeholders are ignored);
  2. every public field of ``SchedulerConfig`` and ``CacheConfig``
     (repro.api.config) is mentioned by name somewhere in the docs, so
     config knobs cannot silently drift out of the documentation again
     (docs/API.md once described SchedulerConfig as a pass-through bag).

Exits non-zero listing every violation. Stdlib + repro only.
"""
from __future__ import annotations

import dataclasses
import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted([REPO / "README.md", REPO / "ROADMAP.md",
                    *(REPO / "docs").glob("*.md")])

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list:
    errors = []
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(REPO)}: file missing")
            continue
        for n, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path = target.split("#", 1)[0]
                if not path:            # pure in-page anchor
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    errors.append(
                        f"{md.relative_to(REPO)}:{n}: broken link "
                        f"-> {target}")
    return errors


def check_config_fields() -> list:
    sys.path.insert(0, str(REPO / "src"))
    from repro.api.config import CacheConfig, SchedulerConfig

    corpus = "\n".join(md.read_text() for md in DOC_FILES if md.exists())
    errors = []
    for cfg in (SchedulerConfig, CacheConfig):
        for f in dataclasses.fields(cfg):
            # fields are documented as `name` (markdown code spans)
            if f"`{f.name}`" not in corpus:
                errors.append(
                    f"{cfg.__name__}.{f.name} is not documented in "
                    "README.md / ROADMAP.md / docs/*.md "
                    "(expected a `"f"{f.name}"r"` code span)")
    return errors


def main() -> int:
    errors = check_links() + check_config_fields()
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    if errors:
        print(f"docs-check: {len(errors)} problem(s)", file=sys.stderr)
        return 1
    n_links = sum(len(LINK_RE.findall(md.read_text()))
                  for md in DOC_FILES if md.exists())
    print(f"docs-check: OK ({len(DOC_FILES)} files, {n_links} links, "
          "all SchedulerConfig/CacheConfig fields documented)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
