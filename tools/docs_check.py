#!/usr/bin/env python
"""Documentation consistency checks (``make docs-check``, run in CI's
static-analysis job).

One gate: every intra-repo markdown link in README.md / ROADMAP.md /
docs/*.md resolves to an existing file (anchors are stripped; external
URLs and the OWNER/REPO badge placeholders are ignored).

Config-field documentation coverage — historically checked here — now
lives in ``tools/zipalint.py`` rule ZPL004, which also verifies each
field is consumed and routed through ``build_engine_options``.

Exits non-zero listing every violation. Stdlib only.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
DOC_FILES = sorted([REPO / "README.md", REPO / "ROADMAP.md",
                    *(REPO / "docs").glob("*.md")])

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def check_links() -> list:
    errors = []
    for md in DOC_FILES:
        if not md.exists():
            errors.append(f"{md.relative_to(REPO)}: file missing")
            continue
        for n, line in enumerate(md.read_text().splitlines(), 1):
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                path = target.split("#", 1)[0]
                if not path:            # pure in-page anchor
                    continue
                resolved = (md.parent / path).resolve()
                if not resolved.exists():
                    errors.append(
                        f"{md.relative_to(REPO)}:{n}: broken link "
                        f"-> {target}")
    return errors


def main() -> int:
    errors = check_links()
    for e in errors:
        print(f"docs-check: {e}", file=sys.stderr)
    if errors:
        print(f"docs-check: {len(errors)} problem(s)", file=sys.stderr)
        return 1
    n_links = sum(len(LINK_RE.findall(md.read_text()))
                  for md in DOC_FILES if md.exists())
    print(f"docs-check: OK ({len(DOC_FILES)} files, {n_links} links)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
