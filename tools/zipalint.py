#!/usr/bin/env python
"""zipalint — repo-specific architectural static analysis (``make zipalint``).

The engine's correctness rests on contracts no general-purpose linter
knows about: the Scheduler subsystem is pure-host, jitted step builders
must not host-sync, buffers passed at ``donate_argnums`` positions are
invalid after the call, and every public config field must stay
documented and consumed. This tool runs AST passes that formalise those
contracts (docs/ANALYSIS.md spells each one out):

  ZPL001  host-purity          pure-host modules must not import device code
  ZPL002  jit-host-sync        no host syncs / Python branching on traced
                               values inside jit-traced scopes
  ZPL003  donation-safety      a buffer at a donate_argnums position must be
                               rebound by the calling statement
  ZPL004  config-discipline    every CacheConfig/SchedulerConfig/
                               ModelRunnerConfig field is documented,
                               consumed and routed via build_engine_options
  ZPL005  engine-sync          device->host syncs in the engine go through
                               _fetch/_block_ready (t_device accounting)
  ZPL000  waiver-hygiene       waiver comments must name a known rule, give
                               a reason, and actually suppress something

Findings are ``path:line: RULE message``; a finding is suppressed by an
inline waiver comment on the same line (or on its own line immediately
above)::

    risky_call()   # zipalint: waive[ZPL005] -- snapshot is a sync point

The reason after ``--`` is mandatory. Stdlib only; exits non-zero on any
finding so CI's static-analysis job can gate on it.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import re
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

REPO = Path(__file__).resolve().parent.parent

RULES = {
    "ZPL000": "waiver-hygiene: waivers must name a known rule, carry a "
              "reason after '--', and suppress at least one finding",
    "ZPL001": "host-purity: modules declared pure-host must not import "
              "jax/jnp or device-executing repro modules",
    "ZPL002": "jit-host-sync: no .item()/.tolist()/np.asarray/"
              "block_until_ready/device_get, float()/int()/bool() on array "
              "expressions, or Python branching on traced values inside "
              "jit-traced scopes",
    "ZPL003": "donation-safety: an argument at a donate_argnums position "
              "must be rebound by the statement making the call (the "
              "donated buffer is invalid afterwards)",
    "ZPL004": "config-discipline: every CacheConfig/SchedulerConfig/"
              "ModelRunnerConfig field must be documented in the docs "
              "corpus, consumed outside api/config.py, and routed through "
              "build_engine_options",
    "ZPL005": "engine-sync-discipline: device->host syncs in "
              "core/engine.py go through _fetch/_block_ready so they are "
              "accounted in t_device telemetry",
}

# --- repo-specific pass configuration ---------------------------------

#: modules under the pure-host contract (docs/ANALYSIS.md). They drive the
#: device but never import it; repro.core.sampling is deliberately absent
#: from the import blacklist below — its host-side surface (SamplingParams,
#: matched_stop) is part of the scheduler-visible request model.
PURE_HOST = (
    "src/repro/core/scheduler.py",
    "src/repro/core/block_manager.py",
    "src/repro/core/request.py",
    "src/repro/core/invariants.py",
)

#: import roots that count as device code for ZPL001 (direct imports only;
#: transitive imports are out of scope for a static pass)
DEVICE_IMPORT_ROOTS = (
    "jax", "jaxlib", "jax.numpy",
    "repro.core.engine", "repro.core.serve_model",
    "repro.core.compression", "repro.core.paged", "repro.core.scoring",
    "repro.kernels", "repro.models",
)

#: modules whose top-level ``build_*`` functions return jit-traced callables
JIT_BUILDER_MODULES = (
    "src/repro/core/serve_model.py",
    "src/repro/core/compression.py",
)

ENGINE_MODULE = "src/repro/core/engine.py"
CONFIG_MODULE = "src/repro/api/config.py"
CONFIG_CLASSES = ("CacheConfig", "SchedulerConfig", "ModelRunnerConfig")

#: method-call names that produce scalars/host values from arrays
ARRAY_REDUCERS = frozenset(
    {"sum", "max", "min", "mean", "any", "all", "argmax", "argmin", "item"})

#: name roots whose calls are assumed array-valued (traced)
ARRAY_NAMESPACES = frozenset({"jnp", "jax", "lax"})

WAIVER_RE = re.compile(
    r"#\s*zipalint:\s*waive\[([^\]]*)\]\s*(?:--\s*(\S.*?))?\s*$")


@dataclasses.dataclass(frozen=True)
class Finding:
    path: str
    line: int
    rule: str
    msg: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


@dataclasses.dataclass(frozen=True)
class Module:
    path: str          # repo-relative posix path
    source: str
    tree: ast.AST


def make_module(path: str, source: str) -> Module:
    return Module(path, source, ast.parse(source, filename=path))


@dataclasses.dataclass
class Context:
    """Everything a pass sees: parsed modules + the docs corpus."""
    modules: Dict[str, Module]
    docs: Dict[str, str] = dataclasses.field(default_factory=dict)


# ----------------------------------------------------------------------
# shared AST helpers

def dotted(node) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def parent_map(tree: ast.AST) -> Dict[int, ast.AST]:
    parents: Dict[int, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[id(child)] = node
    return parents


def enclosing_stmt(node: ast.AST, parents: Dict[int, ast.AST]):
    while node is not None and not isinstance(node, ast.stmt):
        node = parents.get(id(node))
    return node


def enclosing_function(node: ast.AST, parents: Dict[int, ast.AST]):
    node = parents.get(id(node))
    while node is not None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return node
        node = parents.get(id(node))
    return None


def is_array_valued(node: ast.AST) -> bool:
    """Heuristic: does this expression subtree produce a traced array?
    True when it calls into jnp/jax/lax or invokes an array-reducer
    method; static Python (``int(kind == "attn")``, ``np.sqrt(d)``) stays
    clean."""
    for n in ast.walk(node):
        if isinstance(n, ast.Call):
            d = dotted(n.func)
            if d and d.split(".", 1)[0] in ARRAY_NAMESPACES:
                return True
            if isinstance(n.func, ast.Attribute) \
                    and n.func.attr in ARRAY_REDUCERS:
                return True
    return False


def _donate_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
    """Literal donate_argnums of a jax.jit / partial(jax.jit, ...) call."""
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, int):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            out = []
            for e in v.elts:
                if not (isinstance(e, ast.Constant)
                        and isinstance(e.value, int)):
                    return None
                out.append(e.value)
            return tuple(out)
        return None
    return None


def _is_jit_call(call: ast.Call) -> bool:
    return dotted(call.func) == "jax.jit"


def _jit_scope_defs(ctx: Context) -> Dict[str, List[ast.AST]]:
    """Per-module jit-traced scopes: top-level ``build_*`` defs in the
    builder modules, defs decorated with ``jax.jit`` /
    ``partial(jax.jit, ...)``, and defs whose name is passed to
    ``jax.jit`` within the same module."""
    scopes: Dict[str, List[ast.AST]] = {}
    for path, mod in ctx.modules.items():
        found: List[ast.AST] = []
        jit_target_names = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call) and _is_jit_call(node) \
                    and node.args:
                first = node.args[0]
                if isinstance(first, ast.Name):
                    jit_target_names.add(first.id)
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if path in JIT_BUILDER_MODULES \
                    and node.name.startswith("build_"):
                found.append(node)
                continue
            if node.name in jit_target_names:
                found.append(node)
                continue
            for dec in node.decorator_list:
                if isinstance(dec, ast.Call):
                    d = dotted(dec.func)
                    if d in ("functools.partial", "partial") and dec.args \
                            and dotted(dec.args[0]) == "jax.jit":
                        found.append(node)
                        break
                    if d == "jax.jit":
                        found.append(node)
                        break
                elif dotted(dec) == "jax.jit":
                    found.append(node)
                    break
        if found:
            scopes[path] = found
    return scopes


# ----------------------------------------------------------------------
# ZPL001 host-purity


def pass_host_purity(ctx: Context) -> List[Finding]:
    out = []
    for path in PURE_HOST:
        mod = ctx.modules.get(path)
        if mod is None:
            continue
        for node in ast.walk(mod.tree):
            names = []
            if isinstance(node, ast.Import):
                names = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                names = [node.module]
            for name in names:
                if any(name == root or name.startswith(root + ".")
                       for root in DEVICE_IMPORT_ROOTS):
                    out.append(Finding(
                        path, node.lineno, "ZPL001",
                        f"pure-host module imports device code "
                        f"({name!r}); the scheduler subsystem must stay "
                        "importable and testable without JAX"))
    return out


# ----------------------------------------------------------------------
# ZPL002 jit-boundary host-sync


def _check_jit_scope(path: str, scope, out: List[Finding]) -> None:
    fname = scope.name
    for node in ast.walk(scope):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if isinstance(node.func, ast.Attribute) \
                    and node.func.attr in ("item", "tolist",
                                           "block_until_ready"):
                out.append(Finding(
                    path, node.lineno, "ZPL002",
                    f".{node.func.attr}() inside jit scope "
                    f"`{fname}` forces a device->host sync at trace "
                    "time"))
                continue
            if d in ("jax.device_get", "jax.block_until_ready"):
                out.append(Finding(
                    path, node.lineno, "ZPL002",
                    f"{d}() inside jit scope `{fname}` host-syncs"))
                continue
            if d in ("np.asarray", "numpy.asarray"):
                out.append(Finding(
                    path, node.lineno, "ZPL002",
                    f"np.asarray inside jit scope `{fname}` pulls a "
                    "traced array to host"))
                continue
            if d in ("np.array", "numpy.array") and node.args \
                    and not isinstance(node.args[0],
                                       (ast.Constant, ast.List,
                                        ast.Tuple)):
                out.append(Finding(
                    path, node.lineno, "ZPL002",
                    f"np.array on a non-literal inside jit scope "
                    f"`{fname}` pulls a traced array to host"))
                continue
            if isinstance(node.func, ast.Name) \
                    and node.func.id in ("float", "int", "bool") \
                    and node.args and is_array_valued(node.args[0]):
                out.append(Finding(
                    path, node.lineno, "ZPL002",
                    f"{node.func.id}() on an array expression inside "
                    f"jit scope `{fname}` concretises a tracer"))
        elif isinstance(node, (ast.If, ast.While)) \
                and is_array_valued(node.test):
            kind = "if" if isinstance(node, ast.If) else "while"
            out.append(Finding(
                path, node.lineno, "ZPL002",
                f"Python `{kind}` on a traced value inside jit scope "
                f"`{fname}` (use jnp.where / lax.cond)"))


def pass_jit_host_sync(ctx: Context) -> List[Finding]:
    out: List[Finding] = []
    for path, scopes in _jit_scope_defs(ctx).items():
        seen = set()
        for scope in scopes:
            if id(scope) in seen:
                continue
            seen.add(id(scope))
            _check_jit_scope(path, scope, out)
    # dedupe: nested scopes may repeat a finding at the same line
    uniq = {}
    for f in out:
        uniq.setdefault((f.path, f.line, f.msg), f)
    return list(uniq.values())


# ----------------------------------------------------------------------
# ZPL003 donation safety


@dataclasses.dataclass(frozen=True)
class _Donor:
    positions: Tuple[int, ...]
    # None => match the dotted name anywhere in `module`; otherwise only
    # inside the named function (local variable registrations)
    module: Optional[str] = None
    scope: Optional[str] = None


def _donation_registry(ctx: Context):
    """Infer every donating callable in the repo.

    Returns (by_name, factories, findings) where ``by_name`` maps a
    dotted call-site name (``self._decode``, ``jitted``,
    ``_scatter_kv_blocks``) to donor entries and ``factories`` maps a
    bare function name to donate positions for the ``factory(...)(...)``
    immediate-call pattern."""
    by_name: Dict[str, List[_Donor]] = {}
    factories: Dict[str, Tuple[int, ...]] = {}
    findings: List[Finding] = []

    def add(name, donor):
        by_name.setdefault(name, []).append(donor)

    for path, mod in ctx.modules.items():
        parents = parent_map(mod.tree)
        for node in ast.walk(mod.tree):
            # decorated defs: @partial(jax.jit, donate_argnums=...)
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                for dec in node.decorator_list:
                    if isinstance(dec, ast.Call) \
                            and dotted(dec.func) in ("functools.partial",
                                                     "partial") \
                            and dec.args \
                            and dotted(dec.args[0]) == "jax.jit":
                        pos = _donate_positions(dec)
                        if pos:
                            add(node.name, _Donor(pos))
                continue
            if not (isinstance(node, ast.Call) and _is_jit_call(node)):
                continue
            pos = _donate_positions(node)
            if pos is None:
                continue
            stmt = enclosing_stmt(node, parents)
            func = enclosing_function(node, parents)
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    name = dotted(t)
                    if name is None:
                        continue
                    if func is not None and "." not in name:
                        add(name, _Donor(pos, module=path,
                                         scope=func.name))
                    else:
                        add(name, _Donor(pos, module=path))
            if func is not None:
                # the enclosing def builds a donating jit -> treat it as a
                # factory; a factory mixing donating and plain jits cannot
                # be checked at call sites, flag the def itself
                prev = factories.get(func.name)
                if prev is not None and prev != pos:
                    findings.append(Finding(
                        path, func.lineno, "ZPL003",
                        f"factory `{func.name}` builds jits with "
                        "conflicting donate_argnums; split it so call "
                        "sites can be checked"))
                factories[func.name] = pos
    # mixed factories: a factory containing BOTH donating and plain jits
    for path, mod in ctx.modules.items():
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            if node.name not in factories:
                continue
            plain = donated = 0
            for c in ast.walk(node):
                if isinstance(c, ast.Call) and _is_jit_call(c):
                    if _donate_positions(c):
                        donated += 1
                    else:
                        plain += 1
            if donated and plain:
                findings.append(Finding(
                    path, node.lineno, "ZPL003",
                    f"factory `{node.name}` builds both donating and "
                    "non-donating jits; call sites cannot be verified — "
                    "split it into one factory per donation signature"))
    # propagate factories through simple assignments:
    #   self._decode = _cached_step(...)
    for path, mod in ctx.modules.items():
        parents = parent_map(mod.tree)
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)):
                continue
            d = dotted(node.value.func)
            if d is None:
                continue
            pos = factories.get(d.split(".")[-1])
            if pos is None:
                continue
            func = enclosing_function(node, parents)
            for t in node.targets:
                name = dotted(t)
                if name is None:
                    continue
                if func is not None and "." not in name:
                    add(name, _Donor(pos, module=path, scope=func.name))
                else:
                    add(name, _Donor(pos, module=path))
    # one-level wrapper propagation: def w(a, b): return _donor(a, b)
    for path, mod in ctx.modules.items():
        for node in ast.walk(mod.tree):
            if not isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)):
                continue
            for stmt in node.body:
                if not (isinstance(stmt, ast.Return)
                        and isinstance(stmt.value, ast.Call)):
                    continue
                d = dotted(stmt.value.func)
                if d is None or d not in by_name:
                    continue
                params = [a.arg for a in node.args.args]
                donors = [dn for dn in by_name[d]
                          if (dn.module is None or dn.module == path)
                          and (dn.scope is None or dn.scope == node.name)]
                for donor in donors:
                    mapped = []
                    for p in donor.positions:
                        if p >= len(stmt.value.args):
                            break
                        arg = stmt.value.args[p]
                        if isinstance(arg, ast.Name) \
                                and arg.id in params:
                            mapped.append(params.index(arg.id))
                    if mapped and node.name not in by_name:
                        add(node.name, _Donor(tuple(mapped)))
    return by_name, factories, findings


def _flat_targets(stmt) -> List[str]:
    dumps = []

    def rec(t):
        if isinstance(t, (ast.Tuple, ast.List)):
            for e in t.elts:
                rec(e)
        elif isinstance(t, ast.Starred):
            rec(t.value)
        else:
            # unparse, not dump: Store/Load ctx must not break matching
            dumps.append(ast.unparse(t))

    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            rec(t)
    elif isinstance(stmt, (ast.AnnAssign, ast.AugAssign)):
        rec(stmt.target)
    return dumps


def _check_donating_call(path, call, positions, stmt, out) -> None:
    if stmt is None or isinstance(stmt, ast.Return):
        return
    targets = _flat_targets(stmt)
    if not targets and not isinstance(stmt, ast.Expr):
        out.append(Finding(
            path, call.lineno, "ZPL003",
            "donating call used in a non-assignment statement; the "
            "donated buffer cannot be rebound here"))
        return
    for p in positions:
        if p >= len(call.args):
            continue
        if any(isinstance(a, ast.Starred) for a in call.args[:p]):
            continue                      # position not resolvable
        arg = call.args[p]
        if isinstance(arg, (ast.Call, ast.Constant)):
            continue                      # fresh temporary
        desc = ast.unparse(arg)
        if desc in targets:
            continue                      # rebound by this statement
        out.append(Finding(
            path, call.lineno, "ZPL003",
            f"`{desc}` is passed at donated position {p} but not "
            "rebound by this statement — the buffer is invalid after "
            "the call (use-after-donate hazard)"))


def pass_donation_safety(ctx: Context) -> List[Finding]:
    by_name, factories, out = _donation_registry(ctx)
    jit_scopes = _jit_scope_defs(ctx)
    for path, mod in ctx.modules.items():
        parents = parent_map(mod.tree)
        in_jit = set()
        for scope in jit_scopes.get(path, []):
            for n in ast.walk(scope):
                in_jit.add(id(n))
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call) or id(node) in in_jit:
                continue                  # traced calls inline donation
            positions = None
            d = dotted(node.func)
            if d is not None:
                func = enclosing_function(node, parents)
                for key in (d, d.split(".")[-1]):
                    for donor in by_name.get(key, []):
                        if donor.module is not None \
                                and donor.module != path:
                            continue
                        if donor.scope is not None and (
                                func is None
                                or func.name != donor.scope):
                            continue
                        positions = donor.positions
                        break
                    if positions:
                        break
            elif isinstance(node.func, ast.Call):
                inner = dotted(node.func.func)
                if inner is not None:
                    positions = factories.get(inner.split(".")[-1])
            if not positions:
                continue
            _check_donating_call(path, node, positions,
                                 enclosing_stmt(node, parents), out)
    return out


# ----------------------------------------------------------------------
# ZPL004 config discipline


def _config_fields(mod: Module):
    fields = {}       # (class, field) -> lineno
    for node in mod.tree.body:
        if isinstance(node, ast.ClassDef) and node.name in CONFIG_CLASSES:
            for item in node.body:
                if isinstance(item, ast.AnnAssign) \
                        and isinstance(item.target, ast.Name):
                    fields[(node.name, item.target.id)] = item.lineno
    return fields


def pass_config_discipline(ctx: Context) -> List[Finding]:
    mod = ctx.modules.get(CONFIG_MODULE)
    if mod is None:
        return []
    out = []
    fields = _config_fields(mod)
    corpus = "\n".join(ctx.docs.values())
    # attribute reads anywhere in src/repro except the config module itself
    consumed = set()
    for path, m in ctx.modules.items():
        if path == CONFIG_MODULE:
            continue
        for node in ast.walk(m.tree):
            if isinstance(node, ast.Attribute):
                consumed.add(node.attr)
    # fields referenced inside build_engine_options (no silent drops)
    routed = set()
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node.name == "build_engine_options":
            for n in ast.walk(node):
                if isinstance(n, ast.Attribute):
                    routed.add(n.attr)
    for (cls, name), lineno in sorted(fields.items(),
                                      key=lambda kv: kv[1]):
        if f"`{name}`" not in corpus:
            out.append(Finding(
                CONFIG_MODULE, lineno, "ZPL004",
                f"{cls}.{name} is not documented — add a `{name}` code "
                "span to README.md / ROADMAP.md / docs/*.md"))
        if name not in consumed:
            out.append(Finding(
                CONFIG_MODULE, lineno, "ZPL004",
                f"{cls}.{name} is never read outside api/config.py — "
                "dead knob (wire it up or remove it)"))
        if routed and name not in routed:
            out.append(Finding(
                CONFIG_MODULE, lineno, "ZPL004",
                f"{cls}.{name} is not routed through "
                "build_engine_options — the facade silently drops it"))
    return out


# ----------------------------------------------------------------------
# ZPL005 engine sync discipline

#: engine methods that ARE the sanctioned sync points
SYNC_POINTS = ("_fetch", "_block_ready")


def _mentions_self_state(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr == "state" \
                and isinstance(n.value, ast.Name) and n.value.id == "self":
            return True
    return False


def pass_engine_sync(ctx: Context) -> List[Finding]:
    mod = ctx.modules.get(ENGINE_MODULE)
    if mod is None:
        return []
    out: List[Finding] = []
    parents = parent_map(mod.tree)
    for node in ast.walk(mod.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        func = enclosing_function(node, parents)
        fname = func.name if func is not None else "<module>"
        if d in ("jax.device_get", "jax.block_until_ready") \
                and fname not in SYNC_POINTS:
            out.append(Finding(
                ENGINE_MODULE, node.lineno, "ZPL005",
                f"{d}() in `{fname}` bypasses _fetch/_block_ready — the "
                "sync is invisible to t_device accounting"))
        elif isinstance(node.func, ast.Attribute) \
                and node.func.attr in ("item", "tolist"):
            out.append(Finding(
                ENGINE_MODULE, node.lineno, "ZPL005",
                f".{node.func.attr}() in `{fname}` is an implicit "
                "device->host sync; fetch through _fetch instead"))
        elif d == "jax.tree.map" and any(
                dotted(a) in ("np.asarray", "numpy.asarray")
                for a in node.args):
            out.append(Finding(
                ENGINE_MODULE, node.lineno, "ZPL005",
                f"jax.tree.map(np.asarray, ...) in `{fname}` is a "
                "whole-tree device->host sync outside "
                "_fetch/_block_ready"))
        elif d in ("np.asarray", "numpy.asarray") and node.args \
                and _mentions_self_state(node.args[0]):
            out.append(Finding(
                ENGINE_MODULE, node.lineno, "ZPL005",
                f"np.asarray on device state in `{fname}` host-syncs "
                "outside _fetch/_block_ready"))
    return out


PASSES = (
    ("ZPL001", pass_host_purity),
    ("ZPL002", pass_jit_host_sync),
    ("ZPL003", pass_donation_safety),
    ("ZPL004", pass_config_discipline),
    ("ZPL005", pass_engine_sync),
)


# ----------------------------------------------------------------------
# waivers


@dataclasses.dataclass
class _Waiver:
    line: int          # line the waiver applies to
    comment_line: int
    rules: Tuple[str, ...]
    reason: Optional[str]
    used: bool = False


def collect_waivers(mod: Module) -> List[_Waiver]:
    out = []
    for i, line in enumerate(mod.source.splitlines(), 1):
        m = WAIVER_RE.search(line)
        if m is None:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",")
                      if r.strip())
        own_line = not line[:m.start()].strip()
        out.append(_Waiver(line=i + 1 if own_line else i,
                           comment_line=i, rules=rules,
                           reason=m.group(2)))
    return out


def apply_waivers(findings: Sequence[Finding], modules: Dict[str, Module]):
    """Drop waived findings; emit ZPL000 hygiene findings for malformed
    or unused waivers. Returns (kept, n_waived)."""
    waivers: Dict[str, List[_Waiver]] = {
        path: collect_waivers(mod) for path, mod in modules.items()}
    hygiene: List[Finding] = []
    for path, ws in waivers.items():
        for w in ws:
            if not w.reason:
                hygiene.append(Finding(
                    path, w.comment_line, "ZPL000",
                    "waiver without a reason; write "
                    "`# zipalint: waive[RULE] -- why`"))
            for r in w.rules:
                if r != "*" and r not in RULES:
                    hygiene.append(Finding(
                        path, w.comment_line, "ZPL000",
                        f"waiver names unknown rule {r!r}"))
    kept: List[Finding] = []
    n_waived = 0
    for f in findings:
        waived = False
        for w in waivers.get(f.path, []):
            if w.line == f.line and ("*" in w.rules or f.rule in w.rules):
                w.used = True
                waived = True
        if waived:
            n_waived += 1
        else:
            kept.append(f)
    for path, ws in waivers.items():
        for w in ws:
            if not w.used and w.reason \
                    and all(r in RULES or r == "*" for r in w.rules):
                hygiene.append(Finding(
                    path, w.comment_line, "ZPL000",
                    f"unused waiver for {', '.join(w.rules)} — the "
                    "finding it suppressed is gone; remove the comment"))
    return kept + hygiene, n_waived


# ----------------------------------------------------------------------
# driver


def analyze(ctx: Context) -> List[Finding]:
    findings: List[Finding] = []
    for _rule, fn in PASSES:
        findings.extend(fn(ctx))
    return findings


def load_context(root: Path) -> Context:
    modules = {}
    src = root / "src" / "repro"
    for py in sorted(src.rglob("*.py")):
        rel = py.relative_to(root).as_posix()
        modules[rel] = make_module(rel, py.read_text())
    docs = {}
    for md in [root / "README.md", root / "ROADMAP.md",
               *sorted((root / "docs").glob("*.md"))]:
        if md.exists():
            docs[md.name] = md.read_text()
    return Context(modules, docs)


def run(root: Path) -> Tuple[List[Finding], int, int]:
    ctx = load_context(root)
    findings, n_waived = apply_waivers(analyze(ctx), ctx.modules)
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings, n_waived, len(ctx.modules)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="zipalint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--root", type=Path, default=REPO,
                    help="repo root (default: this checkout)")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)
    if args.list_rules:
        for rule, desc in sorted(RULES.items()):
            print(f"{rule}  {desc}")
        return 0
    findings, n_waived, n_files = run(args.root)
    for f in findings:
        print(f"zipalint: {f.render()}", file=sys.stderr)
    if findings:
        print(f"zipalint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print(f"zipalint: OK ({n_files} files, {len(PASSES)} passes, "
          f"{n_waived} waiver(s) honored)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
