"""Paper Tab. 2 / App. C.8: scoring-function ablations via the quality proxy
(top-1 agreement with full-KV greedy decode on the same trained tiny model;
DESIGN.md §7 explains why pass@1 is not reproducible offline)."""
import numpy as np

from benchmarks.common import params_trained, run_engine, workload
from repro.core.compression import CompressOptions

VARIANTS = {
    "attn_only": CompressOptions(window=4, use_global=False,
                                 redundancy="none", pooling="none"),
    "global_a0.8": CompressOptions(window=4, alpha=0.8, redundancy="none",
                                   pooling="none"),
    "global+lightning": CompressOptions(window=4, alpha=0.8,
                                        redundancy="lightning", lam=0.2,
                                        tau=0.4, pooling="none"),
    "paper_c8": CompressOptions(window=4, alpha=0.8, redundancy="lightning",
                                lam=0.2, tau=0.4, pooling="first"),
    "pool_always": CompressOptions(window=4, alpha=0.8,
                                   redundancy="lightning", lam=0.2, tau=0.4,
                                   pooling="always"),
}


def agreement(a, b):
    n = min(len(a), len(b))
    return float(np.mean([a[i] == b[i] for i in range(n)])) if n else 0.0


def run():
    rows = []
    rng = np.random.default_rng(4)
    params = params_trained()
    reqs = workload("amc", 10, rng)
    full = run_engine(reqs, params=params, n_max=None)
    ref = {r: full["done"][r].token_ids for r in full["rids"]}
    for name, opts in VARIANTS.items():
        r = run_engine(reqs, params=params, n_max=3, window=4,
                       compress=opts)
        agr = float(np.mean([agreement(r["done"][a].token_ids, ref[b])
                             for a, b in zip(r["rids"], full["rids"])]))
        rows.append((f"quality/{name}",
                     1e6 * r["wall_s"] / max(r["steps"], 1),
                     f"top1_agreement={agr:.3f};"
                     f"compressions={r['compressions']}"))
    return rows
