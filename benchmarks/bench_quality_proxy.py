"""Paper Tab. 2 / App. C.8: scoring-function ablations via the quality proxy
(top-1 agreement with full-KV greedy decode on the same trained tiny model;
DESIGN.md §7 explains why pass@1 is not reproducible offline).

Usable two ways:

  * ``python -m benchmarks.run bench_quality_proxy`` — legacy CSV rows via
    ``run()`` (name,us_per_step,derived);
  * ``python -m benchmarks.bench_quality_proxy [--smoke] [--out FILE.json]``
    — JSON for the per-PR quality trajectory (CI's bench-smoke artifact),
    same envelope as ``bench_kernels.py``:

      {"schema": "zipage-bench-quality/v1", "jax": ..., "platform": ...,
       "smoke": bool, "results": [{"name", "top1_agreement",
       "compressions", "steps", "tokens", "us_per_step"}, ...]}

    ``top1_agreement`` is scored over the *reference* (full-KV) stream
    length — a variant that stops early is penalised for the tokens it
    never produced, not scored on its shared prefix. ``tools/bench_trend.py``
    accumulates these JSONs across PRs into the quality table next to the
    ``zipage-eval/v1`` accuracy numbers (docs/EVAL.md).
"""
import argparse
import json
import sys

import numpy as np

from benchmarks.common import params_trained, run_engine, workload
from repro.core.compression import CompressOptions

VARIANTS = {
    "attn_only": CompressOptions(window=4, use_global=False,
                                 redundancy="none", pooling="none"),
    "global_a0.8": CompressOptions(window=4, alpha=0.8, redundancy="none",
                                   pooling="none"),
    "global+lightning": CompressOptions(window=4, alpha=0.8,
                                        redundancy="lightning", lam=0.2,
                                        tau=0.4, pooling="none"),
    "paper_c8": CompressOptions(window=4, alpha=0.8, redundancy="lightning",
                                lam=0.2, tau=0.4, pooling="first"),
    "pool_always": CompressOptions(window=4, alpha=0.8,
                                   redundancy="lightning", lam=0.2, tau=0.4,
                                   pooling="always"),
}


def agreement(a, b):
    """Top-1 agreement of stream ``a`` against reference ``b``, scored
    over the reference length: positions ``a`` never produced count as
    disagreement. (The old ``min(len(a), len(b))`` truncation silently
    inflated agreement whenever a compressed variant finished early.)"""
    if not len(b):
        return 1.0
    hits = sum(1 for i in range(len(b)) if i < len(a) and a[i] == b[i])
    return hits / len(b)


def _measure(n_requests):
    """[(name, top1_agreement, engine result)] for every variant."""
    rng = np.random.default_rng(4)
    params = params_trained()
    reqs = workload("amc", n_requests, rng)
    full = run_engine(reqs, params=params, n_max=None)
    ref = {r: full["done"][r].token_ids for r in full["rids"]}
    rows = []
    for name, opts in VARIANTS.items():
        r = run_engine(reqs, params=params, n_max=3, window=4,
                       compress=opts)
        agr = float(np.mean([agreement(r["done"][a].token_ids, ref[b])
                             for a, b in zip(r["rids"], full["rids"])]))
        rows.append((name, agr, r))
    return rows


def run():
    return [(f"quality/{name}",
             1e6 * r["wall_s"] / max(r["steps"], 1),
             f"top1_agreement={agr:.3f};compressions={r['compressions']}")
            for name, agr, r in _measure(10)]


def main(argv=None):
    import jax

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small request count (CI bench-smoke)")
    ap.add_argument("--out", default=None, metavar="FILE.json",
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args(argv)

    report = {
        "schema": "zipage-bench-quality/v1",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "smoke": args.smoke,
        "results": [
            {"name": name,
             "top1_agreement": round(agr, 4),
             "compressions": r["compressions"],
             "steps": r["steps"],
             "tokens": sum(len(o.token_ids) for o in r["done"].values()),
             "us_per_step": round(1e6 * r["wall_s"]
                                  / max(r["steps"], 1), 1)}
            for name, agr, r in _measure(6 if args.smoke else 10)],
    }
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
