"""Paper Fig. 7 / App. E: real-time throughput, per-step time and the
concurrency distribution, Zipage vs nano-vLLM, on the AMC-like workload."""
import numpy as np

from benchmarks.common import run_engine, workload


def run():
    rows = []
    rng = np.random.default_rng(1)
    reqs = workload("amc", 24, rng)
    for name, ov in (("zipage", {}), ("nano_vllm", {"n_max": None})):
        r = run_engine(reqs, **ov)
        conc = np.array([m["n_running"] for m in r["engine"].metrics])
        steps_hi = float((conc >= 12).mean())      # fraction in high band
        t_steps = np.array([m["t_total"] for m in r["engine"].metrics])
        rows.append((f"concurrency/{name}",
                     1e6 * float(t_steps.mean()),
                     f"steps={r['steps']};frac_steps_conc_ge12="
                     f"{steps_hi:.2f};p50_conc={np.median(conc):.0f};"
                     f"max_conc={conc.max()};"
                     f"tok_per_step={r['tokens_per_step']:.2f}"))
    return rows
