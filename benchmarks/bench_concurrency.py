"""Paper Fig. 7 / App. E: real-time throughput, per-step time and the
concurrency distribution, Zipage vs nano-vLLM, on the AMC-like workload.

Usable two ways:

  * ``python -m benchmarks.run bench_concurrency`` — legacy CSV rows via
    ``run()`` (name,us_per_step,derived);
  * ``python -m benchmarks.bench_concurrency [--smoke] [--oversubscribe]
    [--prefix-heavy] [--out FILE.json]`` — JSON for the per-PR
    concurrency trajectory (CI's bench-smoke artifact), same envelope as
    ``bench_kernels.py``:

      {"schema": "zipage-bench-concurrency/v4", "jax": ..., "platform": ...,
       "smoke": bool, "results": [{"name", "tps", "tokens", "steps",
       "tokens_per_step", "mean_concurrency", "p50_concurrency",
       "max_concurrency", "frac_steps_conc_ge12", "tpot_ms", "block_util",
       "compressions", "preemptions", "n_swapped_out", "n_swapped_in",
       "swap_mb", "t_host_ms", "t_device_ms", "mean_decode_horizon",
       "wall_s"}, ...],
       "speedup_tps_zipage_vs_nano": float,
       "oversub_speedup_tps_swap_vs_recompute": float | absent,
       "oversub_speedup_tps_auto_vs_recompute": float | absent,
       "oversub_speedup_step_swap_vs_recompute": float | absent,
       "oversub_speedup_step_auto_vs_recompute": float | absent,
       "prefix_speedup_tps_radix_vs_flat": float | absent,
       "prefix_speedup_step_radix_vs_flat": float | absent,
       "prefix_ttft_ratio_radix_vs_flat": float | absent,
       "prefix_warm_ttft_ratio_radix_vs_flat": float | absent}

    v2 added the per-step host/device time split (``t_host_ms`` is host
    planning+bookkeeping, ``t_device_ms`` is blocked-on-device; means per
    step) and the mean fused decode horizon (docs/PERF.md). v3 adds the
    swap-preemption telemetry per row and, with ``--oversubscribe``, the
    ``oversub_{recompute,swap,auto}`` rows: the same heavily
    oversubscribed reasoning workload (short prompts, very long outputs,
    steady-state demand ~2x the block pool, chunked prefill under a
    token budget) served under each preemption mode. The ``_step``
    speedups compare tokens-per-step — deterministic, unlike wall-clock
    on a noisy CI box — where recompute mode pays for re-prefilling
    preempted requests and swap mode restores their KV from the host
    swap tier instead (docs/SCHEDULER.md "Preemption modes").

    v4 adds, with ``--prefix-heavy``, the multi-turn prefix-sharing
    workload (docs/CACHING.md): conversations fanning out from a few
    block-aligned shared system prompts, then a second round of forked
    continuations of the round-1 streams — prefixed, in arrival order,
    by a burst of cold one-off prompts — through the *same* engine, so
    round 2 can only reuse KV if finished requests registered it. Rows
    ``prefix_flat_fcfs`` (legacy exact-match cache, FCFS admission),
    ``prefix_radix_cache_aware`` (radix tree + cache-aware admission) and
    ``prefix_radix_compressed`` (plus compressed-segment caching) carry
    the cache telemetry per row: ``prefix_hit_rate``,
    ``prefix_hit_tokens``, ``prefix_segment_hits``, ``prefix_evictions``,
    ``cached_tokens_per_block``, ``ttft_ms``, ``ttft_warm_ms`` (mean
    admitted-to-first-token of the requests that had a cache hit — the
    ones cache-aware admission floats ahead of the cold burst). The
    ``prefix_*_vs_flat`` headlines compare radix+cache-aware against the
    flat+FCFS baseline; ``warm_ttft_ratio`` < 1 means warm requests got
    their first token sooner under the radix+cache-aware engine.

``--smoke`` shrinks the request count so the job stays in CI budget.
``tools/bench_trend.py`` accumulates these JSONs across PRs and gates on
decode-throughput regressions (``make bench-trend``) — including the
swap-mode decode throughput once oversubscribed points exist.
"""
import argparse
import json
import sys
import time

import numpy as np

from benchmarks.common import (CFG, DEFAULT_ENGINE, params_random,
                               run_engine, workload)


def _measure(n_requests):
    """[(name, result)] for Zipage vs the full-KV nano-vLLM baseline."""
    rng = np.random.default_rng(1)
    reqs = workload("amc", n_requests, rng)
    out = []
    for name, ov in (("zipage", {}), ("nano_vllm", {"n_max": None})):
        out.append((name, run_engine(reqs, **ov)))
    return out


# oversubscribed scenario (ISSUE 5): sustained preemption churn under a
# shared token budget with chunked prefill, so recompute-mode victims pay
# their re-prefill in budget tokens while swapped victims resume free
OVERSUB_ENGINE = dict(token_budget=64, max_prefill_chunk=16)


def _measure_oversub(n_requests):
    """[(name, result)] for the swap-vs-recompute preemption-mode
    comparison on the oversubscribed workload."""
    reqs = workload("oversub", n_requests, np.random.default_rng(7))
    out = []
    for mode in ("recompute", "swap", "auto"):
        ov = dict(OVERSUB_ENGINE, preemption_mode=mode,
                  swap_space_blocks=0 if mode == "recompute" else 96)
        out.append((f"oversub_{mode}", run_engine(reqs, **ov)))
    return out


# multi-turn prefix-sharing scenario (docs/CACHING.md): conversations
# fork off a few block-aligned shared system prompts, and a second round
# continues the round-1 streams — reuse across rounds only exists if the
# cache registered finished requests
PREFIX_VARIANTS = (
    ("prefix_flat_fcfs",
     dict(prefix_cache_policy="flat", policy="fcfs")),
    ("prefix_radix_cache_aware",
     dict(prefix_cache_policy="radix", policy="cache_aware")),
    ("prefix_radix_compressed",
     dict(prefix_cache_policy="radix", policy="cache_aware",
          cache_compressed_prefixes=True)),
)


def _run_prefix_heavy(n_convs, **overrides):
    """Two rounds of a multi-turn chat/agent workload (with a cache-churn
    burst in between) through one engine; returns the run_engine-shaped
    result dict plus cache telemetry."""
    from repro.api import SamplingParams, Zipage

    kw = dict(DEFAULT_ENGINE)
    # tighter pool + budgeted prefill than the plain scenarios: the cache
    # comparison is about eviction quality and prefill tokens saved, both
    # of which need the pool and the step budget to actually be scarce.
    # The full admission margin keeps the tight pool out of admit/preempt
    # thrash — and is exactly the reserve that cache-aware admission
    # shrinks by each candidate's matched blocks (docs/CACHING.md)
    kw.update(n_total_blocks=64, token_budget=96, admission_margin=1.0)
    kw.update(overrides)
    bs = kw["block_size"]

    def drive(z):
        rng = np.random.default_rng(11)
        sys_prompts = [rng.integers(0, CFG.vocab_size, size=4 * bs).tolist()
                       for _ in range(3)]
        # round 1: shared system prompt + block-aligned user turn
        # (alignment keeps first compressions prompt-pure, so the
        # compressed variant can actually cache segments)
        r1_prompts = []
        for i in range(n_convs):
            user_len = int(rng.choice([bs, 2 * bs]))
            r1_prompts.append(sys_prompts[i % len(sys_prompts)]
                              + rng.integers(0, CFG.vocab_size,
                                             size=user_len).tolist())
        n_out = [int(rng.integers(16, 28)) for _ in range(2 * n_convs)]
        t0 = time.monotonic()
        outs1 = z.generate(r1_prompts,
                           [SamplingParams(max_new_tokens=n_out[i])
                            for i in range(n_convs)], max_steps=20_000)
        # round 2: two forked continuations per conversation (each
        # extending the full round-1 stream with a fresh user turn) plus
        # a burst of cold one-off prompts placed at the *head* of the
        # arrival order. The colds both churn the cache (eviction
        # pressure) and stall FCFS: strict head-of-line admission parks
        # the warm forks behind the block-hungry cold prompts, while
        # cache-aware admission floats the forks (cheap — most of their
        # blocks are already cached) to the front and keeps the decode
        # batch fed.
        forks = []
        for o in outs1:
            stream = o.prompt_token_ids + o.token_ids
            for _ in range(2):
                cont = rng.integers(0, CFG.vocab_size,
                                    size=int(rng.integers(4, 10))).tolist()
                forks.append(stream + cont)
        cold = [rng.integers(0, CFG.vocab_size,
                             size=int(rng.integers(4, 7)) * bs).tolist()
                for _ in range(n_convs)]
        r2_prompts = cold + forks
        r2_params = ([SamplingParams(max_new_tokens=8)] * len(cold)
                     + [SamplingParams(max_new_tokens=n_out[n_convs + i // 2])
                        for i in range(len(forks))])
        outs2 = z.generate(r2_prompts, r2_params, max_steps=20_000)
        return outs1 + outs2, time.monotonic() - t0

    # warm the process-wide compile cache with a throwaway engine running
    # the same workload, then measure a fresh engine of the same serve
    # signature (compiled steps are shared — docs/PERF.md "Warm starts").
    # Without this, variant order skews both the clock and the
    # straggler-aware admission backoff.
    drive(Zipage(CFG, params_random(), **kw))
    z = Zipage(CFG, params_random(), **kw)
    outs, dt = drive(z)
    metrics = z.metrics
    steps = z.step_count
    toks = sum(o.usage.completion_tokens for o in outs)
    tpots = [(o.metrics.t_finish - o.metrics.t_first_token)
             / (o.usage.completion_tokens - 1) for o in outs
             if o.metrics.t_finish and o.metrics.t_first_token
             and o.usage.completion_tokens > 1]
    ttfts = [o.metrics.t_first_token - o.metrics.arrival for o in outs
             if o.metrics.t_first_token is not None]
    # warm = admitted with a cache hit. Cache-aware admission floats
    # these ahead of cold prompts, so their queueing delay is the
    # admission-latency signal; the overall mean mixes in the cold
    # prompts the policy deliberately deferred.
    ttfts_warm = [o.metrics.t_first_token - o.metrics.arrival for o in outs
                  if o.metrics.t_first_token is not None
                  and o.metrics.n_cached_prompt_tokens > 0]
    stats = z.scheduler_stats
    return {
        "engine": z, "metrics": metrics, "outputs": outs, "wall_s": dt,
        "tokens": toks, "steps": steps, "tps": toks / dt,
        "tokens_per_step": toks / max(steps, 1),
        "tpot_ms": 1e3 * float(np.mean(tpots)) if tpots else float("nan"),
        "compressions": sum(m["n_compressing"] for m in metrics),
        "block_util": float(np.mean([m["block_util"] for m in metrics])),
        "ttft_ms": 1e3 * float(np.mean(ttfts)) if ttfts else float("nan"),
        "ttft_warm_ms": (1e3 * float(np.mean(ttfts_warm))
                         if ttfts_warm else float("nan")),
        "cache": {
            "prefix_hit_rate": round(
                stats["prefix_hits"] / max(1, stats["prefix_lookups"]), 3),
            "prefix_hit_tokens": stats["prefix_hit_tokens"],
            "prefix_segment_hits": stats["prefix_segment_hits"],
            "prefix_evictions": stats["prefix_evictions"],
            "cached_tokens_per_block": round(
                stats["cached_tokens_per_block"], 3),
        },
    }


def _measure_prefix_heavy(n_convs):
    """[(name, result)] for the flat-vs-radix prefix-cache comparison on
    the multi-turn workload."""
    return [(name, _run_prefix_heavy(n_convs, **ov))
            for name, ov in PREFIX_VARIANTS]


def _row(name, r):
    metrics = r.get("metrics") or r["engine"].metrics
    conc = np.array([m["n_running"] for m in metrics])
    horizons = [m["decode_horizon"] for m in metrics
                if m.get("decode_horizon", 0) > 0]
    row = {
        "name": name,
        "tps": round(r["tps"], 2),
        "tokens": r["tokens"],
        "steps": r["steps"],
        "tokens_per_step": round(r["tokens_per_step"], 2),
        "mean_concurrency": round(float(conc.mean()), 2),
        "p50_concurrency": float(np.median(conc)),
        "max_concurrency": int(conc.max()),
        "frac_steps_conc_ge12": round(float((conc >= 12).mean()), 3),
        "tpot_ms": round(r["tpot_ms"], 3),
        "block_util": round(r["block_util"], 3),
        "compressions": r["compressions"],
        "preemptions": int(sum(m.get("n_preempted", 0)
                               for m in metrics)),
        "n_swapped_out": int(sum(m.get("n_swapped_out", 0)
                                 for m in metrics)),
        "n_swapped_in": int(sum(m.get("n_swapped_in", 0)
                                for m in metrics)),
        "swap_mb": round(metrics[-1].get("swap_bytes", 0) / 2**20, 3)
        if metrics else 0.0,
        "t_host_ms": round(1e3 * float(np.mean(
            [m["t_host"] for m in metrics])), 3),
        "t_device_ms": round(1e3 * float(np.mean(
            [m["t_device"] for m in metrics])), 3),
        "mean_decode_horizon": round(float(np.mean(horizons)), 2)
        if horizons else 0.0,
        "wall_s": round(r["wall_s"], 3),
    }
    if "cache" in r:                     # prefix-heavy rows (schema v4)
        row.update(r["cache"])
        row["ttft_ms"] = round(r["ttft_ms"], 3)
        row["ttft_warm_ms"] = round(r["ttft_warm_ms"], 3)
    return row


def run():
    """benchmarks.run entry point — legacy CSV rows."""
    rows = []
    for name, r in _measure(24):
        t_steps = np.array([m["t_total"] for m in r["engine"].metrics])
        row = _row(name, r)
        rows.append((f"concurrency/{name}",
                     1e6 * float(t_steps.mean()),
                     f"steps={row['steps']};frac_steps_conc_ge12="
                     f"{row['frac_steps_conc_ge12']:.2f};"
                     f"p50_conc={row['p50_concurrency']:.0f};"
                     f"max_conc={row['max_concurrency']};"
                     f"tok_per_step={row['tokens_per_step']:.2f}"))
    return rows


def main(argv=None):
    import jax

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small request count (CI bench-smoke)")
    ap.add_argument("--oversubscribe", action="store_true",
                    help="add the oversubscribed swap-vs-recompute "
                         "preemption-mode comparison")
    ap.add_argument("--prefix-heavy", action="store_true",
                    help="add the multi-turn prefix-sharing flat-vs-radix "
                         "cache comparison (docs/CACHING.md)")
    ap.add_argument("--out", default=None, metavar="FILE.json",
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args(argv)

    results = {name: _row(name, r)
               for name, r in _measure(8 if args.smoke else 24)}
    report = {
        "schema": "zipage-bench-concurrency/v4",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "smoke": args.smoke,
        "results": list(results.values()),
        "speedup_tps_zipage_vs_nano": round(
            results["zipage"]["tps"] / results["nano_vllm"]["tps"], 3),
    }
    if args.oversubscribe:
        oversub = {name: _row(name, r)
                   for name, r in _measure_oversub(24 if args.smoke
                                                   else 32)}
        report["results"] += list(oversub.values())
        rec = oversub["oversub_recompute"]
        for mode in ("swap", "auto"):
            row = oversub[f"oversub_{mode}"]
            report[f"oversub_speedup_tps_{mode}_vs_recompute"] = round(
                row["tps"] / rec["tps"], 3)
            report[f"oversub_speedup_step_{mode}_vs_recompute"] = round(
                row["tokens_per_step"] / rec["tokens_per_step"], 3)
    if args.prefix_heavy:
        prefix = {name: _row(name, r)
                  for name, r in _measure_prefix_heavy(8 if args.smoke
                                                       else 16)}
        report["results"] += list(prefix.values())
        flat = prefix["prefix_flat_fcfs"]
        radix = prefix["prefix_radix_cache_aware"]
        report["prefix_speedup_tps_radix_vs_flat"] = round(
            radix["tps"] / flat["tps"], 3)
        report["prefix_speedup_step_radix_vs_flat"] = round(
            radix["tokens_per_step"] / flat["tokens_per_step"], 3)
        report["prefix_ttft_ratio_radix_vs_flat"] = round(
            radix["ttft_ms"] / flat["ttft_ms"], 3)
        report["prefix_warm_ttft_ratio_radix_vs_flat"] = round(
            radix["ttft_warm_ms"] / flat["ttft_warm_ms"], 3)
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
