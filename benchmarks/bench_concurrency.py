"""Paper Fig. 7 / App. E: real-time throughput, per-step time and the
concurrency distribution, Zipage vs nano-vLLM, on the AMC-like workload.

Usable two ways:

  * ``python -m benchmarks.run bench_concurrency`` — legacy CSV rows via
    ``run()`` (name,us_per_step,derived);
  * ``python -m benchmarks.bench_concurrency [--smoke] [--oversubscribe]
    [--out FILE.json]`` — JSON for the per-PR concurrency trajectory
    (CI's bench-smoke artifact), same envelope as ``bench_kernels.py``:

      {"schema": "zipage-bench-concurrency/v3", "jax": ..., "platform": ...,
       "smoke": bool, "results": [{"name", "tps", "tokens", "steps",
       "tokens_per_step", "mean_concurrency", "p50_concurrency",
       "max_concurrency", "frac_steps_conc_ge12", "tpot_ms", "block_util",
       "compressions", "preemptions", "n_swapped_out", "n_swapped_in",
       "swap_mb", "t_host_ms", "t_device_ms", "mean_decode_horizon",
       "wall_s"}, ...],
       "speedup_tps_zipage_vs_nano": float,
       "oversub_speedup_tps_swap_vs_recompute": float | absent,
       "oversub_speedup_tps_auto_vs_recompute": float | absent,
       "oversub_speedup_step_swap_vs_recompute": float | absent,
       "oversub_speedup_step_auto_vs_recompute": float | absent}

    v2 added the per-step host/device time split (``t_host_ms`` is host
    planning+bookkeeping, ``t_device_ms`` is blocked-on-device; means per
    step) and the mean fused decode horizon (docs/PERF.md). v3 adds the
    swap-preemption telemetry per row and, with ``--oversubscribe``, the
    ``oversub_{recompute,swap,auto}`` rows: the same heavily
    oversubscribed reasoning workload (short prompts, very long outputs,
    steady-state demand ~2x the block pool, chunked prefill under a
    token budget) served under each preemption mode. The ``_step``
    speedups compare tokens-per-step — deterministic, unlike wall-clock
    on a noisy CI box — where recompute mode pays for re-prefilling
    preempted requests and swap mode restores their KV from the host
    swap tier instead (docs/SCHEDULER.md "Preemption modes").

``--smoke`` shrinks the request count so the job stays in CI budget.
``tools/bench_trend.py`` accumulates these JSONs across PRs and gates on
decode-throughput regressions (``make bench-trend``) — including the
swap-mode decode throughput once oversubscribed points exist.
"""
import argparse
import json
import sys

import numpy as np

from benchmarks.common import run_engine, workload


def _measure(n_requests):
    """[(name, result)] for Zipage vs the full-KV nano-vLLM baseline."""
    rng = np.random.default_rng(1)
    reqs = workload("amc", n_requests, rng)
    out = []
    for name, ov in (("zipage", {}), ("nano_vllm", {"n_max": None})):
        out.append((name, run_engine(reqs, **ov)))
    return out


# oversubscribed scenario (ISSUE 5): sustained preemption churn under a
# shared token budget with chunked prefill, so recompute-mode victims pay
# their re-prefill in budget tokens while swapped victims resume free
OVERSUB_ENGINE = dict(token_budget=64, max_prefill_chunk=16)


def _measure_oversub(n_requests):
    """[(name, result)] for the swap-vs-recompute preemption-mode
    comparison on the oversubscribed workload."""
    reqs = workload("oversub", n_requests, np.random.default_rng(7))
    out = []
    for mode in ("recompute", "swap", "auto"):
        ov = dict(OVERSUB_ENGINE, preemption_mode=mode,
                  swap_space_blocks=0 if mode == "recompute" else 96)
        out.append((f"oversub_{mode}", run_engine(reqs, **ov)))
    return out


def _row(name, r):
    metrics = r["engine"].metrics
    conc = np.array([m["n_running"] for m in metrics])
    horizons = [m["decode_horizon"] for m in metrics
                if m.get("decode_horizon", 0) > 0]
    return {
        "name": name,
        "tps": round(r["tps"], 2),
        "tokens": r["tokens"],
        "steps": r["steps"],
        "tokens_per_step": round(r["tokens_per_step"], 2),
        "mean_concurrency": round(float(conc.mean()), 2),
        "p50_concurrency": float(np.median(conc)),
        "max_concurrency": int(conc.max()),
        "frac_steps_conc_ge12": round(float((conc >= 12).mean()), 3),
        "tpot_ms": round(r["tpot_ms"], 3),
        "block_util": round(r["block_util"], 3),
        "compressions": r["compressions"],
        "preemptions": int(sum(m.get("n_preempted", 0)
                               for m in metrics)),
        "n_swapped_out": int(sum(m.get("n_swapped_out", 0)
                                 for m in metrics)),
        "n_swapped_in": int(sum(m.get("n_swapped_in", 0)
                                for m in metrics)),
        "swap_mb": round(metrics[-1].get("swap_bytes", 0) / 2**20, 3)
        if metrics else 0.0,
        "t_host_ms": round(1e3 * float(np.mean(
            [m["t_host"] for m in metrics])), 3),
        "t_device_ms": round(1e3 * float(np.mean(
            [m["t_device"] for m in metrics])), 3),
        "mean_decode_horizon": round(float(np.mean(horizons)), 2)
        if horizons else 0.0,
        "wall_s": round(r["wall_s"], 3),
    }


def run():
    """benchmarks.run entry point — legacy CSV rows."""
    rows = []
    for name, r in _measure(24):
        t_steps = np.array([m["t_total"] for m in r["engine"].metrics])
        row = _row(name, r)
        rows.append((f"concurrency/{name}",
                     1e6 * float(t_steps.mean()),
                     f"steps={row['steps']};frac_steps_conc_ge12="
                     f"{row['frac_steps_conc_ge12']:.2f};"
                     f"p50_conc={row['p50_concurrency']:.0f};"
                     f"max_conc={row['max_concurrency']};"
                     f"tok_per_step={row['tokens_per_step']:.2f}"))
    return rows


def main(argv=None):
    import jax

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small request count (CI bench-smoke)")
    ap.add_argument("--oversubscribe", action="store_true",
                    help="add the oversubscribed swap-vs-recompute "
                         "preemption-mode comparison")
    ap.add_argument("--out", default=None, metavar="FILE.json",
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args(argv)

    results = {name: _row(name, r)
               for name, r in _measure(8 if args.smoke else 24)}
    report = {
        "schema": "zipage-bench-concurrency/v3",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "smoke": args.smoke,
        "results": list(results.values()),
        "speedup_tps_zipage_vs_nano": round(
            results["zipage"]["tps"] / results["nano_vllm"]["tps"], 3),
    }
    if args.oversubscribe:
        oversub = {name: _row(name, r)
                   for name, r in _measure_oversub(24 if args.smoke
                                                   else 32)}
        report["results"] += list(oversub.values())
        rec = oversub["oversub_recompute"]
        for mode in ("swap", "auto"):
            row = oversub[f"oversub_{mode}"]
            report[f"oversub_speedup_tps_{mode}_vs_recompute"] = round(
                row["tps"] / rec["tps"], 3)
            report[f"oversub_speedup_step_{mode}_vs_recompute"] = round(
                row["tokens_per_step"] / rec["tokens_per_step"], 3)
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
