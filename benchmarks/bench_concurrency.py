"""Paper Fig. 7 / App. E: real-time throughput, per-step time and the
concurrency distribution, Zipage vs nano-vLLM, on the AMC-like workload.

Usable two ways:

  * ``python -m benchmarks.run bench_concurrency`` — legacy CSV rows via
    ``run()`` (name,us_per_step,derived);
  * ``python -m benchmarks.bench_concurrency [--smoke] [--out FILE.json]``
    — JSON for the per-PR concurrency trajectory (CI's bench-smoke
    artifact), same envelope as ``bench_kernels.py``:

      {"schema": "zipage-bench-concurrency/v2", "jax": ..., "platform": ...,
       "smoke": bool, "results": [{"name", "tps", "tokens", "steps",
       "tokens_per_step", "mean_concurrency", "p50_concurrency",
       "max_concurrency", "frac_steps_conc_ge12", "tpot_ms", "block_util",
       "compressions", "preemptions", "t_host_ms", "t_device_ms",
       "mean_decode_horizon", "wall_s"}, ...],
       "speedup_tps_zipage_vs_nano": float}

    v2 adds the per-step host/device time split (``t_host_ms`` is host
    planning+bookkeeping, ``t_device_ms`` is blocked-on-device; means per
    step) and the mean fused decode horizon (docs/PERF.md).

``--smoke`` shrinks the request count so the job stays in CI budget.
``tools/bench_trend.py`` accumulates these JSONs across PRs and gates on
decode-throughput regressions (``make bench-trend``).
"""
import argparse
import json
import sys

import numpy as np

from benchmarks.common import run_engine, workload


def _measure(n_requests):
    """[(name, result)] for Zipage vs the full-KV nano-vLLM baseline."""
    rng = np.random.default_rng(1)
    reqs = workload("amc", n_requests, rng)
    out = []
    for name, ov in (("zipage", {}), ("nano_vllm", {"n_max": None})):
        out.append((name, run_engine(reqs, **ov)))
    return out


def _row(name, r):
    metrics = r["engine"].metrics
    conc = np.array([m["n_running"] for m in metrics])
    horizons = [m["decode_horizon"] for m in metrics
                if m.get("decode_horizon", 0) > 0]
    return {
        "name": name,
        "tps": round(r["tps"], 2),
        "tokens": r["tokens"],
        "steps": r["steps"],
        "tokens_per_step": round(r["tokens_per_step"], 2),
        "mean_concurrency": round(float(conc.mean()), 2),
        "p50_concurrency": float(np.median(conc)),
        "max_concurrency": int(conc.max()),
        "frac_steps_conc_ge12": round(float((conc >= 12).mean()), 3),
        "tpot_ms": round(r["tpot_ms"], 3),
        "block_util": round(r["block_util"], 3),
        "compressions": r["compressions"],
        "preemptions": int(sum(m.get("n_preempted", 0)
                               for m in metrics)),
        "t_host_ms": round(1e3 * float(np.mean(
            [m["t_host"] for m in metrics])), 3),
        "t_device_ms": round(1e3 * float(np.mean(
            [m["t_device"] for m in metrics])), 3),
        "mean_decode_horizon": round(float(np.mean(horizons)), 2)
        if horizons else 0.0,
        "wall_s": round(r["wall_s"], 3),
    }


def run():
    """benchmarks.run entry point — legacy CSV rows."""
    rows = []
    for name, r in _measure(24):
        t_steps = np.array([m["t_total"] for m in r["engine"].metrics])
        row = _row(name, r)
        rows.append((f"concurrency/{name}",
                     1e6 * float(t_steps.mean()),
                     f"steps={row['steps']};frac_steps_conc_ge12="
                     f"{row['frac_steps_conc_ge12']:.2f};"
                     f"p50_conc={row['p50_concurrency']:.0f};"
                     f"max_conc={row['max_concurrency']};"
                     f"tok_per_step={row['tokens_per_step']:.2f}"))
    return rows


def main(argv=None):
    import jax

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small request count (CI bench-smoke)")
    ap.add_argument("--out", default=None, metavar="FILE.json",
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args(argv)

    results = {name: _row(name, r)
               for name, r in _measure(8 if args.smoke else 24)}
    report = {
        "schema": "zipage-bench-concurrency/v2",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "smoke": args.smoke,
        "results": list(results.values()),
        "speedup_tps_zipage_vs_nano": round(
            results["zipage"]["tps"] / results["nano_vllm"]["tps"], 3),
    }
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
