# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness — one module per paper table/figure:

  bench_ablation      Fig. 5/6   TPOT/TPS ablations x 3 workloads
  bench_concurrency   Fig. 7     throughput / step time / concurrency bands
  bench_frameworks    Fig. 8     static-batch vs nano-vllm vs zipage
  bench_budgets       Fig. 9     KV-budget sweep + quality proxy
  bench_layer_stride  Fig. 10    cross-layer compression stride
  bench_redundancy    Fig. 13/16 lightning vs flash redundancy + scaling
  bench_quality_proxy Tab. 2/C.8 scoring-function ablations
  bench_kernels       (impl)     per-kernel us, pallas-interpret vs jnp
  roofline            Roofline   dry-run roofline table

  PYTHONPATH=src python -m benchmarks.run [module ...]
"""
import sys
import time
import traceback

MODULES = [
    "bench_ablation", "bench_concurrency", "bench_frameworks",
    "bench_budgets", "bench_layer_stride", "bench_redundancy",
    "bench_quality_proxy", "bench_kernels", "roofline",
]


def main() -> None:
    want = sys.argv[1:] or MODULES
    print("name,us_per_call,derived")
    for mod in want:
        t0 = time.time()
        try:
            m = __import__(f"benchmarks.{mod}", fromlist=["run"])
            for name, us, derived in m.run():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:
            print(f"{mod}/ERROR,0,{traceback.format_exc().splitlines()[-1]}",
                  flush=True)
        print(f"# {mod} took {time.time() - t0:.1f}s", file=sys.stderr,
              flush=True)


if __name__ == "__main__":
    main()
