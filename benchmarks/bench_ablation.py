"""Paper Figs. 5/6: TPOT + TPS across ablations on three workload shapes.

Configs: zipage (all features), -async, -hybrid (constrained), -prefix,
nano-vllm (no compression). CPU-neutral headline: device steps and
tokens/step (see EXPERIMENTS.md §CPU-metrics note); wall TPS/TPOT included.
"""
import numpy as np

from benchmarks.common import run_engine, workload

CONFIGS = {
    "zipage": {},
    "no_async": {"async_compression": False},
    "constrained": {"scheduling": "constrained"},
    "no_prefix": {"prefix_caching": False},
    "nano_vllm": {"n_max": None},
}


def run():
    rows = []
    rng = np.random.default_rng(0)
    for wl in ("amc", "gsm", "mix"):
        reqs = workload(wl, 24, rng)
        for name, ov in CONFIGS.items():
            r = run_engine(reqs, **ov)
            us = 1e6 * r["wall_s"] / max(r["steps"], 1)
            rows.append((f"ablation/{wl}/{name}", us,
                         f"steps={r['steps']};tok_per_step="
                         f"{r['tokens_per_step']:.2f};tps={r['tps']:.1f};"
                         f"tpot_ms={r['tpot_ms']:.1f};"
                         f"conc={r['mean_concurrency']:.1f};"
                         f"block_util={r['block_util']:.2f}"))
    return rows
