"""Serving-tier latency bench: Poisson arrivals against the in-process
ASGI app (``repro.serve``), measuring what an HTTP client actually sees —
time-to-first-token, inter-token latency and sustained token throughput
through the full intake -> continuous-batching-loop -> SSE fan-out path
(docs/SERVING.md). No sockets: requests are driven through
``repro.serve.testing.ASGIClient``, so the numbers isolate the serving
tier itself and the job is CI-safe.

  python -m benchmarks.bench_serving [--smoke] [--out FILE.json]

JSON envelope, same shape as ``bench_concurrency.py``:

  {"schema": "zipage-bench-serving/v1", "jax": ..., "platform": ...,
   "smoke": bool, "results": [{"name": "serving_poisson", "n_requests",
   "rate_rps", "n_ok", "n_rejected", "tokens", "steps", "wall_s", "tps",
   "ttft_p50_ms", "ttft_p99_ms", "itl_mean_ms", "itl_p50_ms",
   "itl_p99_ms"}]}

Every request streams (SSE) with a per-client id rotated across a small
client pool, so fairness tagging and the per-step fan-out are on the
measured path.  The engine's fused decode flushes up to ``decode_steps``
tokens per SSE frame; inter-token latency is therefore the frame gap
normalised by the tokens the frame carried — the per-token pacing a
client-side detokeniser would observe.  ``--smoke`` shrinks the request
count for CI's bench-smoke job; ``tools/bench_trend.py`` accumulates the
JSONs and gates on p99-TTFT blow-ups and serving-throughput regressions
(``make bench-trend``).
"""
import argparse
import asyncio
import json
import sys
import time

import numpy as np

from benchmarks.common import CFG, DEFAULT_ENGINE, params_random, workload
from repro.api import Zipage
from repro.serve import ServeConfig, create_app
from repro.serve.protocol import render_text
from repro.serve.testing import ASGIClient

CLIENTS = ("alice", "bob", "carol")


async def _one_request(client, prompt, n_out, delay, cid, rec):
    """Sleep until the request's Poisson arrival, then stream it and
    timestamp every SSE frame that carried tokens."""
    await asyncio.sleep(delay)
    rec["submit"] = time.monotonic()
    handle = client.stream(
        "POST", "/v1/completions",
        json={"prompt": render_text(prompt), "max_tokens": n_out,
              "stream": True},
        headers={"x-client-id": cid})
    async with handle:
        await handle.started()
        rec["status"] = handle.status
        if handle.status != 200:
            return
        async for event in handle.events():
            if event == "[DONE]" or not event.get("choices"):
                continue
            ntok = len(event["choices"][0].get("token_ids", []))
            if ntok:
                rec["frames"].append((time.monotonic(), ntok))


async def _drive(app, reqs, rate, rng):
    """Run the full arrival schedule concurrently; returns per-request
    records and the measured wall interval."""
    client = ASGIClient(app)
    # warm-up: compile the prefill/decode dispatches outside the clock
    warm = {"frames": [], "status": None}
    await _one_request(client, reqs[0][0], 4, 0.0, "warmup", warm)
    assert warm["status"] == 200, f"warm-up failed: {warm['status']}"

    delays = np.cumsum(rng.exponential(1.0 / rate, size=len(reqs)))
    recs = [{"frames": [], "status": None} for _ in reqs]
    t0 = time.monotonic()
    await asyncio.gather(*(
        _one_request(client, p, o, float(d), CLIENTS[i % len(CLIENTS)],
                     recs[i])
        for i, ((p, o), d) in enumerate(zip(reqs, delays))))
    t1 = time.monotonic()
    await app.state.drain()
    return recs, t1 - t0


def _measure(n_requests, rate):
    rng = np.random.default_rng(7)
    reqs = workload("gsm", n_requests, rng)       # short in, short out
    zipage = Zipage(CFG, params_random(),
                    **dict(DEFAULT_ENGINE, policy="priority"))
    app = create_app(ServeConfig(max_queued_requests=max(64, n_requests)),
                     zipage=zipage)
    recs, wall = asyncio.run(_drive(app, reqs, rate, rng))

    ok = [r for r in recs if r["status"] == 200 and r["frames"]]
    ttfts = [r["frames"][0][0] - r["submit"] for r in ok]
    # frame gap / tokens-in-frame: per-token pacing despite fused flushes
    itls = [(t - prev_t) / ntok
            for r in ok
            for (prev_t, _), (t, ntok) in zip(r["frames"],
                                              r["frames"][1:])]
    tokens = sum(ntok for r in ok for _, ntok in r["frames"])
    pct = lambda xs, q: (1e3 * float(np.percentile(xs, q))  # noqa: E731
                         if xs else float("nan"))
    return {
        "name": "serving_poisson",
        "n_requests": n_requests,
        "rate_rps": rate,
        "n_ok": len(ok),
        "n_rejected": sum(r["status"] not in (200, None)
                          for r in recs),
        "tokens": tokens,
        "steps": zipage.step_count,
        "wall_s": round(wall, 3),
        "tps": round(tokens / wall, 2),
        "ttft_p50_ms": round(pct(ttfts, 50), 3),
        "ttft_p99_ms": round(pct(ttfts, 99), 3),
        "itl_mean_ms": round(1e3 * float(np.mean(itls)), 3)
        if itls else float("nan"),
        "itl_p50_ms": round(pct(itls, 50), 3),
        "itl_p99_ms": round(pct(itls, 99), 3),
    }


def main(argv=None):
    import jax

    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="small request count (CI bench-smoke)")
    ap.add_argument("--rate", type=float, default=None, metavar="RPS",
                    help="Poisson arrival rate (default: 20 smoke, 10 full)")
    ap.add_argument("--out", default=None, metavar="FILE.json",
                    help="write the JSON report here (default: stdout)")
    args = ap.parse_args(argv)

    n = 12 if args.smoke else 32
    rate = args.rate or (20.0 if args.smoke else 10.0)
    row = _measure(n, rate)
    report = {
        "schema": "zipage-bench-serving/v1",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "smoke": args.smoke,
        "results": [row],
    }
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
