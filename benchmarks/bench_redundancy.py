"""Paper Figs. 13/14/16 + App. C.7: redundancy-score cost.

(a) per-call cost of the compression pipeline with flash vs lightning vs no
    redundancy (jnp backend — the deployable CPU path);
(b) scaling in N (blocks): flash is O(N²·b²), lightning O(N·b²) — the
    measured growth ratios expose the complexity class.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CFG
from repro.core.compression import CompressOptions, build_compress_fn

RNG = np.random.default_rng(5)


def _setup(L, N_total, b, mb, n, w=4):
    h, d, hq = CFG.num_kv_heads, CFG.head_dim, CFG.num_heads
    pools = {
        "k": jnp.asarray(RNG.normal(size=(L, N_total, b, h, d)), jnp.float32),
        "v": jnp.asarray(RNG.normal(size=(L, N_total, b, h, d)), jnp.float32),
        "f": jnp.zeros((L, N_total, b, h), jnp.float32),
    }
    qwin = jnp.asarray(RNG.normal(size=(L, n, w, hq, d)), jnp.float32)
    src = np.stack([RNG.choice(N_total, mb, replace=False)
                    for _ in range(n)]).astype(np.int32)
    req = (jnp.asarray(src), jnp.asarray(src[:, :mb - 1]),
           jnp.arange(n, dtype=jnp.int32),
           jnp.full((n,), mb * b, jnp.int32),
           jnp.zeros((n,), jnp.int32))
    return pools, qwin, req


def timed(fn, *args, iters=5):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    L, b, n, w = 2, 8, 4, 4
    # (a) per-variant cost at fixed size
    mb, N_total = 8, 64
    pools, qwin, req = _setup(L, N_total, b, mb, n, w)
    base_us = {}
    for red in ("none", "lightning", "flash"):
        opts = CompressOptions(window=w, redundancy=red, pooling="none")
        fn = jax.jit(build_compress_fn(CFG, block_size=b, max_blocks=mb,
                                       budget_blocks=mb - 1, opts=opts))
        us = timed(fn, pools, qwin, req)
        base_us[red] = us
        rows.append((f"redundancy/variant/{red}", us,
                     f"overhead_vs_none="
                     f"{us / max(base_us.get('none', us), 1e-9):.2f}x"))
    # (b) scaling in N
    for red in ("lightning", "flash"):
        times = []
        for mb_s in (4, 8, 16):
            pools_s, qwin_s, req_s = _setup(L, 96, b, mb_s, n, w)
            opts = CompressOptions(window=w, redundancy=red, pooling="none")
            fn = jax.jit(build_compress_fn(
                CFG, block_size=b, max_blocks=mb_s,
                budget_blocks=mb_s - 1, opts=opts))
            times.append(timed(fn, pools_s, qwin_s, req_s, iters=3))
        g1 = times[1] / times[0]
        g2 = times[2] / times[1]
        rows.append((f"redundancy/scaling/{red}", times[-1],
                     f"us_N4={times[0]:.0f};us_N8={times[1]:.0f};"
                     f"us_N16={times[2]:.0f};growth_4to8={g1:.2f};"
                     f"growth_8to16={g2:.2f}"))
    return rows
