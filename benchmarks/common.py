"""Shared benchmark fixtures: tiny model (random + briefly trained),
workload generators, engine runner — all through the `repro.api` facade."""
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import SamplingParams, Zipage
from repro.configs import get_config
from repro.models import lm
from repro.training import optimizer as opt
from repro.training.data import DataConfig, batch_at
from repro.training.train_loop import build_train_step

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
_params_cache = {}


def params_random():
    if "rand" not in _params_cache:
        _params_cache["rand"] = lm.init(CFG, jax.random.key(0))
    return _params_cache["rand"]


def params_trained(steps=150):
    """Tiny model trained briefly on the synthetic copy task so attention
    is non-degenerate (needed for eviction-quality proxies)."""
    key = f"trained{steps}"
    if key not in _params_cache:
        dc = DataConfig(seq_len=48, global_batch=16,
                        vocab_size=CFG.vocab_size, kind="copy")
        adamw = opt.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=steps)
        step = jax.jit(build_train_step(CFG, adamw, vocab_chunk=64))
        params = lm.init(CFG, jax.random.key(0))
        state = opt.init_opt_state(params)
        for i in range(steps):
            batch = jax.tree.map(jnp.asarray, batch_at(dc, i))
            params, state, _, m = step(params, state, None, batch)
        _params_cache[key] = params
    return _params_cache[key]


def workload(kind, n, rng):
    reqs = []
    for i in range(n):
        if kind == "amc":           # short in, long out
            p, o = int(rng.integers(8, 24)), int(rng.integers(60, 100))
        elif kind == "gsm":         # short in, short out
            p, o = int(rng.integers(8, 24)), int(rng.integers(8, 20))
        elif kind == "long":        # long in, short out
            p, o = int(rng.integers(80, 140)), int(rng.integers(8, 20))
        elif kind == "oversub":     # short in, very long out: steady-state
            #                         demand far exceeds the block pool, so
            #                         preemption churn is sustained
            p, o = int(rng.integers(8, 24)), int(rng.integers(100, 160))
        else:                       # mix
            if i % 2:
                p, o = int(rng.integers(8, 24)), int(rng.integers(60, 100))
            else:
                p, o = int(rng.integers(8, 24)), int(rng.integers(8, 20))
        reqs.append((rng.integers(0, CFG.vocab_size, size=p).tolist(), o))
    return reqs


DEFAULT_ENGINE = dict(
    block_size=8, n_total_blocks=72, max_batch=32, m_qslots=16, n_max=4,
    window=4, scheduling="hybrid", prefix_caching=True,
    async_compression=True, max_model_len=512, prefill_rows=4,
    prefill_len=64,
    # decode hot path (docs/PERF.md): fused on-device sampling + up to 8
    # decode steps per dispatch within the scheduler's quiescent horizon
    fuse_sampling=True, decode_steps=8)


def run_engine(reqs, params=None, **overrides):
    """Serve `reqs` ([(prompt, n_out), ...]) through the Zipage facade and
    report throughput/concurrency. Facade config overrides (block_size,
    n_max, scheduling, ...) ride on DEFAULT_ENGINE."""
    kw = dict(DEFAULT_ENGINE)
    kw.update(overrides)
    z = Zipage(CFG, params or params_random(), **kw)
    t0 = time.monotonic()
    outs = z.generate([p for p, _o in reqs],
                      [SamplingParams(max_new_tokens=o) for _p, o in reqs],
                      max_steps=20_000)
    dt = time.monotonic() - t0
    toks = sum(o.usage.completion_tokens for o in outs)
    tpots = []
    for o in outs:
        m = o.metrics
        if m.t_finish and m.t_first_token and o.usage.completion_tokens > 1:
            tpots.append((m.t_finish - m.t_first_token) / (o.usage.completion_tokens - 1))
    return {
        "engine": z, "outputs": outs,
        "done": {o.request_id: o for o in outs},
        "rids": [o.request_id for o in outs],
        "wall_s": dt, "tokens": toks, "steps": z.step_count,
        "tps": toks / dt,
        "tokens_per_step": toks / max(z.step_count, 1),
        "tpot_ms": 1e3 * float(np.mean(tpots)) if tpots else float("nan"),
        "mean_concurrency": float(np.mean([m["n_running"]
                                           for m in z.metrics])),
        "compressions": sum(m["n_compressing"] for m in z.metrics),
        "block_util": float(np.mean([m["block_util"]
                                     for m in z.metrics])),
    }
