"""Paper Fig. 8: frameworks comparison on the reasoning workload.

  static_batch : HF-generate-like — fixed batches run to completion with
                 padding, no continuous batching, full KV (dense cache)
  nano_vllm    : PagedAttention engine, no compression
  zipage       : Compressed PagedAttention (this paper)

The static baseline is built from the same serve steps (prefill+decode) but
admits a fixed batch and waits for ALL of it to finish — the padding-token
waste the paper attributes to HF-Gen/MorphKV/R-KV/G-KV appears as low
tokens/step.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CFG, DEFAULT_ENGINE, params_random, \
    run_engine, workload
from repro.core import serve_model


def run_static_batch(reqs, batch_size=8):
    """Fixed-batch full-KV generation (HF-Gen equivalent)."""
    params = params_random()
    spec = serve_model.ServeSpec(
        n_slots=batch_size, block_size=8,
        max_blocks=DEFAULT_ENGINE["max_model_len"] // 8,
        n_total_blocks=batch_size * DEFAULT_ENGINE["max_model_len"] // 8,
        m_qslots=1, window=4, prefill_rows=batch_size, prefill_len=64,
        dtype="float32")
    prefill = jax.jit(serve_model.build_prefill_step(CFG, spec))
    decode = jax.jit(serve_model.build_decode_step(CFG, spec))
    t0 = time.monotonic()
    total_tokens = 0
    steps = 0
    for i in range(0, len(reqs), batch_size):
        batch = reqs[i:i + batch_size]
        state = serve_model.make_state(CFG, spec)
        bt = np.full((batch_size, spec.max_blocks), -1, np.int32)
        for j in range(batch_size):
            bt[j] = np.arange(spec.max_blocks) + j * spec.max_blocks
        state["block_tables"] = jnp.asarray(bt)
        toks = np.zeros((batch_size, spec.prefill_len), np.int32)
        lengths = np.zeros((batch_size,), np.int32)
        for j, (p, _o) in enumerate(batch):
            toks[j, :len(p)] = p
            lengths[j] = len(p)
        state["seq_lens"] = jnp.asarray(lengths)
        state["positions"] = jnp.asarray(lengths)
        logits, state = prefill(
            params, state, jnp.asarray(toks),
            jnp.asarray(np.arange(batch_size, dtype=np.int32)),
            jnp.asarray(lengths),
            jnp.zeros((batch_size,), jnp.int32))
        nexts = np.asarray(jnp.argmax(logits, -1), np.int32)
        out_lens = np.ones((batch_size,), np.int32)
        targets = np.array([o for _p, o in batch], np.int32)
        # decode until the LONGEST request finishes (padding waste)
        while (out_lens < targets).any():
            active = out_lens < targets
            logits, state = decode(params, state, jnp.asarray(nexts),
                                   jnp.asarray(active))
            nexts = np.asarray(jnp.argmax(logits, -1), np.int32)
            out_lens = out_lens + active
            steps += 1
        total_tokens += int(targets.sum())
    dt = time.monotonic() - t0
    return {"tokens": total_tokens, "steps": steps, "wall_s": dt,
            "tps": total_tokens / dt,
            "tokens_per_step": total_tokens / max(steps, 1)}


def run():
    rng = np.random.default_rng(2)
    reqs = workload("amc", 24, rng)
    rows = []
    st = run_static_batch(reqs)
    rows.append(("frameworks/static_batch",
                 1e6 * st["wall_s"] / max(st["steps"], 1),
                 f"steps={st['steps']};tok_per_step="
                 f"{st['tokens_per_step']:.2f};tps={st['tps']:.1f}"))
    for name, ov in (("nano_vllm", {"n_max": None}), ("zipage", {})):
        r = run_engine(reqs, **ov)
        rows.append((f"frameworks/{name}",
                     1e6 * r["wall_s"] / max(r["steps"], 1),
                     f"steps={r['steps']};tok_per_step="
                     f"{r['tokens_per_step']:.2f};tps={r['tps']:.1f};"
                     f"conc={r['mean_concurrency']:.1f}"))
    zip_steps = [float(r[2].split("tok_per_step=")[1].split(";")[0])
                 for r in rows]
    rows.append(("frameworks/zipage_vs_nano_step_speedup", 0.0,
                 f"ratio={zip_steps[2] / max(zip_steps[1], 1e-9):.2f}"))
    return rows
