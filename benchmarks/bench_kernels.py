"""Per-kernel micro-bench: Pallas (interpret mode on CPU — correctness-path
cost, NOT TPU perf) vs the jnp reference, plus an end-to-end
``Zipage.generate()`` run with ``kernel_backend="pallas-interpret"`` that
proves the dispatch layer works through the full serving stack.

Usable two ways:

  * ``python -m benchmarks.run bench_kernels`` — legacy CSV rows via
    ``run()`` (name,us_per_call,derived). Same format; row names moved
    from ``kernels/*/pallas`` to the canonical ``kernels/*/pallas-interpret``
    (the measurement is continuous — the old rows already ran interpret
    mode on CPU);
  * ``python -m benchmarks.bench_kernels [--smoke] [--out FILE.json]`` —
    JSON for the per-PR bench trajectory (CI's bench-smoke artifact):

      {"schema": "zipage-bench-kernels/v2", "jax": ..., "platform": ...,
       "smoke": bool, "results": [{"name", "backend", "us_per_call"}, ...],
       "long_context": {"seq_lens", "block_size", "max_blocks",
                        "pages_visited", "pages_dense", "pages_ratio"},
       "e2e": {"backend", "wall_s", "tokens", "tokens_per_s", "parity"}}

    v2 adds the ragged decode kernel rows (``ragged_attention`` and the
    4k+ mixed-length ``*_long`` pair) and the ``long_context`` DMA
    footprint summary (pages_visited = sum(ceil(seq_len/b)) vs the dense
    grid's B*max_blocks).

``--smoke`` shrinks shapes/iteration counts so the job stays in CI budget.
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

RNG = np.random.default_rng(8)

BACKENDS = ("jnp", "pallas-interpret")


def timed(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_results(smoke=False):
    """[(name, backend, us_per_call)] over the five kernels × two backends."""
    iters = 1 if smoke else 3
    if smoke:
        B, hq, hkv, d, N, b, mb = 2, 4, 2, 16, 16, 4, 4
    else:
        B, hq, hkv, d, N, b, mb = 4, 8, 2, 32, 32, 8, 8
    q = jnp.asarray(RNG.normal(size=(B, hq, d)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(N, b, hkv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(N, b, hkv, d)), jnp.float32)
    bt = jnp.asarray(np.stack([RNG.choice(N, mb, replace=False)
                               for _ in range(B)]).astype(np.int32))
    sl = jnp.full((B,), mb * b, jnp.int32)
    qw = jnp.asarray(RNG.normal(size=(B, 4, hq, d)), jnp.float32)
    pool = jnp.asarray(RNG.normal(size=(N * b, hkv, d)), jnp.float32)
    n_keep = 12 if smoke else 48           # 48 matches the historical rows
    src = jnp.asarray(np.stack([np.sort(RNG.choice(N * b, n_keep,
                                                   replace=False))
                                for _ in range(hkv)]).astype(np.int32))
    cases = [
        ("paged_attention", ops.paged_decode_attention, (q, kp, vp, bt, sl)),
        ("ragged_attention", ops.ragged_decode_attention,
         (q, kp, vp, bt, sl)),
        ("paged_score", ops.score_logits, (qw, kp, bt, sl)),
        ("lightning_redundancy", ops.lightning_redundancy, (kp, bt, sl)),
        ("flash_redundancy", ops.flash_redundancy, (kp, bt, sl)),
        ("compact_gather", ops.compact_gather, (pool, src)),
    ]
    out = []
    for name, fn, args in cases:
        for backend in BACKENDS:
            us = timed(fn, *args, iters=iters, backend=backend)
            out.append((name, backend, us))
    return out


def long_context_results(smoke=False):
    """Long-context mixed-length decode point (4k+ tokens): dense vs
    ragged at a table width where the dense grid's pool-wide iteration
    hurts, plus the analytic DMA footprint the ragged kernel pays.

    Returns ``(rows, summary)``: rows are (name, backend, us_per_call)
    entries for the results list; the summary carries
    ``pages_visited = sum(ceil(seq_len / b))``, the dense grid's
    ``pages_dense = B * max_blocks`` and their ratio."""
    iters = 1 if smoke else 3
    hq, hkv, d = 8, 2, 32
    b, mb = 64, 64                                   # 4096-token table
    B = 4
    seq_lens = np.array([4096, 512, 64, 0], np.int32)
    N = int(sum(-(-s // b) for s in seq_lens)) + 1   # page 0 stays unused
    rng = np.random.default_rng(17)
    q = jnp.asarray(rng.normal(size=(B, hq, d)), jnp.float32)
    kp = jnp.asarray(rng.normal(size=(N, b, hkv, d)), jnp.float32)
    vp = jnp.asarray(rng.normal(size=(N, b, hkv, d)), jnp.float32)
    bt = np.full((B, mb), -1, np.int32)
    pool = list(rng.permutation(np.arange(1, N)))
    for i, s in enumerate(seq_lens):
        for j in range(-(-int(s) // b)):
            bt[i, j] = pool.pop()
    bt_trim, _width = ops.trim_block_tables(bt, seq_lens, b)
    sl = jnp.asarray(seq_lens)
    rows = []
    for backend in BACKENDS:
        rows.append(("paged_attention_long", backend, timed(
            ops.paged_decode_attention, q, kp, vp, jnp.asarray(bt), sl,
            iters=iters, backend=backend)))
        rows.append(("ragged_attention_long", backend, timed(
            ops.ragged_decode_attention, q, kp, vp, jnp.asarray(bt_trim),
            sl, iters=iters, backend=backend)))
    visited = int(sum(-(-int(s) // b) for s in seq_lens))
    dense = B * mb
    summary = {
        "seq_lens": seq_lens.tolist(), "block_size": b, "max_blocks": mb,
        "pages_visited": visited, "pages_dense": dense,
        "pages_ratio": round(visited / dense, 4),
    }
    return rows, summary


def e2e_result(smoke=False):
    """Serve a small batch on tiny-lm through the public facade with
    ``kernel_backend="pallas-interpret"`` and check parity vs jnp."""
    from repro.api import SamplingParams, Zipage

    n_req, n_out = (2, 8) if smoke else (4, 24)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, 64, size=int(rng.integers(4, 10))).tolist()
               for _ in range(n_req)]
    params = SamplingParams(max_new_tokens=n_out)
    outs = {}
    wall = {}
    for backend in BACKENDS:
        z = Zipage.from_config(
            "tiny-lm", block_size=8, n_total_blocks=64, max_batch=4,
            m_qslots=4, n_max=3, window=4, max_model_len=128,
            prefill_rows=2, prefill_len=32, kernel_backend=backend)
        t0 = time.monotonic()
        outs[backend] = z.generate(prompts, params)
        wall[backend] = time.monotonic() - t0
    parity = all(
        a.token_ids == b.token_ids
        for a, b in zip(outs["jnp"], outs["pallas-interpret"]))
    tokens = sum(o.usage.completion_tokens for o in outs["pallas-interpret"])
    return {
        "backend": "pallas-interpret",
        "wall_s": round(wall["pallas-interpret"], 3),
        "wall_s_jnp": round(wall["jnp"], 3),
        "tokens": tokens,
        "tokens_per_s": round(tokens / wall["pallas-interpret"], 2),
        "parity": parity,
    }


def run():
    """benchmarks.run entry point — legacy CSV rows."""
    return [(f"kernels/{name}/{backend}", us, "")
            for name, backend, us in kernel_results()]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / single iteration (CI bench-smoke)")
    ap.add_argument("--out", default=None, metavar="FILE.json",
                    help="write the JSON report here (default: stdout)")
    ap.add_argument("--no-e2e", action="store_true",
                    help="skip the end-to-end Zipage.generate() run")
    args = ap.parse_args(argv)

    rows = kernel_results(smoke=args.smoke)
    long_rows, long_summary = long_context_results(smoke=args.smoke)
    report = {
        "schema": "zipage-bench-kernels/v2",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "smoke": args.smoke,
        "results": [
            {"name": name, "backend": backend,
             "us_per_call": round(us, 1)}
            for name, backend, us in rows + long_rows
        ],
        "long_context": long_summary,
    }
    if not args.no_e2e:
        report["e2e"] = e2e_result(smoke=args.smoke)
        if not report["e2e"]["parity"]:
            print("ERROR: jnp vs pallas-interpret end-to-end mismatch",
                  file=sys.stderr)
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0 if args.no_e2e or report["e2e"]["parity"] else 1


if __name__ == "__main__":
    sys.exit(main())
