"""Per-kernel micro-bench: Pallas (interpret=True on CPU — correctness-path
cost, NOT TPU perf) vs the jnp reference, plus shapes that matter for the
paper (b=64-style pages scaled down for CPU)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

RNG = np.random.default_rng(8)


def timed(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    B, hq, hkv, d, N, b, mb = 4, 8, 2, 32, 32, 8, 8
    q = jnp.asarray(RNG.normal(size=(B, hq, d)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(N, b, hkv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(N, b, hkv, d)), jnp.float32)
    bt = jnp.asarray(np.stack([RNG.choice(N, mb, replace=False)
                               for _ in range(B)]).astype(np.int32))
    sl = jnp.full((B,), mb * b, jnp.int32)
    for backend in ("jnp", "pallas"):
        us = timed(ops.paged_decode_attention, q, kp, vp, bt, sl,
                   backend=backend)
        rows.append((f"kernels/paged_attention/{backend}", us, ""))
    qw = jnp.asarray(RNG.normal(size=(B, 4, hq, d)), jnp.float32)
    for backend in ("jnp", "pallas"):
        us = timed(ops.score_logits, qw, kp, bt, sl, backend=backend)
        rows.append((f"kernels/paged_score/{backend}", us, ""))
    for backend in ("jnp", "pallas"):
        us = timed(ops.lightning_redundancy, kp, bt, sl, backend=backend)
        rows.append((f"kernels/lightning_redundancy/{backend}", us, ""))
    for backend in ("jnp", "pallas"):
        us = timed(ops.flash_redundancy, kp, bt, sl, backend=backend)
        rows.append((f"kernels/flash_redundancy/{backend}", us, ""))
    pool = jnp.asarray(RNG.normal(size=(N * b, hkv, d)), jnp.float32)
    src = jnp.asarray(np.stack([np.sort(RNG.choice(N * b, 48, replace=False))
                                for _ in range(hkv)]).astype(np.int32))
    for backend in ("jnp", "pallas"):
        us = timed(ops.compact_gather, pool, src, backend=backend)
        rows.append((f"kernels/compact_gather/{backend}", us, ""))
    return rows
