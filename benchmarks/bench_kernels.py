"""Per-kernel micro-bench: Pallas (interpret mode on CPU — correctness-path
cost, NOT TPU perf) vs the jnp reference, plus an end-to-end
``Zipage.generate()`` run with ``kernel_backend="pallas-interpret"`` that
proves the dispatch layer works through the full serving stack.

Usable two ways:

  * ``python -m benchmarks.run bench_kernels`` — legacy CSV rows via
    ``run()`` (name,us_per_call,derived). Same format; row names moved
    from ``kernels/*/pallas`` to the canonical ``kernels/*/pallas-interpret``
    (the measurement is continuous — the old rows already ran interpret
    mode on CPU);
  * ``python -m benchmarks.bench_kernels [--smoke] [--out FILE.json]`` —
    JSON for the per-PR bench trajectory (CI's bench-smoke artifact):

      {"schema": "zipage-bench-kernels/v1", "jax": ..., "platform": ...,
       "smoke": bool, "results": [{"name", "backend", "us_per_call"}, ...],
       "e2e": {"backend", "wall_s", "tokens", "tokens_per_s", "parity"}}

``--smoke`` shrinks shapes/iteration counts so the job stays in CI budget.
"""
import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

RNG = np.random.default_rng(8)

BACKENDS = ("jnp", "pallas-interpret")


def timed(fn, *args, iters=3, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args, **kw))
    return (time.perf_counter() - t0) / iters * 1e6


def kernel_results(smoke=False):
    """[(name, backend, us_per_call)] over the five kernels × two backends."""
    iters = 1 if smoke else 3
    if smoke:
        B, hq, hkv, d, N, b, mb = 2, 4, 2, 16, 16, 4, 4
    else:
        B, hq, hkv, d, N, b, mb = 4, 8, 2, 32, 32, 8, 8
    q = jnp.asarray(RNG.normal(size=(B, hq, d)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(N, b, hkv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(N, b, hkv, d)), jnp.float32)
    bt = jnp.asarray(np.stack([RNG.choice(N, mb, replace=False)
                               for _ in range(B)]).astype(np.int32))
    sl = jnp.full((B,), mb * b, jnp.int32)
    qw = jnp.asarray(RNG.normal(size=(B, 4, hq, d)), jnp.float32)
    pool = jnp.asarray(RNG.normal(size=(N * b, hkv, d)), jnp.float32)
    n_keep = 12 if smoke else 48           # 48 matches the historical rows
    src = jnp.asarray(np.stack([np.sort(RNG.choice(N * b, n_keep,
                                                   replace=False))
                                for _ in range(hkv)]).astype(np.int32))
    cases = [
        ("paged_attention", ops.paged_decode_attention, (q, kp, vp, bt, sl)),
        ("paged_score", ops.score_logits, (qw, kp, bt, sl)),
        ("lightning_redundancy", ops.lightning_redundancy, (kp, bt, sl)),
        ("flash_redundancy", ops.flash_redundancy, (kp, bt, sl)),
        ("compact_gather", ops.compact_gather, (pool, src)),
    ]
    out = []
    for name, fn, args in cases:
        for backend in BACKENDS:
            us = timed(fn, *args, iters=iters, backend=backend)
            out.append((name, backend, us))
    return out


def e2e_result(smoke=False):
    """Serve a small batch on tiny-lm through the public facade with
    ``kernel_backend="pallas-interpret"`` and check parity vs jnp."""
    from repro.api import SamplingParams, Zipage

    n_req, n_out = (2, 8) if smoke else (4, 24)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(1, 64, size=int(rng.integers(4, 10))).tolist()
               for _ in range(n_req)]
    params = SamplingParams(max_new_tokens=n_out)
    outs = {}
    wall = {}
    for backend in BACKENDS:
        z = Zipage.from_config(
            "tiny-lm", block_size=8, n_total_blocks=64, max_batch=4,
            m_qslots=4, n_max=3, window=4, max_model_len=128,
            prefill_rows=2, prefill_len=32, kernel_backend=backend)
        t0 = time.monotonic()
        outs[backend] = z.generate(prompts, params)
        wall[backend] = time.monotonic() - t0
    parity = all(
        a.token_ids == b.token_ids
        for a, b in zip(outs["jnp"], outs["pallas-interpret"]))
    tokens = sum(o.n_tokens for o in outs["pallas-interpret"])
    return {
        "backend": "pallas-interpret",
        "wall_s": round(wall["pallas-interpret"], 3),
        "wall_s_jnp": round(wall["jnp"], 3),
        "tokens": tokens,
        "tokens_per_s": round(tokens / wall["pallas-interpret"], 2),
        "parity": parity,
    }


def run():
    """benchmarks.run entry point — legacy CSV rows."""
    return [(f"kernels/{name}/{backend}", us, "")
            for name, backend, us in kernel_results()]


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / single iteration (CI bench-smoke)")
    ap.add_argument("--out", default=None, metavar="FILE.json",
                    help="write the JSON report here (default: stdout)")
    ap.add_argument("--no-e2e", action="store_true",
                    help="skip the end-to-end Zipage.generate() run")
    args = ap.parse_args(argv)

    report = {
        "schema": "zipage-bench-kernels/v1",
        "jax": jax.__version__,
        "platform": jax.default_backend(),
        "smoke": args.smoke,
        "results": [
            {"name": name, "backend": backend,
             "us_per_call": round(us, 1)}
            for name, backend, us in kernel_results(smoke=args.smoke)
        ],
    }
    if not args.no_e2e:
        report["e2e"] = e2e_result(smoke=args.smoke)
        if not report["e2e"]["parity"]:
            print("ERROR: jnp vs pallas-interpret end-to-end mismatch",
                  file=sys.stderr)
    text = json.dumps(report, indent=2)
    if args.out:
        with open(args.out, "w") as f:
            f.write(text + "\n")
        print(f"wrote {args.out}", file=sys.stderr)
    else:
        print(text)
    return 0 if args.no_e2e or report["e2e"]["parity"] else 1


if __name__ == "__main__":
    sys.exit(main())
