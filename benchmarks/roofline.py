"""§Roofline report generator: reads results/dryrun/*.json into the
per-(arch × shape × mesh × variant) table with the three roofline terms,
bottleneck, and MODEL_FLOPS/HLO ratio."""
import glob
import json
import os

RESULTS = os.environ.get("DRYRUN_DIR",
                         os.path.join(os.path.dirname(__file__), "..",
                                      "results", "dryrun"))


def load_records():
    recs = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        try:
            with open(f) as fh:
                recs.append(json.load(fh))
        except Exception:
            pass
    return recs


def run():
    rows = []
    n_ok = n_skip = n_err = 0
    for r in load_records():
        name = (f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}/"
                f"{r.get('variant', 'baseline')}")
        if r["status"] == "skipped":
            n_skip += 1
            continue
        if r["status"] != "ok":
            n_err += 1
            rows.append((name, 0.0, "status=ERROR"))
            continue
        n_ok += 1
        rf = r["roofline"]
        dom = rf["bottleneck"]
        t_dom = rf[f"t_{dom}_s"]
        derived = (f"bottleneck={dom};t_compute_s={rf['t_compute_s']:.4g};"
                   f"t_memory_s={rf['t_memory_s']:.4g};"
                   f"t_collective_s={rf['t_collective_s']:.4g}")
        if "useful_flops_ratio" in r:
            derived += f";useful_flops={r['useful_flops_ratio']:.3f}"
        rows.append((name, 1e6 * t_dom, derived))
    rows.append(("roofline/summary", 0.0,
                 f"ok={n_ok};skipped={n_skip};errors={n_err}"))
    return rows
