"""Paper Fig. 9 / §5.4: KV-budget sweep — throughput vs quality.

Quality proxy (no pretrained weights offline, DESIGN.md §7): greedy decode
with compressed KV vs full KV on the SAME briefly-trained tiny model;
report top-1 agreement over the generation.
"""
import numpy as np

from benchmarks.common import params_trained, run_engine, workload


def agreement(a, b):
    n = min(len(a), len(b))
    if n == 0:
        return 0.0
    return float(np.mean([a[i] == b[i] for i in range(n)]))


def run():
    rows = []
    rng = np.random.default_rng(3)
    params = params_trained()
    reqs = workload("amc", 12, rng)
    full = run_engine(reqs, params=params, n_max=None)
    ref_out = {r: full["done"][r].token_ids for r in full["rids"]}
    for budget_blocks in (2, 3, 4, 6):
        budget = (budget_blocks - 1) * 8
        r = run_engine(reqs, params=params, n_max=budget_blocks)
        agr = float(np.mean([
            agreement(r["done"][rid].token_ids, ref_out[rid2])
            for rid, rid2 in zip(r["rids"], full["rids"])]))
        rows.append((f"budgets/{budget}tok",
                     1e6 * r["wall_s"] / max(r["steps"], 1),
                     f"steps={r['steps']};tok_per_step="
                     f"{r['tokens_per_step']:.2f};"
                     f"step_speedup_vs_full="
                     f"{full['steps'] / max(r['steps'], 1):.2f};"
                     f"top1_agreement={agr:.3f};"
                     f"compressions={r['compressions']}"))
    rows.append(("budgets/full_kv",
                 1e6 * full["wall_s"] / max(full["steps"], 1),
                 f"steps={full['steps']};tok_per_step="
                 f"{full['tokens_per_step']:.2f};top1_agreement=1.000"))
    return rows
