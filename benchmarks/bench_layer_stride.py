"""Paper Fig. 10 / App. C.1: cross-layer parallel compression.

Layer stride l = how many layers one compression call covers. Larger l
amortizes dispatch and exposes cross-layer parallelism; peak activation
scales O(n·l·h·N·b·w). We time compressing L=8 layers with l ∈ {1,2,4,8}.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import CFG
from repro.core.compression import CompressOptions, build_compress_fn

RNG = np.random.default_rng(6)


def run():
    rows = []
    L, b, mb, n, w, N_total = 8, 8, 8, 4, 4, 64
    h, d, hq = CFG.num_kv_heads, CFG.head_dim, CFG.num_heads
    pools = {
        "k": jnp.asarray(RNG.normal(size=(L, N_total, b, h, d)), jnp.float32),
        "v": jnp.asarray(RNG.normal(size=(L, N_total, b, h, d)), jnp.float32),
        "f": jnp.zeros((L, N_total, b, h), jnp.float32),
    }
    qwin = jnp.asarray(RNG.normal(size=(L, n, w, hq, d)), jnp.float32)
    src = np.stack([RNG.choice(N_total, mb, replace=False)
                    for _ in range(n)]).astype(np.int32)
    req = (jnp.asarray(src), jnp.asarray(src[:, :mb - 1]),
           jnp.arange(n, dtype=jnp.int32),
           jnp.full((n,), mb * b, jnp.int32), jnp.zeros((n,), jnp.int32))
    opts = CompressOptions(window=w, redundancy="lightning", pooling="none")

    for stride in (1, 2, 4, 8):
        fn = jax.jit(build_compress_fn(CFG, block_size=b, max_blocks=mb,
                                       budget_blocks=mb - 1, opts=opts))

        def compress_strided(fn=fn, stride=stride):
            # bind loop vars as defaults: the closure outlives the loop
            outs = []
            for g in range(0, L, stride):
                sub_pools = {k: v[g:g + stride] for k, v in pools.items()}
                outs.append(fn(sub_pools, qwin[g:g + stride], req))
            return outs

        out = compress_strided()
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(compress_strided())
        us = (time.perf_counter() - t0) / 3 * 1e6
        rows.append((f"layer_stride/{stride}", us,
                     f"calls={L // stride}"))
    return rows
