"""Render results/dryrun/*.json into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.make_roofline_md > results/roofline.md
"""
import glob
import json
import os

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def fmt(x, pct=False):
    if x is None:
        return "-"
    return f"{x:.4g}"


def main():
    recs = {}
    skips = []
    for f in sorted(glob.glob(os.path.join(RESULTS, "*.json"))):
        with open(f) as fh:
            j = json.load(fh)
        key = (j["arch"], j["shape"], j["mesh"], j.get("variant", "baseline"))
        recs[key] = j
        if j["status"] == "skipped" and j["variant"] == "baseline":
            skips.append((j["arch"], j["shape"], j["mesh"], j["reason"]))

    print("### Roofline table — baseline, single-pod 16x16 (256 chips)\n")
    print("| arch | shape | t_compute (s) | t_memory (s) | t_collective (s)"
          " | bottleneck | roofline frac | MODEL/HLO flops |")
    print("|---|---|---|---|---|---|---|---|")
    for (arch, shape, mesh, var), j in sorted(recs.items()):
        if mesh != "pod1" or var != "baseline" or j["status"] != "ok":
            continue
        r = j["roofline"]
        dom = r["bottleneck"]
        tdom = r[f"t_{dom}_s"]
        frac = r["t_compute_s"] / tdom if tdom else 0
        uf = j.get("useful_flops_ratio")
        print(f"| {arch} | {shape} | {fmt(r['t_compute_s'])} | "
              f"{fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} | "
              f"{dom} | {frac:.3f} | "
              f"{'%.3f' % uf if uf else '-'} |")

    print("\n### Zipage vs full-KV decode (single-pod; paper's technique)\n")
    print("| arch | full-KV t_mem (s) | zipage t_mem (s) | mem-term speedup"
          " | compress step t_mem (s) |")
    print("|---|---|---|---|---|")
    for (arch, shape, mesh, var), j in sorted(recs.items()):
        if shape != "decode_32k" or mesh != "pod1" or var != "baseline":
            continue
        if j["status"] != "ok":
            continue
        z = recs.get((arch, shape, mesh, "zipage"))
        c = recs.get((arch, shape, mesh, "compress"))
        if not z or z["status"] != "ok":
            continue
        t0 = j["roofline"]["t_memory_s"]
        t1 = z["roofline"]["t_memory_s"]
        tc = c["roofline"]["t_memory_s"] if c and c["status"] == "ok" else None
        print(f"| {arch} | {fmt(t0)} | {fmt(t1)} | {t0 / t1:.2f}x | "
              f"{fmt(tc)} |")

    print("\n### Multi-pod (2x16x16 = 512 chips) — baseline deltas\n")
    print("| arch | shape | pod1 dominant (s) | pod2 dominant (s) |"
          " pod2/pod1 |")
    print("|---|---|---|---|---|")
    for (arch, shape, mesh, var), j in sorted(recs.items()):
        if mesh != "pod1" or var != "baseline" or j["status"] != "ok":
            continue
        j2 = recs.get((arch, shape, "pod2", "baseline"))
        if not j2 or j2["status"] != "ok":
            continue
        d1 = j["roofline"][f"t_{j['roofline']['bottleneck']}_s"]
        d2 = j2["roofline"][f"t_{j2['roofline']['bottleneck']}_s"]
        print(f"| {arch} | {shape} | {fmt(d1)} | {fmt(d2)} | "
              f"{d2 / d1:.2f} |")

    print("\n### Skipped cells (per assignment rules)\n")
    seen = set()
    for arch, shape, _mesh, reason in skips:
        if (arch, shape) in seen:
            continue
        seen.add((arch, shape))
        print(f"* `{arch}` × `{shape}`: {reason}")


if __name__ == "__main__":
    main()
