"""Production meshes. Functions, not module constants — importing this module
never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data", "model").
    Multi-pod: 2x16x16 = 512 chips ("pod", "data", "model"); the "pod" axis
    crosses DCI and carries the (optionally int8-compressed) gradient hop."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(shape=None, axes=None):
    """Small mesh over whatever devices exist (tests, examples)."""
    n = len(jax.devices())
    if shape is None:
        shape, axes = (n,), ("data",)
    return jax.make_mesh(shape, axes)
