"""Training launcher: mesh setup, sharding, checkpoint/restart, train loop.

Runs for real on whatever devices exist (CPU tests use a (1,1) or fake-device
mesh) and is the same assembly the dry-run lowers for the production meshes.

  PYTHONPATH=src python -m repro.launch.train --arch tiny-lm --steps 50 \
      --seq-len 64 --global-batch 8 --ckpt-dir /tmp/ck --ckpt-every 20
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models import lm
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.data import DataConfig, batch_at
from repro.training.train_loop import build_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--reduced", action="store_true",
                    help="use the family-preserving reduced config")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--vocab-chunk", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--mesh", default="host",
                    choices=["host", "pod1", "pod2"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = (make_host_mesh() if args.mesh == "host"
            else make_production_mesh(multi_pod=args.mesh == "pod2"))
    adamw = opt.AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                            total_steps=args.steps)
    dc = DataConfig(seq_len=args.seq_len, global_batch=args.global_batch,
                    vocab_size=cfg.vocab_size, seed=args.seed)
    step_fn = build_train_step(cfg, adamw, accum_steps=args.accum,
                               vocab_chunk=args.vocab_chunk)

    params = lm.init(cfg, jax.random.key(args.seed))
    opt_state = opt.init_opt_state(params)
    p_sh = shd.param_shardings(cfg, params, mesh)
    o_sh = shd.zero1_shardings(cfg, params, mesh)
    rep = NamedSharding(mesh, P())
    m_sh = {"loss": rep, "grad_norm": rep, "lr": rep}

    def step3(p, o, b):
        pp, oo, _, m = step_fn(p, o, None, b)
        return pp, oo, m

    start_step = 0
    if args.ckpt_dir:
        os.makedirs(args.ckpt_dir, exist_ok=True)
        last = ckpt.latest_step(args.ckpt_dir)
        if last is not None:
            restored, extra = ckpt.restore(
                args.ckpt_dir, last, {"params": params, "opt": opt_state},
                shardings={"params": p_sh, "opt": o_sh})
            params, opt_state = restored["params"], restored["opt"]
            start_step = extra["data_step"]
            print(f"[train] restored step {start_step} from {args.ckpt_dir}")

    params = jax.device_put(params, p_sh)
    opt_state = jax.device_put(opt_state, o_sh)
    batch0 = jax.tree.map(jnp.asarray, batch_at(dc, 0))
    b_sh = shd.batch_shardings(mesh, batch0)
    jstep = jax.jit(step3, in_shardings=(p_sh, o_sh, b_sh),
                    out_shardings=(p_sh, o_sh, m_sh), donate_argnums=(0, 1))

    t0 = time.time()
    for i in range(start_step, args.steps):
        batch = jax.device_put(
            jax.tree.map(jnp.asarray, batch_at(dc, i)), b_sh)
        params, opt_state, m = jstep(params, opt_state, batch)
        if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i + 1,
                      {"params": params, "opt": opt_state},
                      extra={"data_step": i + 1})
        if (i + 1) % args.log_every == 0 or i == start_step:
            print(f"[train] step {i + 1} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"lr {float(m['lr']):.2e} "
                  f"({(time.time() - t0) / (i - start_step + 1):.2f} s/step)",
                  flush=True)
    print(f"[train] done: final loss {float(m['loss']):.4f}")
    return float(m["loss"])


if __name__ == "__main__":
    main()
