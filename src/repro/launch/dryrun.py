import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST run before any jax import: jax locks the device count on first init.
# (No `from __future__` here: the env var lines above must stay first.)
_DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell
on the production meshes and extract memory/cost/collective roofline terms.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape decode_32k \
      --mesh pod1 [--variant zipage] [--out out.json]
  python -m repro.launch.dryrun --all --out-dir results/dryrun   # subprocess per cell
  python -m repro.launch.dryrun --list

Variants:
  baseline  : the shape's own step (train/prefill/full-KV decode)
  zipage    : decode with the paper's block cap (budget 2048 tokens) —
              bounded pool + compress_step lowered alongside
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, cell_applicable, get_config
from repro.configs.base import ShapeCell
from repro.core import serve_model
from repro.core.compression import CompressOptions, build_compress_fn
from repro.distributed import roofline as rl
from repro.distributed import sharding as shd
from repro.kernels import pallas_compat
from repro.launch.mesh import make_production_mesh
from repro.models import lm
from repro.training import optimizer as opt
from repro.training.data import DataConfig, batch_specs
from repro.training.train_loop import build_train_step

BLOCK_SIZE = 64          # TPU-native page (DESIGN.md §3)
BUDGET_TOKENS = 2048     # paper's main KV budget
WINDOW = 16

ARCHS = [
    "recurrentgemma-2b", "deepseek-v2-lite-16b", "dbrx-132b", "llama3-8b",
    "nemotron-4-15b", "olmo-1b", "qwen2.5-3b", "rwkv6-3b", "whisper-tiny",
    "internvl2-26b",
]


def data_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def n_replicas(mesh):
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))


# ----------------------------------------------------------------------
def frontend_specs(cfg, B, dtype=jnp.bfloat16):
    out = {}
    if cfg.frontend == "vision_stub":
        out["prefix_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_prefix_embeds, cfg.d_model), dtype)
    if cfg.frontend == "audio_stub":
        out["frame_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.cross_seq_len, cfg.d_model), jnp.float32)
    return out


def input_specs(cfg, cell: ShapeCell):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    if cell.kind == "train":
        dc = DataConfig(seq_len=cell.seq_len, global_batch=cell.global_batch,
                        vocab_size=cfg.vocab_size)
        return batch_specs(dc, extra=frontend_specs(cfg, cell.global_batch))
    if cell.kind == "prefill":
        B, S = cell.global_batch, cell.seq_len
        out = {
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "slot_ids": jax.ShapeDtypeStruct((B,), jnp.int32),
            "lengths": jax.ShapeDtypeStruct((B,), jnp.int32),
            "start_pos": jax.ShapeDtypeStruct((B,), jnp.int32),
        }
        out.update(frontend_specs(cfg, B))
        return out
    # decode
    B = cell.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "active": jax.ShapeDtypeStruct((B,), jnp.bool_),
    }


def make_serve_spec(cfg, cell: ShapeCell, mesh, variant):
    b = BLOCK_SIZE
    B = cell.global_batch
    tp = mesh.shape.get("model", 1)
    kv_rep = 1
    if (cfg.num_kv_heads and not cfg.attention_free
            and cfg.attn_type != "mla"
            and tp > cfg.num_kv_heads and tp % cfg.num_kv_heads == 0):
        r = tp // cfg.num_kv_heads
        # q-head groups must stay aligned to stored slots
        if cfg.num_heads % (cfg.num_kv_heads * r) == 0:
            kv_rep = r
    prefix = cfg.num_prefix_embeds if cfg.frontend == "vision_stub" else 0
    if cfg.local_window:
        mb = cfg.local_window // b
    elif variant == "zipage" and cell.kind == "decode":
        mb = BUDGET_TOKENS // b + 1          # N_max blocks (budget + reserve)
    else:
        mb = -(-(cell.seq_len + prefix) // b)
    n_total = max(B * mb, 1)
    # Page-streaming (chunked) attention is the production default for all
    # decode cells (§Perf iteration C: 2.1x on full-KV decode; neutral at
    # the zipage budget where decode is weight-bound). Set
    # DRYRUN_GATHER_ATTN=1 to reproduce the pre-C gather numbers.
    attn_impl = "jnp" if os.environ.get("DRYRUN_GATHER_ATTN") else "chunked"
    return serve_model.ServeSpec(
        n_slots=B, block_size=b, max_blocks=mb, n_total_blocks=n_total,
        m_qslots=B, window=WINDOW, prefill_rows=B, prefill_len=cell.seq_len,
        dtype="bfloat16", kv_replication=kv_rep, attn_backend=attn_impl)


def serve_pspecs(cfg, state_tree, daxes, replicate_batch, *, mesh=None,
                 with_model=False):
    """Serving-state specs. ``with_model=False``: manual shard_map specs
    (data axes only). ``with_model=True``: jit-level specs — additionally
    shard head/feature dims over the auto "model" axis where divisible
    (pools' h_store dim, qwin's h_q dim, MLA latent width, rwkv heads)."""
    spec = None if replicate_batch or not daxes else \
        (daxes if len(daxes) > 1 else daxes[0])
    tp = mesh.shape.get("model", 1) if (mesh and with_model) else 1

    def mdl(dim_size):
        return "model" if (tp > 1 and dim_size % tp == 0) else None

    def one(key, leaf):
        name = key.split("/")[-1]
        nd = len(leaf.shape)
        sh = leaf.shape
        if name in ("k", "v") and nd == 5:            # (L, N, b, h, d)
            return P(None, spec, None, mdl(sh[3]), None)
        if name == "f" and nd == 4:                   # (L, N, b, h)
            return P(None, spec, None, mdl(sh[3]))
        if name == "kv" and nd == 4:                  # MLA (L, N, b, e)
            return P(None, spec, None, mdl(sh[3]))
        if "qwin" in key:                             # (L, M, w, hq, dq)
            return P(None, spec, None, mdl(sh[3]), None)
        if "cross_kv" in key:                         # (L, B, S, h, d)
            return P(None, spec, None, mdl(sh[3]), None)
        if key.startswith("rec"):
            if name == "S":                           # (L, B, h, K, K)
                return P(None, spec, mdl(sh[2]), None, None)
            if name in ("h", "shift"):                # (L, B, w|d)
                return P(None, spec, mdl(sh[2]))
            if name == "conv":                        # (L, B, cw-1, w)
                return P(None, spec, None, mdl(sh[3]))
            return P(*([None, spec] + [None] * (nd - 2)))
        if name in ("block_tables", "seq_lens", "positions", "qslot"):
            return P(*([spec] + [None] * (nd - 1)))
        return P()

    flat, treedef = jax.tree_util.tree_flatten(state_tree)
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(state_tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append(one(key, leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


# ----------------------------------------------------------------------
def lower_train(cfg, cell, mesh):
    adamw = opt.AdamWConfig()
    step = build_train_step(cfg, adamw, accum_steps=1, vocab_chunk=512)
    params_s = lm.param_specs(cfg)
    opt_s = jax.eval_shape(lambda: opt.init_opt_state(params_s))
    batch_s = input_specs(cfg, cell)
    p_sh = shd.param_shardings(cfg, params_s, mesh)
    o_sh = shd.zero1_shardings(cfg, params_s, mesh)
    b_sh = shd.batch_shardings(mesh, batch_s)
    rep = NamedSharding(mesh, P())
    out_sh = (p_sh, o_sh, None, {"loss": rep, "grad_norm": rep, "lr": rep})

    def step_no_err(params, opt_state, batch):
        p, o, _, m = step(params, opt_state, None, batch)
        return p, o, m

    jitted = jax.jit(step_no_err,
                     in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, out_sh[3]),
                     donate_argnums=(0, 1))
    from repro.models.moe_ctx import moe_partitioning
    daxes = data_axes(mesh)
    dspec = daxes if len(daxes) > 1 else daxes[0]
    with pallas_compat.mesh_context(mesh), \
            moe_partitioning(n_replicas(mesh),
                             P(dspec, "model", None, None)):
        lowered = jitted.lower(params_s, opt_s, batch_s)
        compiled = lowered.compile()
    return lowered, compiled


def lower_serve(cfg, cell, mesh, variant):
    spec = make_serve_spec(cfg, cell, mesh, variant)
    daxes = data_axes(mesh)
    B = cell.global_batch
    replicate_batch = (B % n_replicas(mesh)) != 0
    state_s = jax.eval_shape(lambda: serve_model.make_state(cfg, spec))
    st_p = serve_pspecs(cfg, state_s, daxes, replicate_batch)
    st_jit = serve_pspecs(cfg, state_s, daxes, replicate_batch, mesh=mesh,
                          with_model=True)
    bspec = None if replicate_batch else \
        (daxes if len(daxes) > 1 else daxes[0])
    params_s = lm.param_specs(cfg)
    p_sh = shd.param_shardings(cfg, params_s, mesh)
    p_p = jax.tree.map(lambda _: P(), params_s)
    ins = input_specs(cfg, cell)

    if cell.kind == "decode":
        step = serve_model.build_decode_step(cfg, spec)
        in_specs = (p_p, st_p, P(bspec), P(bspec))
        out_specs = (P(bspec), st_p)
        args = (params_s, state_s, ins["tokens"], ins["active"])
    else:
        base_step = serve_model.build_prefill_step(cfg, spec)
        extra = [k for k in ("prefix_embeds", "frame_embeds") if k in ins]

        def step(params, state, tokens, slot_ids, lengths, start_pos,
                 *fe):
            kw = dict(zip(extra, fe))
            return base_step(params, state, tokens, slot_ids, lengths,
                             start_pos, **kw)
        in_specs = (p_p, st_p, P(bspec), P(bspec), P(bspec), P(bspec)) + \
            tuple(P(bspec) for _ in extra)
        out_specs = (P(bspec), st_p)
        args = (params_s, state_s, ins["tokens"], ins["slot_ids"],
                ins["lengths"], ins["start_pos"]) + \
            tuple(ins[k] for k in extra)

    smap = pallas_compat.shard_map(step, mesh=mesh, in_specs=in_specs,
                                   out_specs=out_specs,
                                   axis_names=frozenset(daxes), check=False)
    st_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), st_jit)
    arg_sh = [p_sh, st_sh] + [NamedSharding(mesh, s) for s in in_specs[2:]]
    jitted = jax.jit(smap, in_shardings=tuple(arg_sh), donate_argnums=(1,))
    from repro.models import moe_ctx
    tok = None
    if cfg.attn_type == "mla":
        nd = 3 if cell.kind == "decode" else 4     # (B,[S],hq,e)
        tok = moe_ctx.mla_q_spec.set(P(*([None] * (nd - 1) + ["model"])))
    try:
        with pallas_compat.mesh_context(mesh):
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
    finally:
        if tok is not None:
            moe_ctx.mla_q_spec.reset(tok)
    return lowered, compiled


def lower_compress(cfg, cell, mesh):
    """Zipage compression step at the decode cell's scale."""
    spec = make_serve_spec(cfg, cell, mesh, "zipage")
    daxes = data_axes(mesh)
    reps = n_replicas(mesh)
    bucket = max(reps, 1)
    state_s = jax.eval_shape(lambda: serve_model.make_state(cfg, spec))
    budget_blocks = spec.max_blocks - 1
    fn = build_compress_fn(cfg, block_size=spec.block_size,
                           max_blocks=spec.max_blocks,
                           budget_blocks=budget_blocks,
                           opts=CompressOptions(window=WINDOW))
    pools_s = state_s["pools"]
    qwin_s = state_s["qwin"]
    req_s = (
        jax.ShapeDtypeStruct((bucket, spec.max_blocks), jnp.int32),
        jax.ShapeDtypeStruct((bucket, budget_blocks), jnp.int32),
        jax.ShapeDtypeStruct((bucket,), jnp.int32),
        jax.ShapeDtypeStruct((bucket,), jnp.int32),
        jax.ShapeDtypeStruct((bucket,), jnp.int32),
    )
    dspec = daxes if len(daxes) > 1 else daxes[0]
    pool_p = jax.tree.map(lambda s: P(None, dspec), pools_s)
    qwin_p = P(None, dspec)
    req_p = (P(dspec), P(dspec), P(dspec), P(dspec), P(dspec))
    smap = pallas_compat.shard_map(fn, mesh=mesh,
                                   in_specs=(pool_p, qwin_p, req_p),
                                   out_specs=(pool_p, P(dspec), P(dspec)),
                                   axis_names=frozenset(daxes), check=False)
    jitted = jax.jit(smap, donate_argnums=(0,))
    with pallas_compat.mesh_context(mesh):
        lowered = jitted.lower(pools_s, qwin_s, req_s)
        compiled = lowered.compile()
    return lowered, compiled


# ----------------------------------------------------------------------
def run_cell(arch, shape_name, mesh_name, variant="baseline"):
    cfg = get_config(arch)
    cell = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, cell)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "variant": variant, "status": "skipped", "reason": why}
    if variant in ("zipage", "compress") and (
            cell.kind != "decode" or cfg.attention_free or cfg.local_window):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "variant": variant, "status": "skipped",
                "reason": f"{variant} variant applies to full-attention "
                          "decode shapes only"}
    mesh = make_production_mesh(multi_pod=(mesh_name == "pod2"))
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    if cell.kind == "train":
        lowered, compiled = lower_train(cfg, cell, mesh)
    elif variant == "compress":
        lowered, compiled = lower_compress(cfg, cell, mesh)
    else:
        lowered, compiled = lower_serve(cfg, cell, mesh, variant)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    roof = rl.from_compiled(compiled, chips, hlo_text=hlo)
    coll = rl.collective_bytes(hlo)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "variant": variant, "status": "ok", "chips": chips,
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline": roof.as_dict(),
        "collectives": coll,
    }
    if cell.kind == "train":
        tokens = cell.seq_len * cell.global_batch
        mf = rl.model_flops_per_token(cfg) * tokens / chips
        rec["model_flops_per_chip"] = mf
        rec["useful_flops_ratio"] = mf / max(roof.flops, 1.0)
    return rec


def cells(variants=("baseline", "zipage", "compress")):
    for arch in ARCHS:
        for shape in SHAPES:
            for mesh in ("pod1", "pod2"):
                for v in variants:
                    yield arch, shape, mesh, v


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod1", choices=["pod1", "pod2"])
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "zipage", "compress"])
    ap.add_argument("--out")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for c in cells():
            print(*c)
        return

    if args.all:
        os.makedirs(args.out_dir, exist_ok=True)
        todo = list(cells())
        for arch, shape, mesh, v in todo:
            name = f"{arch}__{shape}__{mesh}__{v}.json"
            path = os.path.join(args.out_dir, name)
            if os.path.exists(path):
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--mesh", mesh,
                   "--variant", v, "--out", path]
            print(">>", name, flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=3600)
            if r.returncode != 0:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape, "mesh": mesh,
                               "variant": v, "status": "error",
                               "error": r.stderr[-2000:]}, f, indent=1)
                print("   ERROR", r.stderr.splitlines()[-1] if r.stderr
                      else "?", flush=True)
        return

    try:
        rec = run_cell(args.arch, args.shape, args.mesh, args.variant)
    except Exception:
        rec = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "variant": args.variant, "status": "error",
               "error": traceback.format_exc()[-4000:]}
    js = json.dumps(rec, indent=1, default=str)
    print(js)
    if args.out:
        with open(args.out, "w") as f:
            f.write(js)
    if rec["status"] == "error":
        sys.exit(1)


if __name__ == "__main__":
    main()
