"""Serving launcher: builds a Zipage facade and runs a synthetic workload.

  PYTHONPATH=src python -m repro.launch.serve --arch tiny-lm \
      --workload amc --n-requests 16 --budget 24
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from repro.api import SamplingParams, Zipage


def synth_workload(kind, n, vocab, rng):
    """Paper's three workload shapes (§5.2): amc = short-in/long-out,
    gsm = short/short, long = long-in/short-out, mix = amc+gsm."""
    reqs = []
    for i in range(n):
        if kind == "amc":
            p, o = rng.integers(8, 24), int(rng.integers(48, 96))
        elif kind == "gsm":
            p, o = rng.integers(8, 24), int(rng.integers(8, 24))
        elif kind == "long":
            p, o = rng.integers(64, 120), int(rng.integers(8, 24))
        else:  # mix
            if i % 2:
                p, o = rng.integers(8, 24), int(rng.integers(48, 96))
            else:
                p, o = rng.integers(8, 24), int(rng.integers(8, 24))
        prompt = rng.integers(0, vocab, size=int(p)).tolist()
        reqs.append((prompt, o))
    return reqs


def run_engine(arch, reqs, *, reduce=False, **opts):
    base = dict(block_size=8, n_total_blocks=192, max_batch=12, m_qslots=6,
                n_max=4, window=4, max_model_len=256, prefill_rows=4,
                prefill_len=128)
    base.update(opts)
    z = Zipage.from_config(arch, reduce=reduce, **base)
    t0 = time.monotonic()
    outs = z.generate([p for p, _o in reqs],
                      [SamplingParams(max_new_tokens=o) for _p, o in reqs],
                      max_steps=5000)
    dt = time.monotonic() - t0
    toks = sum(o.usage.completion_tokens for o in outs)
    return {"engine": z, "tps": toks / dt, "wall_s": dt,
            "tokens": toks, "steps": z.step_count,
            "outputs": {o.request_id: o.token_ids for o in outs}}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-lm")
    ap.add_argument("--workload", default="amc",
                    choices=["amc", "gsm", "long", "mix"])
    ap.add_argument("--n-requests", type=int, default=16)
    ap.add_argument("--budget", type=int, default=24,
                    help="KV budget in tokens ((n_max-1)*block_size)")
    ap.add_argument("--full-kv", action="store_true",
                    help="disable compression (nano-vllm baseline)")
    ap.add_argument("--no-async", dest="asyncc", action="store_false")
    ap.add_argument("--scheduling", default="hybrid",
                    choices=["hybrid", "constrained"])
    ap.add_argument("--no-prefix", dest="prefix", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config
    vocab = get_config(args.arch).vocab_size
    rng = np.random.default_rng(args.seed)
    reqs = synth_workload(args.workload, args.n_requests, vocab, rng)
    n_max = None if args.full_kv else (args.budget // 8 + 1)
    res = run_engine(args.arch, reqs, reduce=args.arch != "tiny-lm",
                     n_max=n_max, async_compression=args.asyncc,
                     scheduling=args.scheduling,
                     prefix_caching=args.prefix)
    z = res.pop("engine")
    res.pop("outputs")
    res["compressions"] = sum(m["n_compressing"] for m in z.metrics)
    res["peak_running"] = max(m["n_running"] for m in z.metrics)
    res["mean_block_util"] = float(np.mean([m["block_util"]
                                            for m in z.metrics]))
    print(json.dumps(res, indent=1))
    return res


if __name__ == "__main__":
    main()
