"""Roofline-term extraction from compiled dry-run artifacts.

  compute   = HLO_FLOPs / (chips * peak_flops)
  memory    = HLO_bytes / (chips * hbm_bw)
  collective= collective_bytes / (chips * ici_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``; collective bytes are NOT
reported there, so we parse the optimized HLO and sum the result-shape bytes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute (documented approximation: result bytes ~ wire bytes per
chip for AR/AG; RS wire bytes are result*world which we scale in-parser).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI (task spec).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[\w\[\],{}\/ ]+?))\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"[.\w]*\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-kind result-shape bytes summed over all collective ops."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape, kind = m.group(1), m.group(2)
        b = _shape_bytes(shape)
        out[kind] = out.get(kind, 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    coll_bytes: float
    chips: int
    per_device: bool = True       # all terms are per-device post-SPMD
    raw_cost_analysis: dict = None
    coll_detail: dict = None

    @property
    def t_compute(self):
        # cost_analysis FLOPs are already per-partition after SPMD
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self):
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self):
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self):
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    def as_dict(self):
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "coll_bytes": self.coll_bytes, "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "raw_cost_analysis": self.raw_cost_analysis,
            "coll_detail": self.coll_detail,
        }


def from_compiled(compiled, chips, hlo_text=None) -> Roofline:
    """Primary numbers come from the loop-aware HLO analyzer (hlo_cost) —
    XLA's cost_analysis counts while bodies once and under-reports scanned
    models by the trip count (see hlo_cost docstring). Post-SPMD shapes are
    per-partition, so all terms are per-chip."""
    from repro.distributed import hlo_cost
    text = hlo_text if hlo_text is not None else compiled.as_text()
    la = hlo_cost.analyze(text)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    r = Roofline(flops=la["flops"], bytes_accessed=la["bytes"],
                 coll_bytes=la["collectives"].get("total", 0.0),
                 chips=chips)
    r.raw_cost_analysis = {"flops": float(ca.get("flops", 0.0)),
                           "bytes": float(ca.get("bytes accessed", 0.0))}
    r.coll_detail = la["collectives"]
    return r


def model_flops_per_token(cfg) -> float:
    """6·N_active·D training FLOPs per token (fwd+bwd); fwd-only = 2·N."""
    return 6.0 * cfg.active_param_count()
