"""Sharding rules: logical param axes -> mesh PartitionSpecs with
divisibility fallbacks.

Rules are name+shape driven so they survive the stacked-stage layout (rules
apply to TRAILING dims; leading scan/stack dims stay unsharded). When a
tensor's natural TP sharding is invalid for an arch (recurrentgemma's 10
q-heads on a 16-way model axis, whisper's 6 heads, rwkv6's 40 wkv heads),
the rule falls back per-tensor — FFN/vocab still shard while attention
replicates — instead of failing the arch (DESIGN.md §5).

ZeRO-1: optimizer moments take the param spec plus the first still-open,
divisible dim sharded over the data axes.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_size(mesh, name):
    return mesh.shape[name] if name in mesh.shape else 1


def _tp(mesh):
    return _axis_size(mesh, "model")


def _data_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_spec(cfg, path: str, shape, mesh) -> P:
    """PartitionSpec for one param leaf (trailing-dims semantics)."""
    tp = _tp(mesh)
    nd = len(shape)
    name = path.split("/")[-1]
    parent = path.split("/")[-2] if "/" in path else ""

    def spec_on(dim_from_end, ok):
        if not ok or tp == 1:
            return P()
        dim = nd + dim_from_end
        if shape[dim] % tp != 0:
            return P()
        out = [None] * nd
        out[dim] = "model"
        return P(*out)

    hq, hkv = cfg.num_heads, cfg.num_kv_heads
    heads_ok = hq and hq % tp == 0
    kv_ok = hkv and hkv % tp == 0

    if name in ("embed", "unembed"):
        # vocab dim over model (logit/embedding parallelism)
        vdim = 0 if name == "embed" else 1
        if shape[vdim] % tp == 0 and tp > 1:
            out = [None] * nd
            out[vdim] = "model"
            return P(*out)
        return P()
    if parent in ("moe", "shared"):
        if name in ("w1", "w3", "w2") and parent == "moe":
            # experts over model (EP)
            edim = nd - 3
            if shape[edim] % tp == 0 and tp > 1:
                out = [None] * nd
                out[edim] = "model"
                return P(*out)
            return P()
        if name in ("w1", "w3"):
            return spec_on(-1, True)
        if name == "w2":
            return spec_on(-2, True)
        return P()
    if name in ("wq",):
        return spec_on(-1, heads_ok)
    if name in ("wk", "wv"):
        return spec_on(-1, kv_ok)
    if name in ("w_uk", "w_uv"):
        return spec_on(-1, heads_ok)
    if name == "wo":
        return spec_on(-2, heads_ok)
    if name in ("bq",):
        return spec_on(-1, heads_ok)
    if name in ("bk", "bv"):
        return spec_on(-1, kv_ok)
    if name in ("w1", "w3"):                   # dense ffn
        return spec_on(-1, True)
    if name == "w2":
        return spec_on(-2, True)
    if name in ("wx", "wy_gate", "conv_w"):    # rg-lru channels
        return spec_on(-1, True)
    if name in ("w_r", "w_k", "w_v", "w_g", "w_lora_b"):   # rwkv mixer
        # NOTE (§Perf iteration A2, REFUTED): replicating these to kill the
        # head-misalignment collectives made the memory term 6x WORSE
        # (replicated chunk-scan compute on every model rank) without
        # removing the collectives. Sharded is the better operating point.
        return spec_on(-1, True)
    if name == "w_o":
        return spec_on(-2, True)
    return P()                                  # norms, gates, router, ...


def _leaf_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


def param_shardings(cfg, param_tree, mesh):
    """Pytree of NamedSharding matching ``param_tree`` (shapes or arrays)."""
    flat, treedef = jax.tree_util.tree_flatten(param_tree)
    specs = []
    for key, leaf in _leaf_paths(param_tree):
        specs.append(NamedSharding(
            mesh, param_spec(cfg, key, leaf.shape, mesh)))
    return jax.tree_util.tree_unflatten(treedef, specs)


def zero1_shardings(cfg, param_tree, mesh):
    """Optimizer-moment shardings: param spec + first open divisible dim
    over the data axes (ZeRO-1)."""
    daxes = _data_axes(mesh)
    dsize = int(np.prod([mesh.shape[a] for a in daxes])) if daxes else 1

    def one(key, leaf):
        base = param_spec(cfg, key, leaf.shape, mesh)
        parts = list(base) + [None] * (len(leaf.shape) - len(base))
        if dsize > 1:
            for i, (s, pspec) in enumerate(zip(leaf.shape, parts)):
                if pspec is None and s % dsize == 0 and s >= dsize:
                    parts[i] = daxes if len(daxes) > 1 else daxes[0]
                    break
        return NamedSharding(mesh, P(*parts))

    flat, treedef = jax.tree_util.tree_flatten(param_tree)
    specs = [one(key, leaf) for key, leaf in _leaf_paths(param_tree)]
    moments = jax.tree_util.tree_unflatten(treedef, specs)
    return {"m": moments, "v": moments,
            "step": NamedSharding(mesh, P())}


def batch_shardings(mesh, batch_tree):
    """Batch dim over all data axes."""
    daxes = _data_axes(mesh)
    spec = daxes if len(daxes) > 1 else (daxes[0] if daxes else None)

    def one(leaf):
        parts = [spec] + [None] * (len(leaf.shape) - 1)
        return NamedSharding(mesh, P(*parts))

    return jax.tree.map(one, batch_tree)


# ----------------------------------------------------------------------
# serving state shardings

def serve_state_shardings(cfg, state_tree, mesh, *, replicate_batch=False):
    """Paged pools shard their block dim over the data axes (each data shard
    is an independent serving replica owning its pool segment); per-slot
    arrays shard the slot dim; weights keep their TP sharding at the jit
    level. ``replicate_batch`` (long_500k, batch=1) replicates instead."""
    daxes = _data_axes(mesh)
    spec = None if replicate_batch or not daxes else \
        (daxes if len(daxes) > 1 else daxes[0])

    def one(key, leaf):
        name = key.split("/")[-1]
        nd = len(leaf.shape)
        if name in ("k", "v", "f", "kv") and "pools" in key:
            parts = [None, spec] + [None] * (nd - 2)     # (L, N, ...)
        elif "qwin" in key:
            parts = [None, spec] + [None] * (nd - 2)     # (L, M, ...)
        elif "cross_kv" in key or "rec" in key.split("/")[0]:
            parts = [None, spec] + [None] * (nd - 2)     # (L, B, ...)
        elif name in ("block_tables", "seq_lens", "positions", "qslot"):
            parts = [spec] + [None] * (nd - 1)
        else:
            parts = [None] * nd
        return NamedSharding(mesh, P(*parts))

    flat, treedef = jax.tree_util.tree_flatten(state_tree)
    specs = [one(key, leaf) for key, leaf in _leaf_paths(state_tree)]
    return jax.tree_util.tree_unflatten(treedef, specs)


def sharding_summary(cfg, param_tree, mesh, max_rows=0):
    """Human-readable table of param shardings + replication fallbacks."""
    rows, fallbacks = [], 0
    for key, leaf in _leaf_paths(param_tree):
        spec = param_spec(cfg, key, leaf.shape, mesh)
        sharded = any(s is not None for s in spec)
        if not sharded and np.prod(leaf.shape) > 1_000_000:
            fallbacks += 1
        rows.append((key, leaf.shape, tuple(spec)))
    if max_rows:
        rows = rows[:max_rows]
    return rows, fallbacks
