"""Loop-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE
(verified: a 10-iteration scan of a 4.2-MFLOP matmul reports 4.2 MFLOPs).
Our models scan over layers, loss chunks and attention chunks, so raw
numbers under-report by 1-2 orders of magnitude. This module re-derives
flops / bytes / collective-bytes from ``compiled.as_text()`` with loop trip
counts honored (``backend_config known_trip_count``, emitted by XLA for all
lax.scan loops).

Scope (documented approximations):
  * flops: dot ops only (2 · prod(result) · contracted); elementwise ops are
    negligible next to matmuls for these models;
  * bytes: operand+result bytes of ops in *execution* computations (entry,
    while bodies, conditional branches); fusion internals excluded — this
    mirrors XLA's bytes-accessed definition post-fusion;
  * collective bytes: result-shape bytes × kind factor (all-reduce 2×,
    others 1×) — ring-algorithm wire traffic per chip.
Shapes in the post-SPMD module are per-partition, so every number is
per-chip.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_OP_LHS = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_OP_KIND = re.compile(r"(?<!%)\b([a-z][\w\-]*)\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CDIM_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*(\(?[^,)]*(?:\[[\d,]*\])[^,)]*\)?)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "ragged-all-to-all")
_WIRE_FACTOR = {"all-reduce": 2.0}


def _shape_elems_bytes(shape_str):
    total_b = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total_b += n * _DTYPE_BYTES[dt]
    return total_b


def _shape_dims(shape_str):
    """First array shape's dims (for dot result/operands)."""
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    kind: str
    rest: str


@dataclasses.dataclass
class Comp:
    name: str
    ops: list
    shapes: dict                      # value name -> shape str
    is_fusion_target: bool = False


def parse_module(text: str):
    comps = {}
    cur = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip()) if "{" in line else None
            if m and "->" in line:
                cur = Comp(m.group(1), [], {})
                # parameter shapes from header
                inner = line[line.find("(") + 1:line.rfind(")->")
                             if ")->" in line else line.rfind(") ->")]
                for pm in _PARAM_RE.finditer(inner):
                    cur.shapes[pm.group(1)] = pm.group(2)
            continue
        s = line.strip()
        if s == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_LHS.match(line)
        if m:
            rhs = m.group(2)
            km = _OP_KIND.search(rhs)
            if not km:
                continue
            op = Op(m.group(1), rhs[:km.start()].strip(), km.group(1),
                    rhs[km.end():])
            cur.ops.append(op)
            cur.shapes[op.name] = op.shape
    return comps


def _dot_flops(op: Op, comp: Comp):
    res = _shape_dims(op.shape)
    if res is None:
        return 0
    n_res = 1
    for d in res:
        n_res *= d
    cm = _CDIM_RE.search(op.rest)
    contracted = 1
    # operand 0 shape
    ops = _OPERAND_RE.findall(op.rest.split(")")[0])
    if ops:
        lhs_shape = comp.shapes.get(ops[0])
        if lhs_shape:
            dims = _shape_dims(lhs_shape)
            if dims and cm:
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(dims):
                        contracted *= dims[int(idx)]
    return 2.0 * n_res * contracted


def analyze(text: str):
    comps = parse_module(text)
    entry = None
    for name in comps:
        if "main" in name:
            entry = name
    if entry is None and comps:
        entry = list(comps)[-1]

    # multipliers via worklist from entry
    mult = defaultdict(float)
    mult[entry] = 1.0
    exec_comps = {entry}
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        c = comps.get(cname)
        if c is None:
            continue
        for op in c.ops:
            children = []
            if op.kind == "while":
                body = _BODY_RE.search(op.rest)
                cond = _COND_RE.search(op.rest)
                trip = _TRIP_RE.search(op.rest)
                n = float(trip.group(1)) if trip else 1.0
                if body:
                    children.append((body.group(1), n, True))
                if cond:
                    children.append((cond.group(1), n, True))
            elif op.kind == "conditional":
                bm = _BRANCH_RE.search(op.rest)
                if bm:
                    for b in _OPERAND_RE.findall(bm.group(1)):
                        children.append((b, 1.0, True))
            else:
                cm = _CALLS_RE.search(op.rest)
                if cm:
                    # fusion targets: flops counted, bytes not
                    children.append((cm.group(1), 1.0, op.kind != "fusion"))
            for child, factor, is_exec in children:
                mult[child] += mult[cname] * factor
                if is_exec:
                    exec_comps.add(child)
                if child not in seen:
                    seen.add(child)
                    order.append(child)

    def _root_kind(comp_name):
        c = comps.get(comp_name)
        return c.ops[-1].kind if c and c.ops else ""

    _INPLACE = ("dynamic-update-slice", "scatter")
    _GATHERY = ("gather", "dynamic-slice")
    # dtype/layout artifacts: the CPU backend lowers bf16 arithmetic to f32
    # with explicit convert/copy/bitcast chains that a TPU compile fuses
    # away — counting them would charge phantom HBM traffic (DESIGN.md §7)
    _LAYOUTY = ("convert", "copy", "bitcast", "transpose", "reshape",
                "broadcast", "slice", "concatenate", "iota", "compare",
                "select", "reduce-window")

    flops = 0.0
    bytes_accessed = 0.0
    coll = defaultdict(float)
    for cname, c in comps.items():
        m = mult.get(cname, 0.0)
        if m == 0.0:
            continue
        for op in c.ops:
            if op.kind in ("dot", "convolution"):
                flops += m * _dot_flops(op, c)
            if op.kind in COLLECTIVES:
                wire = _shape_elems_bytes(op.shape) * \
                    _WIRE_FACTOR.get(op.kind, 1.0)
                coll[op.kind] += m * wire
            if cname in exec_comps and op.kind not in (
                    "parameter", "constant", "tuple", "get-tuple-element",
                    "bitcast", "while", "conditional"):
                # while/conditional shells pass the whole loop carry by
                # reference — not HBM traffic; their bodies are counted.
                eff = op.kind
                if op.kind == "fusion":
                    cm = _CALLS_RE.search(op.rest)
                    if cm:
                        eff = _root_kind(cm.group(1))
                if eff in _LAYOUTY:
                    continue
                ops_str = op.rest.split(")")[0]
                operands = [_shape_elems_bytes(c.shapes.get(o, ""))
                            for o in _OPERAND_RE.findall(ops_str)]
                if eff in _INPLACE:
                    # in-place update: read+write the update region only,
                    # the big buffer operand/result are aliased
                    big = max(operands) if operands else 0
                    b = 2.0 * (sum(operands) - big)
                elif eff in _GATHERY:
                    # reads exactly the gathered rows (+ writes the result)
                    b = 2.0 * _shape_elems_bytes(op.shape)
                else:
                    b = _shape_elems_bytes(op.shape) + sum(operands)
                bytes_accessed += m * b
    coll["total"] = sum(v for k, v in coll.items() if k != "total")
    return {"flops": flops, "bytes": bytes_accessed,
            "collectives": dict(coll)}
