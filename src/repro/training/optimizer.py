"""AdamW + LR schedule (self-contained; optax is not available offline).

Optimizer state is a pytree mirroring the params, so ZeRO-1 sharding is just
a PartitionSpec on the state (distributed/sharding.py maps m/v over the data
axes). Update math runs in fp32 regardless of param dtype.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * \
        (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.lr * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, params, grads, opt_state):
    """Returns (new_params, new_opt_state). Grads may be bf16; math is fp32."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1 - cfg.b1 ** t
    bc2 = 1 - cfg.b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (u + cfg.weight_decay * pf)
        return pf.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, gnorm
