"""Training step builder: loss + grad accumulation + (optionally
pod-compressed) reduction + AdamW. Distribution is orthogonal: the caller
jits this with in/out shardings from repro.distributed.sharding.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import lm
from repro.training import optimizer as opt
from repro.training.compress_grads import pod_compressed_mean


def microbatch(batch, accum_steps):
    def split(x):
        B = x.shape[0]
        return x.reshape(accum_steps, B // accum_steps, *x.shape[1:])
    return jax.tree.map(split, batch)


def build_loss_fn(cfg, *, vocab_chunk=256):
    def loss_fn(params, batch):
        return lm.lm_loss(cfg, params, batch, vocab_chunk=vocab_chunk)
    return loss_fn


def build_train_step(cfg, adamw: opt.AdamWConfig, *, accum_steps=1,
                     vocab_chunk=256, pod_axis=None):
    """Returns train_step(params, opt_state, err_state, batch) ->
    (params, opt_state, err_state, metrics).

    pod_axis: if set (e.g. "pod"), gradients are reduced across that manual
    mesh axis with EF-int8 compression; the step must then run under
    shard_map with that axis manual (launch/train.py arranges it).
    """
    loss_fn = build_loss_fn(cfg, vocab_chunk=vocab_chunk)

    def grads_of(params, batch):
        if accum_steps == 1:
            return jax.value_and_grad(loss_fn)(params, batch)
        micro = microbatch(batch, accum_steps)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32), g_acc, g)
            return (loss_acc + loss, g_acc), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, g_sum), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), g0), micro)
        inv = 1.0 / accum_steps
        return loss_sum * inv, jax.tree.map(lambda g: g * inv, g_sum)

    def train_step(params, opt_state, err_state, batch):
        loss, grads = grads_of(params, batch)
        if pod_axis is not None:
            grads, err_state = pod_compressed_mean(grads, err_state,
                                                   axis_name=pod_axis)
            loss = jax.lax.pmean(loss, pod_axis)
        new_params, new_opt, gnorm = opt.adamw_update(adamw, params, grads,
                                                      opt_state)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": opt.lr_at(adamw, new_opt["step"])}
        return new_params, new_opt, err_state, metrics

    return train_step
