"""Fault-tolerant checkpointing (orbax is not available offline).

* atomic two-phase save: write to ``<dir>.tmp`` then ``os.replace`` — a crash
  mid-save never corrupts the previous checkpoint;
* flat ``.npy`` file per leaf + a JSON manifest with tree structure, dtypes
  and the *logical sharding spec names*, so restore can re-shard onto ANY
  mesh (elastic scaling): arrays are loaded full and ``device_put`` with the
  new mesh's sharding;
* step-tagged directories with retention, ``latest`` resolution.
"""
from __future__ import annotations

import json
import os
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, tree, *, extra=None, keep=3):
    """Atomically save a pytree checkpoint."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, leaf in leaves.items():
        arr = np.asarray(leaf)
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {"file": fname, "dtype": str(arr.dtype),
                                   "shape": list(arr.shape)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)                      # atomic publish
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir, keep):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d))


def latest_step(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *, shardings=None):
    """Restore into the structure of ``like_tree``. ``shardings``: matching
    pytree of jax.sharding.Sharding for elastic placement on a (possibly
    different) mesh; None = host arrays."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves = _flatten_with_paths(like_tree)
    shard_leaves = _flatten_with_paths(shardings) if shardings is not None \
        else {}
    out = {}
    for key in leaves:
        info = manifest["leaves"][key]
        arr = np.load(os.path.join(d, info["file"]))
        if key in shard_leaves:
            out[key] = jax.device_put(arr, shard_leaves[key])
        else:
            out[key] = arr
    # rebuild tree
    flat, treedef = jax.tree_util.tree_flatten_with_path(like_tree)
    vals = []
    for path, _ in flat:
        k = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
        vals.append(out[k])
    return jax.tree_util.tree_unflatten(treedef, vals), manifest["extra"]
