"""int8 cross-pod gradient reduction with error feedback.

At multi-pod scale the "pod" mesh axis crosses the slow DCI links, so the
cross-pod gradient all-reduce is the collective-roofline term that hurts.
We compress exactly (and only) that hop: within-pod reductions stay in
fp32/bf16 via GSPMD ("auto" axes), while the pod axis is manual
(``shard_map``) and reduces int8-quantized gradients with per-leaf shared
scales and error feedback (the quantization residual is carried to the next
step, preserving convergence — 1-bit-Adam/EF-SGD lineage).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def quantize_psum_dequant(g, err, axis_name, *, levels=127):
    """One leaf: error-feedback int8 all-reduce over ``axis_name``."""
    gf = g.astype(jnp.float32) + err
    amax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / levels
    q = jnp.clip(jnp.round(gf / scale), -levels, levels).astype(jnp.int32)
    deq_local = q.astype(jnp.float32) * scale
    new_err = gf - deq_local
    n = jax.lax.psum(1, axis_name)
    total = jax.lax.psum(q, axis_name)          # int wire format
    return (total.astype(jnp.float32) * scale / n).astype(g.dtype), new_err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def pod_compressed_mean(grads, err_state, axis_name="pod"):
    """Tree-mapped EF-int8 mean over the pod axis. Must be called inside a
    shard_map region where ``axis_name`` is manual."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    outs = [quantize_psum_dequant(g, e, axis_name)
            for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e
