"""Deterministic, stateless synthetic data pipeline.

Every batch is a pure function of (seed, step, shard) — restart-exactness
and elastic resharding come for free: a restored run at step k regenerates
exactly the batches a never-crashed run would have seen, on any mesh shape.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    kind: str = "lm"            # lm | copy (needle-retrieval for quality tests)


def batch_at(cfg: DataConfig, step: int):
    """Full global batch at a step (host) — numpy, deterministic."""
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    B, S = cfg.global_batch, cfg.seq_len
    if cfg.kind == "copy":
        # needle retrieval: random prefix, marker, needle; label = the needle
        toks = rng.integers(4, cfg.vocab_size, size=(B, S))
        half = S // 2
        toks[:, half] = 2                       # marker
        toks[:, half + 1:] = toks[:, 1:S - half]
        tokens = toks
    else:
        tokens = rng.integers(0, cfg.vocab_size, size=(B, S))
    labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    return {"tokens": tokens.astype(np.int32),
            "labels": labels.astype(np.int32)}


def batch_specs(cfg: DataConfig, extra=None):
    """ShapeDtypeStructs for the dry run."""
    out = {
        "tokens": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len),
                                       jnp.int32),
        "labels": jax.ShapeDtypeStruct((cfg.global_batch, cfg.seq_len),
                                       jnp.int32),
    }
    if extra:
        out.update(extra)
    return out
