"""Nemotron-4 15B [arXiv:2402.16819]: dense GQA, squared-ReLU FFN, 256k vocab."""
from repro.configs.base import ArchConfig, register

NEMOTRON_4_15B = register(ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    attn_type="gqa",
    ffn_act="sq_relu",
    norm_type="layernorm",
))
