"""InternVL2-26B [arXiv:2404.16821]: InternViT frontend (STUB) + InternLM2-20B LM.

Only the LM backbone is modeled; input_specs() provides precomputed,
already-projected patch embeddings injected as a prefix.
"""
from repro.configs.base import ArchConfig, register

INTERNVL2_26B = register(ArchConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    attn_type="gqa",
    ffn_act="silu_glu",
    norm_type="rmsnorm",
    frontend="vision_stub",
    num_prefix_embeds=256,    # one ViT tile worth of patch embeddings
))
