"""Architecture config system.

Every assigned architecture is a frozen ``ArchConfig``; the model zoo
(`repro.models`) builds the network purely from this description, so adding an
architecture is config-only. ``reduced()`` derives the family-preserving tiny
config used by CPU smoke tests; the full config is only ever traced abstractly
(dry-run lowering with ShapeDtypeStructs).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Tuple

# Layer mixer kinds appearing in ``block_pattern``.
MIX_ATTN = "attn"
MIX_RGLRU = "rglru"
MIX_RWKV = "rwkv"


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    attn_type: str = "gqa"           # gqa | mla | none
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    local_window: int = 0            # >0: sliding-window attention
    qk_norm: bool = False

    # --- MLA (deepseek) ---
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0
    first_dense_layers: int = 0      # leading dense layers before MoE stack
    moe_capacity_factor: float = 1.25

    # --- layer mixing pattern (cycled across layers) ---
    block_pattern: Tuple[str, ...] = (MIX_ATTN,)
    lru_width: int = 0               # RG-LRU recurrence width
    conv1d_width: int = 4            # temporal conv width for rglru blocks

    # --- FFN / norms ---
    ffn_act: str = "silu_glu"        # silu_glu | gelu_glu | sq_relu | gelu
    norm_type: str = "rmsnorm"       # rmsnorm | nonparam_ln | layernorm

    # --- encoder-decoder (whisper) ---
    encoder_layers: int = 0          # >0 => enc-dec; decoder = num_layers
    cross_seq_len: int = 1500        # stub encoder output length

    # --- modality frontend stubs ---
    frontend: str = "none"           # none | audio_stub | vision_stub
    num_prefix_embeds: int = 0       # VLM: number of injected patch embeddings

    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def attention_free(self) -> bool:
        return all(m != MIX_ATTN for m in self.block_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if decode cost is independent of total context length."""
        return self.attention_free or (
            self.local_window > 0 and MIX_ATTN in self.block_pattern
            and all(m in (MIX_ATTN, MIX_RGLRU, MIX_RWKV) for m in self.block_pattern)
            and (self.local_window > 0)
        )

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def kv_entry_dim(self) -> int:
        """Per-token per-layer KV width stored in one paged cache entry."""
        if self.attn_type == "mla":
            # latent c_kv + decoupled rope key, shared across heads
            return self.kv_lora_rank + self.qk_rope_head_dim
        return 2 * self.num_kv_heads * self.head_dim  # K and V

    def layer_kinds(self) -> Tuple[str, ...]:
        """Mixer kind per layer, cycling block_pattern."""
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.num_layers))

    @property
    def num_attn_layers(self) -> int:
        return sum(1 for k in self.layer_kinds() if k == MIX_ATTN)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d
        for kind in self.layer_kinds():
            if kind == MIX_ATTN:
                if self.attn_type == "mla":
                    r, dr = self.kv_lora_rank, self.qk_rope_head_dim
                    hq, dh, dv = self.num_heads, self.head_dim, self.v_head_dim
                    n += d * hq * (dh + dr)          # q proj (nope + rope)
                    n += d * (r + dr)                # kv down proj
                    n += r * hq * (dh + dv)          # kv up proj
                    n += hq * dv * d                 # out proj
                else:
                    hq, hkv, dh = self.num_heads, self.num_kv_heads, self.head_dim
                    n += d * hq * dh + 2 * d * hkv * dh + hq * dh * d
            elif kind == MIX_RGLRU:
                w = self.lru_width or d
                n += 2 * d * w + w * d               # in (x,gate) + out proj
                n += self.conv1d_width * w + 2 * w   # conv + lru gates (approx)
                n += 2 * w * (w // max(1, self.num_heads))  # input/rec gate proj (block diag)
            elif kind == MIX_RWKV:
                n += 6 * d * d                       # r,k,v,g,o,w projections (approx)
            # FFN
            gated = self.ffn_act.endswith("_glu")
            ff_mult = 3 if gated else 2
            if self.num_experts > 0:
                n += d * self.num_experts            # router
                n += self.num_experts * ff_mult * d * self.moe_d_ff
                n += self.num_shared_experts * ff_mult * d * self.moe_d_ff
            else:
                n += ff_mult * d * self.d_ff
        if self.encoder_layers:
            hq, hkv, dh = self.num_heads, self.num_kv_heads, self.head_dim
            gated = self.ffn_act.endswith("_glu")
            ff_mult = 3 if gated else 2
            per = d * hq * dh + 2 * d * hkv * dh + hq * dh * d + ff_mult * d * self.d_ff
            n += self.encoder_layers * per
            # decoder cross-attention
            n += self.num_layers * (d * hq * dh + 2 * d * hkv * dh + hq * dh * d)
        return n

    def active_param_count(self) -> int:
        """Activated params per token (MoE: only routed top-k experts)."""
        if self.num_experts == 0:
            return self.param_count()
        dense = dataclasses.replace(self, num_experts=0, num_shared_experts=0)
        n = dense.param_count()
        gated = self.ffn_act.endswith("_glu")
        ff_mult = 3 if gated else 2
        moe_layers = self.num_layers - self.first_dense_layers
        # remove the dense FFN we counted, add router + active experts
        n -= moe_layers * ff_mult * self.d_model * self.d_ff
        act = self.num_experts_per_tok + self.num_shared_experts
        n += moe_layers * (self.d_model * self.num_experts
                           + act * ff_mult * self.d_model * self.moe_d_ff)
        return n

    # ------------------------------------------------------------------
    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        pat = len(self.block_pattern)
        num_layers = max(pat, 2 if pat == 1 else pat)
        d_model = 64
        head_dim = 16
        num_heads = 0 if self.num_heads == 0 else 4
        if self.attn_type == "mla":
            kv_heads = num_heads
        elif self.num_kv_heads and self.num_heads:
            kv_heads = max(1, num_heads * self.num_kv_heads // self.num_heads)
        else:
            kv_heads = 0
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=num_layers,
            d_model=d_model,
            num_heads=num_heads,
            num_kv_heads=kv_heads,
            head_dim=head_dim,
            d_ff=128,
            vocab_size=256,
            kv_lora_rank=32 if self.kv_lora_rank else 0,
            qk_rope_head_dim=8 if self.qk_rope_head_dim else 0,
            v_head_dim=16 if self.v_head_dim else 0,
            num_experts=4 if self.num_experts else 0,
            num_experts_per_tok=min(2, self.num_experts_per_tok) if self.num_experts else 0,
            num_shared_experts=min(1, self.num_shared_experts),
            moe_d_ff=32 if self.moe_d_ff else 0,
            first_dense_layers=min(1, self.first_dense_layers),
            lru_width=64 if self.lru_width else 0,
            local_window=32 if self.local_window else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            cross_seq_len=8 if self.encoder_layers else self.cross_seq_len,
            num_prefix_embeds=4 if self.num_prefix_embeds else 0,
        )


# ----------------------------------------------------------------------
# Input-shape cells (assigned per the task; identical across LM archs).
@dataclass(frozen=True)
class ShapeCell:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524_288, 1, "decode"),
}


_REGISTRY = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        # Import all config modules lazily on first miss.
        from repro import configs as _c  # noqa
        _c.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_arch_names():
    from repro import configs as _c
    _c.load_all()
    return sorted(_REGISTRY)


def cell_applicable(cfg: ArchConfig, shape: ShapeCell) -> Tuple[bool, str]:
    """Whether a (arch, shape) dry-run cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k requires sub-quadratic attention; " \
                      f"{cfg.name} is full-attention (skip per spec)"
    return True, ""
