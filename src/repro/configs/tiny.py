"""Tiny LM for CPU examples, engine tests and quality-proxy benchmarks."""
from repro.configs.base import ArchConfig, register

TINY_LM = register(ArchConfig(
    name="tiny-lm",
    family="dense",
    num_layers=4,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    attn_type="gqa",
    ffn_act="silu_glu",
    norm_type="rmsnorm",
))
