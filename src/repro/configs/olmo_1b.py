"""OLMo-1B [arXiv:2402.00838]: dense MHA, non-parametric LayerNorm."""
from repro.configs.base import ArchConfig, register

OLMO_1B = register(ArchConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    attn_type="gqa",
    ffn_act="silu_glu",
    norm_type="nonparam_ln",
    tie_embeddings=True,
))
