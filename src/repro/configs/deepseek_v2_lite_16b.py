"""DeepSeek-V2-Lite 16B [arXiv:2405.04434]: MLA (kv_lora=512) + 64-expert MoE top-6.

The assignment header says "MoE 64e top-6", matching the public V2-Lite
(64 routed experts, 2 shared, top-6, expert d_ff=1408, first layer dense);
the parenthetical "160 routed" belongs to full V2 and is not used — see
DESIGN.md §4.
"""
from repro.configs.base import ArchConfig, register

DEEPSEEK_V2_LITE = register(ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,          # MLA: latent shared; heads expanded from latent
    head_dim=128,             # qk_nope_head_dim
    d_ff=10944,               # dense FFN of the first layer
    vocab_size=102400,
    attn_type="mla",
    kv_lora_rank=512,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    moe_d_ff=1408,
    first_dense_layers=1,
    ffn_act="silu_glu",
    norm_type="rmsnorm",
))
