"""DBRX-132B [hf:databricks/dbrx-base]: fine-grained MoE, 16 experts top-4."""
from repro.configs.base import ArchConfig, register

DBRX_132B = register(ArchConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,           # dense-equivalent (unused: all layers MoE)
    vocab_size=100352,
    attn_type="gqa",
    rope_theta=500_000.0,
    num_experts=16,
    num_experts_per_tok=4,
    moe_d_ff=10752,
    ffn_act="silu_glu",
    norm_type="layernorm",
))
