"""RecurrentGemma-2B [arXiv:2402.19427]: RG-LRU + local attention, 1 attn : 2 lru.

26 layers cycling (rglru, rglru, attn); local window 2048; MQA (kv=1);
sub-quadratic => runs the long_500k cell.
"""
from repro.configs.base import ArchConfig, register

RECURRENTGEMMA_2B = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    attn_type="gqa",
    local_window=2048,
    block_pattern=("rglru", "rglru", "attn"),
    lru_width=2560,
    conv1d_width=4,
    ffn_act="gelu_glu",
    norm_type="rmsnorm",
    tie_embeddings=True,
))
