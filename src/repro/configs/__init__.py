"""Arch configs — one module per assigned architecture (+ paper's model)."""
import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig, ShapeCell, SHAPES, get_config, register, all_arch_names,
    cell_applicable,
)

_MODULES = [
    "recurrentgemma_2b",
    "deepseek_v2_lite_16b",
    "dbrx_132b",
    "llama3_8b",
    "nemotron_4_15b",
    "olmo_1b",
    "qwen2_5_3b",
    "rwkv6_3b",
    "whisper_tiny",
    "internvl2_26b",
    "qwen3_8b",
    "tiny",
]

_loaded = False


def load_all():
    global _loaded
    if _loaded:
        return
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
    _loaded = True
