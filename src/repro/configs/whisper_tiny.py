"""Whisper-tiny [arXiv:2212.04356]: enc-dec backbone; conv/mel frontend is a STUB.

Per the assignment, only the transformer backbone is modeled; input_specs()
provides precomputed frame embeddings for the encoder. Decoder self-attention
KV is paged/evictable; cross-attention KV is static. prefill/decode cells
exercise the decoder backbone at the assigned (non-Whisper-native) lengths
with RoPE positions — noted in DESIGN.md §4.
"""
from repro.configs.base import ArchConfig, register

WHISPER_TINY = register(ArchConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,             # decoder layers
    encoder_layers=4,
    cross_seq_len=1500,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    attn_type="gqa",
    ffn_act="gelu",
    norm_type="layernorm",
    frontend="audio_stub",
    tie_embeddings=True,
))
