"""Llama-3 8B [arXiv:2407.21783]: dense GQA, 128k vocab."""
from repro.configs.base import ArchConfig, register

LLAMA3_8B = register(ArchConfig(
    name="llama3-8b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    attn_type="gqa",
    rope_theta=500_000.0,
    ffn_act="silu_glu",
    norm_type="rmsnorm",
))
