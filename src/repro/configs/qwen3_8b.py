"""Qwen3-8B-like — the paper's primary evaluation model (Zipage §5).

Not part of the assigned pool; included so the paper's own experiments have a
first-class config. Dims follow the public Qwen3-8B card.
"""
from repro.configs.base import ArchConfig, register

QWEN3_8B = register(ArchConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    attn_type="gqa",
    qk_norm=True,
    rope_theta=1_000_000.0,
    ffn_act="silu_glu",
    norm_type="rmsnorm",
))
