"""RWKV-6 (Finch) 3B [arXiv:2404.05892]: attention-free, data-dependent decay.

d_model=2560, head_dim=64 => 40 wkv heads. Decode state is O(1) per request
(no paged KV; Zipage eviction inapplicable — DESIGN.md §4). Runs long_500k.
"""
from repro.configs.base import ArchConfig, register

RWKV6_3B = register(ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,             # wkv heads (d_model / 64)
    num_kv_heads=0,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attn_type="none",
    block_pattern=("rwkv",),
    ffn_act="sq_relu",        # rwkv channel-mix uses relu^2
    norm_type="layernorm",
))
