"""Qwen2.5-3B [hf:Qwen/Qwen2.5]: dense GQA (kv=2) with QKV bias."""
from repro.configs.base import ArchConfig, register

QWEN2_5_3B = register(ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    attn_type="gqa",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    ffn_act="silu_glu",
    norm_type="rmsnorm",
    tie_embeddings=True,
))
