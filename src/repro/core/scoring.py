"""Compression scoring functions φ(Q, K, I) — pure-jnp reference backend.

All functions operate on ONE request and ONE layer in *cache order*:
  q_win   : (w, h_q, d)   observation-window queries (chronological, roped)
  entries : (T, h, d)     gathered key entries, T = n_blocks·b
  valid   : (T,)          bool, entry < seq_len
Per-head scores (T, h): for GQA h = h_kv (paper App. C.2 max-reduce); for MLA
h = 1 (latent shared across heads). Batch/layer vmap happens at call sites;
Pallas kernels (repro.kernels) implement the same contracts on paged layout.

Note on Alg. 1's mask: the paper writes ``-inf if u + b - w > v`` which masks
*past* keys; a causal observation window must mask *future* keys
(v > u + b - w), as in SnapKV/MorphKV. We implement the causal direction and
record the sign discrepancy in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ----------------------------------------------------------------------
def attention_scores(q_win, entries, valid, seq_len, *, scale=None):
    """Paper Alg. 1 + App. C.2 reductions -> (T, h) scores.

    q_win query u sits at cache position seq_len - w + u; keys at cache
    position t. Future keys (t > query pos) are masked causally.
    """
    w, hq, d = q_win.shape
    T, h = entries.shape[0], entries.shape[1]
    g = hq // h
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    qg = q_win.reshape(w, h, g, d).astype(jnp.float32)
    s = jnp.einsum("whgd,thd->hgwt", qg, entries.astype(jnp.float32)) * scale
    qpos = seq_len - w + jnp.arange(w)                     # (w,)
    causal = jnp.arange(T)[None, :] <= qpos[:, None]       # (w, T)
    mask = causal & valid[None, :]
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)                         # over T
    p = jnp.where(mask[None, None], p, 0.0)
    p = p.max(axis=1)                                      # GQA max-reduce over g
    return p.mean(axis=1).T                                # mean over w -> (T, h)


def mla_attention_scores(q_win_abs, entries, valid, seq_len, *, r, scale):
    """MLA variant: q_win_abs: (w, h_q, r+dr) absorbed queries; entries
    (T, r+dr) latent cache. Returns (T, 1)."""
    w, hq, _ = q_win_abs.shape
    T = entries.shape[0]
    s = jnp.einsum("whe,te->wht", q_win_abs.astype(jnp.float32),
                   entries.astype(jnp.float32)) * scale
    qpos = seq_len - w + jnp.arange(w)
    mask = (jnp.arange(T)[None, :] <= qpos[:, None]) & valid[None, :]
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    p = jnp.where(mask[:, None], p, 0.0)
    p = p.max(axis=1)                                      # over q heads
    return p.mean(axis=0)[:, None]                         # (T, 1)


# ----------------------------------------------------------------------
def global_score_update(scores, f_prev, hist_len, alpha):
    """Paper Alg. 2 (G-KV): decayed max with history. scores/f_prev: (T, h);
    entries with cache position < hist_len carry history. Returns the
    overwritten scores (also the new F)."""
    T = scores.shape[0]
    has_hist = (jnp.arange(T) < hist_len)[:, None]
    return jnp.where(has_hist, jnp.maximum(alpha * f_prev, scores), scores)


# ----------------------------------------------------------------------
def _cosine_matrix(entries, valid):
    """(h, T, T) cosine similarity; invalid rows/cols zeroed."""
    e = entries.astype(jnp.float32)
    norm = jnp.linalg.norm(e, axis=-1, keepdims=True)
    ehat = e / jnp.maximum(norm, 1e-12)
    c = jnp.einsum("thd,shd->hts", ehat, ehat)
    vm = valid[:, None] & valid[None, :]
    return jnp.where(vm[None], c, 0.0)


def _zero_last_above(c, p_thresh):
    """Per column, zero the LAST (newest-row) entry exceeding p (paper C.5:
    prefer retaining newer tokens). c: (h, T, T)."""
    T = c.shape[-1]
    above = c > p_thresh                                    # (h, t, s)
    rev = above[:, ::-1, :]
    has = above.any(axis=1)                                 # (h, s)
    last = T - 1 - jnp.argmax(rev, axis=1)                  # (h, s)
    hit = jax.nn.one_hot(last, T, axis=1, dtype=bool) & has[:, None, :]
    return jnp.where(hit, 0.0, c)


def redundancy_full(entries, valid, *, p_thresh=0.8):
    """R-KV redundancy, full-matrix oracle (O(T²·d) compute, O(T²) memory).
    Returns raw row-sums normalized by valid length: (T, h)."""
    c = _cosine_matrix(entries, valid)
    T = c.shape[-1]
    c = c * (1.0 - jnp.eye(T))                              # zero diagonal
    c = _zero_last_above(c, p_thresh)
    n = jnp.maximum(valid.sum(), 1)
    return (c.sum(axis=-1) / n).T                           # (T, h)


def redundancy_lightning(entries, valid, *, block_size, p_thresh=0.8):
    """Lightning redundancy (paper C.7): similarities only within each page.
    O(T·b) compute/memory. Returns row-sums normalized by b: (T, h)."""
    T, h, d = entries.shape
    b = block_size
    nb = T // b
    e = entries.astype(jnp.float32)
    ehat = e / jnp.maximum(jnp.linalg.norm(e, axis=-1, keepdims=True), 1e-12)
    eb = ehat.reshape(nb, b, h, d)
    vb = valid.reshape(nb, b)
    c = jnp.einsum("nthd,nshd->nhts", eb, eb)               # (nb, h, b, b)
    vm = vb[:, :, None] & vb[:, None, :]
    c = jnp.where(vm[:, None], c, 0.0)
    c = c * (1.0 - jnp.eye(b))
    # per-column zero of last above-threshold entry, within the block
    above = c > p_thresh
    has = above.any(axis=2)                                 # (nb, h, b)
    last = b - 1 - jnp.argmax(above[:, :, ::-1, :], axis=2)
    hit = jax.nn.one_hot(last, b, axis=2, dtype=bool) & has[:, :, None, :]
    c = jnp.where(hit, 0.0, c)
    r = c.sum(axis=-1) / b                                  # (nb, h, b)
    return r.transpose(0, 2, 1).reshape(T, h)


def redundancy_softmax(r_raw, valid, *, tau=1.0):
    """Distribution over the sequence dim with temperature (paper C.8)."""
    x = jnp.where(valid[:, None], r_raw / tau, NEG_INF)
    return jax.nn.softmax(x, axis=0)


# ----------------------------------------------------------------------
def max_pool_scores(scores, valid, *, kernel=7):
    """SnapKV sequence-dim max pooling (paper C.4), same-padded, masked."""
    s = jnp.where(valid[:, None], scores, NEG_INF)
    pads = [s]
    for off in range(1, kernel // 2 + 1):
        pads.append(jnp.roll(s, off, axis=0).at[:off].set(NEG_INF))
        pads.append(jnp.roll(s, -off, axis=0).at[-off:].set(NEG_INF))
    out = jnp.stack(pads).max(axis=0)
    return jnp.where(valid[:, None], out, 0.0)


# ----------------------------------------------------------------------
def combine_scores(attn_s, red_dist, valid, win_len, seq_len, *, lam):
    """Final score (paper Eq. 4 + window pinning): S - λ·R, observation
    window (last win_len valid entries) pinned to +inf, invalid to -inf."""
    T = attn_s.shape[0]
    s = attn_s - lam * red_dist
    pos = jnp.arange(T)
    in_win = (pos >= seq_len - win_len) & (pos < seq_len)
    s = jnp.where(in_win[:, None], jnp.inf, s)
    return jnp.where(valid[:, None], s, -jnp.inf)


def quality_stats(attn_s, red_raw, valid, seq_len):
    """Per-request quality telemetry for the scheduler (docs/EVAL.md).

    attn_s: (T, h) raw window-attention distribution (pre global-update /
    pooling); red_raw: (T, h) raw redundancy row-sums (zeros when
    redundancy scoring is off). Returns (2,) float32:
    ``[mean raw redundancy over valid entries, normalized attention
    entropy in [0, 1]]``. High entropy = attention spread over the whole
    sequence (eviction is risky); high redundancy = many near-duplicate
    entries (compression is cheap).
    """
    v = valid[:, None]
    n_valid = jnp.maximum(valid.sum(), 1)
    red_mean = jnp.where(v, red_raw, 0.0).sum() / (
        n_valid * red_raw.shape[1])
    p = jnp.where(v, attn_s, 0.0)
    p = p / jnp.maximum(p.sum(axis=0, keepdims=True), 1e-12)
    ent = -jnp.where(v & (p > 0), p * jnp.log(jnp.maximum(p, 1e-12)),
                     0.0).sum(axis=0)                       # (h,)
    ent_norm = ent.mean() / jnp.log(jnp.maximum(seq_len, 2).astype(
        jnp.float32))
    return jnp.stack([red_mean, ent_norm]).astype(jnp.float32)


def topk_tag(scores, k):
    """Boolean keep-tag per head: top-k along the sequence dim. (T, h)->(T, h)."""
    T, h = scores.shape
    idx = jax.lax.top_k(scores.T, k)[1]                     # (h, k)
    tag = jnp.zeros((h, T), bool).at[jnp.arange(h)[:, None], idx].set(True)
    return tag.T
