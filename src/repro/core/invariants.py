"""Whole-engine runtime sanitizer (``ZIPAGE_SANITIZE=1``).

Generalizes ``BlockManager.check_invariants()`` into an audit of the
entire serving engine — scheduler queues, slot/qslot pools, block
refcounts, the host swap tier, token-budget accounting and the
compression invariants of the paper (block cap, observation-window
ownership). The engine runs :func:`check_engine` after every ``step()``
when the env var is set (``make test-sanitize`` runs tier-1 that way);
tests call :func:`audit_engine` directly to inspect the messages.

Every violation message is actionable: it names the object (rid, slot,
block id), the numbers that disagree, and the class of bug it implies
(leak vs double-free vs orphan). docs/ANALYSIS.md documents each check.

Pure host: this module must import neither ``jax`` nor any
device-executing repro module (zipalint rule ZPL001 enforces it).
Device mirrors are read through ``np.asarray``, which triggers the
device->host transfer via ``__array__`` without a jax import — the
sanitizer is explicitly a sync point, which is why it is opt-in
(docs/PERF.md notes the overhead).
"""
from __future__ import annotations

import math
import os
from collections import Counter
from typing import TYPE_CHECKING, Dict, List

import numpy as np

if TYPE_CHECKING:                                   # pragma: no cover
    from repro.core.block_manager import BlockManager
    from repro.core.scheduler import Scheduler

#: truthy spellings accepted for ZIPAGE_SANITIZE
_TRUTHY = ("1", "true", "yes", "on")


class InvariantViolation(AssertionError):
    """Raised by :func:`check_engine`; one line per violated invariant."""


def enabled() -> bool:
    """Whether the per-step engine audit is switched on via env."""
    return os.environ.get("ZIPAGE_SANITIZE", "").lower() in _TRUTHY


# ----------------------------------------------------------------------
# audit groups — each appends human-readable violation strings


def _queue_states(sched: "Scheduler", out: List[str]) -> None:
    """Queue disjointness + per-queue request-state consistency."""
    from repro.core.request import State

    queues = {
        "waiting": list(sched.waiting),
        "running": list(sched.running),
        "swapped": list(sched.swapped),
        "finished": list(sched.finished.values()),
    }
    seen: Dict[int, str] = {}
    for qname, reqs in queues.items():
        for r in reqs:
            if r.rid in seen:
                out.append(
                    f"rid {r.rid} appears in both the {seen[r.rid]!r} and "
                    f"{qname!r} queues — queues must be disjoint (a "
                    "preempt/finish path forgot to remove it)")
            seen[r.rid] = qname
    allowed = {
        "waiting": {State.WAITING},
        "running": {State.RUNNING, State.BLOCKED, State.COMPRESSING},
        "swapped": {State.SWAPPED},
        "finished": {State.FINISHED},
    }
    for qname, reqs in queues.items():
        for r in reqs:
            if r.state not in allowed[qname]:
                out.append(
                    f"rid {r.rid} sits in the {qname!r} queue with state "
                    f"{r.state.value!r} — allowed: "
                    f"{sorted(s.value for s in allowed[qname])}")
            if qname != "running":
                if r.slot != -1 or r.qslot != -1:
                    out.append(
                        f"rid {r.rid} ({qname}) still holds slot={r.slot} "
                        f"qslot={r.qslot} — only running requests may own "
                        "slots (orphaned slot leak)")
                if r.blocks:
                    out.append(
                        f"rid {r.rid} ({qname}) still lists "
                        f"{len(r.blocks)} block(s) — only running "
                        "requests hold device blocks (block leak)")


def _slot_pools(sched: "Scheduler", out: List[str]) -> None:
    """free_slots/free_qslots + per-request assignments partition the
    slot and qslot id spaces exactly."""
    p = sched.p
    for kind, size, free, held in (
            ("slot", p.max_batch, sched.free_slots,
             [r.slot for r in sched.running if r.slot >= 0]),
            ("qslot", p.m_qslots, sched.free_qslots,
             [r.qslot for r in sched.running if r.qslot >= 0])):
        dup = [s for s, c in Counter(held).items() if c > 1]
        if dup:
            out.append(
                f"{kind}(s) {sorted(dup)} owned by more than one running "
                "request — assignment/release mismatch")
        bad = [s for s in held + list(free) if not 0 <= s < size]
        if bad:
            out.append(
                f"{kind} id(s) {sorted(set(bad))} out of range "
                f"[0, {size}) — corrupted pool")
        overlap = set(held) & set(free)
        if overlap:
            out.append(
                f"{kind}(s) {sorted(overlap)} both free and held — a "
                "request was freed without clearing its handle (or the "
                "pool was double-pushed)")
        n = len(set(held)) + len(set(free))
        if n != size and not dup and not bad and not overlap:
            out.append(
                f"{kind} pool accounts for {n} of {size} ids "
                f"({len(free)} free + {len(set(held))} held) — "
                f"{'leaked' if n < size else 'duplicated'} "
                f"{kind}(s): {sorted(set(range(size)) - set(held) - set(free))}")


def _block_refcounts(sched: "Scheduler", out: List[str]) -> None:
    """bm.ref must equal, per block, the number of running requests
    listing that block (prefix-shared blocks count once per holder)."""
    bm = sched.bm
    holders: Counter = Counter()
    for r in sched.running:
        dup = [b for b, c in Counter(r.blocks).items() if c > 1]
        if dup:
            out.append(
                f"rid {r.rid} lists block(s) {sorted(dup)} more than once "
                "in its block table — self-aliased table (compression "
                "commit or swap-in wrote overlapping ids)")
        holders.update(set(r.blocks))
    for b in range(bm.num_blocks):
        ref, held = bm.ref[b], holders.get(b, 0)
        if ref == held:
            continue
        if ref > held:
            out.append(
                f"block {b}: refcount {ref} > {held} holder(s) — leaked "
                "reference (a release path was skipped; the block can "
                "never be reclaimed)")
        else:
            out.append(
                f"block {b}: refcount {ref} < {held} holder(s) — "
                "double-free (the block can be handed to another request "
                "while still referenced: silent KV corruption)")
    live = {b for b in range(bm.num_blocks) if bm.ref[b] > 0}
    free_set = set(bm.free) | set(bm.cached_free)
    if len(free_set) != len(bm.free) + len(bm.cached_free):
        out.append(
            "block(s) "
            f"{sorted(set(bm.free) & set(bm.cached_free))} are in both "
            "the free list and the prefix-cached free list")
    clash = free_set & live
    if clash:
        out.append(
            f"block(s) {sorted(clash)} are simultaneously free and "
            "referenced — double-free into the pool")
    missing = set(range(bm.num_blocks)) - free_set - live
    if missing:
        out.append(
            f"block(s) {sorted(missing)} are neither free nor referenced "
            "— leaked out of the pool entirely")
    for h, b in bm.hash_to_block.items():
        if bm.block_hash.get(b) != h:
            out.append(
                f"prefix-cache hash map out of sync: hash {h} -> block "
                f"{b} but block_hash[{b}] == {bm.block_hash.get(b)}")


def _prefix_tree(sched: "Scheduler", out: List[str]) -> None:
    """Radix prefix-cache structure: node<->hash<->block bijection, tree
    linkage, path closure (a referenced node's ancestors stay referenced)
    and the free-list exclusion of cached payload. Flat policy keeps no
    tree, so there is nothing to audit."""
    bm = sched.bm
    if bm.prefix_cache_policy != "radix":
        if bm.nodes or bm.segments:
            out.append(
                f"flat-policy BlockManager holds {len(bm.nodes)} radix "
                f"node(s) / {len(bm.segments)} segment(s) — tree state "
                "leaked across a policy boundary")
        return
    raw_free = set(bm.free)
    if set(bm.nodes) != set(bm.hash_to_block):
        only_n = sorted(set(bm.nodes) - set(bm.hash_to_block))[:4]
        only_h = sorted(set(bm.hash_to_block) - set(bm.nodes))[:4]
        out.append(
            f"radix node set diverged from hash_to_block (nodes-only "
            f"{only_n}, hashes-only {only_h}) — register/deregister "
            "updated one map but not the other")
    for h, node in bm.nodes.items():
        b = node.block
        if bm.hash_to_block.get(h) != b:
            out.append(
                f"radix node {h} points at block {b} but hash_to_block "
                f"maps it to {bm.hash_to_block.get(h)} — node/block "
                "bijection broken")
        if bm.node_of_block.get(b) is not node:
            out.append(
                f"block {b} of radix node {h} is not node_of_block's "
                "entry for that block — reverse map stale")
        if b in raw_free:
            out.append(
                f"block {b} backs cached radix node {h} but sits in the "
                "raw free list — it can be reallocated while the cache "
                "still advertises its content")
        parent = node.parent
        if parent is not None:
            if parent.children.get(h) is not node:
                out.append(
                    f"radix node {h} names a parent that does not list "
                    "it as a child — tree linkage corrupt")
            if bm.ref[b] > 0 and bm.ref[parent.block] == 0 \
                    and parent.block not in bm.cached_free:
                out.append(
                    f"radix node {h} (block {b}) is referenced but its "
                    f"parent block {parent.block} is neither referenced "
                    "nor cached — path closure broken (eviction can "
                    "orphan a live suffix)")
    for b in bm.seg_of_block:
        if b in raw_free:
            out.append(
                f"block {b} is compressed-segment payload "
                f"({bm.seg_of_block[b]}) but sits in the raw free list — "
                "segment-vs-pool accounting out of sync")


def _swap_pool(sched: "Scheduler", out: List[str]) -> None:
    """Host swap tier: per-rid reservations match the swapped queue and
    partition the host block space with swap_free."""
    bm = sched.bm
    q_rids = {r.rid for r in sched.swapped}
    bm_rids = set(bm.swapped)
    for rid in sorted(bm_rids - q_rids):
        out.append(
            f"rid {rid} holds {len(bm.swapped[rid])} host swap block(s) "
            "but is not in the swapped queue — swap-pool leak (swap-in "
            "or abort forgot release_swapped)")
    for rid in sorted(q_rids - bm_rids):
        out.append(
            f"rid {rid} is in the swapped queue but owns no host swap "
            "blocks — its KV copy is gone and swap-in will corrupt")
    held = [b for blocks in bm.swapped.values() for b in blocks]
    dup = [b for b, c in Counter(held + list(bm.swap_free)).items()
           if c > 1]
    if dup:
        out.append(
            f"host swap block(s) {sorted(dup)} double-booked across "
            "swap_free / per-rid reservations")
    n = len(set(held) | set(bm.swap_free))
    if n != bm.swap_space_blocks and not dup:
        out.append(
            f"host swap pool accounts for {n} of "
            f"{bm.swap_space_blocks} blocks — leaked host blocks")


def _token_budget(engine, out: List[str]) -> None:
    """The step's scheduled tokens must fit the configured budget."""
    if not engine.metrics:
        return
    m = engine.metrics[-1]
    if m.get("step") != engine.step_count:
        return
    budget = m.get("token_budget")
    scheduled = m.get("n_scheduled_tokens")
    if budget is not None and scheduled is not None and scheduled > budget:
        out.append(
            f"step {m['step']} scheduled {scheduled} tokens against a "
            f"token_budget of {budget} — the budget accounting "
            "over-admitted (continuous-batching overdraw)")


def _request_counters(engine, out: List[str]) -> None:
    """Per-request progress counters stay inside their envelopes."""
    sched = engine.scheduler
    p = sched.p
    b = p.block_size
    paged = ("pools" in engine.state and not p.attention_free
             and not p.ring_blocks)
    for r in sched.running:
        if not 0 <= r.win_count <= p.window:
            out.append(
                f"rid {r.rid}: win_count {r.win_count} outside "
                f"[0, window={p.window}] — observation-window cursor "
                "corrupt")
        if p.compression_enabled and r.win_count > 0 and r.qslot < 0:
            out.append(
                f"rid {r.rid}: win_count {r.win_count} > 0 without a "
                "qslot — window rows were recorded into a slot it does "
                "not own (qwin ownership violation)")
        if not 0 <= r.n_prefilled <= r.prefill_target <= len(r.full_prompt):
            out.append(
                f"rid {r.rid}: prefill cursor n_prefilled="
                f"{r.n_prefilled} target={r.prefill_target} vs prompt "
                f"len {len(r.full_prompt)} — chunked-prefill bookkeeping "
                "out of order")
        if len(r.output) > r.max_new_tokens:
            out.append(
                f"rid {r.rid}: emitted {len(r.output)} tokens past "
                f"max_new_tokens={r.max_new_tokens} — finish check "
                "missed the length cap")
        if not paged:
            continue
        if r.seq_len > r.n_blocks * b:
            out.append(
                f"rid {r.rid}: seq_len {r.seq_len} exceeds its "
                f"{r.n_blocks} block(s) x {b} capacity — decode is "
                "writing past the block table")
        if r.compressed:
            # the quality-aware planner legitimately lets a request run
            # past n_max before compressing (compression_deferral /
            # "protect" policy — docs/EVAL.md), so audit against the
            # scheduler's worst-case per-request cap, not the global n_max
            n_cap = (sched._n_max_cap(r, worst_case=True)
                     if p.n_max is not None else 0)
            cap = n_cap + max(1, math.ceil(p.window / b))
            if r.pos_gap:
                # segment adoption (docs/CACHING.md) marks the request
                # compressed at admission, but its block table tracks
                # seq_len like an uncompressed request until its own
                # first compression fires — allow the seq_len envelope
                cap = max(cap, -(-(r.seq_len + max(1, p.decode_steps))
                                 // b))
            if r.n_blocks > cap:
                out.append(
                    f"rid {r.rid}: compressed but holds {r.n_blocks} "
                    f"blocks > per-request cap {n_cap} + in-flight "
                    f"allowance {cap - n_cap} — compression failed to "
                    "release its sources (paper block cap violated)")
        else:
            cap = -(-(r.seq_len + max(1, p.decode_steps)) // b)
            if r.n_blocks > cap:
                out.append(
                    f"rid {r.rid}: uncompressed with {r.n_blocks} blocks "
                    f"for seq_len {r.seq_len} (cap {cap}) — "
                    "over-allocation / stale table entries")


def _device_mirrors(engine, out: List[str]) -> None:
    """Host seq/pos mirrors vs the device tables. Only meaningful when
    the last push is still current (nothing structural moved since) and
    on paged archs whose host counters advance in lockstep."""
    sched = engine.scheduler
    p = sched.p
    if ("pools" not in engine.state or p.attention_free or p.ring_blocks
            or engine._pushed_version != sched.version):
        return
    seq = np.asarray(engine.state["seq_lens"])
    pos = np.asarray(engine.state["positions"])
    for r in sched.running:
        if r.slot < 0:
            continue
        if int(seq[r.slot]) != r.seq_len:
            out.append(
                f"rid {r.rid} slot {r.slot}: device seq_len "
                f"{int(seq[r.slot])} != host {r.seq_len} — the mirrors "
                "diverged (missed push or double advance)")
        if int(pos[r.slot]) != r.position:
            out.append(
                f"rid {r.rid} slot {r.slot}: device position "
                f"{int(pos[r.slot])} != host {r.position} — the mirrors "
                "diverged (missed push or double advance)")


def _qwin_ownership(engine, out: List[str]) -> None:
    """Observation-window rows of FREE qslots must never change between
    audits — a change means some decode/compress dispatch wrote a row no
    active slot owns (the PR-4 qwin masking bug class). Shadows are host
    copies keyed by qslot; reassignment retires the shadow."""
    if "qwin" not in engine.state or not engine.compression_enabled:
        return
    sched = engine.scheduler
    free = set(sched.free_qslots)
    shadow = engine._qwin_shadow
    # rows legitimately writable under the last table push: a qslot can
    # be assigned AND freed within one step (tenant finishes), so current
    # freeness alone is not enough to declare a row quiescent
    dispatched = {int(q) for q in engine.host_qslot if q >= 0}
    for q in list(shadow):
        if q not in free or q in dispatched:
            del shadow[q]
    qwin = None
    for q in sorted(free - dispatched):
        if qwin is None:
            qwin = np.asarray(engine.state["qwin"])
        row = qwin[:, q]
        prev = shadow.get(q)
        if prev is None:
            shadow[q] = row.copy()
        elif not np.array_equal(prev, row):
            out.append(
                f"free qslot {q}: observation-window row changed while "
                "unassigned — a dispatch wrote into a window it does not "
                "own (masking bug: check the qslot gather/scatter masks)")
            shadow[q] = row.copy()            # don't re-report every step


# ----------------------------------------------------------------------


def audit_engine(engine) -> List[str]:
    """Run every audit group; returns violation messages (empty = clean)."""
    out: List[str] = []
    sched = engine.scheduler
    _queue_states(sched, out)
    _slot_pools(sched, out)
    _block_refcounts(sched, out)
    _prefix_tree(sched, out)
    _swap_pool(sched, out)
    _token_budget(engine, out)
    _request_counters(engine, out)
    _device_mirrors(engine, out)
    _qwin_ownership(engine, out)
    return out


def check_engine(engine) -> None:
    """Raise :class:`InvariantViolation` listing every violation found."""
    violations = audit_engine(engine)
    if violations:
        raise InvariantViolation(
            f"ZIPAGE_SANITIZE: {len(violations)} engine invariant "
            "violation(s) after step "
            f"{engine.step_count}:\n  - " + "\n  - ".join(violations))
