"""Token sampling: per-request params and the jit-compatible batch sampler.

``SamplingParams`` is the request-scoped contract of the serving API
(re-exported as ``repro.api.SamplingParams``).  ``sample_batch`` is the
engine's device-side sampler: every row carries its own temperature,
top-k/top-p and PRNG state, so one fixed-shape jitted call serves a
continuous batch of heterogeneous requests.

Per-row randomness is keyed by ``fold_in(key(seed), n_generated)`` — a
request's token stream depends only on its own (seed, position), never on
batch composition, admission order, or preemption. That is what makes
per-request seeds reproducible under continuous batching.
"""
from __future__ import annotations

import dataclasses
import difflib
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling / termination parameters (vLLM-style).

    temperature <= 0 means greedy (argmax). ``top_k <= 0`` disables the
    top-k filter; ``top_p`` must be in (0, 1], where exactly ``1.0``
    disables nucleus filtering. ``stop`` is a tuple of
    token-id sequences; a match ends the request with finish reason
    ``"stop"`` and the matched tokens are truncated from the output.
    ``eos_ids`` lists token ids that terminate generation (kept in the
    output); ``None`` disables eos detection entirely — there is no ``-1``
    sentinel in this API. ``seed`` drives the per-request PRNG stream;
    ``logprobs`` requests the sampled token's logprob at each position.

    ``compression_policy`` states the request's KV-compression intent
    (docs/EVAL.md): ``"default"`` follows the engine-wide budget,
    ``"protect"`` defers compression and shields the request from
    preemption while memory allows, ``"aggressive"`` compresses at the
    earliest opportunity and volunteers first for preemption.

    OpenAI spellings are accepted where they map cleanly:
    ``max_tokens`` is a validated alias of ``max_new_tokens`` (passing
    both with different values is an error), and ``n`` is accepted but
    must be 1 — parallel sampling is one-request-per-stream here.
    Unknown keyword arguments are rejected with a did-you-mean error
    rather than silently ignored.
    """
    temperature: float = 0.0
    top_k: int = 0
    top_p: float = 1.0
    max_new_tokens: int = 16
    stop: Tuple[Tuple[int, ...], ...] = ()
    eos_ids: Optional[Tuple[int, ...]] = None
    seed: int = 0
    logprobs: bool = False
    compression_policy: str = "default"
    # OpenAI-spelled aliases (docs/SERVING.md): normalized in __post_init__
    # so equality/replace always see the canonical fields
    max_tokens: Optional[int] = None     # alias of max_new_tokens
    n: int = 1                           # only n=1 is supported

    def __post_init__(self):
        if self.n != 1:
            raise ValueError(
                f"n={self.n} (parallel sampling) is not supported: the "
                "engine serves one stream per request. Submit n separate "
                "requests sharing the prompt (one seed each) and fan the "
                "choices in client-side.")
        if self.max_tokens is not None:
            if (self.max_new_tokens != _DEFAULT_MAX_NEW
                    and self.max_new_tokens != self.max_tokens):
                raise ValueError(
                    f"max_tokens={self.max_tokens} conflicts with "
                    f"max_new_tokens={self.max_new_tokens}; max_tokens is "
                    "an alias — pass one or the other")
            object.__setattr__(self, "max_new_tokens", int(self.max_tokens))
            object.__setattr__(self, "max_tokens", None)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.compression_policy not in ("default", "protect",
                                           "aggressive"):
            raise ValueError(
                "compression_policy must be one of "
                "'default' | 'protect' | 'aggressive'")
        if not (0.0 < self.top_p <= 1.0):
            raise ValueError("top_p must be in (0, 1]")
        # normalize stop/eos to hashable tuples (lists are convenient at
        # call sites; the engine relies on immutability)
        object.__setattr__(self, "stop", tuple(
            tuple(int(t) for t in s) for s in self.stop))
        if self.eos_ids is not None:
            object.__setattr__(self, "eos_ids", tuple(
                int(t) for t in self.eos_ids))

    @property
    def is_greedy(self) -> bool:
        return self.temperature <= 0.0

    @classmethod
    def from_legacy(cls, max_new_tokens: int, eos_id: int = -1,
                    temperature: float = 0.0, seed: int = 0
                    ) -> "SamplingParams":
        """Map the old ``submit(..., eos_id=-1)`` sentinel convention
        (kept for the frozen ``tests/_legacy_engine.py`` oracle)."""
        return cls(temperature=temperature, seed=seed,
                   max_new_tokens=max_new_tokens,
                   eos_ids=None if eos_id < 0 else (eos_id,))


_DEFAULT_MAX_NEW = 16      # must match the field default above
_PARAM_FIELDS = tuple(f.name for f in dataclasses.fields(SamplingParams))

# wrap the dataclass-generated __init__ so unknown keyword arguments get a
# did-you-mean error instead of a bare TypeError (callers routinely arrive
# from JSON request bodies where a typo would otherwise read as "ignored")
_dataclass_init = SamplingParams.__init__


def _checked_init(self, *args, **kwargs):
    unknown = [k for k in kwargs if k not in _PARAM_FIELDS]
    if unknown:
        hints = []
        for k in unknown:
            close = difflib.get_close_matches(k, _PARAM_FIELDS, n=1)
            hints.append(f"{k!r}" + (f" (did you mean {close[0]!r}?)"
                                     if close else ""))
        raise TypeError(
            f"unknown SamplingParams field(s) {', '.join(hints)}; known "
            f"fields: {', '.join(_PARAM_FIELDS)}")
    _dataclass_init(self, *args, **kwargs)


_checked_init.__wrapped__ = _dataclass_init
SamplingParams.__init__ = _checked_init


def matched_stop(output: Sequence[int],
                 params: SamplingParams) -> Optional[Tuple[int, ...]]:
    """The stop token-sequence the output currently ends with, if any."""
    for s in params.stop:
        if s and len(output) >= len(s) and tuple(output[-len(s):]) == s:
            return s
    return None


# ----------------------------------------------------------------------
# device-side samplers

def sample(logits, key, *, temperature=0.6, greedy=False):
    """Legacy batch-uniform sampler. logits: (B, V) fp32 -> (B,) int32."""
    if greedy or temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, -1).astype(jnp.int32)


def sample_batch(logits, seeds, counters, temps, top_k, top_p):
    """Per-row temperature / top-k / top-p sampling with per-row PRNG.

    logits: (B, V) fp32; seeds/counters: (B,) uint32/int32 per-row PRNG
    state; temps/top_p: (B,) fp32; top_k: (B,) int32 (<=0 disables).
    Returns (tokens (B,) int32, logprobs (B,) fp32) where logprobs are
    log-softmax of the *unfiltered* distribution at the chosen token.
    Rows with temp <= 0 take the argmax.
    """
    V = logits.shape[-1]
    greedy_tok = jnp.argmax(logits, -1).astype(jnp.int32)
    full_logprobs = jax.nn.log_softmax(logits, -1)

    sorted_logits, sorted_idx = jax.lax.top_k(logits, V)
    ranks = jnp.arange(V)[None, :]
    k = jnp.where(top_k[:, None] > 0, top_k[:, None], V)
    probs = jax.nn.softmax(sorted_logits, -1)
    cum = jnp.cumsum(probs, -1)
    # nucleus: keep tokens while the mass *before* them is < top_p, so the
    # highest-probability token always survives
    keep = (ranks < k) & ((cum - probs) < top_p[:, None])
    masked = jnp.where(keep, sorted_logits, -jnp.inf)
    scaled = masked / jnp.maximum(temps, 1e-6)[:, None]

    def draw(seed, counter, row):
        key = jax.random.fold_in(jax.random.key(seed), counter)
        return jax.random.categorical(key, row)

    rank_sampled = jax.vmap(draw)(seeds, counters, scaled)
    sampled_tok = jnp.take_along_axis(
        sorted_idx, rank_sampled[:, None], -1)[:, 0].astype(jnp.int32)
    tok = jnp.where(temps <= 0.0, greedy_tok, sampled_tok)
    lp = jnp.take_along_axis(full_logprobs, tok[:, None], -1)[:, 0]
    return tok, lp
