"""Token sampling."""
import jax
import jax.numpy as jnp


def sample(logits, key, *, temperature=0.6, greedy=False):
    """logits: (B, V) fp32 -> (B,) int32."""
    if greedy or temperature <= 0:
        return jnp.argmax(logits, -1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature, -1).astype(jnp.int32)
