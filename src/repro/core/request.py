"""Request object and lifecycle states (paper Fig. 2)."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional

from repro.core.sampling import SamplingParams, matched_stop


class State(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"          # decoding (slot assigned)
    BLOCKED = "blocked"          # in running queue, cannot decode (no block /
    #                              slotless past the b-w boundary)
    COMPRESSING = "compressing"  # async compression in flight, skips decode
    SWAPPED = "swapped"          # preempted to the host swap tier; KV parked
    #                              in CPU memory, awaiting swap-in
    FINISHED = "finished"


class FinishReason:
    STOP = "stop"                # eos token or stop sequence
    LENGTH = "length"            # hit max_new_tokens
    ABORT = "abort"              # cancelled via abort()


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    arrival: float = 0.0
    sampling: SamplingParams = dataclasses.field(
        default_factory=SamplingParams)
    priority: int = 0                  # higher = served first ("priority"
    #                                    scheduler policy; FCFS ignores it)

    state: State = State.WAITING
    output: List[int] = dataclasses.field(default_factory=list)
    logprobs: List[float] = dataclasses.field(default_factory=list)
    finish_reason: Optional[str] = None
    blocks: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    qslot: int = -1
    compressed: bool = False           # has undergone >=1 compression
    seq_len: int = 0                   # cache entries (cache order)
    position: int = 0                  # absolute next position
    n_cached: int = 0                  # prefix-cache hit tokens
    # compressed-prefix adoption (docs/CACHING.md): token position minus
    # cache index. 0 normally; a segment hit sets it to the tokens the
    # compressed payload condensed away (span - k), and the engine's
    # prefill subtracts it when deriving cache-write indices from token
    # positions.
    pos_gap: int = 0
    chain: List[int] = dataclasses.field(default_factory=list)
    n_shared: int = 0                  # shared blocks at admission
    preempt_count: int = 0
    n_swaps: int = 0                   # swap-mode preemptions among those
    win_count: int = 0                 # observation-window entries captured

    # chunked-prefill progress (owned by repro.core.scheduler): tokens of
    # ``full_prompt`` already written to the KV cache vs the admission-time
    # target. Equal once prefill completes; a token-budget-limited step may
    # leave a gap that later steps close.
    n_prefilled: int = 0
    prefill_target: int = 0

    # per-request compression metrics
    n_compressions: int = 0            # compression events undergone
    comp_blocks_freed: int = 0         # blocks released by those events

    # quality telemetry from the last compression launch (written back by
    # the engine one step later, once the stats fetch is free): mean raw
    # redundancy over retained entries and normalized window-attention
    # entropy in [0, 1]. None until the request first compresses. The
    # scheduler's quality-aware planner (docs/EVAL.md) orders candidates
    # and shields eviction victims with these.
    redundancy: Optional[float] = None
    attn_entropy: Optional[float] = None

    # metrics
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def full_prompt(self) -> List[int]:
        """Effective prompt on (re-)admission: original + generated so far."""
        return self.prompt + self.output

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    @property
    def prefill_pending(self) -> bool:
        """True while admitted but not yet fully prefilled (chunked prefill
        spread over multiple steps by the scheduler's token budget)."""
        return self.n_prefilled < self.prefill_target

    def remaining_work(self) -> int:
        """Tokens still to process (prefill remainder + decode remainder);
        the shortest-remaining ("srpt") policy key."""
        if self.state == State.WAITING:
            pre = len(self.prompt) + len(self.output)
        else:
            pre = max(0, self.prefill_target - self.n_prefilled)
        return pre + max(0, self.max_new_tokens - len(self.output))

    def tokens_in_last_block(self, block_size: int) -> int:
        r = self.seq_len % block_size
        return block_size if (r == 0 and self.seq_len > 0) else r

    def check_finish(self) -> Optional[str]:
        """Finish reason the request has reached, or None if still going."""
        sp = self.sampling
        if self.output:
            if sp.eos_ids is not None and self.output[-1] in sp.eos_ids:
                return FinishReason.STOP
            if matched_stop(self.output, sp) is not None:
                return FinishReason.STOP
        if len(self.output) >= self.max_new_tokens:
            return FinishReason.LENGTH
        return None

    def done(self) -> bool:
        return self.check_finish() is not None

    def truncate_stop(self) -> None:
        """Drop a matched stop sequence from the tail of the output
        (eos tokens are kept, vLLM-style)."""
        s = matched_stop(self.output, self.sampling)
        if s is not None:
            del self.output[-len(s):]
            del self.logprobs[len(self.output):]
