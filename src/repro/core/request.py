"""Request object and lifecycle states (paper Fig. 2)."""
from __future__ import annotations

import dataclasses
import enum
from typing import List, Optional


class State(enum.Enum):
    WAITING = "waiting"
    RUNNING = "running"          # decoding (slot assigned)
    BLOCKED = "blocked"          # in running queue, cannot decode (no block /
    #                              slotless past the b-w boundary)
    COMPRESSING = "compressing"  # async compression in flight, skips decode
    FINISHED = "finished"


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int
    eos_id: int = -1
    arrival: float = 0.0

    state: State = State.WAITING
    output: List[int] = dataclasses.field(default_factory=list)
    blocks: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    qslot: int = -1
    compressed: bool = False           # has undergone >=1 compression
    seq_len: int = 0                   # cache entries (cache order)
    position: int = 0                  # absolute next position
    n_cached: int = 0                  # prefix-cache hit tokens
    chain: List[int] = dataclasses.field(default_factory=list)
    n_shared: int = 0                  # shared blocks at admission
    preempt_count: int = 0
    win_count: int = 0                 # observation-window entries captured

    # metrics
    t_first_token: Optional[float] = None
    t_finish: Optional[float] = None

    @property
    def full_prompt(self) -> List[int]:
        """Effective prompt on (re-)admission: original + generated so far."""
        return self.prompt + self.output

    @property
    def n_blocks(self) -> int:
        return len(self.blocks)

    def tokens_in_last_block(self, block_size: int) -> int:
        r = self.seq_len % block_size
        return block_size if (r == 0 and self.seq_len > 0) else r

    def done(self) -> bool:
        if self.output and self.eos_id >= 0 and self.output[-1] == self.eos_id:
            return True
        return len(self.output) >= self.max_new_tokens
