"""The compression-aware Scheduler: the paper's "comprehensive scheduling
strategy" (§4.3–§4.5) as a standalone, pluggable subsystem.

Pure host-side logic — no JAX imports. The scheduler owns the request
queues (waiting / running / finished), the decode- and query-slot pools,
and every admission / preemption / compression-planning decision; the
engine (``repro.core.engine.ZipageEngine``) owns the device state and
merely *executes* the :class:`SchedulerOutputs` plan each step produces.

Per-step protocol (driven by ``ZipageEngine.step()``):

    plan = scheduler.schedule()            # qslots, admission, prefill chunks
    engine runs prefill from plan.prefill_chunks
    scheduler.plan_compression(plan)       # detect + pick dest blocks (§4.4)
    engine launches the compression kernel from plan.compress
    scheduler.commit_compression(plan)     # release blocks, swap tables
    active = scheduler.schedule_decode(plan)   # growth, blocking, preemption
    engine decodes `active`
    scheduler.end_step(plan)               # async rejoin + finish detection
    scheduler.observe_latency(dt)          # straggler-aware admission scale

The plan is refined in phases rather than produced whole because the
observation-window counters that gate compression only land with the final
prefill chunk, and finish detection depends on the tokens the device
sampled — see docs/SCHEDULER.md for the full queue lifecycle.

Pluggable policies (``SchedulerConfig.policy`` on the ``repro.api``
facade): ``fcfs`` (default — byte-for-byte the pre-extraction engine
behavior), ``priority`` (``Request.priority`` descending), ``srpt``
(shortest remaining work first) and ``cache_aware`` (most reusable
prefix first, scored by a side-effect-free radix-tree probe —
docs/CACHING.md). Preemption victim order is a policy too
(``SchedulerConfig.preemption``; defaults to the admission policy's
reverse).
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings
from collections import deque
from typing import Deque, Dict, List, Optional, Sequence

from repro.core.block_manager import BlockManager
from repro.core.request import Request, State

# ----------------------------------------------------------------------
# configuration


@dataclasses.dataclass(frozen=True)
class SchedulerParams:
    """Everything the scheduler needs to decide, nothing the device needs.

    Built by the engine from ``EngineOptions`` + model-derived flags; built
    directly in tests (the point of the extraction: policy logic is unit-
    testable without a model or JAX).
    """
    block_size: int = 16
    max_batch: int = 16              # decode slots
    m_qslots: int = 8                # paper's M (query-slot pool)
    n_max: Optional[int] = 4         # block cap; None => full-KV baseline
    window: int = 4                  # observation window w
    scheduling: str = "hybrid"       # hybrid | constrained (§4.3)
    async_compression: bool = True
    prefill_rows: int = 4            # admission batch ceiling per step
    # --- policy knobs (SchedulerConfig on the repro.api facade) ---
    policy: str = "fcfs"             # fcfs | priority | srpt | cache_aware
    preemption: Optional[str] = None  # victim-order policy; None => policy
    # what preemption *does* (docs/SCHEDULER.md "Preemption modes"):
    # "recompute" frees the victim's blocks and re-prefills on
    # re-admission; "swap" parks its KV in the host swap tier and restores
    # it block-for-block; "auto" picks per victim by the cost model below
    preemption_mode: str = "recompute"   # recompute | swap | auto
    # auto cost model: host-copy cost of one KV token-slot (one direction)
    # in re-prefill-token equivalents. swap iff
    #   2 * n_blocks * block_size * swap_cost_per_token < len(full_prompt)
    # — a compressed victim (small n, long history) swaps, a short
    # uncompressed one recomputes.
    swap_cost_per_token: float = 0.5
    block_bytes: int = 0             # KV bytes per block (swap telemetry)
    token_budget: Optional[int] = None   # prefill+decode tokens per step
    max_prefill_chunk: Optional[int] = None  # per-request chunk cap per step
    admission_margin: float = 0.0    # fraction of projected growth reserved
    # cache *compressed* prefixes too (docs/CACHING.md): at a request's
    # first prompt-pure compression, keep the condensed payload registered
    # as a radix segment later prompts can adopt wholesale. Requires the
    # radix prefix-cache policy; off by default because an adopted
    # continuation is not bit-identical to a cold run (the compression is
    # lossy).
    cache_compressed_prefixes: bool = False
    # multi-step decode ceiling (docs/PERF.md): max fused decode+sample
    # iterations per engine step; quiescent_horizon() trims it per request
    decode_steps: int = 1
    # --- quality-aware compression (docs/EVAL.md) ---
    # feed the per-request scoring telemetry (Request.redundancy /
    # Request.attn_entropy, written back by the engine after each
    # compression launch) back into planning: candidates compress
    # lowest-redundancy-first, "default"-policy requests defer compression
    # by `compression_deferral` blocks past n_max while the pool keeps
    # `quality_defer_min_free` blocks free, and requests whose window
    # attention entropy is >= `quality_entropy_threshold` are shielded
    # from preemption while an unshielded victim exists. Off by default:
    # the planner is then byte-identical to the pre-quality scheduler.
    quality_aware: bool = False
    compression_deferral: int = 2    # extra blocks past n_max before a
    #                                  deferring request must compress
    quality_defer_min_free: int = 16  # free-pool floor for deferral
    quality_entropy_threshold: float = 0.85  # normalized entropy in [0,1]
    # --- model/engine-derived flags ---
    compression_enabled: bool = True
    budget_blocks: int = 3           # n_max - 1 (compression destination)
    prefix_ok: bool = True
    attention_free: bool = False
    ring_blocks: int = 0             # local-window ring size (0 = paged)


@dataclasses.dataclass(frozen=True)
class PrefillChunk:
    """One request's prefill work this step: ``full_prompt[start:start+n]``.
    ``is_final`` marks the chunk that completes the prompt — only then is a
    first token sampled and the observation window considered primed."""
    request: Request
    start: int
    n_tokens: int
    is_final: bool


@dataclasses.dataclass(frozen=True)
class CompressionLaunch:
    """A planned compression (§4.4): write the compressed KV into ``dest``,
    keep ``reserved`` as the in-progress block, return ``release`` to the
    pool once the kernel has consumed the sources."""
    request: Request
    dest: List[int]
    reserved: int
    release: List[int]


@dataclasses.dataclass
class SchedulerOutputs:
    """The explicit per-step plan ``ZipageEngine.step()`` executes."""
    step: int = 0
    admitted: List[Request] = dataclasses.field(default_factory=list)
    prefill_chunks: List[PrefillChunk] = dataclasses.field(
        default_factory=list)
    compress: List[CompressionLaunch] = dataclasses.field(
        default_factory=list)
    decode: List[Request] = dataclasses.field(default_factory=list)
    preempted: List[Request] = dataclasses.field(default_factory=list)
    swapped_out: List[Request] = dataclasses.field(default_factory=list)
    swapped_in: List[Request] = dataclasses.field(default_factory=list)
    finished: List[Request] = dataclasses.field(default_factory=list)
    n_blocked: int = 0
    token_budget: Optional[int] = None

    @property
    def n_prefill_tokens(self) -> int:
        return sum(c.n_tokens for c in self.prefill_chunks)

    @property
    def n_scheduled_tokens(self) -> int:
        return self.n_prefill_tokens + len(self.decode)


# ----------------------------------------------------------------------
# policies


class SchedulingPolicy:
    """Ordering hooks. ``admission_order`` ranks the waiting queue (admission
    is strict head-of-line within that order: the first request that does
    not fit stops the pass, preserving the paper's FCFS fairness argument);
    ``victim_order`` ranks running requests most-preemptible first."""
    name = "base"

    def admission_order(self, waiting: Sequence[Request]) -> List[Request]:
        raise NotImplementedError

    def victim_order(self, running: Sequence[Request]) -> List[Request]:
        raise NotImplementedError


class FcfsPolicy(SchedulingPolicy):
    """Arrival order in, LIFO out — exactly the pre-extraction engine."""
    name = "fcfs"

    def admission_order(self, waiting):
        return list(waiting)

    def victim_order(self, running):
        return list(reversed(running))


class PriorityPolicy(SchedulingPolicy):
    """``Request.priority`` descending (ties: arrival order); victims are
    the lowest-priority, most-recently-admitted requests."""
    name = "priority"

    def admission_order(self, waiting):
        return sorted(waiting, key=lambda r: (-r.priority, r.arrival, r.rid))

    def victim_order(self, running):
        order = list(enumerate(running))
        order.sort(key=lambda ir: (ir[1].priority, -ir[0]))
        return [r for _i, r in order]


class SrptPolicy(SchedulingPolicy):
    """Shortest remaining work first (prefill remainder + decode remainder);
    victims are the longest-remaining requests. Minimises mean latency on
    reasoning workloads with known generation caps."""
    name = "srpt"

    def admission_order(self, waiting):
        return sorted(waiting,
                      key=lambda r: (r.remaining_work(), r.arrival, r.rid))

    def victim_order(self, running):
        order = list(enumerate(running))
        order.sort(key=lambda ir: (-ir[1].remaining_work(), -ir[0]))
        return [r for _i, r in order]


class CacheAwarePolicy(SchedulingPolicy):
    """Most-reusable-prefix-first admission (docs/CACHING.md): waiting
    requests are scored by the prompt tokens a side-effect-free prefix-cache
    probe (``BlockManager.probe_prefix``) says the pool already holds,
    highest first, ties broken by arrival — so head-of-line blocking never
    strands a cheap cache hit behind an expensive miss, and cached blocks
    become admitted requests before pool pressure evicts them. Victims are
    FCFS-like (most recently admitted first): the newest request has
    accumulated the least reusable state. Bound to the engine's block
    manager at scheduler construction (``bind``); unbound it degrades to
    plain FCFS ordering."""
    name = "cache_aware"

    def __init__(self):
        self.bm: Optional[BlockManager] = None
        self.allow_compressed = False

    def bind(self, bm: BlockManager, allow_compressed: bool = False) -> None:
        self.bm = bm
        self.allow_compressed = allow_compressed

    def _score(self, r: Request) -> int:
        if self.bm is None:
            return 0
        return self.bm.probe_prefix(r.full_prompt,
                                    allow_compressed=self.allow_compressed)

    def admission_order(self, waiting):
        return sorted(waiting,
                      key=lambda r: (-self._score(r), r.arrival, r.rid))

    def victim_order(self, running):
        return list(reversed(running))


POLICIES = {p.name: p for p in (FcfsPolicy(), PriorityPolicy(),
                                SrptPolicy(), CacheAwarePolicy())}


def make_policy(name: str) -> SchedulingPolicy:
    try:
        proto = POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown scheduler policy {name!r}; expected one "
                         f"of {tuple(POLICIES)}") from None
    # a fresh instance per scheduler: stateful policies (cache_aware binds
    # its engine's block manager) must not leak state across engines
    return type(proto)()


# ----------------------------------------------------------------------


class Scheduler:
    """Owns the queues and every scheduling decision; see module docstring
    for the per-step protocol."""

    def __init__(self, params: SchedulerParams, bm: BlockManager):
        if params.token_budget is not None \
                and params.token_budget < params.max_batch:
            raise ValueError(
                f"token_budget ({params.token_budget}) must be >= max_batch "
                f"({params.max_batch}) so every running request can decode "
                "each step")
        if params.admission_margin < 0:
            raise ValueError("admission_margin must be >= 0")
        if params.decode_steps < 1:
            raise ValueError("decode_steps must be >= 1")
        if params.compression_deferral < 0:
            raise ValueError("compression_deferral must be >= 0")
        if params.quality_defer_min_free < 0:
            raise ValueError("quality_defer_min_free must be >= 0")
        if params.preemption_mode not in ("recompute", "swap", "auto"):
            raise ValueError(
                f"unknown preemption_mode {params.preemption_mode!r}; "
                "expected one of ('recompute', 'swap', 'auto')")
        if params.preemption_mode == "swap" and bm.swap_space_blocks <= 0:
            raise ValueError(
                "preemption_mode='swap' requires swap_space_blocks > 0 "
                "(the host swap tier is sized by CacheConfig."
                "swap_space_blocks)")
        if params.preemption_mode == "auto" and bm.swap_space_blocks <= 0:
            warnings.warn(
                "preemption_mode='auto' with swap_space_blocks=0: the "
                "swap tier is unarmed, every preemption will recompute",
                stacklevel=2)
        if params.cache_compressed_prefixes \
                and bm.prefix_cache_policy != "radix":
            raise ValueError(
                "cache_compressed_prefixes=True requires "
                "prefix_cache_policy='radix' — the flat prefix cache "
                "cannot index compressed segments")
        self.p = params
        self.bm = bm
        self.policy = make_policy(params.policy)
        self.preempt_policy = make_policy(params.preemption
                                          or params.policy)
        for pol in (self.policy, self.preempt_policy):
            if hasattr(pol, "bind"):
                pol.bind(bm, params.cache_compressed_prefixes)
        self.waiting: Deque[Request] = deque()
        self.running: List[Request] = []      # admission order
        self.swapped: Deque[Request] = deque()   # host swap tier, FIFO
        self.finished: Dict[int, Request] = {}
        # swap execution is device work: the engine registers these two
        # callbacks (swap_executor(r, device_blocks, host_blocks) and
        # swap_in_executor(r, host_blocks, device_blocks)) when the host
        # swap tier is enabled and the arch supports it (paged attention,
        # no per-slot recurrent state). They run synchronously at plan
        # time so a victim's KV is parked before its blocks are reused.
        # None => swap unavailable, every preemption recomputes.
        self.swap_executor = None
        self.swap_in_executor = None
        # cumulative swap telemetry (surfaced via stats())
        self.n_swapped_out = 0
        self.n_swapped_in = 0
        self.swap_bytes = 0
        # cumulative quality telemetry (stats(); docs/EVAL.md): compression
        # events by SamplingParams.compression_policy, plus (request, step)
        # instances where the quality planner deferred a base-rule-due
        # compression
        self.n_comp_by_policy = {"default": 0, "protect": 0,
                                 "aggressive": 0}
        self.n_comp_deferred = 0
        self.free_slots = list(range(params.max_batch - 1, -1, -1))
        self.free_qslots = list(range(params.m_qslots - 1, -1, -1))
        # straggler-aware admission: EWMA of step latency vs baseline
        self.ewma: Optional[float] = None
        self.admission_scale = 1.0
        # monotonically increasing whenever scheduler-owned state that the
        # device tables mirror (slots, qslots, block lists, seq lens)
        # changes; the engine compares it against the last pushed version
        # to skip redundant host->device table uploads (docs/PERF.md)
        self.version = 0

    # ------------------------------------------------------------------
    # queue entry points

    def add_request(self, r: Request) -> None:
        self.waiting.append(r)

    def has_work(self) -> bool:
        return bool(self.waiting or self.running or self.swapped)

    def abort(self, rid: int) -> Optional[Request]:
        """Remove a waiting/running/swapped request, return its blocks to
        the pool and hand it back for finish bookkeeping (None if
        unknown)."""
        for r in list(self.waiting):
            if r.rid == rid:
                self.waiting.remove(r)
                return r
        for r in self.running:
            if r.rid == rid:
                self._release_slots(r)
                self.running.remove(r)
                return r
        for r in list(self.swapped):
            if r.rid == rid:
                self.bm.release_swapped(rid)
                self.swapped.remove(r)
                return r
        return None

    # ------------------------------------------------------------------
    # shared helpers

    def _needed_blocks(self, n_tokens: int) -> int:
        if self.p.attention_free:
            return 0
        if self.p.ring_blocks:
            return self.p.ring_blocks
        return -(-n_tokens // self.p.block_size)

    def _projected_blocks(self, n_tokens: int,
                          r: Optional[Request] = None) -> int:
        """Steady-state footprint of ``n_tokens``: with compression on, the
        block cap bounds it — the paper's lever for admission (§4.3). With
        a request in hand the cap is its *effective* one (``_n_max_cap``),
        so a deferring ``protect`` request projects the extra blocks it
        will actually hold."""
        raw = self._needed_blocks(n_tokens)
        if self.p.compression_enabled and self.p.n_max is not None:
            cap = self.p.n_max if r is None else self._n_max_cap(r)
            return min(raw, cap)
        return raw

    def projected_growth(self) -> int:
        """Blocks the running batch may still demand, under *post-
        compression* projections: each request's final footprint is capped
        at ``n_max`` once it compresses, so with compression on this stays
        small no matter how long the generations run."""
        total = 0
        for r in self.running:
            final_len = len(r.prompt) + len(r.output) \
                + max(0, r.max_new_tokens - len(r.output))
            total += max(0,
                         self._projected_blocks(final_len, r) - r.n_blocks)
        return total

    def _release_slots(self, r: Request) -> None:
        """Return r's blocks, decode slot and query slot to their pools
        (shared by preempt/finish/abort)."""
        self.version += 1
        self.bm.release(r.blocks)
        r.blocks = []
        if r.slot >= 0:
            self.free_slots.append(r.slot)
        if r.qslot >= 0:
            self.free_qslots.append(r.qslot)
        r.slot = r.qslot = -1

    # ------------------------------------------------------------------
    # quality-aware compression planning (docs/EVAL.md)

    @staticmethod
    def _comp_policy(r: Request) -> str:
        """The request's ``SamplingParams.compression_policy``."""
        return r.sampling.compression_policy

    def _n_max_cap(self, r: Request, worst_case: bool = False) -> int:
        """Effective block cap at which ``r``'s compression comes due.

        ``aggressive`` compresses at the paper's base cap ``n_max``;
        ``protect`` always defers by ``2 * compression_deferral`` extra
        blocks (per-request intent needs no global knob); ``default``
        defers by ``compression_deferral`` only when the planner is
        ``quality_aware`` *and* the pool has headroom
        (``quality_defer_min_free`` free blocks) — so the default path is
        bit-identical to the base rule unless opted in. Callers guarantee
        ``compression_enabled`` (n_max is not None).

        ``worst_case`` ignores the instantaneous pool headroom and
        returns the static envelope — what the sanitizer audits against,
        since a request deferred while the pool had headroom legitimately
        holds its extra blocks for a step or two after the pool fills."""
        n_max = self.p.n_max
        pol = self._comp_policy(r)
        if pol == "aggressive":
            return n_max
        if pol == "protect":
            return n_max + 2 * self.p.compression_deferral
        if self.p.quality_aware \
                and (worst_case
                     or self.bm.num_free >= self.p.quality_defer_min_free):
            return n_max + self.p.compression_deferral
        return n_max

    def _compression_due(self, r: Request) -> bool:
        """The single compression-trigger predicate shared by
        ``plan_compression`` (ready filter) and ``schedule_decode`` (the
        "compression will handle it" block gate) — keeping the two phases
        consistent by construction."""
        return (self.p.compression_enabled and r.qslot >= 0
                and r.seq_len == r.n_blocks * self.p.block_size
                and r.win_count >= self.p.window
                and r.n_blocks >= self._n_max_cap(r))

    def _victim_shielded(self, r: Request) -> bool:
        """Whether eviction should pass over ``r`` while an unshielded
        victim exists: explicit per-request intent (``protect``), or —
        under the quality-aware planner — measured high attention entropy
        (eviction of spread-attention requests is what degrades reasoning
        traces; docs/EVAL.md). ``aggressive`` requests volunteered, so
        telemetry never shields them."""
        pol = self._comp_policy(r)
        if pol == "protect":
            return True
        return (self.p.quality_aware and pol != "aggressive"
                and r.attn_entropy is not None
                and r.attn_entropy >= self.p.quality_entropy_threshold)

    def _preempt_mode(self, r: Request) -> str:
        """Resolve what preemption does to this victim (docs/SCHEDULER.md).
        Falls back to recompute whenever swap is unavailable: no engine
        executor (unsupported arch), no blocks to park, or a full swap
        pool."""
        mode = self.p.preemption_mode
        if mode == "recompute":
            return "recompute"
        if (self.swap_executor is None or not r.blocks
                or not self.bm.can_swap_out(r.n_blocks)):
            return "recompute"
        if mode == "swap":
            return "swap"
        # auto: bytes moved (out now + back in later) vs re-prefilling the
        # full accumulated prompt. A compressed victim holds n_max-ish
        # blocks against a far longer history — swap wins; a short
        # uncompressed one is cheaper to recompute.
        swap_cost = (2 * r.n_blocks * self.p.block_size
                     * self.p.swap_cost_per_token)
        recompute_cost = len(r.prompt) + len(r.output)
        return "swap" if swap_cost < recompute_cost else "recompute"

    def _reset_for_recompute(self, r: Request) -> None:
        """Recompute-mode bookkeeping: all progress is discarded; the
        generated tokens survive as prompt suffix (``full_prompt``) and
        the request re-enters the front of the waiting queue."""
        r.compressed = False
        r.seq_len = r.position = 0
        r.n_cached = 0
        r.pos_gap = 0
        r.win_count = 0
        r.n_prefilled = r.prefill_target = 0
        r.state = State.WAITING
        self.waiting.appendleft(r)       # front of waiting queue (§3)

    def _preempt(self, r: Request, outs: Optional[SchedulerOutputs]) -> None:
        if self._preempt_mode(r) == "swap":
            self._swap_out(r, outs)
            return
        self._release_slots(r)
        r.preempt_count += 1
        self.running.remove(r)
        self._reset_for_recompute(r)
        if outs is not None:
            outs.preempted.append(r)

    def _swap_out(self, r: Request, outs: Optional[SchedulerOutputs]) -> None:
        """Swap-mode preemption: park the victim's KV in the host swap
        pool, then free its device resources. Unlike recompute, all
        progress state (seq_len/position/compressed/prefill cursor, and —
        via the executor — the observation window and its win_count)
        survives the round trip. Shared prefix blocks are copy-on-swap:
        the host copy makes the restore self-contained while the device
        ref merely drops."""
        self.version += 1
        host_blocks = self.bm.swap_out(r.rid, r.n_blocks)
        # the executor also parks the observation-window rows while the
        # victim still owns its qslot, so win_count survives the swap
        self.swap_executor(r, list(r.blocks), host_blocks)
        self.bm.release(r.blocks)        # prefix-safe: shared blocks decref
        r.blocks = []
        if r.slot >= 0:
            self.free_slots.append(r.slot)
        if r.qslot >= 0:
            self.free_qslots.append(r.qslot)
        r.slot = r.qslot = -1
        r.n_shared = 0
        r.preempt_count += 1
        r.n_swaps += 1
        r.state = State.SWAPPED
        self.running.remove(r)
        self.swapped.append(r)
        self.n_swapped_out += 1
        self.swap_bytes += len(host_blocks) * self.p.block_bytes
        if outs is not None:
            outs.preempted.append(r)
            outs.swapped_out.append(r)

    def _find_victim(self, requester: Request,
                     exclude: frozenset = frozenset()) -> Optional[Request]:
        """§4.3/§4.4 victim tiers, in two passes: the first skips quality-
        shielded requests (``_victim_shielded``), the second admits them —
        shielding redirects pressure, it never deadlocks it. With no
        shielded or ``aggressive`` request present both passes reduce to
        the pre-quality search exactly."""
        victim = self._find_victim_pass(requester, exclude, shielded=True)
        if victim is None:
            victim = self._find_victim_pass(requester, exclude,
                                            shielded=False)
        return victim

    def _find_victim_pass(self, requester: Request, exclude: frozenset,
                          shielded: bool) -> Optional[Request]:
        """§4.3/§4.4 victim tiers — slotless first under hybrid scheduling,
        then uncompressed under prefix caching — ordered within each tier
        by the preemption policy (``aggressive``-policy volunteers
        stable-partitioned first). ``exclude`` holds requests that must not
        be preempted (e.g. peers already planned into this step's
        compression set, whose block lists a launch still references);
        ``shielded=True`` additionally passes over quality-shielded
        requests."""
        order = self.preempt_policy.victim_order(self.running)
        if any(self._comp_policy(r) == "aggressive" for r in order):
            order = ([r for r in order
                      if self._comp_policy(r) == "aggressive"]
                     + [r for r in order
                        if self._comp_policy(r) != "aggressive"])
        if shielded:
            order = [r for r in order if not self._victim_shielded(r)]
        if self.p.scheduling == "hybrid":
            for r in order:
                if r is requester or r.rid in exclude \
                        or r.state == State.FINISHED:
                    continue
                if r.qslot < 0:
                    # a compressed request can be slotless here only after
                    # a qslot-starved swap-in; recompute-preempting it
                    # would discard its condensed KV, so it stays
                    # swap-only even in this tier
                    if r.compressed and self._preempt_mode(r) != "swap":
                        continue
                    return r
        if self.p.prefix_ok:
            for r in order:
                if r is requester or r.rid in exclude \
                        or r.state == State.FINISHED:
                    continue
                if not r.compressed:
                    return r
        # swap-only tier: compressed victims are never recompute-preempted
        # (re-prefilling would both waste the compression and rebuild raw
        # KV, changing their downstream tokens), but the host swap tier
        # preserves their compressed KV exactly — and moves n_max-fewer
        # blocks doing it, so eviction-then-swap beats either alone.
        if self.p.preemption_mode != "recompute":
            for r in order:
                if r is requester or r.rid in exclude \
                        or r.state == State.FINISHED:
                    continue
                if r.compressed and self._preempt_mode(r) == "swap":
                    return r
        return None

    def _preempt_for_blocks(self, n_needed: int, requester: Request,
                            outs: Optional[SchedulerOutputs],
                            exclude: frozenset = frozenset()) -> bool:
        """Free blocks via preemption per §4.3/§4.4 rules. Returns success."""
        while not self.bm.can_allocate(n_needed):
            victim = self._find_victim(requester, exclude)
            if victim is None:
                return False
            self._preempt(victim, outs)
        return True

    def _can_decode_slotless(self, r: Request) -> bool:
        """Hybrid rule: decode without a qslot while < N_max blocks or
        < b - w tokens in the last block."""
        b, w = self.p.block_size, self.p.window
        return (r.n_blocks < self.p.n_max
                or r.tokens_in_last_block(b) < b - w)

    def _assign_qslots(self) -> None:
        """Paper §4.3 rule 3: free query slots go to the foremost running
        requests lacking one (only first M are eligible)."""
        if not self.p.compression_enabled:
            return
        for i, r in enumerate(self.running):
            if not self.free_qslots:
                break
            if i >= self.p.m_qslots:
                break
            if r.qslot < 0 and r.state != State.FINISHED:
                r.qslot = self.free_qslots.pop()
                self.version += 1
                if r.state == State.BLOCKED:
                    r.state = State.RUNNING

    # ------------------------------------------------------------------
    # phase 1: admission + prefill-chunk planning

    def schedule(self, step: int = 0) -> SchedulerOutputs:
        outs = SchedulerOutputs(step=step,
                                token_budget=self.p.token_budget)
        self._swap_in_ready(outs)
        self._assign_qslots()
        # token budget shared across prefill + decode (continuous batching):
        # every decodable running request is reserved one token up front,
        # prefill chunks split what remains.
        if self.p.token_budget is None:
            prefill_avail = math.inf
        else:
            n_decode_est = sum(1 for r in self.running
                               if r.state != State.FINISHED
                               and not r.prefill_pending and not r.done())
            prefill_avail = max(0, self.p.token_budget - n_decode_est)
        max_chunk = self.p.max_prefill_chunk or math.inf
        # carried-over partial prefills (token-budget mode) come first, in
        # admission order — they already hold slots and blocks.
        for r in self.running:
            if not r.prefill_pending:
                continue
            prefill_avail = self._plan_chunk(outs, r, prefill_avail,
                                             max_chunk)
        self._admit(outs, prefill_avail, max_chunk)
        return outs

    def _plan_chunk(self, outs: SchedulerOutputs, r: Request,
                    prefill_avail, max_chunk):
        """Plan one request's prefill chunk for this step. A final chunk
        reserves one extra budget token: the request decodes in the same
        step once its prompt completes, and that decode shares the
        budget."""
        rem = r.prefill_target - r.n_prefilled
        cap = min(rem, max_chunk)
        if cap >= rem and prefill_avail >= rem + 1:
            outs.prefill_chunks.append(PrefillChunk(r, r.n_prefilled, rem,
                                                    is_final=True))
            return prefill_avail - (rem + 1)
        # a non-final chunk must leave >=1 prompt token for the final one —
        # only final chunks sample the first token
        take = int(min(cap, max(0, prefill_avail), rem - 1))
        if take > 0:
            outs.prefill_chunks.append(PrefillChunk(r, r.n_prefilled, take,
                                                    is_final=False))
            return prefill_avail - take
        return prefill_avail

    def _swap_in_ready(self, outs: SchedulerOutputs) -> None:
        """Re-admit swapped requests (FIFO — they already spent their
        prefill compute) while a decode slot and device blocks are
        available under the same admission margin waiting requests face.
        The engine's swap-in executor restores the KV synchronously, so
        the request decodes this very step."""
        # a swapped queue with no executor (e.g. a swap-mode snapshot
        # restored into an engine without a swap tier) can never swap in:
        # demote those requests to recompute re-admission — their parked
        # KV is unreachable, but full_prompt rebuilds it
        while self.swapped and self.swap_in_executor is None:
            r = self.swapped.popleft()
            self.bm.release_swapped(r.rid)
            self._reset_for_recompute(r)
        while self.swapped:
            r = self.swapped[0]
            n = self.bm.n_swapped_blocks(r.rid)
            if not self.free_slots:
                break
            margin = 0
            if self.p.admission_margin > 0:
                final_len = len(r.prompt) + r.max_new_tokens
                own = max(0, self._projected_blocks(final_len) - n)
                margin = math.ceil(self.p.admission_margin
                                   * (self.projected_growth() + own))
            if not self.bm.can_allocate(n, margin=margin):
                break
            self.version += 1
            host_blocks = self.bm.swapped_blocks(r.rid)
            r.blocks = self.bm.allocate(n)
            r.slot = self.free_slots.pop()
            if self.p.compression_enabled and self.free_qslots \
                    and len(self.running) < self.p.m_qslots:
                r.qslot = self.free_qslots.pop()
            r.state = State.RUNNING
            # slot/qslot + blocks are assigned before the copy: the
            # executor re-arms tokens_next for the new slot and, given a
            # qslot, restores the parked observation window (returns
            # truthy); without that restore the window must re-prime
            if not self.swap_in_executor(r, host_blocks, r.blocks):
                r.win_count = 0
            self.bm.release_swapped(r.rid)
            self.swapped.popleft()
            self.running.append(r)
            self.n_swapped_in += 1
            self.swap_bytes += n * self.p.block_bytes
            outs.swapped_in.append(r)

    def _admit(self, outs: SchedulerOutputs, prefill_avail, max_chunk):
        if self.swapped:
            # anti-thrash: while a swapped request cannot come back (the
            # head of the queue lacks a slot or blocks), admitting fresh
            # prompts would grab exactly the resources it is waiting for
            return prefill_avail
        limit = max(1, int(self.p.prefill_rows * self.admission_scale))
        for r in self.policy.admission_order(self.waiting):
            if len(outs.admitted) >= limit or not self.free_slots:
                break
            if self.p.scheduling == "constrained" \
                    and self.p.compression_enabled and not self.free_qslots:
                break
            prompt = r.full_prompt
            if prefill_avail < 1:
                break                    # no token budget left this step
            if self.p.prefix_ok:
                m = self.bm.lookup_prefix_ex(
                    prompt,
                    allow_compressed=self.p.cache_compressed_prefixes)
                shared, n_cached, chain = m.blocks, m.n_tokens, m.chain
                # a compressed-segment hit covers more tokens than the KV
                # entries it occupies; the gap shifts every cache index
                # below the token position for the rest of the request's
                # life (Request.pos_gap)
                pos_gap = m.n_tokens - m.n_entries
            else:
                shared, n_cached, chain = [], 0, []
                pos_gap = 0
            n_new = self._needed_blocks(len(prompt) - pos_gap) - len(shared)
            # compression-aware admission: beyond the prompt's own blocks,
            # require `admission_margin` of the batch's projected *post-
            # compression* growth to stay free. margin 0.0 (default) is the
            # paper's greedy admit-then-preempt behavior.
            margin = 0
            if self.p.admission_margin > 0:
                # final length counts max_new_tokens from the *original*
                # prompt — full_prompt already contains any tokens a
                # preempted request generated, and max_new_tokens caps the
                # total output
                final_len = len(r.prompt) + r.max_new_tokens
                own_growth = max(
                    0,
                    self._projected_blocks(final_len)
                    - self._needed_blocks(len(prompt)))
                margin = math.ceil(self.p.admission_margin
                                   * (self.projected_growth() + own_growth))
                # cache-aware refinement: matched blocks are KV the pool
                # already holds — admitting this request does not compete
                # with the batch's projected growth for them, so the
                # reserve shrinks by the hit size
                margin = max(0, margin - len(shared))
            if not self.bm.can_allocate(n_new, margin=margin):
                # roll back the prefix refs and stop admitting (strict
                # head-of-line within the policy order)
                if shared:
                    self.bm.release(shared)
                break
            self.version += 1
            new_blocks = self.bm.allocate(n_new) if n_new else []
            r.blocks = shared + new_blocks
            r.n_cached, r.chain, r.n_shared = n_cached, chain, len(shared)
            r.pos_gap = pos_gap
            # an adopted segment's blocks sit below token positions the
            # chain hashes describe — registering them would serve
            # compressed KV as raw; only gap-free admissions register
            if self.p.prefix_ok and chain and pos_gap == 0:
                self.bm.register_prefix(r.blocks, chain, len(shared))
            r.slot = self.free_slots.pop()
            if self.p.compression_enabled and self.free_qslots \
                    and len(self.running) < self.p.m_qslots:
                r.qslot = self.free_qslots.pop()
            ring = self.p.ring_blocks
            r.seq_len = (min(len(prompt), ring) if ring
                         else (0 if self.p.attention_free
                               else len(prompt) - pos_gap))
            r.position = len(prompt)
            if pos_gap:
                r.compressed = True      # lives under compressed accounting
            r.state = State.RUNNING
            r.n_prefilled = r.n_cached
            r.prefill_target = len(prompt)
            self.waiting.remove(r)
            self.running.append(r)
            outs.admitted.append(r)
            # a zero-token final chunk still flows through prefill so the
            # first token is sampled (full prefix-cache hit)
            prefill_avail = self._plan_chunk(outs, r, prefill_avail,
                                             max_chunk)
        return prefill_avail

    # ------------------------------------------------------------------
    # phase 2: compression planning (after prefill — window counters land
    # with the final chunk)

    def plan_compression(self, outs: SchedulerOutputs) -> None:
        if not self.p.compression_enabled:
            return
        b = self.p.block_size
        eligible = [r for r in self.running
                    if r.state in (State.RUNNING, State.BLOCKED)
                    and not r.prefill_pending
                    and r.qslot >= 0
                    and r.seq_len == r.n_blocks * b
                    and r.win_count >= self.p.window]
        ready = [r for r in eligible if self._compression_due(r)]
        # quality telemetry: base-rule-due candidates the effective cap
        # (_n_max_cap) let keep their full KV another step
        self.n_comp_deferred += sum(
            1 for r in eligible
            if r.n_blocks >= self.p.n_max and not self._compression_due(r))
        if self.p.quality_aware and len(ready) > 1:
            # lowest-redundancy-first within each policy class (ROADMAP
            # item 5 / docs/EVAL.md): aggressive volunteers lead, protect
            # trails; un-measured requests (no telemetry yet) keep their
            # running-order position at the back of their class
            rank = {"aggressive": 0, "default": 1, "protect": 2}
            ready = [r for _i, r in sorted(
                enumerate(ready),
                key=lambda ir: (rank[self._comp_policy(ir[1])],
                                ir[1].redundancy is None,
                                ir[1].redundancy or 0.0, ir[0]))]
        nb = self.p.budget_blocks
        # compression-ready peers are off-limits for preemption here: an
        # earlier launch in this set still references their block lists,
        # and preempting a later one would empty the blocks this very loop
        # is about to slice
        no_preempt = frozenset(r.rid for r in ready)
        def cow_need(r):
            # copy-on-write: a block another reader depends on — shared
            # prefix (ref > 1), cached compressed-segment payload, or a
            # radix cache registration — must not be overwritten in
            # place; compression copies into fresh dest blocks instead
            n_prefix = sum(1 for blk in r.blocks
                           if self.bm.is_cow_protected(blk))
            need = 0
            if n_prefix:
                need = min(n_prefix, nb)
                if self.bm.is_cow_protected(
                        r.blocks[min(nb, r.n_blocks - 1)]):
                    need += 1                      # reserved must be fresh too
            return n_prefix, need

        for r in ready:
            n_prefix, need = cow_need(r)
            if need and not self.bm.can_allocate(need) \
                    and not self._preempt_for_blocks(need, r, outs,
                                                     exclude=no_preempt):
                # out of road: no free or evictable block and no
                # preemptible victim (a whole batch can be compression-
                # ready at once, and ready peers shield each other). A
                # protection that exists only for the cache's benefit — a
                # sole-referenced radix registration, not a segment
                # payload — is best-effort: drop those registrations and
                # condense in place (the legacy behavior, minus its stale
                # entries) rather than deadlock the batch on fresh blocks
                # that can never materialise.
                soft = [blk for blk in r.blocks
                        if self.bm.ref[blk] == 1
                        and blk in self.bm.block_hash
                        and blk not in self.bm.seg_of_block]
                if soft:
                    self.bm.invalidate_blocks(soft)
                    n_prefix, need = cow_need(r)
                if need and not self.bm.can_allocate(need):
                    r.state = State.BLOCKED        # retry next step
                    continue
            if n_prefix == 0:
                dest = r.blocks[:nb]
                reserved = r.blocks[nb]
                release = r.blocks[nb + 1:]
            else:
                fresh = self.bm.allocate(min(n_prefix, nb))
                dest = fresh + r.blocks[n_prefix:][:nb - len(fresh)]
                if self.bm.is_cow_protected(
                        r.blocks[min(nb, r.n_blocks - 1)]):
                    reserved = self.bm.allocate(1)[0]
                    keep = set(dest) | {reserved}
                    release = [blk for blk in r.blocks if blk not in keep]
                else:
                    reserved = r.blocks[nb] if len(r.blocks) > nb else \
                        self.bm.allocate(1)[0]
                    keep = set(dest) | {reserved}
                    release = [blk for blk in r.blocks if blk not in keep]
            outs.compress.append(CompressionLaunch(r, dest, reserved,
                                                   release))

    def commit_compression(self, outs: SchedulerOutputs) -> None:
        """Deterministic host bookkeeping once the kernel is launched:
        release the source blocks, swap in the compressed table, and (in
        async mode) park the request for this step's decode (§4.5)."""
        k = self.p.budget_blocks * self.p.block_size
        if outs.compress:
            self.version += 1
        for c in outs.compress:
            r = c.request
            span = r.seq_len                 # tokens this launch condenses
            first = not r.compressed
            shared_released = [blk for blk in c.release
                               if self.bm.ref[blk] > 1]
            self.bm.release(c.release)
            r.n_compressions += 1
            r.comp_blocks_freed += len(c.release) - len(shared_released)
            self.n_comp_by_policy[self._comp_policy(r)] += 1
            r.blocks = list(c.dest) + [c.reserved]
            r.seq_len = k
            r.compressed = True
            r.n_shared = 0
            if self.bm.prefix_cache_policy == "radix":
                # the kernel overwrites dest/reserved in place: any cache
                # registration naming them would serve condensed KV under a
                # raw-KV hash — drop it, subtree and all (flat keeps the
                # legacy behavior for parity with the frozen engine)
                self.bm.invalidate_blocks(r.blocks)
                if (self.p.cache_compressed_prefixes and first
                        and span <= r.prefill_target
                        and 0 < span // self.p.block_size <= len(r.chain)):
                    # prompt-pure first compression (no decoded token in
                    # the span, so the condensed payload and the selection
                    # that produced it depend only on the prompt): cache it
                    # as a segment keyed by the span-ending chain hash
                    self.bm.register_segment(
                        r.chain[span // self.p.block_size - 1],
                        list(c.dest), span)
            if self.p.async_compression:
                r.state = State.COMPRESSING     # sits out this decode step

    # ------------------------------------------------------------------
    # phase 3: decode planning

    def schedule_decode(self, outs: SchedulerOutputs) -> List[Request]:
        """Ensure every decodable request has room for one token; apply
        blocking/preemption rules. Fills ``outs.decode``."""
        b = self.p.block_size
        active = []
        for r in list(self.running):
            if r.state == State.COMPRESSING:
                continue
            if r.prefill_pending:
                continue                 # chunked prefill still in flight
            if r.done():
                # already terminated (eos/stop on the prefill-sampled
                # token); decoding again would bury the match under a
                # second token before end_step sees it
                continue
            if r.state == State.BLOCKED:
                r.state = State.RUNNING          # retry below
            if r not in self.running:            # got preempted this step
                continue
            if self.p.attention_free:
                active.append(r)
                continue
            if self.p.ring_blocks:
                active.append(r)
                continue
            # hybrid slotless boundary rule
            if (self.p.compression_enabled and r.qslot < 0
                    and not self._can_decode_slotless(r)):
                r.state = State.BLOCKED
                continue
            if r.seq_len == r.n_blocks * b:      # last block full
                if self._compression_due(r):
                    # compression will handle it (was detected this step or
                    # will be next step); skip decode if it somehow races
                    r.state = State.BLOCKED
                    continue
                ok = self.bm.can_allocate(1) or \
                    self._preempt_for_blocks(1, r, outs)
                if not ok or r not in self.running:
                    if r in self.running:
                        r.state = State.BLOCKED
                    continue
                blk = self.bm.allocate(1)[0]
                r.blocks.append(blk)
                self.version += 1
            active.append(r)
        outs.decode = [r for r in active if r in self.running]
        return outs.decode

    # ------------------------------------------------------------------
    # multi-step decode horizon (docs/PERF.md)

    def quiescent_horizon(self, active: Sequence[Request],
                          outs: Optional[SchedulerOutputs] = None):
        """Per-request *host-free* decode budgets for this step, and the
        fused scan length ``K = max(caps)`` (capped by ``decode_steps``).

        ``caps[i]`` is how many consecutive tokens ``active[i]`` can decode
        before a decision only the host can make comes due: a block
        allocation or compression launch (last allocated block fills), the
        hybrid slotless ``b - w`` boundary (§4.3), finish-by-length, or
        per-token stop-sequence matching. A row whose cap is below K simply
        sits out the scan's remaining iterations (the decode batch is
        dense, so the masked rows cost nothing) and resumes next step —
        its (seed, position)-keyed token stream is unaffected.

        Under a ``token_budget`` each row's cap is additionally bounded by
        its even share of what this step's prefill chunks (``outs``) left
        over, preserving the per-step invariant
        ``n_prefill_tokens + n_decode <= token_budget``.

        Returns ``(K, caps)`` with ``caps`` aligned to ``active``;
        ``K == 1`` reproduces single-step scheduling exactly.
        """
        limit = self.p.decode_steps
        if self.p.token_budget is not None and active:
            avail = self.p.token_budget \
                - (outs.n_prefill_tokens if outs else 0)
            # schedule() reserved one token per decodable row up front,
            # so every active row's share is at least 1
            limit = min(limit, max(1, avail // len(active)))
        caps = []
        for r in active:
            if limit <= 1 or r.sampling.stop:
                caps.append(1)        # host matches stop sequences per token
                continue
            c = min(limit, r.max_new_tokens - len(r.output))
            caps.append(max(1, self._host_free_steps(r, c)))
        return max(caps, default=1), caps

    def _host_free_steps(self, r: Request, cap: int) -> int:
        """Consecutive decode tokens ``r`` can take without host
        intervention, at most ``cap``. The first token was already
        validated (and its block allocated) by ``schedule_decode``."""
        if self.p.attention_free or self.p.ring_blocks:
            return cap               # no paged growth: length-bound only
        b, w = self.p.block_size, self.p.window
        s, n = r.seq_len + 1, r.n_blocks
        k = 1
        while k < cap:
            if s >= n * b:
                break                # needs a block (or compression) next
            if self.p.compression_enabled and r.qslot < 0:
                til = b if (s % b == 0 and s > 0) else s % b
                if not (n < self.p.n_max or til < b - w):
                    break            # hybrid slotless boundary (§4.3)
            s += 1
            k += 1
        return k

    # ------------------------------------------------------------------
    # phase 4: step epilogue

    def end_step(self, outs: SchedulerOutputs) -> List[Request]:
        """Async-compressed requests rejoin; finished requests release their
        resources. Returns (and records) the newly finished."""
        for r in self.running:
            if r.state == State.COMPRESSING:
                r.state = State.RUNNING
        for r in list(self.running):
            if r.state == State.COMPRESSING or r.prefill_pending:
                continue
            reason = r.check_finish()
            if reason is None:
                continue
            r.finish_reason = reason
            r.truncate_stop()
            self._register_finished_prefix(r)
            self._release_slots(r)
            r.state = State.FINISHED
            r.t_finish = time.monotonic()
            self.running.remove(r)
            self.finished[r.rid] = r
            outs.finished.append(r)
        outs.n_blocked = sum(1 for r in self.running
                             if r.state == State.BLOCKED)
        return outs.finished

    def _register_finished_prefix(self, r: Request) -> None:
        """Radix multi-turn reuse (docs/CACHING.md): before a finished
        request's blocks return to the pool, register its *generated*
        tokens' full blocks under the extended hash chain. The next turn of
        the conversation — prompt + this output + a new user message —
        then longest-prefix matches straight through the generation instead
        of stopping at the old prompt boundary. Only raw (uncompressed,
        gap-free) KV is registerable; compressed requests contribute via
        ``cache_compressed_prefixes`` segments instead."""
        if (self.bm.prefix_cache_policy != "radix" or not self.p.prefix_ok
                or r.compressed or r.pos_gap or not r.blocks
                or self.p.ring_blocks or self.p.attention_free):
            return
        b = self.p.block_size
        stream = r.full_prompt
        # seq_len counts KV entries actually written; truncate_stop may
        # have trimmed the stream below it, and the final sampled token's
        # KV was never written — min() keeps hashes honest
        n_full = min(min(r.seq_len, len(stream)) // b, r.n_blocks)
        if n_full <= 0:
            return
        h, chain = 0, []
        for i in range(n_full):
            h = self.bm.chain_hash(h, tuple(stream[i * b:(i + 1) * b]))
            chain.append(h)
        self.bm.register_prefix(r.blocks, chain, 0)

    def observe_latency(self, dt: float) -> None:
        """Straggler-aware admission: back off when step latency inflates."""
        self.ewma = dt if self.ewma is None else 0.9 * self.ewma + 0.1 * dt
        if self.ewma > 0 and dt > 3.0 * self.ewma:
            self.admission_scale = max(0.25, self.admission_scale * 0.5)
        else:
            self.admission_scale = min(1.0, self.admission_scale * 1.1)

    # ------------------------------------------------------------------
    def stats(self, outs: SchedulerOutputs,
              n_decoded: Optional[int] = None) -> dict:
        """Per-step telemetry merged into the engine's metrics entries and
        surfaced as ``Zipage.scheduler_stats`` (docs/SCHEDULER.md).
        ``n_decoded`` is the number of decode tokens actually emitted —
        under a multi-step horizon that exceeds ``len(outs.decode)``, and
        ``budget_util`` must reflect it."""
        scheduled = outs.n_prefill_tokens + (
            n_decoded if n_decoded is not None else len(outs.decode))
        return {
            "policy": self.policy.name,
            "preemption_mode": self.p.preemption_mode,
            "n_admitted": len(outs.admitted),
            "n_preempted": len(outs.preempted),
            "n_swapped_out": len(outs.swapped_out),
            "n_swapped_in": len(outs.swapped_in),
            "n_swapped": len(self.swapped),
            "swap_bytes": self.swap_bytes,
            "swap_util": self.bm.swap_util,
            "n_blocked": outs.n_blocked,
            "n_finished": len(outs.finished),
            "n_prefill_tokens": outs.n_prefill_tokens,
            "n_scheduled_tokens": scheduled,
            "token_budget": outs.token_budget,
            "budget_util": (scheduled / outs.token_budget
                            if outs.token_budget else None),
            "free_blocks": self.bm.num_free,
            "admission_scale": self.admission_scale,
            # quality-aware compression telemetry (cumulative;
            # docs/EVAL.md): events by SamplingParams.compression_policy
            # plus quality-planner deferrals
            "quality_aware": self.p.quality_aware,
            "n_comp_default": self.n_comp_by_policy["default"],
            "n_comp_protect": self.n_comp_by_policy["protect"],
            "n_comp_aggressive": self.n_comp_by_policy["aggressive"],
            "n_comp_deferred": self.n_comp_deferred,
            # prefix-cache telemetry (cumulative; docs/CACHING.md)
            **self.bm.cache_stats(),
        }
