"""Zipage: the Compressed-PagedAttention serving engine (paper §4).

The engine is the *execution* half of the serving stack: it owns the
device state, the jitted prefill/decode/compress steps and the host
mirrors that feed them. Every scheduling decision — admission, chunked-
prefill token budgeting, compression planning, preemption, finish
bookkeeping — lives in the standalone ``repro.core.scheduler.Scheduler``
subsystem (docs/SCHEDULER.md); ``step()`` merely executes the
:class:`~repro.core.scheduler.SchedulerOutputs` plan it produces:

  * continuous batching over fixed decode slots with a shared
    prefill+decode token budget,
  * Compressed PagedAttention with per-request block cap N_max (§4.1/4.2),
  * constrained + hybrid scheduling with query-slot accounting (§4.3),
  * block-level prefix caching with compression into target blocks (§4.4),
  * asynchronous compression: compressing requests sit out one decode step
    and rejoin; decode of the rest is dispatched without waiting (§4.5),
  * preemption with a schedulable *mode* — recompute, host-KV swap
    (CPU swap pool, batched block gather/scatter), or an auto cost model
    picking per victim — plus pluggable victim order, pluggable
    admission policies (FCFS / priority / shortest-remaining), and
    compression-aware admission margins,
  * per-request sampling (``SamplingParams``: temperature/top-k/top-p with
    per-request PRNG streams, stop sequences, eos sets, logprobs),
  * mid-flight cancellation (``abort``) returning blocks to the pool,
  * snapshot/restore fault tolerance.

This is the internal layer; the public surface is ``repro.api.Zipage``.

Setting ``n_max=None`` disables compression entirely, which *is* the
nano-vLLM baseline of the paper's comparisons (plain PagedAttention).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import invariants, serve_model
from repro.core.block_manager import BlockManager
from repro.core.compression import CompressOptions, build_compress_fn
from repro.core.request import FinishReason, Request, State
from repro.core.sampling import SamplingParams, sample_batch
from repro.core.scheduler import (PrefillChunk, Scheduler, SchedulerOutputs,
                                  SchedulerParams)

# compiled compression executables shared across engines with identical
# (arch, serve-spec, compress-options, bucket) signatures, so warming the
# n ∈ {1, 2, 4} buckets at engine init (ISSUE 4 satellite) costs one
# compile per unique configuration per process, not one per engine
_COMPRESS_CACHE: Dict[tuple, callable] = {}

# fused decode+sample steps (docs/PERF.md), likewise shared per
# (arch, serve-spec, chunk-length): the jit objects (and the XLA
# executables they cache) are reused across engines, so warming at init
# compiles each chunk length once per process
_FUSED_CACHE: Dict[tuple, callable] = {}

# prefill / unfused-decode jits shared per (kind, arch, serve-spec) — the
# step builders are pure functions of (cfg, spec), so engines with the
# same signature reuse one jit object instead of recompiling
_STEP_CACHE: Dict[tuple, callable] = {}

# swap gather/scatter jits (host swap tier, docs/SCHEDULER.md) shared per
# (kind, arch, serve-spec); block ids are padded to max_blocks so one
# executable serves every victim size
_SWAP_CACHE: Dict[tuple, callable] = {}

_SAMPLER = None      # module-wide jit of sampling.sample_batch


def _cached_step(kind: str, cfg, spec):
    key = (kind, cfg, spec)
    fn = _STEP_CACHE.get(key)
    if fn is None:
        build = (serve_model.build_prefill_step if kind == "prefill"
                 else serve_model.build_decode_step)
        fn = jax.jit(build(cfg, spec), donate_argnums=(1,))
        _STEP_CACHE[key] = fn
    return fn


def _sampler_jit():
    global _SAMPLER
    if _SAMPLER is None:
        _SAMPLER = jax.jit(sample_batch)
    return _SAMPLER


def _fused_chunk_sizes(k: int) -> List[int]:
    """Decompose a horizon into power-of-two dispatch lengths
    (largest-first), so only O(log decode_steps) scan lengths are ever
    compiled; a single big chunk is split in half so the token fetch for
    chunk N can overlap chunk N+1's compute (pipelined fetch)."""
    sizes = []
    rem = k
    while rem:
        p = 1 << (rem.bit_length() - 1)
        sizes.append(p)
        rem -= p
    if len(sizes) == 1 and k >= 4:
        sizes = [k // 2, k // 2]
    return sizes


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    block_size: int = 16
    n_total_blocks: int = 256
    max_batch: int = 16              # decode slots
    m_qslots: int = 8                # paper's M (query-slot pool)
    n_max: Optional[int] = 4         # block cap; None => full-KV baseline
    window: int = 4                  # observation window w
    scheduling: str = "hybrid"       # hybrid | constrained
    prefix_caching: bool = True
    # prefix-cache structure (docs/CACHING.md): "radix" (default) is the
    # SGLang-style radix tree — longest-prefix match anywhere in the
    # waiting queue, leaf-first LRU eviction, multi-turn reuse of finished
    # generations; "flat" is the legacy exact-match hash map, kept for
    # parity with the frozen pre-radix engine
    prefix_cache_policy: str = "radix"
    # cap unreferenced cached blocks at this fraction of the pool (LRU
    # eviction beyond it); 1.0 = only reclaim under allocation pressure
    prefix_cache_watermark: float = 1.0
    # additionally cache compressed prefixes as radix segments other
    # prompts can adopt (lossy — adopted continuations are not
    # bit-identical to cold runs; docs/CACHING.md)
    cache_compressed_prefixes: bool = False
    async_compression: bool = True
    compress: CompressOptions = dataclasses.field(
        default_factory=lambda: CompressOptions(window=4))
    max_model_len: int = 512
    prefill_rows: int = 4
    prefill_len: int = 128
    # scheduler policy knobs (repro.core.scheduler / docs/SCHEDULER.md);
    # surfaced as SchedulerConfig on the repro.api facade
    policy: str = "fcfs"             # fcfs | priority | srpt
    preemption: Optional[str] = None  # victim-order policy; None => policy
    # host swap tier (docs/SCHEDULER.md "Preemption modes"): what
    # preemption does (recompute | swap | auto) and how many CPU-side
    # block slots back it (0 disables swap entirely)
    preemption_mode: str = "recompute"
    swap_space_blocks: int = 0
    swap_cost_per_token: float = 0.5  # auto cost model's exchange rate
    token_budget: Optional[int] = None   # prefill+decode tokens per step
    max_prefill_chunk: Optional[int] = None  # per-request chunk cap per step
    admission_margin: float = 0.0    # fraction of projected growth reserved
    # quality-aware compression planning (docs/EVAL.md; SchedulerConfig on
    # the facade): order candidates lowest-redundancy-first, defer
    # default-policy compressions while the pool has headroom, and shield
    # high-attention-entropy requests from preemption. Off by default —
    # the planner is then bit-identical to the pre-quality scheduler.
    quality_aware: bool = False
    compression_deferral: int = 2
    quality_defer_min_free: int = 16
    quality_entropy_threshold: float = 0.85
    # decode hot-path knobs (docs/PERF.md; ModelRunnerConfig on the facade):
    # fuse_sampling runs the per-slot sampler inside the jitted decode step
    # (no (B, V) logits materialisation, tokens stay on device);
    # decode_steps > 1 additionally runs up to that many decode+sample
    # iterations per dispatch (lax.scan) within the scheduler's
    # quiescent_horizon(). decode_steps > 1 requires fuse_sampling.
    fuse_sampling: bool = True
    decode_steps: int = 1
    # Engine-global sampling defaults, used only when ``add_request()`` is
    # called without a ``SamplingParams``. Public callers always pass one
    # (the ``repro.api`` facade constructs it); the retired ``submit()``
    # shim was the last API that leaned on these.
    temperature: float = 0.0         # 0 => greedy
    seed: int = 0
    dtype: str = "float32"
    measure_phases: bool = False     # block per phase for timing benches
    # engine-wide kernel backend (repro.kernels.ops): auto | jnp |
    # pallas-interpret | pallas-tpu, plus "chunked" (decode attention only).
    # Drives ServeSpec.attn_backend and — when compress.backend is left at
    # "auto" — the compression kernels too.
    kernel_backend: str = "auto"
    # decode kernel family (ServeSpec.decode_kernel): "ragged" makes
    # per-slot attention work proportional to the slot's live block count
    # (padded/evicted pages never fetched); "dense" is the pool-wide-grid
    # fallback. Streams are bit-identical either way (docs/KERNELS.md).
    decode_kernel: str = "ragged"


class ZipageEngine:
    def __init__(self, cfg: ArchConfig, params, opts: EngineOptions):
        # compression inherits the engine-wide kernel backend unless its
        # CompressOptions.backend was configured away from "auto"
        # ("chunked" is decode-attention-only and does not propagate)
        if opts.compress.backend == "auto" \
                and opts.kernel_backend not in ("auto", "chunked"):
            opts = dataclasses.replace(opts, compress=dataclasses.replace(
                opts.compress, backend=opts.kernel_backend))
        self.cfg = cfg
        self.opts = opts
        self.params = params
        b = opts.block_size
        assert opts.window == opts.compress.window
        self.compression_enabled = (
            opts.n_max is not None and not cfg.attention_free
            and not cfg.local_window)
        self.budget_blocks = (opts.n_max - 1) if self.compression_enabled else 0
        self.max_blocks = -(-opts.max_model_len // b)
        self.spec = serve_model.ServeSpec(
            n_slots=opts.max_batch, block_size=b, max_blocks=self.max_blocks,
            n_total_blocks=opts.n_total_blocks, m_qslots=opts.m_qslots,
            window=opts.window, prefill_rows=opts.prefill_rows,
            prefill_len=opts.prefill_len, dtype=opts.dtype,
            attn_backend=opts.kernel_backend,
            decode_kernel=opts.decode_kernel)
        if opts.decode_steps > 1 and not opts.fuse_sampling:
            raise ValueError("decode_steps > 1 requires fuse_sampling")
        prefix_ok = (opts.prefix_caching and not cfg.attention_free
                     and not cfg.local_window and not cfg.is_enc_dec)
        self.prefix_ok = prefix_ok
        self._ring = (self.spec.ring_blocks(cfg) if cfg.local_window else 0)
        self.state = serve_model.make_state(cfg, self.spec)
        # fused-decode device state (docs/PERF.md): the next input token,
        # the per-slot live mask and the per-slot PRNG counter live on
        # device so consecutive fused dispatches chain without a host
        # round-trip. Present in both modes so snapshots are
        # mode-portable; the unfused path simply never reads them.
        self.state["tokens_next"] = jnp.zeros((opts.max_batch,), jnp.int32)
        self.state["active_mask"] = jnp.zeros((opts.max_batch,), bool)
        self.state["sample_counters"] = jnp.zeros((opts.max_batch,),
                                                  jnp.int32)
        # host swap tier (docs/SCHEDULER.md): only paged-attention archs
        # without per-slot recurrent/cross state can vacate a slot and
        # restore elsewhere — the KV pool is the whole story for them
        self._swap_ok = (opts.swap_space_blocks > 0
                         and "pools" in self.state and not self._ring
                         and "rec" not in self.state
                         and "cross_kv" not in self.state)
        if opts.swap_space_blocks > 0 and not self._swap_ok:
            warnings.warn(
                f"preemption_mode={opts.preemption_mode!r} cannot swap on "
                "this arch (recurrent/ring/enc-dec state is per-slot, not "
                "paged); falling back to recompute-mode preemption",
                stacklevel=2)
        # the scheduling subsystem: owns queues, slot pools and the block
        # manager; every policy decision happens in there
        self.scheduler = Scheduler(
            SchedulerParams(
                block_size=b, max_batch=opts.max_batch,
                m_qslots=opts.m_qslots, n_max=opts.n_max,
                window=opts.window, scheduling=opts.scheduling,
                async_compression=opts.async_compression,
                prefill_rows=opts.prefill_rows,
                policy=opts.policy, preemption=opts.preemption,
                # arch can't swap (warned above): degrade to recompute.
                # swap_space_blocks == 0 passes the mode through so the
                # scheduler rejects the contradictory config.
                preemption_mode=(opts.preemption_mode
                                 if self._swap_ok
                                 or opts.swap_space_blocks == 0
                                 else "recompute"),
                swap_cost_per_token=opts.swap_cost_per_token,
                block_bytes=self._kv_block_bytes(),
                token_budget=opts.token_budget,
                max_prefill_chunk=opts.max_prefill_chunk,
                admission_margin=opts.admission_margin,
                quality_aware=opts.quality_aware,
                compression_deferral=opts.compression_deferral,
                quality_defer_min_free=opts.quality_defer_min_free,
                quality_entropy_threshold=opts.quality_entropy_threshold,
                # compressed-prefix caching needs segments to register
                # (compression on) and hits to be adoptable (prefix on);
                # outside that it is silently inert, not an error
                cache_compressed_prefixes=(opts.cache_compressed_prefixes
                                           and self.compression_enabled
                                           and prefix_ok),
                decode_steps=opts.decode_steps,
                compression_enabled=self.compression_enabled,
                budget_blocks=self.budget_blocks,
                prefix_ok=prefix_ok, attention_free=cfg.attention_free,
                ring_blocks=self._ring),
            BlockManager(opts.n_total_blocks, b,
                         enable_prefix_cache=prefix_ok,
                         swap_space_blocks=(opts.swap_space_blocks
                                            if self._swap_ok else 0),
                         prefix_cache_policy=opts.prefix_cache_policy,
                         prefix_cache_watermark=opts.prefix_cache_watermark))
        self._decode = _cached_step("decode", cfg, self.spec)
        self._prefill = _cached_step("prefill", cfg, self.spec)
        self._fused_fns: Dict[int, callable] = {}
        self._compress_fns: Dict[tuple, callable] = {}
        self._comp_bufs: Dict[tuple, tuple] = {}
        # host mirrors of the device tables (rebuilt from scheduler state
        # before each push)
        self.host_bt = np.full((opts.max_batch, self.max_blocks), -1, np.int32)
        self.host_seq = np.zeros((opts.max_batch,), np.int32)
        self.host_pos = np.zeros((opts.max_batch,), np.int32)
        self.host_qslot = np.full((opts.max_batch,), -1, np.int32)
        self.tokens_next = np.zeros((opts.max_batch,), np.int32)
        # dirty tracking: device tables are re-pushed only when the
        # scheduler's state version moved past what was last uploaded;
        # sampling-state mirrors track what the fused path believes lives
        # on device (None = unknown -> full push)
        self._pushed_version = -1
        self._tokens_dirty = True
        self._dev_mask: Optional[np.ndarray] = None
        self._dev_counters: Optional[np.ndarray] = None
        self._samp_version = -1
        self._samp_arrays = None
        self._eos_width = 1
        self._t_blocked = 0.0
        self._step_decoded = 0
        self._last_horizon = 0
        self._step_pages_visited = 0
        self._step_pages_dense = 0

        self._rid = 0
        self._rng = np.random.default_rng(opts.seed)
        self._sampler = _sampler_jit()
        # quality telemetry in flight: (rids, device stats) from the last
        # compression launch, fetched lazily at the START of the next step
        # — by then the step's token fetch has already synced the device,
        # so the read is free and async compression keeps its overlap
        self._pending_quality = None
        self.metrics: List[dict] = []
        # step hooks: called with each step's metrics entry after the step
        # completes — the async serving loop (repro.api.aio) uses this for
        # load-aware Retry-After estimates without polling ``metrics``
        self.step_hooks: List[Callable[[dict], None]] = []
        self.step_count = 0
        self.swap_pool: Optional[Dict[str, np.ndarray]] = None
        self._swap_qwin: Dict[int, np.ndarray] = {}   # rid -> parked window
        self._swap_bufs: Dict[int, dict] = {}         # bucket -> staging
        # runtime sanitizer (docs/ANALYSIS.md): whole-engine state audit
        # after every step when ZIPAGE_SANITIZE=1; _qwin_shadow holds
        # host copies of free observation-window rows so writes to rows
        # no active slot owns are caught (the PR-4 qwin masking bug class)
        self.sanitize = invariants.enabled()
        self._qwin_shadow: Dict[int, np.ndarray] = {}
        if self._swap_ok:
            self._init_swap()
        if self.compression_enabled:
            self._warm_compression()
        if opts.fuse_sampling:
            self._warm_fused()
        self._warm_prefill()

    # ------------------------------------------------------------------
    # scheduler views (the queues live in the scheduler; these keep the
    # engine's historical surface for tests, the facade and embedders)

    @property
    def bm(self) -> BlockManager:
        return self.scheduler.bm

    @property
    def waiting(self):
        return self.scheduler.waiting

    @property
    def running(self) -> List[Request]:
        return self.scheduler.running

    @property
    def finished(self) -> Dict[int, Request]:
        return self.scheduler.finished

    @property
    def free_slots(self) -> List[int]:
        return self.scheduler.free_slots

    @property
    def free_qslots(self) -> List[int]:
        return self.scheduler.free_qslots

    @property
    def admission_scale(self) -> float:
        return self.scheduler.admission_scale

    @property
    def _ewma(self):
        return self.scheduler.ewma

    @_ewma.setter
    def _ewma(self, value):
        self.scheduler.ewma = value

    # ------------------------------------------------------------------
    def add_request(self, prompt,
                    sampling: Optional[SamplingParams] = None,
                    priority: int = 0) -> int:
        """Enqueue a request with per-request ``SamplingParams``. This is
        the primary entry point (the ``repro.api.Zipage`` facade calls
        it). ``priority`` matters only under the "priority" scheduler
        policy (higher = first)."""
        if sampling is None:
            sampling = SamplingParams(temperature=self.opts.temperature,
                                      seed=self._default_seed())
        assert len(prompt) + sampling.max_new_tokens \
            <= self.opts.max_model_len, "request exceeds max_model_len"
        rid = self._rid
        self._rid += 1
        self.scheduler.add_request(Request(
            rid=rid, prompt=list(map(int, prompt)),
            max_new_tokens=sampling.max_new_tokens, sampling=sampling,
            priority=priority, arrival=time.monotonic()))
        return rid

    def _default_seed(self) -> int:
        """Decorrelate per-request streams under the engine-global seed:
        identical seeds would replay identical draws per position."""
        return (self.opts.seed * 1_000_003 + self._rid) & 0xFFFFFFFF

    def abort(self, rid: int) -> bool:
        """Cancel a request mid-flight: remove it from the waiting queue or
        the running batch, return its blocks to the pool, and record it as
        finished with reason ``"abort"``. Returns False if the rid is
        unknown or already finished."""
        r = self.scheduler.abort(rid)
        if r is None:
            return False
        self._swap_qwin.pop(rid, None)
        r.state = State.FINISHED
        r.finish_reason = FinishReason.ABORT
        r.t_finish = time.monotonic()
        self.scheduler.finished[rid] = r
        return True

    # ------------------------------------------------------------------
    # plan execution: prefill

    def _run_prefill(self, chunks: Sequence[PrefillChunk]):
        """Execute the planned prefill chunks. A chunk longer than the
        device bucket S is fed in multiple rounds (the paged prefill step
        is chunk-capable via start_pos — the same mechanism prefix-cache
        hits use); only a request's *final* chunk samples its first
        token."""
        P, S = self.opts.prefill_rows, self.opts.prefill_len
        remaining: Dict[int, List[int]] = {}
        offset: Dict[int, int] = {}
        final_chunk: Dict[int, bool] = {}
        pending: List[Request] = []
        for c in chunks:
            r = c.request
            remaining[r.rid] = list(r.full_prompt[c.start:c.start
                                                  + c.n_tokens])
            offset[r.rid] = c.start
            final_chunk[r.rid] = c.is_final
            pending.append(r)
        while pending:
            batch = pending[:P]
            toks = np.zeros((P, S), np.int32)
            slot_ids = np.full((P,), -1, np.int32)
            lengths = np.zeros((P,), np.int32)
            start = np.zeros((P,), np.int32)
            rope = np.zeros((P,), np.int32)
            kw = {}
            if self.cfg.is_enc_dec:
                kw["frame_embeds"] = jnp.zeros(
                    (P, self.cfg.cross_seq_len, self.cfg.d_model),
                    jnp.float32)
            final = []
            for i, r in enumerate(batch):
                chunk = remaining[r.rid][:S]
                toks[i, :len(chunk)] = chunk
                slot_ids[i] = r.slot
                lengths[i] = len(chunk)
                # cache-write index vs rope position: identical except
                # after compressed-prefix adoption, where the payload
                # condensed pos_gap tokens away (docs/CACHING.md)
                start[i] = offset[r.rid] - r.pos_gap
                rope[i] = offset[r.rid]
                remaining[r.rid] = remaining[r.rid][len(chunk):]
                offset[r.rid] += len(chunk)
                r.n_prefilled = offset[r.rid]
                if not remaining[r.rid] and final_chunk[r.rid]:
                    final.append((i, r, len(chunk)))
            self._push_host_state()
            logits, self.state = self._prefill(
                self.params, self.state, jnp.asarray(toks),
                jnp.asarray(slot_ids), jnp.asarray(lengths),
                jnp.asarray(start), rope_start=jnp.asarray(rope), **kw)
            # only rows finishing their last chunk consume a sample; with
            # no final rows this round, skip sampling entirely — no
            # argmax dispatch, no host sync (ISSUE 4 satellite)
            if final:
                row_reqs: List[Optional[Request]] = [None] * P
                for i, r, _n in final:
                    row_reqs[i] = r
                tok, lp = self._sample_rows(logits, row_reqs)
                for i, r, chunk_len in final:
                    self.tokens_next[r.slot] = tok[i]
                    self._tokens_dirty = True
                    self._record_token(r, tok[i],
                                       None if lp is None else lp[i])
                    if r.qslot >= 0:
                        r.win_count = min(self.opts.window, chunk_len)
            still = [r for r in batch if remaining[r.rid]]
            pending = still + pending[P:]

    # ------------------------------------------------------------------
    # plan execution: compression

    def _comp_buffers(self, n, width=None):
        """Pre-allocated padded host buffers for a bucket-``(n, width)``
        launch (re-filled with defaults on reuse — cheap next to a
        realloc). ``width`` is the trimmed block-table width
        (kernels.ops.block_table_width): the compression pre-pass kernels
        run dense grids over the table, so handing them a pool-wide
        ``max_blocks`` table makes every launch pay for pages no victim
        owns."""
        if width is None:
            width = self.max_blocks
        bufs = self._comp_bufs.get((n, width))
        if bufs is None:
            bufs = (np.full((n, width), -1, np.int32),
                    np.full((n, self.budget_blocks), -1, np.int32),
                    np.full((n,), -1, np.int32),
                    np.zeros((n,), np.int32),
                    np.zeros((n,), np.int32))
            self._comp_bufs[(n, width)] = bufs
        else:
            src_bt, dest_bt, qslots, seq_lens, hist = bufs
            src_bt.fill(-1)
            dest_bt.fill(-1)
            qslots.fill(-1)
            seq_lens.fill(0)
            hist.fill(0)
        return bufs

    def _compress_fn(self, n, width=None):
        """Compiled compression executable for bucket size ``n`` at
        trimmed table width ``width``, shared process-wide across engines
        with the same signature.

        Deliberately a plain ``jax.jit`` rather than an AOT
        ``.lower().compile()``: the AOT dispatch path was observed to
        round the scoring floats slightly differently from the jit path
        on CPU, and the top-k survivor margins of a near-uniform
        attention window sit close enough to zero (~1e-5 on the tiny
        eval models) that a ~1e-7 rounding delta flips which entry
        survives — making engine outputs depend on which compile path
        produced the executable."""
        if width is None:
            width = self.max_blocks
        fn = self._compress_fns.get((n, width))
        if fn is not None:
            return fn
        key = (self.cfg, self.spec, self.opts.compress,
               self.budget_blocks, n, width)
        fn = _COMPRESS_CACHE.get(key)
        if fn is None:
            fn = jax.jit(build_compress_fn(
                self.cfg, block_size=self.opts.block_size,
                max_blocks=width,
                budget_blocks=self.budget_blocks, opts=self.opts.compress))
            _COMPRESS_CACHE[key] = fn
        self._compress_fns[(n, width)] = fn
        return fn

    def _comp_width(self, max_used_blocks) -> int:
        """Bucketed trimmed table width for a compression launch."""
        from repro.kernels import ops as kops
        return kops.block_table_width(max_used_blocks, self.max_blocks)

    def _warm_compression(self):
        """Compile the n ∈ {1, 2, 4} compression buckets (and allocate
        their padded host buffers) before serving starts, so the first
        compression-bearing steps don't stall mid-serve on trace+compile.
        Victims carry ~n_max blocks when compression fires, so warm the
        matching trimmed table width.  The warming calls run on the
        all-padding request buffers (qslot -1 rows), which make them
        semantic no-ops — every survivor scatter drops OOB — so the
        zeroed engine state is untouched."""
        width = self._comp_width(self.opts.n_max or 1)
        for n in (1, 2, 4):
            if n <= max(1, self.opts.m_qslots):
                bufs = self._comp_buffers(n, width)
                req = tuple(jnp.asarray(a) for a in bufs)
                self._block_ready(self._compress_fn(n, width)(
                    self.state["pools"], self.state["qwin"], req))

    def _launch_compression(self, outs: SchedulerOutputs):
        """Dispatch the compression kernel over the planned launches, then
        let the scheduler commit the (deterministic) host bookkeeping."""
        planned = outs.compress
        if not planned:
            return
        n = 1
        while n < len(planned):
            n *= 2
        width = self._comp_width(max(c.request.n_blocks for c in planned))
        src_bt, dest_bt, qslots, seq_lens, hist = self._comp_buffers(n, width)
        for i, c in enumerate(planned):
            r = c.request
            src_bt[i, :r.n_blocks] = r.blocks
            dest_bt[i] = c.dest
            qslots[i] = r.qslot
            seq_lens[i] = r.seq_len
            hist[i] = self.budget_blocks * self.opts.block_size \
                if r.compressed else 0
        pools = self.state["pools"]
        req = (jnp.asarray(src_bt), jnp.asarray(dest_bt), jnp.asarray(qslots),
               jnp.asarray(seq_lens), jnp.asarray(hist))
        new_pools, _, qstats = self._compress_fn(n, width)(
            pools, self.state["qwin"], req)
        self.state["pools"] = new_pools
        self._pending_quality = ([c.request.rid for c in planned], qstats)
        self.scheduler.commit_compression(outs)
        if self.opts.measure_phases or not self.opts.async_compression:
            self._block_ready(self.state["pools"])

    # ------------------------------------------------------------------
    # plan execution: host swap tier (docs/SCHEDULER.md "Preemption modes")

    def _kv_block_bytes(self) -> int:
        """Bytes one pool block occupies across all layers and leaves —
        the unit of the scheduler's swap-traffic telemetry and auto cost
        model."""
        pools = self.state.get("pools")
        if not pools:
            return 0
        return int(sum(leaf.size // leaf.shape[1] * leaf.dtype.itemsize
                       for leaf in jax.tree.leaves(pools)))

    def _init_swap(self):
        """Allocate the CPU swap pool (one host mirror per pools leaf,
        ``swap_space_blocks`` wide) and register the two synchronous
        executors the scheduler calls at plan time. Warm both jits at
        every power-of-2 bucket width with all-padding ids (semantic
        no-ops) so preemption under pressure never stalls on
        trace+compile."""
        self.swap_pool = {
            k: np.zeros((leaf.shape[0], self.opts.swap_space_blocks)
                        + leaf.shape[2:], dtype=leaf.dtype)
            for k, leaf in self.state["pools"].items()}
        self.scheduler.swap_executor = self._swap_out_blocks
        self.scheduler.swap_in_executor = self._swap_in_blocks
        m = 1
        while True:
            pad = jnp.full((m,), -1, jnp.int32)
            gathered = self._swap_out_fn()(self.state["pools"], pad)
            self.state["pools"] = self._swap_in_fn()(
                self.state["pools"], pad, gathered)
            if m >= self.max_blocks:
                break
            m = min(2 * m, self.max_blocks)

    # one factory per donation signature (zipalint ZPL003): swap-out
    # gathers without touching the pools, swap-in scatters with the pools
    # donated — callers of _swap_in_fn() must rebind self.state["pools"]

    def _swap_out_fn(self):
        key = ("swap_out", self.cfg, self.spec)
        fn = _SWAP_CACHE.get(key)
        if fn is None:
            fn = jax.jit(serve_model.build_swap_out_step(self.cfg,
                                                         self.spec))
            _SWAP_CACHE[key] = fn
        return fn

    def _swap_in_fn(self):
        key = ("swap_in", self.cfg, self.spec)
        fn = _SWAP_CACHE.get(key)
        if fn is None:
            fn = jax.jit(serve_model.build_swap_in_step(self.cfg,
                                                        self.spec),
                         donate_argnums=(0,))
            _SWAP_CACHE[key] = fn
        return fn

    def _swap_bucket(self, n: int) -> int:
        """Power-of-2 padded width for an ``n``-block swap (capped at
        max_blocks), so only O(log max_blocks) shapes are ever traced and
        a typical compressed victim moves ~n_max blocks, not the full
        table width."""
        return min(self.max_blocks, 1 << max(0, n - 1).bit_length())

    def _pad_block_ids(self, blocks: Sequence[int], width: int):
        ids = np.full((width,), -1, np.int32)
        ids[:len(blocks)] = blocks
        return jnp.asarray(ids)

    def _swap_out_blocks(self, r: Request, src_blocks, dst_host_blocks):
        """Scheduler swap-out callback: gather the victim's blocks from
        every layer's pools and park the copy in the CPU swap pool. The
        fetch is synchronous, so the blocks are safe to reuse the moment
        this returns — the scheduler releases them right after. The
        victim's observation-window rows ride along (keyed by rid), so a
        swap-in with a fresh qslot resumes compression scoring exactly
        where the swap-out left it."""
        n = len(src_blocks)
        gathered = self._swap_out_fn()(
            self.state["pools"],
            self._pad_block_ids(src_blocks, self._swap_bucket(n)))
        gathered = self._fetch(gathered)
        for k, arr in gathered.items():
            self.swap_pool[k][:, dst_host_blocks] = np.asarray(arr)[:, :n]
        if r.qslot >= 0 and "qwin" in self.state:
            self._swap_qwin[r.rid] = self._fetch(
                self.state["qwin"][:, r.qslot])

    def _swap_in_buffers(self, m: int):
        """Reusable padded host staging buffers for a bucket-``m``
        swap-in (cf. ``_comp_buffers``; realloc-free hot path)."""
        bufs = self._swap_bufs.get(m)
        if bufs is None:
            bufs = {k: np.zeros((host.shape[0], m) + host.shape[2:],
                                dtype=host.dtype)
                    for k, host in self.swap_pool.items()}
            self._swap_bufs[m] = bufs
        return bufs

    def _swap_in_blocks(self, r: Request, src_host_blocks,
                        dst_dev_blocks) -> bool:
        """Scheduler swap-in callback: scatter the parked copy back into
        freshly allocated device blocks (pools donated — restored in
        place) and re-arm the decode input: the victim's last sampled
        token becomes ``tokens_next`` for its new slot. Returns True when
        the observation window was restored too (the scheduler keeps
        ``win_count`` only then)."""
        n = len(dst_dev_blocks)
        m = self._swap_bucket(n)
        bufs = self._swap_in_buffers(m)
        vals = {}
        for k, host in self.swap_pool.items():
            bufs[k][:, :n] = host[:, src_host_blocks]
            vals[k] = jnp.asarray(bufs[k])
        self.state["pools"] = self._swap_in_fn()(
            self.state["pools"], self._pad_block_ids(dst_dev_blocks, m),
            vals)
        if r.output and not r.prefill_pending:
            self.tokens_next[r.slot] = r.output[-1]
            self._tokens_dirty = True
        qwin = self._swap_qwin.pop(r.rid, None)
        if qwin is None or r.qslot < 0:
            return False
        self.state["qwin"] = self.state["qwin"].at[:, r.qslot].set(
            jnp.asarray(qwin))
        return True

    # ------------------------------------------------------------------
    # plan execution: decode

    def _fetch(self, x):
        """Device->host read; the wait is counted as blocked-on-device time
        (the ``t_device`` share of the per-step metrics)."""
        t = time.monotonic()
        out = jax.device_get(x)
        self._t_blocked += time.monotonic() - t
        return out

    def _block_ready(self, x):
        t = time.monotonic()
        jax.block_until_ready(x)
        self._t_blocked += time.monotonic() - t

    def _push_host_state(self, force: bool = False):
        """Rebuild the host mirrors from scheduler-owned request state and
        push them to the device tables — but only when the scheduler's
        state version moved past what was last uploaded. Decode itself
        advances ``seq_lens``/``positions`` on device, so steady decode
        streaks push nothing at all (docs/PERF.md)."""
        v = self.scheduler.version
        if not force and v == self._pushed_version:
            return
        self.host_bt.fill(-1)
        self.host_qslot.fill(-1)
        for r in self.scheduler.running:
            if r.slot < 0:
                continue
            self.host_bt[r.slot, :r.n_blocks] = r.blocks
            self.host_seq[r.slot] = r.seq_len
            self.host_pos[r.slot] = r.position
            self.host_qslot[r.slot] = r.qslot
        self.state["block_tables"] = jnp.asarray(self.host_bt)
        self.state["seq_lens"] = jnp.asarray(self.host_seq)
        self.state["positions"] = jnp.asarray(self.host_pos)
        self.state["qslot"] = jnp.asarray(self.host_qslot)
        self._pushed_version = v

    def _sample_rows(self, logits, reqs: Sequence[Optional[Request]]):
        """Sample one token per row; ``reqs[i]`` is the request occupying
        row i (None for padding rows). All-greedy batches with no logprob
        consumers take the cheap argmax path; otherwise the jitted
        per-row sampler runs with each request's (seed, n_generated) PRNG
        state, so outputs are independent of batch composition.
        Returns (tokens, logprobs) as numpy; logprobs is None on the
        fast path."""
        if not any(r is not None and (not r.sampling.is_greedy
                                      or r.sampling.logprobs)
                   for r in reqs):
            return self._fetch(jnp.argmax(logits, -1)), None
        n = logits.shape[0]
        seeds = np.zeros((n,), np.uint32)
        counters = np.zeros((n,), np.int32)
        temps = np.zeros((n,), np.float32)
        top_k = np.zeros((n,), np.int32)
        top_p = np.ones((n,), np.float32)
        for i, r in enumerate(reqs):
            if r is None:
                continue
            sp = r.sampling
            seeds[i] = np.uint32(sp.seed & 0xFFFFFFFF)
            counters[i] = len(r.output)
            temps[i] = sp.temperature
            top_k[i] = sp.top_k
            top_p[i] = sp.top_p
        tok, lp = self._sampler(
            logits, jnp.asarray(seeds), jnp.asarray(counters),
            jnp.asarray(temps), jnp.asarray(top_k), jnp.asarray(top_p))
        return self._fetch((tok, lp))

    @staticmethod
    def _record_token(r: Request, tok: int, lp) -> None:
        r.output.append(int(tok))
        if r.sampling.logprobs and lp is not None:
            r.logprobs.append(float(lp))
        if r.t_first_token is None:
            r.t_first_token = time.monotonic()

    def _advance_decoded(self, r: Request) -> None:
        """Per-token host bookkeeping shared by the fused and unfused
        decode paths (cache-length / position / window counters)."""
        if r.qslot >= 0:
            r.win_count = min(self.opts.window, r.win_count + 1)
        r.seq_len = min(r.seq_len + 1, self._ring) if self._ring \
            else (r.seq_len if self.cfg.attention_free else r.seq_len + 1)
        r.position += 1
        self.host_seq[r.slot] = r.seq_len
        self.host_pos[r.slot] = r.position
        self._step_decoded += 1

    def _track_pages(self, active, caps, k):
        """Accumulate the step's page-visit telemetry (docs/PERF.md): the
        ragged decode kernel DMAs ``ceil(attend_len / b)`` pages per row
        per sub-step, while a dense-grid launch pays ``max_blocks`` for
        every slot — active or not — every sub-step. Pure host arithmetic
        from scheduler state; no device traffic."""
        b = self.opts.block_size
        for r, c in zip(active, caps):
            self._step_pages_visited += sum(
                -(-(r.seq_len + j + 1) // b) for j in range(c))
        self._step_pages_dense += k * self.opts.max_batch * self.max_blocks

    def _run_decode(self, active):
        if not active:
            return
        self._track_pages(active, [1] * len(active), 1)
        mask = np.zeros((self.opts.max_batch,), bool)
        for r in active:
            mask[r.slot] = True
        self._push_host_state()
        logits, self.state = self._decode(
            self.params, self.state, jnp.asarray(self.tokens_next),
            jnp.asarray(mask))
        slot_reqs: List[Optional[Request]] = [None] * self.opts.max_batch
        for r in active:
            slot_reqs[r.slot] = r
        tok, lp = self._sample_rows(logits, slot_reqs)
        for r in active:
            t = int(tok[r.slot])
            self.tokens_next[r.slot] = t
            self._record_token(r, t, None if lp is None else lp[r.slot])
            self._advance_decoded(r)

    # ------------------------------------------------------------------
    # plan execution: fused decode + multi-step horizon (docs/PERF.md)

    def _sampling_tensors(self):
        """Per-slot sampling tensors for the fused decode step (seeds,
        temperatures, top-k/top-p, padded eos-id sets), rebuilt only when
        the scheduler's slot assignments changed. The eos pad width only
        ever grows, so the fused jit never re-traces on shrink."""
        v = self.scheduler.version
        if self._samp_arrays is not None and self._samp_version == v:
            return self._samp_arrays
        B = self.opts.max_batch
        seeds = np.zeros((B,), np.uint32)
        temps = np.zeros((B,), np.float32)
        top_k = np.zeros((B,), np.int32)
        top_p = np.ones((B,), np.float32)
        e = self._eos_width
        for r in self.scheduler.running:
            if r.slot >= 0 and r.sampling.eos_ids:
                e = max(e, len(r.sampling.eos_ids))
        self._eos_width = 1 << (e - 1).bit_length()
        eos = np.full((B, self._eos_width), -1, np.int32)
        for r in self.scheduler.running:
            if r.slot < 0:
                continue
            sp = r.sampling
            seeds[r.slot] = np.uint32(sp.seed & 0xFFFFFFFF)
            temps[r.slot] = sp.temperature
            top_k[r.slot] = sp.top_k
            top_p[r.slot] = sp.top_p
            if sp.eos_ids:
                eos[r.slot, :len(sp.eos_ids)] = sp.eos_ids
        self._samp_arrays = tuple(
            jnp.asarray(a) for a in (seeds, temps, top_k, top_p, eos))
        self._samp_version = v
        return self._samp_arrays

    def _push_sampling_state(self, active):
        """Sync the device-carried sampling state (live mask, PRNG
        counters, next input tokens) with the host's view — pushing only
        the pieces that actually diverged. During steady decode the device
        advances all three itself, so nothing is uploaded."""
        B = self.opts.max_batch
        mask = np.zeros((B,), bool)
        counters = np.zeros((B,), np.int32)
        for r in active:
            mask[r.slot] = True
            counters[r.slot] = len(r.output)
        if self._dev_mask is None \
                or not np.array_equal(mask, self._dev_mask):
            self.state["active_mask"] = jnp.asarray(mask)
        if self._dev_counters is None \
                or not np.array_equal(counters, self._dev_counters):
            self.state["sample_counters"] = jnp.asarray(counters)
        if self._tokens_dirty:
            self.state["tokens_next"] = jnp.asarray(self.tokens_next)
            self._tokens_dirty = False
        self._dev_mask = mask
        self._dev_counters = counters

    def _fused_fn(self, k: int):
        fn = self._fused_fns.get(k)
        if fn is None:
            key = (self.cfg, self.spec, k)
            fn = _FUSED_CACHE.get(key)
            if fn is None:
                fn = jax.jit(serve_model.build_fused_decode_step(
                    self.cfg, self.spec, k), donate_argnums=(1,))
                _FUSED_CACHE[key] = fn
            self._fused_fns[k] = fn
        return fn

    def _warm_fused(self):
        """Compile every fused chunk length the configured ``decode_steps``
        can produce, before serving starts. The warming calls run with an
        all-false ``active_mask``, which makes them semantic no-ops on the
        zeroed engine state (no KV writes, no counter movement) — they
        exist purely to populate the jit caches."""
        sizes = set()
        for k in range(1, self.opts.decode_steps + 1):
            sizes.update(_fused_chunk_sizes(k))
        caps = jnp.zeros((self.opts.max_batch,), jnp.int32)
        samp = self._sampling_tensors()
        for k in sorted(sizes):
            _t, _l, self.state = self._fused_fn(k)(
                self.params, self.state, np.int32(0), caps, *samp)

    def _warm_prefill(self):
        """Compile the prefill step at init (padding-only rows: slot_ids
        are all -1, so every write drops and the call is a no-op on the
        zeroed state), keeping the first admission from stalling on
        trace+compile mid-serve."""
        P, S = self.opts.prefill_rows, self.opts.prefill_len
        kw = {}
        if self.cfg.is_enc_dec:
            kw["frame_embeds"] = jnp.zeros(
                (P, self.cfg.cross_seq_len, self.cfg.d_model), jnp.float32)
        _logits, self.state = self._prefill(
            self.params, self.state, jnp.zeros((P, S), jnp.int32),
            jnp.full((P,), -1, jnp.int32), jnp.zeros((P,), jnp.int32),
            jnp.zeros((P,), jnp.int32),
            rope_start=jnp.zeros((P,), jnp.int32), **kw)

    def _run_decode_fused(self, active, plan=None):
        """Fused decode+sample over the scheduler's quiescent horizon: up
        to K decode steps in O(log K) power-of-two dispatches, with each
        chunk's token block fetched only after the next chunk is in
        flight. That lets the host record chunk N's tokens while the
        device is already computing chunk N+1 (the carried
        ``active_mask`` keeps in-flight eos exact across chunks)."""
        if not active:
            return
        K, caps = self.scheduler.quiescent_horizon(active, plan)
        self._last_horizon = K
        self._track_pages(active, caps, K)
        self._push_host_state()
        self._push_sampling_state(active)
        samp = self._sampling_tensors()
        caps_arr = np.zeros((self.opts.max_batch,), np.int32)
        for r, c in zip(active, caps):
            caps_arr[r.slot] = c
        caps_dev = jnp.asarray(caps_arr)
        ks = _fused_chunk_sizes(K)
        chunks = []
        off = 0
        for k in ks:
            tok, lp, self.state = self._fused_fn(k)(
                self.params, self.state, np.int32(off), caps_dev, *samp)
            chunks.append((off, k, tok, lp))
            off += k
        halted: set = set()
        for off, k, tok, lp in chunks:
            tok, lp = self._fetch((tok, lp))
            self._record_decode_block(active, off, k, tok, lp, caps, halted)

    def _record_decode_block(self, active, off, k, tok, lp, caps, halted):
        """Replay a fetched ``(k, B)`` token block into request state,
        mirroring the device's in-scan gating exactly: each row consumes
        tokens up to its cap, stopping early at its first eos hit."""
        for idx, r in enumerate(active):
            if r.rid in halted:
                continue
            for j in range(min(k, caps[idx] - off)):
                t = int(tok[j, r.slot])
                self.tokens_next[r.slot] = t
                self._dev_counters[r.slot] += 1
                self._record_token(r, t, float(lp[j, r.slot]))
                self._advance_decoded(r)
                sp = r.sampling
                if sp.eos_ids is not None and t in sp.eos_ids:
                    halted.add(r.rid)
                    self._dev_mask[r.slot] = False
                    break

    # ------------------------------------------------------------------
    def _drain_quality_stats(self):
        """Write the previous step's compression quality telemetry back
        onto the still-live requests (Request.redundancy /
        Request.attn_entropy — the scheduler's quality-aware planning
        signal, docs/EVAL.md). Runs at step start: the previous step's
        token fetch already synced the device, so this host read costs
        nothing and never blocks an in-flight async compression."""
        pq = self._pending_quality
        if pq is None:
            return
        self._pending_quality = None
        rids, dev = pq
        stats = np.asarray(self._fetch(dev))
        live = {r.rid: r for r in self.scheduler.running}
        for sw in self.scheduler.swapped:
            live[sw.rid] = sw
        for i, rid in enumerate(rids):
            r = live.get(rid)
            if r is None:
                continue
            r.redundancy = float(stats[i, 0])
            r.attn_entropy = float(stats[i, 1])

    def step(self):
        """One serving step: ask the scheduler for a plan, execute it.
        All admission/preemption/compression-planning decisions are the
        scheduler's (repro.core.scheduler); this loop only sequences the
        device work."""
        t0 = time.monotonic()
        self._drain_quality_stats()
        self._t_blocked = 0.0
        self._step_decoded = 0
        self._last_horizon = 0
        self._step_pages_visited = 0
        self._step_pages_dense = 0
        self.step_count += 1
        plan = self.scheduler.schedule(self.step_count)
        t_admit = time.monotonic()
        if plan.prefill_chunks:
            self._run_prefill(plan.prefill_chunks)
            if self.opts.measure_phases:
                self._block_ready(self.state["pools"]
                                  if "pools" in self.state
                                  else self.state["rec"])
        t_prefill = time.monotonic()
        self.scheduler.plan_compression(plan)
        self._launch_compression(plan)
        t_comp = time.monotonic()
        active = self.scheduler.schedule_decode(plan)
        if self.opts.fuse_sampling:
            self._run_decode_fused(active, plan)
        else:
            self._run_decode(active)
        if self.opts.measure_phases:
            self._block_ready(self.state["pools"]
                              if "pools" in self.state
                              else self.state["rec"])
        t_dec = time.monotonic()
        self.scheduler.end_step(plan)
        used = self.opts.n_total_blocks - self.bm.num_free
        entry = {
            "step": self.step_count,
            "t_total": t_dec - t0,
            "t_prefill": t_prefill - t_admit,
            "t_compress": t_comp - t_prefill,
            "t_decode": t_dec - t_comp,
            # host planning/bookkeeping vs blocked-on-device split
            # (t_host + t_device == t_total; docs/PERF.md)
            "t_device": self._t_blocked,
            "t_host": max(0.0, (t_dec - t0) - self._t_blocked),
            "n_running": len(self.scheduler.running),
            "n_waiting": len(self.scheduler.waiting),
            "n_active": len(active),
            "n_compressing": len(plan.compress),
            "n_prefilled": len(plan.admitted),
            "block_util": used / self.opts.n_total_blocks,
            "tokens": self._step_decoded + len(plan.admitted),
            "decode_horizon": self._last_horizon,
            # ragged-kernel DMA footprint vs what a dense grid would pay
            # this step (docs/PERF.md "Pages visited")
            "pages_visited": self._step_pages_visited,
            "pages_dense": self._step_pages_dense,
        }
        entry.update(self.scheduler.stats(plan,
                                          n_decoded=self._step_decoded))
        self.metrics.append(entry)
        # normalise by the fused horizon so a K-step dispatch doesn't read
        # as a straggler to the admission backoff
        self.scheduler.observe_latency(
            (t_dec - t0) / max(1, self._last_horizon))
        if self.sanitize:
            invariants.check_engine(self)
        for hook in self.step_hooks:
            hook(entry)

    def run(self, max_steps=10_000):
        while self.scheduler.has_work() and self.step_count < max_steps:
            self.step()
        return {r.rid: r for r in self.scheduler.finished.values()}

    # ------------------------------------------------------------------
    # fault tolerance: full engine snapshot/restore

    def snapshot(self):
        import copy
        # snapshot IS a full-state sync point by design; per-leaf _fetch
        # would add nothing but overhead here
        # zipalint: waive[ZPL005] -- snapshot is an intentional whole-state sync
        dev = {k: jax.tree.map(np.asarray, v) for k, v in self.state.items()}
        return {
            "device": dev,
            "host": copy.deepcopy({
                "bt": self.host_bt, "seq": self.host_seq,
                "pos": self.host_pos, "qslot": self.host_qslot,
                "tokens_next": self.tokens_next,
                "free_slots": self.scheduler.free_slots,
                "free_qslots": self.scheduler.free_qslots,
                "rid": self._rid, "step": self.step_count,
                "admission_scale": self.scheduler.admission_scale,
                "ewma": self.scheduler.ewma,
                "n_swapped_out": self.scheduler.n_swapped_out,
                "n_swapped_in": self.scheduler.n_swapped_in,
                "swap_bytes": self.scheduler.swap_bytes,
                "n_comp_by_policy": self.scheduler.n_comp_by_policy,
                "n_comp_deferred": self.scheduler.n_comp_deferred,
            }),
            "requests": copy.deepcopy({
                "waiting": list(self.scheduler.waiting),
                "running": self.scheduler.running,
                "swapped": list(self.scheduler.swapped),
                "finished": self.scheduler.finished,
            }),
            "bm": copy.deepcopy(self.bm),
            "swap_pool": (None if self.swap_pool is None else
                          {k: v.copy() for k, v in self.swap_pool.items()}),
            "swap_qwin": {rid: np.asarray(a).copy()
                          for rid, a in self._swap_qwin.items()},
        }

    def restore(self, snap):
        import copy
        self.state = {k: jax.tree.map(jnp.asarray, v)
                      for k, v in snap["device"].items()}
        h = copy.deepcopy(snap["host"])
        self.host_bt, self.host_seq = h["bt"], h["seq"]
        self.host_pos, self.host_qslot = h["pos"], h["qslot"]
        self.tokens_next = h["tokens_next"]
        sched = self.scheduler
        sched.free_slots, sched.free_qslots = h["free_slots"], h["free_qslots"]
        sched.admission_scale = h.get("admission_scale", 1.0)
        sched.ewma = h.get("ewma")
        sched.n_swapped_out = h.get("n_swapped_out", 0)
        sched.n_swapped_in = h.get("n_swapped_in", 0)
        sched.swap_bytes = h.get("swap_bytes", 0)
        sched.n_comp_by_policy = dict(h.get(
            "n_comp_by_policy",
            {"default": 0, "protect": 0, "aggressive": 0}))
        sched.n_comp_deferred = h.get("n_comp_deferred", 0)
        # in-flight quality telemetry references pre-snapshot device
        # buffers; the requests it describes were deep-copied anyway
        self._pending_quality = None
        self._rid, self.step_count = h["rid"], h["step"]
        r = copy.deepcopy(snap["requests"])
        sched.waiting = deque(r["waiting"])
        sched.running = r["running"]
        sched.swapped = deque(r.get("swapped", []))
        sched.finished = r["finished"]
        sched.bm = copy.deepcopy(snap["bm"])
        sp = snap.get("swap_pool")
        if sp is not None and self.swap_pool is not None:
            self.swap_pool = {k: v.copy() for k, v in sp.items()}
        self._swap_qwin = {rid: a.copy()
                           for rid, a in snap.get("swap_qwin", {}).items()}
        # invalidate every device mirror: the next step re-pushes tables
        # and fused sampling state wholesale (sanitizer shadows of the
        # old device buffers are stale too)
        self._pushed_version = -1
        self._tokens_dirty = True
        self._qwin_shadow = {}
        self._dev_mask = None
        self._dev_counters = None
        self._samp_version = -1
        self._samp_arrays = None
