"""Low-level paged KV-pool operations (pure jnp reference backend).

Pool layout (per attention layer): ``(N_total, b, h_kv, d)`` for K and V —
a page is a ``(b, h_kv·d)`` contiguous stripe, chosen so the TPU kernel's
page DMA is dense (DESIGN.md §3). MLA pools store the latent entry
``(N_total, b, r + d_rope)``. The Pallas kernels in ``repro.kernels``
implement the same contracts; ``repro.core.backend`` selects at runtime.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ----------------------------------------------------------------------
# writes

def scatter_token(pool, block_tables, positions, values):
    """Write one token per request into its page slot.

    pool: (N_total, b, ...); block_tables: (B, max_blocks) int32;
    positions: (B,) slot position in cache order; values: (B, ...).
    Rows with position < 0 are skipped (inactive slots).
    """
    b = pool.shape[1]
    blk = jnp.take_along_axis(block_tables, (positions[:, None] // b), 1)[:, 0]
    slot = positions % b
    flat = pool.reshape((-1,) + pool.shape[2:])
    idx = blk * b + slot
    idx = jnp.where(positions >= 0, idx, pool.shape[0] * b)  # OOB -> dropped
    flat = flat.at[idx].set(values.astype(pool.dtype), mode="drop")
    return flat.reshape(pool.shape)


def scatter_prefill(pool, block_tables, values, lengths, start=None):
    """Write a whole prefill segment. values: (B, S, ...); lengths: (B,)."""
    B, S = values.shape[:2]
    b = pool.shape[1]
    pos = jnp.arange(S)[None, :] + (0 if start is None else start[:, None])
    blk = jnp.take_along_axis(block_tables, pos // b, 1)       # (B, S)
    idx = blk * b + pos % b
    valid = (jnp.arange(S)[None, :] <
             (lengths[:, None] - (0 if start is None else start[:, None])))
    idx = jnp.where(valid, idx, pool.shape[0] * b)
    flat = pool.reshape((-1,) + pool.shape[2:])
    flat = flat.at[idx.reshape(-1)].set(
        values.reshape((-1,) + values.shape[2:]).astype(pool.dtype),
        mode="drop")
    return flat.reshape(pool.shape)


# ----------------------------------------------------------------------
# whole-block copies (host swap tier, docs/SCHEDULER.md)

def gather_kv_blocks(pool, block_ids):
    """Gather whole blocks across every layer of a pool leaf.

    pool: (L, N_total, b, ...); block_ids: (m,) int32, padded with -1.
    Returns (L, m, b, ...); padding rows carry garbage — callers slice by
    the real block count. The swap-out half of the host swap tier: the
    result is fetched to host and parked in the CPU swap pool.
    """
    return pool[:, jnp.maximum(block_ids, 0)]


def scatter_kv_blocks(pool, block_ids, values):
    """Inverse of :func:`gather_kv_blocks`: write (L, m, b, ...) values
    back into the pool at ``block_ids`` (-1 entries dropped). Swap-in
    restores a preempted request's KV bit-for-bit."""
    n = pool.shape[1]
    idx = jnp.where(block_ids >= 0, block_ids, n)
    return pool.at[:, idx].set(values.astype(pool.dtype), mode="drop")


# ----------------------------------------------------------------------
# reads

def gather_entries(pool, block_tables):
    """Gather each request's pages into cache order.

    pool: (N_total, b, ...); block_tables: (B, max_blocks).
    Returns (B, max_blocks*b, ...). Negative table entries yield garbage —
    callers must mask by seq_len.
    """
    safe = jnp.maximum(block_tables, 0)
    out = pool[safe]                                   # (B, mb, b, ...)
    return out.reshape((out.shape[0], -1) + out.shape[3:])


# ----------------------------------------------------------------------
# decode attention (reference backend)

def paged_decode_attention(q, k_pool, v_pool, block_tables, seq_lens,
                           *, scale=None):
    """One-token GQA attention against the paged pool.

    q: (B, h_q, d); pools: (N_total, b, h_kv, d); block_tables: (B, mb);
    seq_lens: (B,) valid entries per request. Returns (B, h_q, d).
    """
    B, hq, d = q.shape
    hkv = k_pool.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    ks = gather_entries(k_pool, block_tables)          # (B, T, hkv, d)
    vs = gather_entries(v_pool, block_tables)
    T = ks.shape[1]
    qg = q.reshape(B, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, ks.astype(jnp.float32)) * scale
    mask = jnp.arange(T)[None, :] < seq_lens[:, None]  # (B, T)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    # masked positions carry zero probability, but the gathered V there is
    # pool garbage (negative table entries gather page 0) and 0·NaN = NaN
    # would leak through the contraction — zero the masked V lanes
    vs = jnp.where(mask[..., None, None], vs.astype(jnp.float32), 0.0)
    o = jnp.einsum("bhgt,bthd->bhgd", p, vs)
    return o.reshape(B, hq, d).astype(q.dtype)


def paged_decode_attention_chunked(q, k_pool, v_pool, block_tables, seq_lens,
                                   *, scale=None):
    """Flash-decoding over pages in pure HLO: scan over block-table columns,
    one (B, b, h_kv, d) page gather + online-softmax update per step.

    Reads each page exactly once instead of materializing the full
    (B, T, h, d) gathered copies — the HLO analogue of the Pallas kernel's
    VMEM loop (EXPERIMENTS.md §Perf iteration C).
    """
    B, hq, d = q.shape
    N, b, hkv, _ = k_pool.shape
    g = hq // hkv
    mb = block_tables.shape[1]
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    qg = q.reshape(B, hkv, g, d).astype(jnp.float32)
    bt = jnp.maximum(block_tables, 0)

    def body(carry, i):
        m, l, acc = carry
        blk = bt[:, i]                                  # (B,)
        ks = k_pool[blk]                                # (B, b, hkv, d)
        vs = v_pool[blk]
        s = jnp.einsum("bhgd,bchd->bhgc", qg,
                       ks.astype(jnp.float32)) * scale
        kpos = i * b + jnp.arange(b)
        mask = kpos[None] < seq_lens[:, None]           # (B, b)
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[:, None, None], p, 0.0)
        # p is 0 on masked lanes but the page holds garbage there
        # (0·NaN = NaN): zero masked V before the contraction
        vs = jnp.where(mask[..., None, None], vs.astype(jnp.float32), 0.0)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgc,bchd->bhgd", p, vs)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, hkv, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, hkv, g), jnp.float32)
    a0 = jnp.zeros((B, hkv, g, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), jnp.arange(mb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, hq, d).astype(q.dtype)


def paged_decode_attention_mla(q_nope_abs, q_rope, kv_pool, block_tables,
                               seq_lens, *, r, scale):
    """MLA absorbed decode: score = q_abs·c + q_rope·k_rope; out in latent.

    q_nope_abs: (B, h_q, r) — queries already absorbed through W_uk;
    q_rope: (B, h_q, d_rope); kv_pool: (N_total, b, r + d_rope).
    Returns latent output (B, h_q, r) (caller applies W_uv).
    """
    B, hq, _ = q_nope_abs.shape
    entries = gather_entries(kv_pool, block_tables)    # (B, T, r+dr)
    T = entries.shape[1]
    # Contract against the FULL entry: slicing entries[..., :r] on the
    # model-sharded latent dim is shard-misaligned (576 = 16x36 vs r=512)
    # and forces GSPMD to all-gather the whole gathered cache (~30 GB/chip
    # measured). The concat-q form keeps the contraction sharded (scores
    # psum only); the r-slice moves to the tiny (B, hq, ·) output.
    # EXPERIMENTS.md §Perf iteration D.
    q_cat = jnp.concatenate([q_nope_abs, q_rope], -1)  # (B, hq, r+dr)
    from repro.models import moe_ctx
    qspec = moe_ctx.mla_q_spec.get()
    if qspec is not None:
        # align q's sharding with the latent-width-sharded cache so the
        # score contraction psums instead of all-gathering the cache
        q_cat = jax.lax.with_sharding_constraint(q_cat, qspec)
    s = jnp.einsum("bhe,bte->bht", q_cat.astype(jnp.float32),
                   entries.astype(jnp.float32)) * scale
    mask = jnp.arange(T)[None, :] < seq_lens[:, None]
    s = jnp.where(mask[:, None], s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    ent_o = jnp.where(mask[..., None], entries.astype(jnp.float32), 0.0)
    o = jnp.einsum("bht,bte->bhe", p, ent_o)
    return o[..., :r].astype(q_nope_abs.dtype)


def paged_prefill_attention(q, k_pool, v_pool, block_tables, q_start,
                            kv_lens, *, local_window=0):
    """Prefill chunk attention against pages (for chunked prefill / shared
    prefixes already resident in the pool).

    q: (B, S, h_q, d) at absolute cache positions q_start + arange(S);
    kv_lens: (B,) total valid cache entries (including this chunk, already
    written). Causal within the chunk.
    """
    B, S, hq, d = q.shape
    hkv = k_pool.shape[2]
    g = hq // hkv
    ks = gather_entries(k_pool, block_tables)
    vs = gather_entries(v_pool, block_tables)
    T = ks.shape[1]
    qg = q.reshape(B, S, hkv, g, d).astype(jnp.float32)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, ks.astype(jnp.float32)) / np.sqrt(d)
    qpos = q_start[:, None] + jnp.arange(S)[None]                  # (B, S)
    kpos = jnp.arange(T)[None]                                     # (1, T)
    mask = (kpos[:, None] <= qpos[..., None]) & (kpos[:, None] < kv_lens[:, None, None])
    if local_window:
        mask &= kpos[:, None] > qpos[..., None] - local_window
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, -1)
    kv_valid = kpos < kv_lens[:, None]                             # (B, T)
    vs = jnp.where(kv_valid[..., None, None], vs.astype(jnp.float32), 0.0)
    o = jnp.einsum("bhgst,bthd->bshgd", p, vs)
    return o.reshape(B, S, hq, d).astype(q.dtype)
