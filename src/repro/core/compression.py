"""The compression operation of Compressed PagedAttention (paper §4.2).

``build_compress_fn`` returns a jit-able function that compresses a fixed-size
batch of requests across all attention layers: score -> top-k tag -> compact
into destination blocks (paper Alg. 4, re-derived as a stable keep-first
gather — DESIGN.md §3). Padding rows (qslot < 0) are dropped via OOB scatter.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import scoring
from repro.core.paged import gather_entries


@dataclasses.dataclass(frozen=True)
class CompressOptions:
    """Paper-recommended defaults (App. C.8)."""
    window: int = 16                 # observation window w
    alpha: float = 0.8               # global-score decay
    use_global: bool = True
    redundancy: str = "lightning"    # lightning | flash | none
    lam: float = 0.2                 # λ in Eq. 4
    tau: float = 0.4                 # redundancy softmax temperature
    p_thresh: float = 0.8            # similarity zero-out threshold
    pooling: str = "first"           # none | first | always
    pool_kernel: int = 7
    # kernel backend (repro.kernels.ops.resolve_backend):
    # auto | jnp | pallas-interpret | pallas-tpu (+ deprecated alias
    # "pallas"). "auto" picks pallas-tpu on TPU hosts, jnp elsewhere;
    # the engine substitutes its ModelRunnerConfig.kernel_backend here
    # unless this field was set explicitly.
    backend: str = "auto"


def _score_one(cfg, opts, q_win, entries, fscore, valid, seq_len, hist_len,
               block_size, precomputed=None):
    """Scores for one request, one layer. Returns (final_scores, new_F,
    stats); scores/F are (T, h_s) with h_s = h_kv (GQA) or 1 (MLA), stats
    is the (2,) ``scoring.quality_stats`` telemetry vector. ``precomputed``
    carries (s_attn, red_raw) from the batched Pallas kernels when
    backend=pallas."""
    is_mla = cfg.attn_type == "mla"
    if precomputed is not None:
        s, red_raw = precomputed
    elif is_mla:
        r = cfg.kv_lora_rank
        scale = 1.0 / np.sqrt(cfg.head_dim + cfg.qk_rope_head_dim)
        s = scoring.mla_attention_scores(q_win, entries, valid, seq_len,
                                         r=r, scale=scale)
        red_entries = entries[:, None, :r]              # latent only, h=1
    else:
        s = scoring.attention_scores(q_win, entries, valid, seq_len)
        red_entries = entries
    attn_raw = s
    if opts.redundancy != "none":
        if precomputed is not None:
            raw = red_raw
        elif opts.redundancy == "lightning":
            raw = scoring.redundancy_lightning(
                red_entries, valid, block_size=block_size,
                p_thresh=opts.p_thresh)
        else:
            raw = scoring.redundancy_full(red_entries, valid,
                                          p_thresh=opts.p_thresh)
        red = scoring.redundancy_softmax(raw, valid, tau=opts.tau)
    else:
        raw = jnp.zeros_like(s)
        red = jnp.zeros_like(s)
    stats = scoring.quality_stats(attn_raw, raw, valid, seq_len)
    if opts.use_global and opts.alpha > 0:
        s = scoring.global_score_update(s, fscore, hist_len, opts.alpha)
    new_f = s
    if opts.pooling == "always":
        s = scoring.max_pool_scores(s, valid, kernel=opts.pool_kernel)
    elif opts.pooling == "first":
        pooled = scoring.max_pool_scores(s, valid, kernel=opts.pool_kernel)
        s = jnp.where(hist_len == 0, pooled, s)
    final = scoring.combine_scores(s, red, valid, opts.window, seq_len,
                                   lam=opts.lam)
    return final, new_f, stats


def _compact_pool(pool, src_bt, src_cache, dest_slots):
    """Move surviving entries (per-head streams). pool: (N, b, h, ...);
    src_cache: (h, k) survivor cache positions; dest_slots: (k,) flat slots
    (OOB => dropped)."""
    N, b, h = pool.shape[0], pool.shape[1], pool.shape[2]
    flat = pool.reshape((N * b, h) + pool.shape[3:])
    src_slot = jnp.take(src_bt, src_cache // b) * b + src_cache % b  # (h, k)
    heads = jnp.arange(h)[:, None]
    vals = flat[src_slot, heads]                                     # (h, k, ...)
    flat = flat.at[dest_slots[None, :], heads].set(vals, mode="drop")
    return flat.reshape(pool.shape)


def build_compress_fn(cfg, *, block_size, max_blocks, budget_blocks,
                      opts: CompressOptions):
    """Returns compress(pools, qwin, req) -> (new_pools, new_seq_lens,
    stats).

    pools: {"k","v","f"} with (L, N, b, h, d) ×2 + (L, N, b, h)  (GQA), or
           {"kv","f"} with (L, N, b, r+dr) + (L, N, b, 1)        (MLA).
    qwin: (L, M, w, h_q, dq) observation-window query pool (ring order).
    req tuple (all leading dim n, the padded compression bucket):
      src_bt:    (n, max_blocks)    source block tables (-1 padded)
      dest_bt:   (n, budget_blocks) destination blocks (in-place: first
                 budget_blocks of src; prefix-sharing: fresh target blocks)
      qslots:    (n,) query-slot ids (-1 => padding row, no-op)
      seq_lens:  (n,) valid entries (= n_blocks·b, last block full)
      hist_lens: (n,) entries carrying global-score history (0 first time)
    """
    from repro.kernels import ops as kops

    b = block_size
    T = max_blocks * b
    k_keep = budget_blocks * b
    is_mla = cfg.attn_type == "mla"

    backend = kops.resolve_backend(opts.backend)
    use_pallas = backend.startswith("pallas") and not is_mla

    def one_layer(pool_slices, qwin_l, req):
        src_bt, dest_bt, qslots, seq_lens, hist_lens = req

        pre_s = pre_r = None
        if use_pallas:
            w = qwin_l.shape[1]
            rings = qwin_l[jnp.maximum(qslots, 0)]        # (n, w, hq, dq)
            order = (seq_lens[:, None] - w + jnp.arange(w)[None]) % w
            q_wins = jnp.take_along_axis(
                rings, order[:, :, None, None], 1)
            btc = jnp.maximum(src_bt, 0).astype(jnp.int32)
            logits = kops.score_logits(q_wins, pool_slices["k"], btc,
                                       seq_lens, backend=backend)
            pre_s = kops.attention_scores_from_logits(logits, seq_lens)
            if opts.redundancy == "lightning":
                pre_r = kops.lightning_redundancy(
                    pool_slices["k"], btc, seq_lens,
                    p_thresh=opts.p_thresh, backend=backend)
            elif opts.redundancy == "flash":
                pre_r = kops.flash_redundancy(
                    pool_slices["k"], btc, seq_lens,
                    p_thresh=opts.p_thresh, backend=backend)
            else:
                pre_r = jnp.zeros_like(pre_s)

        def per_req(src_bt_i, dest_bt_i, qslot, seq_len, hist_len,
                    pre=None):
            ring = qwin_l[jnp.maximum(qslot, 0)]          # (w, h_q, dq)
            w = ring.shape[0]
            order = (seq_len - w + jnp.arange(w)) % w
            q_win = ring[order]
            bt = jnp.where(src_bt_i >= 0, src_bt_i, 0)
            key_pool = pool_slices["kv"] if is_mla else pool_slices["k"]
            entries = gather_entries(key_pool, bt[None])[0]
            fscore = gather_entries(pool_slices["f"], bt[None])[0]
            valid = jnp.arange(T) < seq_len
            final, new_f, stats = _score_one(cfg, opts, q_win, entries,
                                             fscore, valid, seq_len,
                                             hist_len, b, precomputed=pre)
            tag = scoring.topk_tag(final, k_keep)         # (T, h_s)
            # stable keep-first sort == survivors in original cache order
            order_keep = jnp.argsort(~tag.T, axis=-1, stable=True)
            src_cache = order_keep[:, :k_keep]            # (h_s, k)
            dslots = jnp.where(dest_bt_i >= 0, dest_bt_i, 2**30 // b)
            dest_flat = (jnp.repeat(dslots, b) * b
                         + jnp.tile(jnp.arange(b), budget_blocks))
            dest_flat = jnp.where(qslot >= 0, dest_flat, 2**30)
            return src_cache, dest_flat, new_f, stats

        if use_pallas:
            src_cache, dest_flat, new_f, stats = jax.vmap(per_req)(
                src_bt, dest_bt, qslots, seq_lens, hist_lens,
                (pre_s, pre_r))
        else:
            src_cache, dest_flat, new_f, stats = jax.vmap(per_req)(
                src_bt, dest_bt, qslots, seq_lens, hist_lens)

        # Apply moves sequentially (scan) — vmapping full-pool functional
        # updates would copy the pool once per request.
        def apply_one(pools_acc, moves):
            src_bt_i, src_cache_i, dest_flat_i, new_f_i = moves
            bt = jnp.where(src_bt_i >= 0, src_bt_i, 0)
            out = dict(pools_acc)
            if is_mla:
                out["kv"] = _compact_pool(pools_acc["kv"][:, :, None], bt,
                                          src_cache_i,
                                          dest_flat_i)[:, :, 0]
            else:
                out["k"] = _compact_pool(pools_acc["k"], bt, src_cache_i,
                                         dest_flat_i)
                out["v"] = _compact_pool(pools_acc["v"], bt, src_cache_i,
                                         dest_flat_i)
            # F is refreshed (post-global scores) and moved with its entries
            h_s = new_f_i.shape[1]
            heads = jnp.arange(h_s)[:, None]
            fvals = new_f_i.T[heads, src_cache_i]          # (h_s, k)
            fflat = pools_acc["f"].reshape(-1, h_s)
            fflat = fflat.at[dest_flat_i[None, :], heads].set(fvals,
                                                              mode="drop")
            out["f"] = fflat.reshape(pools_acc["f"].shape)
            return out, None

        pools_out, _ = jax.lax.scan(
            apply_one, pool_slices, (src_bt, src_cache, dest_flat, new_f))
        return pools_out, stats

    def compress(pools, qwin, req):
        """-> (new_pools, new_seq_lens, stats) where stats is (n, 2)
        per-request quality telemetry (``scoring.quality_stats``, averaged
        across layers; garbage on padding rows)."""
        qslots, seq_lens = req[2], req[3]

        def scan_body(_, xs):
            pool_slices, qwin_l = xs
            return None, one_layer(pool_slices, qwin_l, req)

        _, (new_pools, stats_l) = jax.lax.scan(scan_body, None,
                                               (pools, qwin))
        new_seq = jnp.where(qslots >= 0, jnp.int32(k_keep),
                            seq_lens.astype(jnp.int32))
        return new_pools, new_seq, stats_l.mean(axis=0)

    return compress
