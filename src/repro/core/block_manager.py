"""Host-side paged block manager: free list, ref counts, block-level prefix
cache. Pure Python/numpy — drives the jitted device steps but never runs on
device.

Two prefix-cache policies (``CacheConfig.prefix_cache_policy``,
docs/CACHING.md):

``flat``
    The pre-radix behavior, byte-for-byte: a hash-chain map consulted for
    exact full-block matches, oldest-first eviction of unreferenced cached
    blocks. Kept for parity testing against the frozen legacy engine.

``radix``
    An SGLang-style radix tree over the same block-content hash chain.
    Every registered block is a tree node (one token-block per node, so
    "radix" collapses to a trie over block hashes — the natural unit here,
    since blocks are the allocation granularity); eviction is LRU over
    *leaves* only, so a hot shared prefix survives while its cold
    per-request suffixes are reclaimed first. The tree also carries
    *segments*: cached prefixes whose payload is **compressed** KV
    (``budget_blocks`` blocks condensing a longer span — the paper's
    compression applied to the cache itself), matched with transparent
    re-expansion accounting at hit time (``PrefixMatch.n_tokens`` covered
    vs ``n_entries`` occupied).

The flat-era surfaces (``hash_to_block`` / ``block_hash`` /
``cached_free``) stay live and authoritative in radix mode; the tree is an
index over them plus the segment maps.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple


class OutOfBlocks(Exception):
    pass


class _RadixNode:
    """One cached full block: ``key`` is its chain hash (which encodes the
    whole prefix up to and including this block), ``block`` the physical id.
    Children are keyed by their chain hash."""
    __slots__ = ("key", "block", "parent", "children")

    def __init__(self, key: int, block: int,
                 parent: Optional["_RadixNode"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[int, "_RadixNode"] = {}


class _Segment:
    """A cached *compressed* prefix: ``blocks`` hold the condensed KV of the
    first ``n_tokens`` prompt tokens; ``key`` is the chain hash of the last
    full block the span covers. The cache itself holds no references —
    payload blocks park in ``cached_free`` when the last holder lets go, and
    they enter/leave it all-or-none (every holder holds the whole payload)."""
    __slots__ = ("key", "blocks", "n_tokens")

    def __init__(self, key: int, blocks: List[int], n_tokens: int):
        self.key = key
        self.blocks = blocks
        self.n_tokens = n_tokens


@dataclasses.dataclass(frozen=True)
class PrefixMatch:
    """Result of :meth:`BlockManager.lookup_prefix_ex`. ``n_tokens`` prompt
    tokens are covered by ``blocks`` holding ``n_entries`` KV cache entries;
    the two differ exactly when the match is a compressed segment
    (``compressed=True``), and the caller must account for the gap when
    deriving cache-write indices from token positions."""
    blocks: List[int]
    n_tokens: int
    n_entries: int
    chain: List[int]
    compressed: bool


PREFIX_CACHE_POLICIES = ("flat", "radix")


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int,
                 enable_prefix_cache: bool = True,
                 swap_space_blocks: int = 0,
                 prefix_cache_policy: str = "flat",
                 prefix_cache_watermark: float = 1.0):
        if prefix_cache_policy not in PREFIX_CACHE_POLICIES:
            raise ValueError(
                f"unknown prefix_cache_policy {prefix_cache_policy!r}; "
                f"expected one of {PREFIX_CACHE_POLICIES}")
        if not 0.0 <= prefix_cache_watermark <= 1.0:
            raise ValueError("prefix_cache_watermark must be in [0, 1] "
                             "(a fraction of the block pool)")
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.enable_prefix_cache = enable_prefix_cache
        self.prefix_cache_policy = prefix_cache_policy
        self.prefix_cache_watermark = prefix_cache_watermark
        self._radix = prefix_cache_policy == "radix"
        self.free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.ref: List[int] = [0] * num_blocks
        # prefix cache: content-hash -> block id; blocks with ref==0 but a
        # live hash are reusable-before-eviction (LRU order)
        self.hash_to_block: Dict[int, int] = {}
        self.block_hash: Dict[int, int] = {}
        self.cached_free: "OrderedDict[int, None]" = OrderedDict()
        # radix index over the hash maps (radix policy only)
        self.nodes: Dict[int, _RadixNode] = {}
        self.node_of_block: Dict[int, _RadixNode] = {}
        # compressed cached prefixes (radix policy only)
        self.segments: Dict[int, _Segment] = {}
        self.seg_of_block: Dict[int, _Segment] = {}
        self._seg_tokens = 0            # sum of segment n_tokens (O(1) stats)
        # cumulative cache telemetry (surfaced via cache_stats())
        self.n_lookups = 0
        self.n_hits = 0
        self.n_hit_tokens = 0
        self.n_segment_hits = 0
        self.n_evicted_blocks = 0
        self.n_invalidated_blocks = 0
        # host swap tier (docs/SCHEDULER.md "Preemption modes"): a CPU-side
        # pool of block slots a swap-out parks KV copies in. Swapped blocks
        # are per-request private copies — shared prefix blocks are
        # copy-on-swap, so the device ref counts simply drop by one and the
        # prefix cache keeps serving its other holders.
        self.swap_space_blocks = swap_space_blocks
        self.swap_free: List[int] = list(range(swap_space_blocks - 1, -1, -1))
        self.swapped: Dict[int, List[int]] = {}      # rid -> host blocks

    # ------------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free) + len(self.cached_free)

    def _deregister_block(self, blk: int) -> None:
        """Drop ``blk``'s hash registration (and radix node, if any)."""
        node = self.node_of_block.get(blk)
        if node is not None:
            self._drop_node(node)
            return
        h = self.block_hash.pop(blk, None)
        if h is not None:
            self.hash_to_block.pop(h, None)

    def _drop_node(self, node: _RadixNode) -> None:
        self.nodes.pop(node.key, None)
        self.node_of_block.pop(node.block, None)
        if self.block_hash.get(node.block) == node.key:
            del self.block_hash[node.block]
        self.hash_to_block.pop(node.key, None)
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
            node.parent = None

    def _deregister_segment_of(self, blk: int) -> None:
        """If ``blk`` is compressed-segment payload, drop the whole segment
        registration. Peer payload blocks already parked in ``cached_free``
        lose their cache claim and return to the raw free list."""
        seg = self.seg_of_block.get(blk)
        if seg is None:
            return
        self.segments.pop(seg.key, None)
        self._seg_tokens -= seg.n_tokens
        for p in seg.blocks:
            self.seg_of_block.pop(p, None)
            if p in self.cached_free and p not in self.block_hash:
                del self.cached_free[p]
                self.free.append(p)

    def _evict_lru_leaf(self) -> Optional[int]:
        """Radix eviction: oldest unreferenced *leaf* (a cached block no
        cached chain extends), or an oldest whole segment. Interior nodes
        are skipped — a shared prefix outlives its suffixes. Always finds a
        victim when ``cached_free`` is non-empty: every holder of a cached
        node holds its whole root path, so an unreferenced node's
        descendants are unreferenced too and the scan reaches a leaf."""
        for blk in self.cached_free:
            node = self.node_of_block.get(blk)
            if node is not None and not node.children:
                del self.cached_free[blk]
                self._drop_node(node)
                self.n_evicted_blocks += 1
                return blk
            seg = self.seg_of_block.get(blk)
            if seg is not None \
                    and all(b in self.cached_free for b in seg.blocks):
                self.segments.pop(seg.key, None)
                self._seg_tokens -= seg.n_tokens
                for b in seg.blocks:
                    self.seg_of_block.pop(b, None)
                    del self.cached_free[b]
                    if b != blk:
                        self.free.append(b)
                self.n_evicted_blocks += len(seg.blocks)
                return blk
        return None

    def _pop_block(self) -> int:
        if self.free:
            return self.free.pop()
        if self._radix:
            blk = self._evict_lru_leaf()
            if blk is not None:
                return blk
        if self.cached_free:
            blk, _ = self.cached_free.popitem(last=False)   # evict oldest
            self._deregister_block(blk)
            self._deregister_segment_of(blk)
            self.n_evicted_blocks += 1
            return blk
        raise OutOfBlocks()

    def can_allocate(self, n: int, margin: int = 0) -> bool:
        """True if ``n`` blocks can be handed out while still leaving
        ``margin`` free. The scheduler's compression-aware admission passes
        the projected post-compression growth of the running batch as the
        margin (docs/SCHEDULER.md)."""
        return self.num_free >= n + margin

    @property
    def utilization(self) -> float:
        return 1.0 - self.num_free / self.num_blocks

    def allocate(self, n: int) -> List[int]:
        if not self.can_allocate(n):
            raise OutOfBlocks()
        blocks = [self._pop_block() for _ in range(n)]
        for b in blocks:
            self.ref[b] = 1
        return blocks

    def fork(self, block: int) -> int:
        """Add a reference to a shared block."""
        assert self.ref[block] >= 1
        self.ref[block] += 1
        return block

    def release(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            assert self.ref[b] > 0, f"double free of block {b}"
            self.ref[b] -= 1
            if self.ref[b] == 0:
                cached = b in self.block_hash or b in self.seg_of_block
                if cached and self.enable_prefix_cache:
                    self.cached_free[b] = None      # keep contents reusable
                else:
                    if cached:
                        # prefix cache toggled off at runtime (e.g. a
                        # snapshot/restore round trip): drop the hash /
                        # segment registration symmetrically instead of
                        # leaving stale entries pointing at a free block
                        self._deregister_block(b)
                        self._deregister_segment_of(b)
                    self.free.append(b)
        self._enforce_watermark()

    def _enforce_watermark(self) -> None:
        """Cap unreferenced cached blocks at ``prefix_cache_watermark *
        num_blocks``, evicting LRU (leaf-first under radix) beyond it.
        1.0 — the default — disables the cap: cached blocks are only
        reclaimed under allocation pressure."""
        if self.prefix_cache_watermark >= 1.0:
            return
        limit = int(self.prefix_cache_watermark * self.num_blocks)
        while len(self.cached_free) > limit:
            blk = self._evict_lru_leaf() if self._radix else None
            if blk is None:
                if not self.cached_free:
                    break
                blk, _ = self.cached_free.popitem(last=False)
                self._deregister_block(blk)
                self._deregister_segment_of(blk)
                self.n_evicted_blocks += 1
            self.free.append(blk)

    # ------------------------------------------------------------------
    # prefix cache

    @staticmethod
    def chain_hash(prev_hash: int, tokens: Tuple[int, ...]) -> int:
        return hash((prev_hash, tokens))

    def _block_chain(self, token_ids: Sequence[int]) -> List[int]:
        bs = self.block_size
        chain: List[int] = []
        h = 0
        for i in range(len(token_ids) // bs):
            h = self.chain_hash(h, tuple(token_ids[i * bs:(i + 1) * bs]))
            chain.append(h)
        return chain

    def _claim(self, blocks: Sequence[int]) -> None:
        """Take a reference on matched blocks, resurrecting any that were
        parked unreferenced (which also refreshes their LRU recency)."""
        for blk in blocks:
            if blk in self.cached_free:
                del self.cached_free[blk]
            self.ref[blk] += 1

    def lookup_prefix(self, token_ids: Sequence[int]):
        """Longest cached prefix of FULL blocks (legacy exact-match API).

        Returns (blocks, n_tokens_matched, chain) where chain is the list of
        hashes for all full blocks of the prompt (for later registration).
        Unlike :meth:`lookup_prefix_ex` this never caps a full-prompt match
        and never consults compressed segments — it is byte-for-byte the
        pre-radix behavior.
        """
        bs = self.block_size
        chain, blocks = [], []
        h = 0
        n_full = len(token_ids) // bs
        matched = True
        n_matched = 0
        self.n_lookups += 1
        for i in range(n_full):
            h = self.chain_hash(h, tuple(token_ids[i * bs:(i + 1) * bs]))
            chain.append(h)
            if matched and self.enable_prefix_cache and h in self.hash_to_block:
                blk = self.hash_to_block[h]
                if blk in self.cached_free:          # resurrect
                    del self.cached_free[blk]
                self.ref[blk] += 1
                blocks.append(blk)
                n_matched += bs
            else:
                matched = False
        if n_matched:
            self.n_hits += 1
            self.n_hit_tokens += n_matched
        return blocks, n_matched, chain

    def lookup_prefix_ex(self, token_ids: Sequence[int],
                         allow_compressed: bool = False) -> PrefixMatch:
        """Longest-prefix match over the radix tree, optionally including
        compressed segments. References are taken on the returned blocks.

        Radix refinement over :meth:`lookup_prefix`: a match covering the
        *entire* prompt is capped one block short, so the final prefill
        chunk always carries at least one real token and the first sampled
        token comes from the true last-prompt-token query — cache-hit
        streams stay bit-identical to cache-miss streams.

        With ``allow_compressed``, a registered segment beats the exact
        match when it covers more tokens; the caller sees
        ``n_entries < n_tokens`` and must thread the position gap through
        prefill (``Request.pos_gap``).
        """
        chain = self._block_chain(token_ids)
        self.n_lookups += 1
        bs = self.block_size
        n_exact = 0
        if self.enable_prefix_cache:
            for h in chain:
                if h in self.hash_to_block:
                    n_exact += 1
                else:
                    break
        if self._radix and n_exact and n_exact * bs >= len(token_ids):
            n_exact -= 1                 # full-prompt hit: leave one chunk
        seg = None
        if allow_compressed and self._radix and self.enable_prefix_cache:
            for j in range(len(chain) - 1, -1, -1):
                s = self.segments.get(chain[j])
                if s is not None and s.n_tokens == (j + 1) * bs \
                        and s.n_tokens < len(token_ids) \
                        and s.n_tokens > n_exact * bs:
                    seg = s
                    break
        if seg is not None:
            self._claim(seg.blocks)
            self.n_hits += 1
            self.n_segment_hits += 1
            self.n_hit_tokens += seg.n_tokens
            return PrefixMatch(list(seg.blocks), seg.n_tokens,
                               len(seg.blocks) * bs, chain, True)
        blocks = [self.hash_to_block[h] for h in chain[:n_exact]]
        self._claim(blocks)
        if n_exact:
            self.n_hits += 1
            self.n_hit_tokens += n_exact * bs
        return PrefixMatch(blocks, n_exact * bs, n_exact * bs, chain, False)

    def probe_prefix(self, token_ids: Sequence[int],
                     allow_compressed: bool = False) -> int:
        """Side-effect-free probe: prompt tokens a lookup would cover. No
        references taken, no LRU touch, no counters — the ``cache_aware``
        admission policy calls this per waiting request per step."""
        if not self.enable_prefix_cache:
            return 0
        chain = self._block_chain(token_ids)
        n_exact = 0
        for h in chain:
            if h in self.hash_to_block:
                n_exact += 1
            else:
                break
        best = n_exact * self.block_size
        if allow_compressed and self._radix:
            for j in range(len(chain) - 1, -1, -1):
                s = self.segments.get(chain[j])
                if s is not None and s.n_tokens < len(token_ids):
                    best = max(best, s.n_tokens)
                    break
        return min(best, max(0, len(token_ids) - 1))

    def register_prefix(self, blocks: Sequence[int], chain: Sequence[int],
                        start_block: int) -> None:
        """Register newly-filled full blocks under their chain hashes. Under
        the radix policy each registration also inserts a tree node chained
        to its parent block's node (registration of a block whose ancestor
        chain was evicted is skipped — the tree never holds dangling
        paths)."""
        if not self.enable_prefix_cache:
            return
        for i, h in enumerate(chain[start_block:], start=start_block):
            if i >= len(blocks):
                break
            blk = blocks[i]
            if h in self.hash_to_block or blk in self.block_hash \
                    or blk in self.seg_of_block:
                continue
            if self._radix:
                parent = self.nodes.get(chain[i - 1]) if i > 0 else None
                if i > 0 and parent is None:
                    continue
                node = _RadixNode(h, blk, parent)
                self.nodes[h] = node
                self.node_of_block[blk] = node
                if parent is not None:
                    parent.children[h] = node
            self.hash_to_block[h] = blk
            self.block_hash[blk] = h

    def register_segment(self, key: int, blocks: Sequence[int],
                         n_tokens: int) -> None:
        """Cache a compressed prefix (radix policy only): ``blocks`` hold
        the condensed KV of the first ``n_tokens`` prompt tokens, keyed by
        the chain hash of the last full block the span covers. No-op if the
        key is already cached or a payload block is otherwise registered."""
        if not self.enable_prefix_cache or not self._radix:
            return
        if n_tokens <= 0 or key in self.segments:
            return
        if any(b in self.block_hash or b in self.seg_of_block
               for b in blocks):
            return
        seg = _Segment(key, list(blocks), n_tokens)
        self.segments[key] = seg
        for b in blocks:
            self.seg_of_block[b] = seg
        self._seg_tokens += n_tokens

    def invalidate_blocks(self, blocks: Sequence[int]) -> None:
        """Drop every cache registration naming ``blocks`` — called before
        their payload is overwritten (in-place compression dest/reserved
        blocks). A dropped radix node takes its whole subtree with it
        (descendants are only reachable through the parent chain); orphaned
        descendants are provably unreferenced, so their blocks move from
        ``cached_free`` straight to the free list."""
        for b in blocks:
            self._deregister_segment_of(b)
            node = self.node_of_block.get(b)
            if node is not None:
                self._drop_subtree(node)
            elif b in self.block_hash:
                self._deregister_block(b)
                self.n_invalidated_blocks += 1

    def _drop_subtree(self, node: _RadixNode) -> None:
        for child in list(node.children.values()):
            self._drop_subtree(child)
        blk = node.block
        self._drop_node(node)
        self.n_invalidated_blocks += 1
        if blk in self.cached_free and blk not in self.seg_of_block:
            del self.cached_free[blk]
            self.free.append(blk)

    def is_shared(self, block: int) -> bool:
        return self.ref[block] > 1

    def is_cow_protected(self, block: int) -> bool:
        """True if overwriting ``block`` in place would corrupt another
        reader: it is shared (ref > 1), it serves as cached
        compressed-segment payload, or — under the radix policy — it is
        registered in the prefix tree (cached content is immutable; a
        later request may claim it at any time). Compression planning
        treats protected blocks like shared prefix blocks and copies into
        fresh dest blocks instead (copy-on-write), so the cached prefix
        outlives the compression that condensed it."""
        if self.ref[block] > 1 or block in self.seg_of_block:
            return True
        return self._radix and block in self.block_hash

    def cache_stats(self) -> dict:
        """Cumulative prefix-cache telemetry (merged into
        ``Scheduler.stats()`` -> ``Zipage.scheduler_stats``).
        ``cached_tokens_per_block`` is the effective-capacity headline: a
        full-KV cache pins it at ``block_size``, compressed segments push
        it above (docs/PERF.md "Effective prefix-cache capacity")."""
        n_blocks = len(self.block_hash) + len(self.seg_of_block)
        n_tokens = self.block_size * len(self.block_hash) + self._seg_tokens
        return {
            "prefix_cache_policy": self.prefix_cache_policy,
            "prefix_lookups": self.n_lookups,
            "prefix_hits": self.n_hits,
            "prefix_hit_tokens": self.n_hit_tokens,
            "prefix_segment_hits": self.n_segment_hits,
            "prefix_evictions": self.n_evicted_blocks,
            "prefix_cached_blocks": n_blocks,
            "prefix_cached_tokens": n_tokens,
            "cached_tokens_per_block":
                (n_tokens / n_blocks) if n_blocks else 0.0,
        }

    # ------------------------------------------------------------------
    # host swap tier

    @property
    def swap_util(self) -> float:
        if not self.swap_space_blocks:
            return 0.0
        return 1.0 - len(self.swap_free) / self.swap_space_blocks

    def can_swap_out(self, n: int) -> bool:
        return 0 < n <= len(self.swap_free)

    def swap_out(self, rid: int, n: int) -> List[int]:
        """Reserve ``n`` host blocks for ``rid``'s KV copy. The caller
        copies the device blocks out *before* releasing them (the device
        side stays ref-counted: shared blocks merely drop one ref)."""
        assert rid not in self.swapped, f"rid {rid} already swapped out"
        if not self.can_swap_out(n):
            raise OutOfBlocks()
        host = [self.swap_free.pop() for _ in range(n)]
        self.swapped[rid] = host
        return host

    def swapped_blocks(self, rid: int) -> List[int]:
        return list(self.swapped[rid])

    def n_swapped_blocks(self, rid: int) -> int:
        return len(self.swapped[rid])

    def release_swapped(self, rid: int) -> None:
        """Return ``rid``'s host blocks to the swap pool (after swap-in
        copied them back, or on abort of a swapped request)."""
        self.swap_free.extend(self.swapped.pop(rid))

    # invariant checks (used by property tests and repro.core.invariants)
    def check_invariants(self) -> None:
        live = [b for b in range(self.num_blocks) if self.ref[b] > 0]
        free_set = set(self.free) | set(self.cached_free)
        assert len(free_set) == len(self.free) + len(self.cached_free)
        assert free_set.isdisjoint(live)
        assert len(live) + len(free_set) == self.num_blocks
        # hash <-> block bijection, both directions
        for h, b in self.hash_to_block.items():
            assert self.block_hash.get(b) == h
        for b, h in self.block_hash.items():
            assert self.hash_to_block.get(h) == b
        # no registered (cached) block on the raw free list, and every
        # unreferenced cached block is actually registered somewhere
        raw_free = set(self.free)
        assert raw_free.isdisjoint(self.block_hash)
        assert raw_free.isdisjoint(self.seg_of_block)
        for b in self.cached_free:
            assert b in self.block_hash or b in self.seg_of_block
        # radix tree audit
        if self._radix:
            assert set(self.nodes) == set(self.hash_to_block)
            assert len(self.node_of_block) == len(self.nodes)
            for h, node in self.nodes.items():
                assert node.key == h
                assert self.hash_to_block[h] == node.block
                assert self.node_of_block.get(node.block) is node
                if node.parent is not None:
                    assert node.parent.children.get(h) is node
                    # path closure: a referenced node's parent is referenced
                    if self.ref[node.block] > 0:
                        assert self.ref[node.parent.block] > 0
                for ck, child in node.children.items():
                    assert child.parent is node
                    assert self.nodes.get(ck) is child
        else:
            assert not self.nodes and not self.segments
        # segments: consistent maps, all-or-none holders
        n_payload = 0
        for key, seg in self.segments.items():
            assert seg.key == key
            n_payload += len(seg.blocks)
            assert len({self.ref[b] for b in seg.blocks}) == 1
            for b in seg.blocks:
                assert self.seg_of_block.get(b) is seg
                assert b not in self.block_hash
        assert n_payload == len(self.seg_of_block)
        assert self._seg_tokens == sum(s.n_tokens
                                       for s in self.segments.values())
        # swap pool: free + per-rid reservations partition the host blocks
        held = [b for blocks in self.swapped.values() for b in blocks]
        swap_all = set(self.swap_free) | set(held)
        assert len(swap_all) == len(self.swap_free) + len(held)
        assert len(swap_all) == self.swap_space_blocks
