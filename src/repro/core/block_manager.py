"""Host-side paged block manager: free list, ref counts, block-level prefix
cache (vLLM-style hash chaining). Pure Python/numpy — drives the jitted
device steps but never runs on device."""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple


class OutOfBlocks(Exception):
    pass


class BlockManager:
    def __init__(self, num_blocks: int, block_size: int,
                 enable_prefix_cache: bool = True,
                 swap_space_blocks: int = 0):
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.enable_prefix_cache = enable_prefix_cache
        self.free: List[int] = list(range(num_blocks - 1, -1, -1))
        self.ref: List[int] = [0] * num_blocks
        # prefix cache: content-hash -> block id; blocks with ref==0 but a
        # live hash are reusable-before-eviction (LRU order)
        self.hash_to_block: Dict[int, int] = {}
        self.block_hash: Dict[int, int] = {}
        self.cached_free: "OrderedDict[int, None]" = OrderedDict()
        # host swap tier (docs/SCHEDULER.md "Preemption modes"): a CPU-side
        # pool of block slots a swap-out parks KV copies in. Swapped blocks
        # are per-request private copies — shared prefix blocks are
        # copy-on-swap, so the device ref counts simply drop by one and the
        # prefix cache keeps serving its other holders.
        self.swap_space_blocks = swap_space_blocks
        self.swap_free: List[int] = list(range(swap_space_blocks - 1, -1, -1))
        self.swapped: Dict[int, List[int]] = {}      # rid -> host blocks

    # ------------------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free) + len(self.cached_free)

    def _pop_block(self) -> int:
        if self.free:
            return self.free.pop()
        if self.cached_free:
            blk, _ = self.cached_free.popitem(last=False)   # evict oldest
            h = self.block_hash.pop(blk, None)
            if h is not None:
                self.hash_to_block.pop(h, None)
            return blk
        raise OutOfBlocks()

    def can_allocate(self, n: int, margin: int = 0) -> bool:
        """True if ``n`` blocks can be handed out while still leaving
        ``margin`` free. The scheduler's compression-aware admission passes
        the projected post-compression growth of the running batch as the
        margin (docs/SCHEDULER.md)."""
        return self.num_free >= n + margin

    @property
    def utilization(self) -> float:
        return 1.0 - self.num_free / self.num_blocks

    def allocate(self, n: int) -> List[int]:
        if not self.can_allocate(n):
            raise OutOfBlocks()
        blocks = [self._pop_block() for _ in range(n)]
        for b in blocks:
            self.ref[b] = 1
        return blocks

    def fork(self, block: int) -> int:
        """Add a reference to a shared block."""
        assert self.ref[block] >= 1
        self.ref[block] += 1
        return block

    def release(self, blocks: Sequence[int]) -> None:
        for b in blocks:
            assert self.ref[b] > 0, f"double free of block {b}"
            self.ref[b] -= 1
            if self.ref[b] == 0:
                if b in self.block_hash and self.enable_prefix_cache:
                    self.cached_free[b] = None      # keep contents reusable
                else:
                    self.free.append(b)

    # ------------------------------------------------------------------
    # prefix cache

    @staticmethod
    def chain_hash(prev_hash: int, tokens: Tuple[int, ...]) -> int:
        return hash((prev_hash, tokens))

    def lookup_prefix(self, token_ids: Sequence[int]):
        """Longest cached prefix of FULL blocks.

        Returns (blocks, n_tokens_matched, chain) where chain is the list of
        hashes for all full blocks of the prompt (for later registration).
        """
        bs = self.block_size
        chain, blocks = [], []
        h = 0
        n_full = len(token_ids) // bs
        matched = True
        n_matched = 0
        for i in range(n_full):
            h = self.chain_hash(h, tuple(token_ids[i * bs:(i + 1) * bs]))
            chain.append(h)
            if matched and self.enable_prefix_cache and h in self.hash_to_block:
                blk = self.hash_to_block[h]
                if blk in self.cached_free:          # resurrect
                    del self.cached_free[blk]
                self.ref[blk] += 1
                blocks.append(blk)
                n_matched += bs
            else:
                matched = False
        return blocks, n_matched, chain

    def register_prefix(self, blocks: Sequence[int], chain: Sequence[int],
                        start_block: int) -> None:
        """Register newly-filled full blocks under their chain hashes."""
        if not self.enable_prefix_cache:
            return
        for i, h in enumerate(chain[start_block:], start=start_block):
            if i >= len(blocks):
                break
            blk = blocks[i]
            if h not in self.hash_to_block:
                self.hash_to_block[h] = blk
                self.block_hash[blk] = h

    def is_shared(self, block: int) -> bool:
        return self.ref[block] > 1

    # ------------------------------------------------------------------
    # host swap tier

    @property
    def swap_util(self) -> float:
        if not self.swap_space_blocks:
            return 0.0
        return 1.0 - len(self.swap_free) / self.swap_space_blocks

    def can_swap_out(self, n: int) -> bool:
        return 0 < n <= len(self.swap_free)

    def swap_out(self, rid: int, n: int) -> List[int]:
        """Reserve ``n`` host blocks for ``rid``'s KV copy. The caller
        copies the device blocks out *before* releasing them (the device
        side stays ref-counted: shared blocks merely drop one ref)."""
        assert rid not in self.swapped, f"rid {rid} already swapped out"
        if not self.can_swap_out(n):
            raise OutOfBlocks()
        host = [self.swap_free.pop() for _ in range(n)]
        self.swapped[rid] = host
        return host

    def swapped_blocks(self, rid: int) -> List[int]:
        return list(self.swapped[rid])

    def n_swapped_blocks(self, rid: int) -> int:
        return len(self.swapped[rid])

    def release_swapped(self, rid: int) -> None:
        """Return ``rid``'s host blocks to the swap pool (after swap-in
        copied them back, or on abort of a swapped request)."""
        self.swap_free.extend(self.swapped.pop(rid))

    # invariant checks (used by property tests)
    def check_invariants(self) -> None:
        live = [b for b in range(self.num_blocks) if self.ref[b] > 0]
        free_set = set(self.free) | set(self.cached_free)
        assert len(free_set) == len(self.free) + len(self.cached_free)
        assert free_set.isdisjoint(live)
        assert len(live) + len(free_set) == self.num_blocks
        for h, b in self.hash_to_block.items():
            assert self.block_hash.get(b) == h
        # swap pool: free + per-rid reservations partition the host blocks
        held = [b for blocks in self.swapped.values() for b in blocks]
        swap_all = set(self.swap_free) | set(held)
        assert len(swap_all) == len(self.swap_free) + len(held)
        assert len(swap_all) == self.swap_space_blocks
