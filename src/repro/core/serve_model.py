"""Serving-time model execution over the paged KV pool.

Builds jit-able ``prefill_step`` and ``decode_step`` for any ArchConfig:
  * attention layers read/write the paged pools (GQA or MLA-latent layout),
  * local-window layers (recurrentgemma) use ring pages bounded by the window,
  * recurrent layers (RG-LRU / RWKV6) keep O(1) per-slot states,
  * observation-window queries are written into the Q pool (paper §4.2),
  * layers are scanned (HLO stays small for 48-layer archs) with the pools
    carried and updated via dynamic_update_index_in_dim.

State layout (all leading dims static):
  pools:   {"k","v","f"} (L_attn, N, b, h_kv, d)×2 + (L_attn, N, b, h_kv)
           or {"kv","f"} (L_attn, N, b, r+dr) + (L_attn, N, b, 1)   [MLA]
  qwin:    (L_attn, M, w, h_q, dq) ring-ordered observation queries
  block_tables (B, max_blocks) int32, seq_lens (B,), positions (B,),
  qslot (B,) int32, rec: per-kind recurrent states (L_rec leading dim),
  cross_kv: (L_dec, B, S_mem, h_kv, d)×2 for enc-dec archs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import layers as ML
from repro.models import lm
from repro.models.common import apply_norm, apply_rope, \
    chunked_causal_attention
from repro.core import paged


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    n_slots: int                # decode batch slots
    block_size: int
    max_blocks: int             # block-table width per request
    n_total_blocks: int         # pool size
    m_qslots: int               # query-slot pool (paper's M)
    window: int = 16            # observation window w
    prefill_rows: int = 4       # prefill bucket rows
    prefill_len: int = 256      # padded prefill length
    dtype: str = "bfloat16"
    # decode-attention backend: "chunked" (jnp chunked reference) or any
    # repro.kernels.ops backend name — auto | jnp | pallas-interpret |
    # pallas-tpu (+ deprecated alias "pallas"). Resolved once at trace time.
    attn_backend: str = "auto"
    # decode kernel family: "ragged" (length-aware — per-slot work scales
    # with the slot's live block count, docs/KERNELS.md "Ragged decode")
    # or "dense" (every slot pays pool-wide max_blocks). Token streams are
    # bit-identical between the two on every backend; the knob exists as a
    # fallback/ablation switch. Ignored by the "chunked" attn_backend and
    # by MLA models (latent-cache decode has its own path).
    decode_kernel: str = "ragged"
    # KV-head replication for TP > h_kv (vLLM-style): pools store
    # h_kv * kv_replication head slots laid out repeat-consecutive
    # [kv0, kv0, ..., kv1, kv1, ...] so model-shard s's q-head group maps to
    # its own stored slot (DESIGN.md §5). GQA math is unchanged: treat
    # h_store as h_kv with group size h_q / h_store.
    kv_replication: int = 1

    def ring_blocks(self, cfg):
        """Ring capacity for local-window attention (== window tokens)."""
        assert cfg.local_window % self.block_size == 0
        return cfg.local_window // self.block_size


# ----------------------------------------------------------------------
# layer ordinal bookkeeping

def stage_layout(cfg: ArchConfig):
    """Returns plan plus, per stage, the attn/rec ordinal offsets."""
    plan = lm.build_plan(cfg)
    kinds_unit = [k for k, _ in plan["unit"]]
    a_unit = sum(1 for k in kinds_unit if k == "attn")
    r_unit = len(kinds_unit) - a_unit
    a_head = sum(1 for k, _ in plan["head"] if k == "attn")
    r_head = len(plan["head"]) - a_head
    a_tail = sum(1 for k, _ in plan["tail"] if k == "attn")
    n_attn = a_head + plan["n_units"] * a_unit + a_tail
    n_rec = cfg.num_layers - n_attn
    return {
        "plan": plan, "a_unit": a_unit, "r_unit": r_unit,
        "a_head": a_head, "r_head": r_head,
        "n_attn": n_attn, "n_rec": n_rec,
    }


def qwin_dim(cfg: ArchConfig):
    if cfg.attn_type == "mla":
        return cfg.kv_lora_rank + cfg.qk_rope_head_dim
    return cfg.head_dim


# ----------------------------------------------------------------------
# state construction

def make_state(cfg: ArchConfig, spec: ServeSpec):
    lay = stage_layout(cfg)
    dt = jnp.dtype(spec.dtype)
    L, B = lay["n_attn"], spec.n_slots
    N, b = spec.n_total_blocks, spec.block_size
    st = {
        "block_tables": jnp.full((B, spec.max_blocks), -1, jnp.int32),
        "seq_lens": jnp.zeros((B,), jnp.int32),
        "positions": jnp.zeros((B,), jnp.int32),
        "qslot": jnp.full((B,), -1, jnp.int32),
    }
    if L:
        if cfg.attn_type == "mla":
            e = cfg.kv_lora_rank + cfg.qk_rope_head_dim
            st["pools"] = {"kv": jnp.zeros((L, N, b, e), dt),
                           "f": jnp.zeros((L, N, b, 1), jnp.float32)}
        else:
            h = cfg.num_kv_heads * spec.kv_replication
            d = cfg.head_dim
            st["pools"] = {"k": jnp.zeros((L, N, b, h, d), dt),
                           "v": jnp.zeros((L, N, b, h, d), dt),
                           "f": jnp.zeros((L, N, b, h), jnp.float32)}
        st["qwin"] = jnp.zeros((L, spec.m_qslots, spec.window,
                                cfg.num_heads, qwin_dim(cfg)), dt)
    if lay["n_rec"]:
        kinds = cfg.layer_kinds()
        if "rglru" in kinds:
            w = cfg.lru_width or cfg.d_model
            st["rec"] = {
                "h": jnp.zeros((lay["n_rec"], B, w), jnp.float32),
                "conv": jnp.zeros((lay["n_rec"], B, cfg.conv1d_width - 1, w), dt),
            }
        else:  # rwkv
            hh, K = cfg.num_heads, cfg.head_dim
            st["rec"] = {
                "S": jnp.zeros((lay["n_rec"], B, hh, K, K), jnp.float32),
                "shift": jnp.zeros((lay["n_rec"], B, cfg.d_model), dt),
            }
    if cfg.is_enc_dec:
        h, d = cfg.num_kv_heads, cfg.head_dim
        st["cross_kv"] = {
            "k": jnp.zeros((cfg.num_layers, B, cfg.cross_seq_len, h, d), dt),
            "v": jnp.zeros((cfg.num_layers, B, cfg.cross_seq_len, h, d), dt),
        }
    return st


# ----------------------------------------------------------------------
# per-layer decode

def _dyn(arr, i):
    return jax.lax.dynamic_index_in_dim(arr, i, 0, keepdims=False)


def _dyn_set(arr, val, i):
    return jax.lax.dynamic_update_index_in_dim(arr, val.astype(arr.dtype), i, 0)


def _decode_attn(cfg, spec, p, x, carry, a_idx, write_pos, attend_len,
                 positions, qring_pos, qslot):
    """One attention layer, one token. x: (B, d). Returns (out, carry)."""
    B = x.shape[0]
    pools, qwin = carry["pools"], carry["qwin"]
    bt = carry["block_tables"]
    if cfg.attn_type == "mla":
        r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
        dh, dv = cfg.head_dim, cfg.v_head_dim
        q_nope, q_rope = ML.mla_queries(cfg, p, x[:, None], positions[:, None])
        q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]          # (B, hq, ·)
        c, k_rope = ML.mla_latent(cfg, p, x[:, None], positions[:, None])
        entry = jnp.concatenate([c[:, 0], k_rope[:, 0]], -1)  # (B, r+dr)
        kv_l = _dyn(pools["kv"], a_idx)
        kv_l = paged.scatter_token(kv_l, bt, write_pos, entry)
        pools = dict(pools, kv=_dyn_set(pools["kv"], kv_l, a_idx))
        w_uk = p["w_uk"].reshape(r, cfg.num_heads, dh)
        q_abs = jnp.einsum("bhd,rhd->bhr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32)).astype(x.dtype)
        scale = 1.0 / np.sqrt(dh + dr)
        o_lat = paged.paged_decode_attention_mla(
            q_abs, q_rope, kv_l, bt, attend_len, r=r, scale=scale)
        w_uv = p["w_uv"].reshape(r, cfg.num_heads, dv)
        o = jnp.einsum("bhr,rhd->bhd", o_lat.astype(jnp.float32),
                       w_uv.astype(jnp.float32))
        o = o.reshape(B, cfg.num_heads * dv).astype(x.dtype)
        q_entry = jnp.concatenate([q_abs, q_rope], -1)        # (B, hq, r+dr)
    else:
        q, k, v = ML.attn_qkv(cfg, p, x)                      # (B, h, d)
        q = apply_rope(q[:, None], positions[:, None], cfg.rope_theta)[:, 0]
        k = apply_rope(k[:, None], positions[:, None], cfg.rope_theta)[:, 0]
        if spec.kv_replication > 1:
            k = jnp.repeat(k, spec.kv_replication, axis=1)
            v = jnp.repeat(v, spec.kv_replication, axis=1)
        k_l = paged.scatter_token(_dyn(pools["k"], a_idx), bt, write_pos, k)
        v_l = paged.scatter_token(_dyn(pools["v"], a_idx), bt, write_pos, v)
        pools = dict(pools,
                     k=_dyn_set(pools["k"], k_l, a_idx),
                     v=_dyn_set(pools["v"], v_l, a_idx))
        if spec.attn_backend == "chunked":
            o = paged.paged_decode_attention_chunked(q, k_l, v_l, bt,
                                                     attend_len)
        elif spec.decode_kernel == "ragged":
            from repro.kernels import ops as kops
            o = kops.ragged_decode_attention(q, k_l, v_l, bt, attend_len,
                                             backend=spec.attn_backend)
        else:
            from repro.kernels import ops as kops
            backend = kops.resolve_backend(spec.attn_backend)
            if backend.startswith("pallas"):
                o = kops.paged_decode_attention(q, k_l, v_l, bt, attend_len,
                                                backend=backend)
            else:
                o = paged.paged_decode_attention(q, k_l, v_l, bt, attend_len)
        o = o.reshape(B, cfg.num_heads * cfg.head_dim)
        q_entry = q
    # observation-window query write (ring at qring_pos) for slots w/ qslot.
    # Inactive rows (write_pos < 0: masked out or past their fused-horizon
    # cap) must not write: their query is garbage and their ring position
    # is frozen, so it would overwrite a real entry the compression
    # scoring still needs.
    qw_l = _dyn(qwin, a_idx)                                  # (M, w, hq, dq)
    Mq, w = qw_l.shape[0], qw_l.shape[1]
    live = (qslot >= 0) & (write_pos >= 0)
    qs = jnp.where(qslot >= 0, qslot, Mq)
    qw_flat = qw_l.reshape(Mq * w, *qw_l.shape[2:])
    qidx = jnp.where(live, qs * w + qring_pos % w, Mq * w)
    qw_flat = qw_flat.at[qidx].set(q_entry.astype(qw_flat.dtype), mode="drop")
    carry = dict(carry, pools=pools,
                 qwin=_dyn_set(qwin, qw_flat.reshape(qw_l.shape), a_idx))
    return o @ p["wo"].astype(x.dtype), carry


def _decode_rec(cfg, p, x, carry, r_idx, kind, active):
    rec = carry["rec"]
    if kind == "rglru":
        stl = {"h": _dyn(rec["h"], r_idx), "conv": _dyn(rec["conv"], r_idx)}
        out, new = ML.rglru_step(cfg, p, x, stl)
    else:
        stl = {"S": _dyn(rec["S"], r_idx), "shift": _dyn(rec["shift"], r_idx)}
        out, new = ML.rwkv_step(cfg, p, x, stl)
    # freeze state for inactive slots
    new = jax.tree.map(
        lambda n, o: jnp.where(
            active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o), new, stl)
    rec = {k: _dyn_set(rec[k], new[k], r_idx) for k in rec}
    return out, dict(carry, rec=rec)


def _decode_cross(cfg, p, x, carry, l_idx):
    ck = _dyn(carry["cross_kv"]["k"], l_idx)      # (B, Sm, h, d)
    cv = _dyn(carry["cross_kv"]["v"], l_idx)
    B = x.shape[0]
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, hq, dh)
    g = hq // hkv
    qg = q.reshape(B, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bhgd,bmhd->bhgm", qg, ck.astype(jnp.float32)) / np.sqrt(dh)
    a = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgm,bmhd->bhgd", a, cv.astype(jnp.float32))
    o = o.reshape(B, hq * dh).astype(x.dtype)
    return o @ p["wo"].astype(x.dtype)


def build_decode_step(cfg: ArchConfig, spec: ServeSpec):
    """decode_step(params, state, tokens, active) -> (logits, new_state).

    tokens: (B,) int32; active: (B,) bool. Inactive slots produce garbage
    logits and leave all their state untouched.
    """
    lay = stage_layout(cfg)
    plan = lay["plan"]
    b = spec.block_size
    ring = spec.ring_blocks(cfg) * b if cfg.local_window else 0

    def layer_apply(p, x, carry, kind, ffn_kind, a_idx, r_idx, l_idx,
                    ctx):
        active, positions = ctx
        h = apply_norm(cfg, p["ln1"], x)
        if kind == "attn":
            if ring:
                write_pos = jnp.where(active, positions % ring, -1)
                attend_len = jnp.minimum(positions + 1, ring)
            else:
                write_pos = jnp.where(active, carry["seq_lens"], -1)
                attend_len = carry["seq_lens"] + 1
            mix, carry = _decode_attn(cfg, spec, p["attn"], h, carry, a_idx,
                                      write_pos, attend_len, positions,
                                      carry["seq_lens"], carry["qslot"])
        else:
            mix, carry = _decode_rec(cfg, p[kind], h, carry, r_idx, kind,
                                     active)
        x = x + mix
        if "cross" in p:
            x = x + _decode_cross(cfg, p["cross"],
                                  apply_norm(cfg, p["ln_x"], x), carry, l_idx)
        h2 = apply_norm(cfg, p["ln2"], x)
        if ffn_kind == "moe":
            x = x + ML.moe_forward(cfg, p["moe"], h2[:, None],
                                   valid=active[:, None])[:, 0]
        else:
            x = x + ML.ffn_forward(cfg, p["ffn"], h2)
        return x, carry

    def step(params, state, tokens, active):
        dt = jnp.dtype(spec.dtype)
        x = params["embed"].astype(dt)[tokens]
        positions = state["positions"]
        carry = {k: state[k] for k in
                 ("pools", "qwin", "rec", "cross_kv", "block_tables",
                  "seq_lens", "qslot") if k in state}
        ctx = (active, positions)
        a_i, r_i, l_i = 0, 0, 0
        for p_, (kind, ffn) in zip(params.get("head", []), plan["head"]):
            x, carry = layer_apply(p_, x, carry, kind, ffn, a_i, r_i, l_i, ctx)
            a_i += int(kind == "attn"); r_i += int(kind != "attn"); l_i += 1

        if plan["n_units"]:
            a0, r0, l0 = a_i, r_i, l_i
            au, ru = lay["a_unit"], lay["r_unit"]
            nu = plan["n_units"]

            def body(c2, xs):
                x, carry = c2
                unit_p, uidx = xs
                aa, rr, ll = a0 + uidx * au, r0 + uidx * ru, l0 + uidx * len(plan["unit"])
                for j, (kind, ffn) in enumerate(plan["unit"]):
                    na = sum(1 for kk, _ in plan["unit"][:j] if kk == "attn")
                    x, carry = layer_apply(unit_p[str(j)], x, carry, kind, ffn,
                                           aa + na, rr + (j - na), ll + j, ctx)
                return (x, carry), None

            (x, carry), _ = jax.lax.scan(
                body, (x, carry), (params["main"], jnp.arange(nu)))
            a_i += nu * au; r_i += nu * ru; l_i += nu * len(plan["unit"])
        for p_, (kind, ffn) in zip(params.get("tail", []), plan["tail"]):
            x, carry = layer_apply(p_, x, carry, kind, ffn, a_i, r_i, l_i, ctx)
            a_i += int(kind == "attn"); r_i += int(kind != "attn"); l_i += 1

        x = apply_norm(cfg, params["final_norm"], x)
        logits = (x @ lm.unembed_matrix(cfg, params).astype(x.dtype)
                  ).astype(jnp.float32)
        inc = active.astype(jnp.int32)
        new_state = dict(state)
        new_state.update({k: carry[k] for k in carry})
        new_state["seq_lens"] = jnp.where(
            ring > 0, jnp.minimum(state["seq_lens"] + inc, ring),
            state["seq_lens"] + inc) if ring else state["seq_lens"] + inc
        new_state["positions"] = state["positions"] + inc
        return logits, new_state

    return step


def build_fused_decode_step(cfg: ArchConfig, spec: ServeSpec, n_steps: int):
    """``n_steps`` decode+sample iterations in one dispatch (docs/PERF.md).

    fused(params, state, idx0, step_caps, seeds, temps, top_k, top_p,
          eos_ids) -> (tokens (n_steps, B), logprobs (n_steps, B), new_state)

    The host round-trip per generated token disappears: the sampler runs on
    the logits inside the same program (no ``(B, V)`` materialisation), and
    ``tokens_next`` / ``active_mask`` / ``sample_counters`` are carried as
    device state, so consecutive dispatches chain without the host reading
    the tokens in between.

    Per-row gating inside the scan:
      * ``step_caps`` (B,) int32 — row i decodes only while the global step
        index (``idx0 + j``) is ``< step_caps[i]``; rows whose host-free
        budget (block capacity, remaining tokens, stop-sequence matching)
        is exhausted sit out the rest of the horizon with zero cost (the
        batch is dense either way) and resume next engine step.
      * eos: a row that samples one of its ``eos_ids`` (padded with -1,
        which never matches) clears its own ``active_mask`` bit for the
        remaining iterations — tokens after eos are frozen, never written
        to the KV cache, and ignored by the host's replay.

    Sampling matches ``sampling.sample_batch`` bit-for-bit: per-row
    (seed, n_generated)-keyed PRNG, temperature/top-k/top-p, logprobs from
    the unfiltered distribution.
    """
    from repro.core.sampling import sample_batch

    core = build_decode_step(cfg, spec)

    def fused(params, state, idx0, step_caps, seeds, temps, top_k, top_p,
              eos_ids):
        def body(st, j):
            gate = st["active_mask"] & (idx0 + j < step_caps)
            logits, st2 = core(params, st, st["tokens_next"], gate)
            tok, lp = sample_batch(logits, seeds, st["sample_counters"],
                                   temps, top_k, top_p)
            tok = jnp.where(gate, tok, st["tokens_next"])
            eos_hit = gate & jnp.any(tok[:, None] == eos_ids, axis=-1)
            st2["tokens_next"] = tok
            st2["sample_counters"] = st["sample_counters"] \
                + gate.astype(jnp.int32)
            st2["active_mask"] = st["active_mask"] & ~eos_hit
            return st2, (tok, lp)

        new_state, (toks, lps) = jax.lax.scan(
            body, state, jnp.arange(n_steps))
        return toks, lps, new_state

    return fused


# ----------------------------------------------------------------------
# host swap tier (docs/SCHEDULER.md): batched device<->host block copies

def _swap_backend(spec: ServeSpec) -> str:
    # "chunked" is a decode-attention-only alias; block copies dispatch
    # through the regular kernel backends
    return "auto" if spec.attn_backend == "chunked" else spec.attn_backend


def build_swap_out_step(cfg: ArchConfig, spec: ServeSpec):
    """``swap_out(pools, block_ids) -> gathered`` — gather whole KV blocks
    (every layer, every pool leaf) for a swap-out.

    ``block_ids`` is padded to a fixed width with -1 so one compiled
    executable serves every victim size; padding rows return garbage the
    engine slices off before parking the copy in the CPU swap pool.
    Dispatches through ``repro.kernels.ops`` (``resolve_backend``).
    """
    from repro.kernels import ops as kops

    backend = _swap_backend(spec)

    def swap_out(pools, block_ids):
        return {k: kops.gather_kv_blocks(v, block_ids, backend=backend)
                for k, v in pools.items()}

    return swap_out


def build_swap_in_step(cfg: ArchConfig, spec: ServeSpec):
    """``swap_in(pools, block_ids, values) -> pools`` — scatter previously
    swapped-out blocks back into the device pools (swap-in restores the
    victim's KV bit-for-bit; -1 ids dropped). The engine jits this with
    the pools donated, so restoration happens in place."""
    from repro.kernels import ops as kops

    backend = _swap_backend(spec)

    def swap_in(pools, block_ids, values):
        return {k: kops.scatter_kv_blocks(pools[k], block_ids, values[k],
                                          backend=backend)
                for k in pools}

    return swap_in


# ----------------------------------------------------------------------
# prefill

def build_prefill_step(cfg: ArchConfig, spec: ServeSpec):
    """prefill_step(params, state, tokens, slot_ids, lengths, start_pos,
    [frame_embeds], [prefix_embeds], [rope_start]) -> (last_logits,
    new_state).

    tokens: (P, S) padded prompts (suffix after any shared prefix);
    slot_ids: (P,) destination slots (-1 = padding row); lengths: (P,) valid
    suffix length; start_pos: (P,) KV entries already cached (prefix-cache
    hits) — the cache-write index of each row's first token; rope_start:
    (P,) the *rotary position* of that token, defaulting to start_pos. The
    two differ only after a compressed-prefix adoption (docs/CACHING.md),
    where the cached payload condensed more tokens than the entries it
    occupies. The caller must have installed block tables / seq_lens for
    these slots BEFORE calling (seq_lens[slot] = start_pos + length).
    """
    lay = stage_layout(cfg)
    plan = lay["plan"]
    b = spec.block_size
    ring = spec.ring_blocks(cfg) * b if cfg.local_window else 0
    w_obs = spec.window

    def gather_slot(arr, slot_ids):
        return arr[jnp.maximum(slot_ids, 0)]

    def layer_apply(p, x, carry, kind, ffn_kind, a_idx, r_idx, l_idx, ctx):
        slot_ids, lengths, start_pos, positions, valid, memory = ctx
        P, S, _ = x.shape
        h = apply_norm(cfg, p["ln1"], x)
        if kind == "attn":
            mix, carry = _prefill_attn(p["attn"], h, carry, a_idx, ctx)
        else:
            mix, carry = _prefill_rec(p[kind], h, carry, r_idx, kind, ctx)
        x = x + mix
        if "cross" in p:
            xm = apply_norm(cfg, p["ln_x"], x)
            mem_o = ML.cross_attn_forward(cfg, p["cross"], xm, memory)
            x = x + mem_o
            carry = _store_cross(p["cross"], memory, carry, l_idx, slot_ids)
        h2 = apply_norm(cfg, p["ln2"], x)
        if ffn_kind == "moe":
            x = x + ML.moe_forward(cfg, p["moe"], h2,
                                   valid=valid & (slot_ids >= 0)[:, None])
        else:
            x = x + ML.ffn_forward(cfg, p["ffn"], h2)
        return x, carry

    def _store_cross(p, memory, carry, l_idx, slot_ids):
        hkv, dh = cfg.num_kv_heads, cfg.head_dim
        P, Sm, _ = memory.shape
        k = (memory @ p["wk"].astype(memory.dtype)).reshape(P, Sm, hkv, dh)
        v = (memory @ p["wv"].astype(memory.dtype)).reshape(P, Sm, hkv, dh)
        ck = _dyn(carry["cross_kv"]["k"], l_idx)
        cv = _dyn(carry["cross_kv"]["v"], l_idx)
        sid = jnp.where(slot_ids >= 0, slot_ids, ck.shape[0])
        ck = ck.at[sid].set(k.astype(ck.dtype), mode="drop")
        cv = cv.at[sid].set(v.astype(cv.dtype), mode="drop")
        cross = {"k": _dyn_set(carry["cross_kv"]["k"], ck, l_idx),
                 "v": _dyn_set(carry["cross_kv"]["v"], cv, l_idx)}
        return dict(carry, cross_kv=cross)

    def _prefill_attn(p, h, carry, a_idx, ctx):
        slot_ids, lengths, start_pos, positions, valid, _ = ctx
        P, S, _ = h.shape
        bt = gather_slot(carry["block_tables"], slot_ids)
        pools = carry["pools"]
        if cfg.attn_type == "mla":
            r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
            dh, dv = cfg.head_dim, cfg.v_head_dim
            q_nope, q_rope = ML.mla_queries(cfg, p, h, positions)
            c, k_rope = ML.mla_latent(cfg, p, h, positions)
            entry = jnp.concatenate([c, k_rope], -1)        # (P, S, r+dr)
            kv_l = _dyn(pools["kv"], a_idx)
            wpos = jnp.where(valid & (slot_ids >= 0)[:, None],
                             start_pos[:, None] + jnp.arange(S)[None], -1)
            kv_l = _scatter_prefill_pos(kv_l, bt, wpos, entry)
            pools = dict(pools, kv=_dyn_set(pools["kv"], kv_l, a_idx))
            # attention: expanded form over own chunk + paged for prefix
            w_uk = p["w_uk"].reshape(r, cfg.num_heads, dh)
            q_abs = jnp.einsum("bshd,rhd->bshr", q_nope.astype(jnp.float32),
                               w_uk.astype(jnp.float32)).astype(h.dtype)
            q_full = jnp.concatenate([q_abs, q_rope], -1)   # (P,S,hq,r+dr)
            scale = 1.0 / np.sqrt(dh + dr)
            o_lat = _paged_prefill_mla(q_full, kv_l, bt, start_pos,
                                       start_pos + lengths, r, scale)
            w_uv = p["w_uv"].reshape(r, cfg.num_heads, dv)
            o = jnp.einsum("bshr,rhd->bshd", o_lat.astype(jnp.float32),
                           w_uv.astype(jnp.float32))
            o = o.reshape(P, S, cfg.num_heads * dv).astype(h.dtype)
            q_entry = q_full
        else:
            q, k, v = ML.attn_qkv(cfg, p, h)
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
            if spec.kv_replication > 1:
                k = jnp.repeat(k, spec.kv_replication, axis=2)
                v = jnp.repeat(v, spec.kv_replication, axis=2)
            if ring:
                wpos = positions % ring
                keep = positions >= (start_pos + lengths - ring)[:, None]
                wpos = jnp.where(valid & keep & (slot_ids >= 0)[:, None],
                                 wpos, -1)
            else:
                wpos = jnp.where(valid & (slot_ids >= 0)[:, None],
                                 start_pos[:, None] + jnp.arange(S)[None], -1)
            k_l = _scatter_prefill_pos(_dyn(pools["k"], a_idx), bt, wpos, k)
            v_l = _scatter_prefill_pos(_dyn(pools["v"], a_idx), bt, wpos, v)
            pools = dict(pools, k=_dyn_set(pools["k"], k_l, a_idx),
                         v=_dyn_set(pools["v"], v_l, a_idx))
            if ring:
                o = chunked_causal_attention(q, k, v,
                                             local_window=cfg.local_window)
            else:
                o = paged.paged_prefill_attention(
                    q, k_l, v_l, bt, start_pos, start_pos + lengths)
            o = o.reshape(P, S, cfg.num_heads * cfg.head_dim)
            q_entry = q
        # seed observation window with the last w_obs valid queries
        qwin = carry["qwin"]
        qw_l = _dyn(qwin, a_idx)
        Mq = qw_l.shape[0]
        qslot = gather_slot(carry["qslot"], slot_ids)
        # cache position of each query = start_pos + s
        cache_pos = start_pos[:, None] + jnp.arange(S)[None]
        end = (start_pos + lengths)[:, None]
        in_win = valid & (cache_pos >= end - w_obs)
        ring_idx = cache_pos % w_obs
        qs = jnp.where((qslot >= 0) & (slot_ids >= 0), qslot, Mq)
        flat_idx = jnp.where(in_win, qs[:, None] * w_obs + ring_idx, Mq * w_obs)
        qw_flat = qw_l.reshape(Mq * w_obs, *qw_l.shape[2:])
        qw_flat = qw_flat.at[flat_idx.reshape(-1)].set(
            q_entry.reshape((-1,) + q_entry.shape[2:]).astype(qw_flat.dtype),
            mode="drop")
        carry = dict(carry, pools=pools,
                     qwin=_dyn_set(qwin, qw_flat.reshape(qw_l.shape), a_idx))
        return o @ p["wo"].astype(h.dtype), carry

    def _prefill_rec(p, h, carry, r_idx, kind, ctx):
        slot_ids, lengths, start_pos, positions, valid, _ = ctx
        P, S, _ = h.shape
        rec = carry["rec"]
        if kind == "rglru":
            xw = causal_conv_masked(p, h @ p["wx"].astype(h.dtype), valid)
            a, bb = ML._rglru_gates(cfg, p, xw)
            a = jnp.where(valid[..., None], a, 1.0)
            bb = jnp.where(valid[..., None], bb, 0.0)
            def comb(l, r_):
                al, bl = l
                ar, br = r_
                return al * ar, bl * ar + br
            _, hs = jax.lax.associative_scan(comb, (a, bb), axis=1)
            gate = jax.nn.gelu((h @ p["wy_gate"].astype(h.dtype))
                               .astype(jnp.float32))
            out = (hs * gate).astype(h.dtype) @ p["wo"].astype(h.dtype)
            # final state at last valid position
            last = jnp.maximum(lengths - 1, 0)
            h_last = jnp.take_along_axis(hs, last[:, None, None], 1)[:, 0]
            # conv state: last cw-1 inputs (xw pre-conv? conv uses raw xw ins)
            xw_raw = h @ p["wx"].astype(h.dtype)
            xw_raw = jnp.where(valid[..., None], xw_raw, 0)
            cw = cfg.conv1d_width
            idx = last[:, None] - jnp.arange(cw - 2, -1, -1)[None]
            conv_st = jnp.take_along_axis(
                xw_raw, jnp.maximum(idx, 0)[..., None], 1)
            conv_st = jnp.where((idx >= 0)[..., None], conv_st, 0)
            sid = jnp.where(slot_ids >= 0, slot_ids, rec["h"].shape[1])
            h_all = _dyn(rec["h"], r_idx).at[sid].set(h_last, mode="drop")
            c_all = _dyn(rec["conv"], r_idx).at[sid].set(
                conv_st.astype(rec["conv"].dtype), mode="drop")
            rec = dict(rec, h=_dyn_set(rec["h"], h_all, r_idx),
                       conv=_dyn_set(rec["conv"], c_all, r_idx))
            return out, dict(carry, rec=rec)
        else:  # rwkv — chunked matmul form (state round-trips /chunk;
            #        EXPERIMENTS.md §Perf iteration A)
            chunk = 64 if S % 64 == 0 else (
                32 if S % 32 == 0 else (S if S < 32 else 1))
            if chunk > 1:
                out, S_fin = ML.rwkv_forward(cfg, p, h, chunk=chunk,
                                             valid=valid, return_state=True)
            else:
                out, S_fin = _rwkv_prefill_naive(cfg, p, h, valid)
            last = jnp.maximum(lengths - 1, 0)
            shift = jnp.take_along_axis(h, last[:, None, None], 1)[:, 0]
            sid = jnp.where(slot_ids >= 0, slot_ids, rec["S"].shape[1])
            S_all = _dyn(rec["S"], r_idx).at[sid].set(S_fin, mode="drop")
            sh_all = _dyn(rec["shift"], r_idx).at[sid].set(
                shift.astype(rec["shift"].dtype), mode="drop")
            rec = dict(rec, S=_dyn_set(rec["S"], S_all, r_idx),
                       shift=_dyn_set(rec["shift"], sh_all, r_idx))
            return out, dict(carry, rec=rec)

    def causal_conv_masked(p, xw, valid):
        xw = jnp.where(valid[..., None], xw, 0)
        return ML.causal_conv1d(p, xw)

    def step(params, state, tokens, slot_ids, lengths, start_pos,
             frame_embeds=None, prefix_embeds=None, rope_start=None):
        dt = jnp.dtype(spec.dtype)
        P, S = tokens.shape
        x = params["embed"].astype(dt)[tokens]
        if prefix_embeds is not None:
            # VLM patch prefix occupies cache positions [0, n_patch); only
            # fresh (start_pos == 0) rows prepend it.
            npfx = prefix_embeds.shape[1]
            x = jnp.concatenate([prefix_embeds.astype(dt), x], axis=1)
            S = S + npfx
            lengths = lengths + npfx
        # rope_start decouples the absolute token position from the
        # cache-write index (start_pos): after compressed-prefix adoption
        # the KV cache holds fewer entries than the prompt has tokens
        # (Request.pos_gap), so rotary positions run ahead of cache slots.
        # Default (None) keeps the historical coupled behavior.
        if rope_start is None:
            rope_start = start_pos
        positions = rope_start[:, None] + jnp.arange(S)[None]
        valid = jnp.arange(S)[None] < lengths[:, None]
        memory = None
        if cfg.is_enc_dec:
            memory = lm.encode(cfg, params, frame_embeds)
        carry = {k: state[k] for k in
                 ("pools", "qwin", "rec", "cross_kv", "block_tables",
                  "seq_lens", "qslot") if k in state}
        ctx = (slot_ids, lengths, start_pos, positions, valid, memory)
        a_i, r_i, l_i = 0, 0, 0
        for p_, (kind, ffn) in zip(params.get("head", []), plan["head"]):
            x, carry = layer_apply(p_, x, carry, kind, ffn, a_i, r_i, l_i, ctx)
            a_i += int(kind == "attn"); r_i += int(kind != "attn"); l_i += 1
        if plan["n_units"]:
            a0, r0, l0 = a_i, r_i, l_i
            au, ru = lay["a_unit"], lay["r_unit"]
            nu = plan["n_units"]

            def body(c2, xs):
                x, carry = c2
                unit_p, uidx = xs
                aa, rr, ll = a0 + uidx * au, r0 + uidx * ru, \
                    l0 + uidx * len(plan["unit"])
                for j, (kind, ffn) in enumerate(plan["unit"]):
                    na = sum(1 for kk, _ in plan["unit"][:j] if kk == "attn")
                    x, carry = layer_apply(unit_p[str(j)], x, carry, kind,
                                           ffn, aa + na, rr + (j - na),
                                           ll + j, ctx)
                return (x, carry), None

            (x, carry), _ = jax.lax.scan(
                body, (x, carry), (params["main"], jnp.arange(nu)))
            a_i += nu * au; r_i += nu * ru; l_i += nu * len(plan["unit"])
        for p_, (kind, ffn) in zip(params.get("tail", []), plan["tail"]):
            x, carry = layer_apply(p_, x, carry, kind, ffn, a_i, r_i, l_i, ctx)
            a_i += int(kind == "attn"); r_i += int(kind != "attn"); l_i += 1
        x = apply_norm(cfg, params["final_norm"], x)
        # last valid token's logits per row
        last = jnp.maximum(lengths - 1, 0)
        x_last = jnp.take_along_axis(x, last[:, None, None], 1)[:, 0]
        logits = (x_last @ lm.unembed_matrix(cfg, params).astype(x.dtype)
                  ).astype(jnp.float32)
        new_state = dict(state)
        new_state.update(carry)
        return logits, new_state

    return step


def _rwkv_prefill_naive(cfg, p, h, valid):
    """O(S) token scan fallback for chunk-incompatible lengths."""
    P, S, _ = h.shape
    x_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, logw = ML._rwkv_proj(cfg, p, h, x_prev)
    hh, K = cfg.num_heads, cfg.head_dim
    rh = r.reshape(P, S, hh, K).astype(jnp.float32)
    kh = k.reshape(P, S, hh, K).astype(jnp.float32)
    vh = v.reshape(P, S, hh, K).astype(jnp.float32)
    logw = jnp.where(valid[..., None], logw, 0.0)
    kh = jnp.where(valid[..., None, None], kh, 0.0)
    wh = jnp.exp(logw.reshape(P, S, hh, K))
    u = p["u"]

    def stp(Sst, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, Sst + u[None, :, :, None] * kv)
        return wt[..., None] * Sst + kv, yt

    S0 = jnp.zeros((P, hh, K, K), jnp.float32)
    S_fin, y = jax.lax.scan(
        stp, S0, (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
                  vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3)))
    y = y.transpose(1, 0, 2, 3)
    return ML._rwkv_out(cfg, p, y, g, P, S), S_fin


def _scatter_prefill_pos(pool, bt, wpos, values):
    """Scatter (P, S, ...) values at explicit cache positions wpos (P, S);
    wpos < 0 dropped. bt: (P, max_blocks)."""
    b = pool.shape[1]
    blk = jnp.take_along_axis(bt, jnp.maximum(wpos, 0) // b, 1)
    idx = blk * b + jnp.maximum(wpos, 0) % b
    idx = jnp.where(wpos >= 0, idx, pool.shape[0] * b)
    flat = pool.reshape((-1,) + pool.shape[2:])
    flat = flat.at[idx.reshape(-1)].set(
        values.reshape((-1,) + values.shape[2:]).astype(pool.dtype),
        mode="drop")
    return flat.reshape(pool.shape)


def _paged_prefill_mla(q_full, kv_pool, bt, q_start, kv_lens, r, scale):
    """MLA prefill attention in absorbed space against the pool. Contracts
    the FULL (r+dr)-wide entries so the sharded latent dim is never sliced
    (§Perf iteration D — see paged.paged_decode_attention_mla)."""
    P, S, hq, _ = q_full.shape
    entries = paged.gather_entries(kv_pool, bt)      # (P, T, r+dr)
    T = entries.shape[1]
    from repro.models import moe_ctx
    qspec = moe_ctx.mla_q_spec.get()
    if qspec is not None:
        q_full = jax.lax.with_sharding_constraint(q_full, qspec)
    s = jnp.einsum("bshe,bte->bhst", q_full.astype(jnp.float32),
                   entries.astype(jnp.float32)) * scale
    qpos = q_start[:, None] + jnp.arange(S)[None]
    kpos = jnp.arange(T)[None]
    mask = (kpos[:, None] <= qpos[..., None]) & \
        (kpos[:, None] < kv_lens[:, None, None])
    s = jnp.where(mask[:, None], s, paged.NEG_INF)
    pr = jax.nn.softmax(s, -1)
    # entries past kv_lens are pool garbage gathered through clamped -1
    # table slots; pr is 0 there but 0·NaN = NaN — zero them first
    kv_valid = kpos < kv_lens[:, None]                  # (P, T)
    ent_o = jnp.where(kv_valid[..., None], entries.astype(jnp.float32), 0.0)
    o = jnp.einsum("bhst,bte->bshe", pr, ent_o)
    return o[..., :r].astype(q_full.dtype)
