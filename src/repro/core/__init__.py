"""The paper's primary contribution: Compressed PagedAttention + the Zipage
serving engine (scheduler, paged pools, compression, prefix cache).

Public API:
    from repro.core import ZipageEngine, EngineOptions, CompressOptions
"""
from repro.core.compression import CompressOptions, build_compress_fn  # noqa
from repro.core.engine import EngineOptions, ZipageEngine  # noqa
from repro.core.memory_planner import MemoryPlan, plan_memory  # noqa
from repro.core.request import Request, State  # noqa
