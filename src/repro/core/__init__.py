"""The paper's primary contribution: Compressed PagedAttention + the Zipage
serving engine (scheduler, paged pools, compression, prefix cache).

This is the INTERNAL layer. The stable public surface is the facade:

    from repro.api import Zipage, SamplingParams      # see docs/API.md

``ZipageEngine``/``EngineOptions`` remain importable for tests and
embedders that need scheduler internals.
"""
from repro.core.compression import CompressOptions, build_compress_fn  # noqa
from repro.core.engine import EngineOptions, ZipageEngine  # noqa
from repro.core.memory_planner import MemoryPlan, plan_memory  # noqa
from repro.core.request import FinishReason, Request, State  # noqa
from repro.core.sampling import SamplingParams  # noqa
from repro.core.scheduler import (POLICIES, CompressionLaunch,  # noqa
                                  PrefillChunk, Scheduler, SchedulerOutputs,
                                  SchedulerParams, SchedulingPolicy)
