"""Memory planning for Compressed PagedAttention (paper Eq. 1 / Eq. 2).

Closed-form solution of the linear program: the maximum concurrency is
``M = floor(m_avail / (m_kv·N_max + m_q))`` with
``N_total = floor((m_avail − M·m_q) / m_kv)`` (global score inflates m_kv by
``1 + 1/(2d)`` per Eq. 2).
"""
from __future__ import annotations

import dataclasses



@dataclasses.dataclass(frozen=True)
class MemoryPlan:
    M: int                # maximum concurrency (query slots)
    N_total: int          # KV pool blocks
    m_kv_block: int       # bytes per block (all layers)
    m_q_req: int          # bytes of query cache per request
    bytes_kv_pool: int
    bytes_q_pool: int


def bytes_per_kv_block(cfg, block_size, *, dtype_bytes=2, with_global=True):
    """KV bytes of one block across all attention layers (+ F if global)."""
    L = cfg.num_attn_layers
    per_tok = cfg.kv_entry_dim * dtype_bytes
    if with_global:
        # F: one fp32... paper sizes F at 1/(2d) of K+V => one score per
        # (token, kv head) in the KV dtype; we match that accounting.
        if cfg.attn_type == "mla":
            per_tok += 1 * dtype_bytes
        else:
            per_tok += cfg.num_kv_heads * dtype_bytes
    return L * block_size * per_tok


def bytes_q_per_request(cfg, window, *, dtype_bytes=2):
    L = cfg.num_attn_layers
    if cfg.attn_type == "mla":
        dq = cfg.kv_lora_rank + cfg.qk_rope_head_dim
    else:
        dq = cfg.head_dim
    return L * window * cfg.num_heads * dq * dtype_bytes


def plan_memory(cfg, m_available: int, n_max: int, *, block_size,
                window=16, with_global=True, dtype_bytes=2) -> MemoryPlan:
    m_kv = bytes_per_kv_block(cfg, block_size, dtype_bytes=dtype_bytes,
                              with_global=with_global)
    m_q = bytes_q_per_request(cfg, window, dtype_bytes=dtype_bytes)
    M = int(m_available // (m_kv * n_max + m_q))
    if M <= 0:
        raise ValueError("not enough memory for a single request at this "
                         f"N_max: avail={m_available}, need={m_kv * n_max + m_q}")
    N_total = int((m_available - M * m_q) // m_kv)
    # constraint M <= N_total / N_max holds by construction; assert anyway
    assert M <= N_total / n_max + 1e-9
    return MemoryPlan(M=M, N_total=N_total, m_kv_block=m_kv, m_q_req=m_q,
                      bytes_kv_pool=N_total * m_kv, bytes_q_pool=M * m_q)
