"""Per-request sampling parameters (facade re-export).

``SamplingParams`` lives next to the device sampler in
``repro.core.sampling`` (the engine consumes it directly); the public
import path is this module / ``repro.api``.
"""
from repro.core.sampling import SamplingParams  # noqa: F401

__all__ = ["SamplingParams"]
