"""Per-request sampling parameters (facade re-export).

``SamplingParams`` lives next to the device sampler in
``repro.core.sampling`` (the engine consumes it directly); the public
import path is this module / ``repro.api``. Besides sampling and
termination, it carries the request's ``compression_policy``
(``"default" | "protect" | "aggressive"`` — docs/EVAL.md), the
per-request intent the scheduler's quality-aware compression planner
consumes.
"""
from repro.core.sampling import SamplingParams  # noqa: F401

__all__ = ["SamplingParams"]
