"""Public serving API for the Zipage engine.

Stable surface — examples, benchmarks and launchers import from here only:

    from repro.api import Zipage, SamplingParams

    z = Zipage.from_config("tiny-lm", block_size=8, n_total_blocks=64)
    outs = z.generate([[1, 2, 3]], SamplingParams(max_new_tokens=32))

See docs/API.md for the full tour (streaming, abort, config split).
"""
from repro.api.config import (KERNEL_BACKENDS, CacheConfig,  # noqa: F401
                              ModelRunnerConfig, SchedulerConfig,
                              build_engine_options)
from repro.api.outputs import (CompletionChunk, CompressionMetrics,  # noqa: F401
                               FinishReason, RequestMetrics, RequestOutput,
                               UsageInfo)
from repro.api.params import SamplingParams  # noqa: F401
from repro.api.engine import Zipage  # noqa: F401
from repro.api.aio import (AsyncEngineLoop, EngineDraining,  # noqa: F401
                           EngineSaturated)

__all__ = [
    "Zipage", "AsyncEngineLoop", "EngineSaturated", "EngineDraining",
    "SamplingParams", "RequestOutput", "CompletionChunk",
    "RequestMetrics", "CompressionMetrics", "FinishReason", "UsageInfo",
    "CacheConfig", "SchedulerConfig", "ModelRunnerConfig",
    "build_engine_options", "KERNEL_BACKENDS",
]
