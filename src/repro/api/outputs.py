"""Result objects returned by the ``Zipage`` facade.

Callers never see raw ``repro.core.request.Request`` internals: the facade
translates them into immutable-ish snapshots — ``RequestOutput`` for the
request-level view (batch ``generate()`` and per-step streaming state) and
``CompletionChunk`` for the incremental delta a single ``step()`` produced.

Both carry the fields an OpenAI-protocol layer needs verbatim
(docs/SERVING.md): ``finish_reason`` in ``{"stop", "length", "abort"}``
and a ``UsageInfo`` record (prompt/completion/total token counts), so
``repro.serve`` maps responses 1:1 without recomputing anything.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import List, Optional

from repro.core.request import FinishReason, Request  # noqa: F401 (re-export)


@dataclasses.dataclass(frozen=True)
class UsageInfo:
    """OpenAI-shaped token accounting for one request."""
    prompt_tokens: int
    completion_tokens: int
    total_tokens: int

    @classmethod
    def of(cls, n_prompt: int, n_completion: int) -> "UsageInfo":
        return cls(prompt_tokens=n_prompt, completion_tokens=n_completion,
                   total_tokens=n_prompt + n_completion)


@dataclasses.dataclass(frozen=True)
class CompressionMetrics:
    """Per-request Compressed-PagedAttention accounting (paper §4)."""
    n_compressions: int          # compression events this request underwent
    blocks_freed: int            # pool blocks physically freed by them
    kv_tokens_held: int          # live KV-cache entries at snapshot time
    kv_budget_tokens: Optional[int]  # (n_max-1)*block_size, None = full KV


@dataclasses.dataclass(frozen=True)
class RequestMetrics:
    arrival: float
    t_first_token: Optional[float]
    t_finish: Optional[float]
    preempt_count: int
    n_cached_prompt_tokens: int  # prefix-cache hit tokens at admission
    compression: CompressionMetrics


@dataclasses.dataclass(frozen=True)
class CompletionChunk:
    """Tokens a request gained in one engine step (streaming delta).

    ``finish_reason`` is set (``"stop" | "length" | "abort"``) on the
    chunk that finishes the request — the streaming protocol's terminal
    marker — and ``usage`` rides along on that same final chunk, so an
    SSE layer emits OpenAI's last-chunk usage record without a second
    lookup. Both are None on intermediate chunks.
    """
    request_id: int
    index: int                   # offset of token_ids[0] in the full output
    token_ids: List[int]
    logprobs: Optional[List[float]]
    finish_reason: Optional[str] = None
    usage: Optional[UsageInfo] = None


@dataclasses.dataclass(frozen=True)
class RequestOutput:
    """Snapshot of one request's progress, vLLM-style.

    ``token_ids`` is the full output so far (stop sequences already
    truncated); ``chunk`` is the delta since the previous emission, when
    the output came from ``Zipage.step()``. ``finish_reason`` is one of
    ``"stop" | "length" | "abort"`` once ``finished``. ``usage`` is the
    OpenAI-shaped token accounting at snapshot time.
    """
    request_id: int
    prompt_token_ids: List[int]
    token_ids: List[int]
    finished: bool
    finish_reason: Optional[str]
    logprobs: Optional[List[float]]
    metrics: RequestMetrics
    usage: Optional[UsageInfo] = None
    chunk: Optional[CompletionChunk] = None

    @property
    def n_tokens(self) -> int:
        """Deprecated: use ``usage.completion_tokens`` (one-release shim)."""
        warnings.warn(
            "RequestOutput.n_tokens is deprecated; read "
            "usage.completion_tokens (the OpenAI-shaped UsageInfo record) "
            "instead", DeprecationWarning, stacklevel=2)
        return len(self.token_ids)


def snapshot_request(r: Request, kv_budget_tokens: Optional[int],
                     chunk: Optional[CompletionChunk] = None
                     ) -> RequestOutput:
    """Build a RequestOutput view of an engine-internal Request."""
    return RequestOutput(
        request_id=r.rid,
        prompt_token_ids=list(r.prompt),
        token_ids=list(r.output),
        finished=r.finish_reason is not None,
        finish_reason=r.finish_reason,
        logprobs=list(r.logprobs) if r.sampling.logprobs else None,
        metrics=RequestMetrics(
            arrival=r.arrival,
            t_first_token=r.t_first_token,
            t_finish=r.t_finish,
            preempt_count=r.preempt_count,
            n_cached_prompt_tokens=r.n_cached,
            compression=CompressionMetrics(
                n_compressions=r.n_compressions,
                blocks_freed=r.comp_blocks_freed,
                kv_tokens_held=r.seq_len,
                kv_budget_tokens=kv_budget_tokens)),
        usage=UsageInfo.of(len(r.prompt), len(r.output)),
        chunk=chunk)
