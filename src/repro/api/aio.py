"""Async-native surface over the ``Zipage`` facade.

``AsyncEngineLoop`` owns a background task that drives the engine's
continuous-batching ``step()`` on a single-thread executor while the
event loop stays free for intake and streaming.  All engine mutation is
serialized through that one task: ``add_request`` / ``abort`` enqueue
*ops* that the loop applies between steps, so no two threads ever touch
scheduler state concurrently.  Per-step results fan out to per-request
``asyncio.Queue`` streams via the facade's step listener, marshaled onto
the event loop with ``call_soon_threadsafe``.

This is the layer both the public async API (``Zipage.generate_async`` /
``Zipage.stream``) and the HTTP tier (``repro.serve``) sit on — the
server is a thin protocol adapter, not a privileged engine client
(docs/SERVING.md).

Backpressure is bounded and observable: when the waiting backlog reaches
``max_queued_requests``, ``add_request`` raises :class:`EngineSaturated`
carrying a load-aware ``retry_after`` estimate (EWMA of step latency via
the engine's ``step_hooks``).  ``drain()`` implements graceful
shutdown: intake closes (:class:`EngineDraining`), running requests
finish, streams flush, and the loop task exits.
"""
from __future__ import annotations

import asyncio
import concurrent.futures
from typing import AsyncIterator, Dict, List, Optional, Sequence, Tuple

from repro.api.outputs import RequestOutput
from repro.core.sampling import SamplingParams


class EngineSaturated(RuntimeError):
    """Waiting-queue backpressure: the engine's backlog is at capacity.

    ``retry_after`` is a load-aware estimate (seconds) of when capacity
    should free up; the HTTP tier maps this to ``429`` + ``Retry-After``.
    """

    def __init__(self, backlog: int, limit: int, retry_after: float):
        super().__init__(
            f"engine saturated: {backlog} queued requests (limit {limit}); "
            f"retry in ~{retry_after:.0f}s")
        self.backlog = backlog
        self.limit = limit
        self.retry_after = retry_after


class EngineDraining(RuntimeError):
    """Intake is closed: the loop is draining toward shutdown (HTTP 503)."""


_DONE = object()      # stream sentinel: request finished, queue closes


class AsyncEngineLoop:
    """Background continuous-batching loop over one ``Zipage`` facade.

    One instance per event loop; create inside a running loop (it binds
    ``asyncio.get_running_loop()`` at ``start()``).
    """

    def __init__(self, zipage, *, max_queued_requests: int = 256):
        self.zipage = zipage
        self.max_queued_requests = max_queued_requests
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._task: Optional[asyncio.Task] = None
        # step() blocks on device work; one worker keeps every engine
        # mutation on a single thread while the event loop serves I/O
        self._executor: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self._ops: Optional[asyncio.Queue] = None
        self._streams: Dict[int, asyncio.Queue] = {}
        self._n_intake = 0            # ops enqueued but not yet applied
        self._draining = False
        self._drained: Optional[asyncio.Event] = None
        self._step_ewma: float = 0.05  # seconds; seeded, refined by hooks
        self._failure: Optional[BaseException] = None

    # ------------------------------------------------------------------
    # lifecycle

    async def start(self) -> "AsyncEngineLoop":
        if self._task is not None:
            return self
        self._loop = asyncio.get_running_loop()
        self._ops = asyncio.Queue()
        self._drained = asyncio.Event()
        self._executor = concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="zipage-step")
        self.zipage.add_listener(self._on_step_outputs)
        self.zipage.engine.step_hooks.append(self._on_step_metrics)
        self._task = self._loop.create_task(self._run(), name="zipage-loop")
        return self

    @property
    def started(self) -> bool:
        return self._task is not None

    @property
    def draining(self) -> bool:
        return self._draining

    @property
    def backlog(self) -> int:
        """Requests waiting for a decode slot: intake ops not yet applied
        plus the scheduler's waiting queue (running ones hold capacity
        already and don't count against admission)."""
        return self._n_intake + len(self.zipage.engine.waiting)

    @property
    def retry_after(self) -> float:
        """Seconds until the backlog plausibly has room: one queue drain
        at the EWMA step latency, floored at 1s for header friendliness."""
        return max(1.0, self._step_ewma * max(1, self.backlog))

    async def drain(self) -> None:
        """Graceful shutdown: close intake (new ``add_request`` raises
        :class:`EngineDraining`), let running/waiting requests finish,
        flush their streams, then stop the loop task."""
        self._draining = True
        if self._task is None:
            return
        self._ops.put_nowait(("noop", None, None))   # wake an idle loop
        await self._drained.wait()
        try:
            await self._task
        except BaseException:         # noqa: B036 — kept in self._failure
            pass
        self._teardown()

    async def stop(self) -> None:
        """Fast shutdown: abort everything in flight, then drain."""
        self._draining = True
        if self._task is None:
            return
        for rid in list(self._streams):
            await self._enqueue_op("abort", rid)
        await self.drain()

    def _teardown(self):
        if self._executor is not None:
            self._executor.shutdown(wait=True)
            self._executor = None
        self.zipage.remove_listener(self._on_step_outputs)
        hooks = self.zipage.engine.step_hooks
        if self._on_step_metrics in hooks:
            hooks.remove(self._on_step_metrics)
        self._task = None

    # ------------------------------------------------------------------
    # intake / abort / streams

    async def add_request(self, prompt: Sequence[int],
                          params: Optional[SamplingParams] = None,
                          priority: int = 0) -> int:
        """Admit a request; returns its id once the loop applied the op.

        Raises :class:`EngineSaturated` when the backlog is at
        ``max_queued_requests`` and :class:`EngineDraining` once
        ``drain()`` closed intake.
        """
        if self._draining:
            raise EngineDraining("engine is draining; not accepting requests")
        # backpressure is judged before the loop even spins up, so a
        # saturated engine rejects without scheduling work
        if self.backlog >= self.max_queued_requests:
            raise EngineSaturated(self.backlog, self.max_queued_requests,
                                  self.retry_after)
        if self._task is None:
            await self.start()
        return await self._enqueue_op("add", (list(prompt), params, priority))

    async def abort(self, request_id: int) -> Optional[RequestOutput]:
        """Cancel a request mid-flight (client disconnect). Blocks/slots
        return to the pool; the stream flushes its terminal snapshot
        (``finish_reason="abort"``) and closes."""
        return await self._enqueue_op("abort", request_id)

    def stream_outputs(self, request_id: int) -> AsyncIterator[RequestOutput]:
        """Async-iterate a request's ``RequestOutput`` emissions (each with
        a ``chunk`` delta) until the terminal one (``finished=True``)."""
        q = self._streams.get(request_id)
        if q is None:
            raise KeyError(f"unknown or already-closed stream {request_id}")

        async def _iter():
            while True:
                item = await q.get()
                if item is _DONE:
                    return
                if isinstance(item, BaseException):
                    raise item
                yield item
        return _iter()

    async def generate(self, prompt: Sequence[int],
                       params: Optional[SamplingParams] = None,
                       priority: int = 0) -> RequestOutput:
        """Submit one request and await its final snapshot."""
        rid = await self.add_request(prompt, params, priority)
        final = None
        async for out in self.stream_outputs(rid):
            final = out
        assert final is not None and final.finished
        return final

    # ------------------------------------------------------------------
    # loop internals (everything below runs on the event-loop thread,
    # except the listener/hook bodies marked threadsafe-marshal)

    async def _enqueue_op(self, kind: str, payload):
        fut = self._loop.create_future()
        if kind == "add":
            self._n_intake += 1     # decremented at apply time (loop task)
        self._ops.put_nowait((kind, payload, fut))
        return await fut

    def _apply_op(self, kind: str, payload, fut):
        if kind == "add":
            self._n_intake -= 1
        try:
            if kind == "add":
                prompt, params, priority = payload
                rid = self.zipage.add_request(prompt, params,
                                              priority=priority)
                self._streams[rid] = asyncio.Queue()
                result = rid
            elif kind == "abort":
                result = self.zipage.abort(payload)
                q = self._streams.pop(payload, None)
                if q is not None and result is not None:
                    q.put_nowait(result)
                    q.put_nowait(_DONE)
                elif q is not None:
                    q.put_nowait(_DONE)
            else:                     # "noop": wake-up only
                result = None
        except BaseException as e:    # noqa: B036 — surfaced via future
            if fut is not None and not fut.done():
                fut.set_exception(e)
            return
        if fut is not None and not fut.done():
            fut.set_result(result)

    async def _run(self):
        step = self.zipage.step
        try:
            while True:
                # apply every queued op before the next step so admission
                # order matches arrival order
                while not self._ops.empty():
                    self._apply_op(*self._ops.get_nowait())
                if self.zipage.has_unfinished():
                    await self._loop.run_in_executor(self._executor, step)
                    continue
                if self._draining:
                    break
                self._apply_op(*await self._ops.get())   # idle: park here
        except BaseException as e:    # noqa: B036 — fanned to streams
            self._failure = e
            for q in self._streams.values():
                q.put_nowait(e)
                q.put_nowait(_DONE)
            self._streams.clear()
            raise
        finally:
            self._draining = True
            self._drained.set()

    def _on_step_outputs(self, outs: List[RequestOutput]):
        """Facade step listener — runs on the executor thread; marshal
        the fan-out onto the event loop."""
        self._loop.call_soon_threadsafe(self._fanout, outs)

    def _fanout(self, outs: List[RequestOutput]):
        for out in outs:
            q = self._streams.get(out.request_id)
            if q is None:             # aborted/closed stream: drop
                continue
            q.put_nowait(out)
            if out.finished:
                q.put_nowait(_DONE)
                del self._streams[out.request_id]

    def _on_step_metrics(self, entry: dict):
        """Engine step hook — executor thread; a single float store is
        atomic under the GIL, no marshal needed."""
        self._step_ewma = 0.8 * self._step_ewma + 0.2 * entry["t_total"]
