"""Orthogonal serving configs composed into the internal ``EngineOptions``.

The engine-internal ``EngineOptions`` mixes cache sizing, scheduler policy
and runner shapes in one bag. The public API splits them along ownership
lines (mirroring vLLM's CacheConfig/SchedulerConfig split):

  * ``CacheConfig``       — KV pool: paging, budget, compression, prefix cache
  * ``SchedulerConfig``   — batching policy: slots, query slots, async comp.
  * ``ModelRunnerConfig`` — device step shapes: prefill buckets, dtype

``build_engine_options`` composes the three back into ``EngineOptions`` for
the internal layer; ``route_overrides`` lets call sites pass flat kwargs
(``Zipage.from_config("tiny-lm", block_size=8, max_batch=4)``) that are
routed to the config owning each field.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions
from repro.kernels import ops as _kernel_ops


@dataclasses.dataclass(frozen=True)
class CacheConfig:
    """KV-cache pool layout and the Compressed-PagedAttention budget."""
    block_size: int = 16
    n_total_blocks: int = 256
    n_max: Optional[int] = 4         # block cap; None => full-KV baseline
    window: int = 4                  # observation window w
    prefix_caching: bool = True
    # prefix-cache index structure (docs/CACHING.md): "radix" (default)
    # keeps cached blocks in a radix tree over chain hashes — partial-
    # prefix reuse at block granularity, leaf-first LRU eviction, and
    # compressed-segment caching; "flat" is the legacy exact-map
    # behavior kept for byte-for-byte parity with the frozen engine
    prefix_cache_policy: str = "radix"
    # LRU high-watermark: cap unreferenced-but-cached blocks at this
    # fraction of the pool (excess is evicted leaf-first on release);
    # 1.0 disables the cap — cached blocks are then reclaimed only on
    # allocation pressure
    prefix_cache_watermark: float = 1.0
    # also cache *compressed* prefixes (docs/CACHING.md "Compressed
    # segments"): a prompt-pure compression's condensed payload is kept
    # as a cache segment, so a later request with the same long prompt
    # adopts n_tokens of history for k cache entries. Requires the radix
    # policy and compression enabled; hits are semantically (not
    # bit-wise) equivalent to recompute — see the docs caveat.
    cache_compressed_prefixes: bool = False
    compress: Optional[CompressOptions] = None   # None => window defaults
    max_model_len: int = 512
    # host swap tier: CPU-side block slots backing swap-mode preemption
    # (SchedulerConfig.preemption_mode). 0 disables the tier; preempted
    # requests are then always re-prefilled (recompute mode).
    swap_space_blocks: int = 0


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    """The compression-aware scheduling strategy (paper §4.3/§4.5),
    executed by ``repro.core.scheduler.Scheduler`` — see docs/SCHEDULER.md
    for the full queue lifecycle and what each knob trades off."""
    max_batch: int = 16              # decode slots
    m_qslots: int = 8                # paper's M (query-slot pool)
    scheduling: str = "hybrid"       # hybrid | constrained
    async_compression: bool = True
    # admission/preemption policy (repro.core.scheduler.POLICIES):
    # fcfs | priority (Request.priority desc) | srpt (shortest remaining)
    # | cache_aware (most projected prefix-cache-reusable blocks first,
    # FCFS tie-break; victims are least-reusable first — docs/CACHING.md
    # "Cache-aware admission")
    policy: str = "fcfs"
    # victim-order policy for preemption; None => same as `policy`
    preemption: Optional[str] = None
    # what preemption *does* (docs/SCHEDULER.md "Preemption modes"):
    # "recompute" frees the victim's blocks and re-prefills on
    # re-admission; "swap" parks its KV in the host swap tier
    # (CacheConfig.swap_space_blocks) and restores it block-for-block;
    # "auto" picks per victim by the swap-bytes-vs-re-prefill cost model
    preemption_mode: str = "recompute"
    # auto's exchange rate: host-copy cost of one KV token-slot (one
    # direction), in re-prefill-token equivalents — swap a victim iff
    # 2 * n_blocks * block_size * swap_cost_per_token < tokens to
    # re-prefill. Lower it on fast interconnects to swap more eagerly.
    swap_cost_per_token: float = 0.5
    # shared prefill+decode token budget per step (continuous batching with
    # chunked prefill); None => unbounded (prefill completes in-step)
    token_budget: Optional[int] = None
    # per-request prefill chunk cap per step; None => budget-limited only
    max_prefill_chunk: Optional[int] = None
    # compression-aware admission: fraction of the running batch's
    # projected *post-compression* block growth that must stay free when
    # admitting. 0.0 => the paper's greedy admit-then-preempt behavior.
    admission_margin: float = 0.0
    # quality-aware compression planning (docs/EVAL.md): feed the
    # per-request scoring telemetry back into the planner — candidates
    # compress lowest-redundancy-first, default-policy requests defer
    # compression by `compression_deferral` blocks past n_max while at
    # least `quality_defer_min_free` pool blocks stay free, and requests
    # whose normalized window-attention entropy is
    # >= `quality_entropy_threshold` are shielded from preemption while
    # an unshielded victim exists. False => the planner is bit-identical
    # to the pre-quality scheduler (per-request
    # SamplingParams.compression_policy "protect"/"aggressive" still
    # apply).
    quality_aware: bool = False
    compression_deferral: int = 2
    quality_defer_min_free: int = 16
    quality_entropy_threshold: float = 0.85


#: kernel backends accepted by ``ModelRunnerConfig.kernel_backend``:
#: everything the kernel dispatch layer resolves, plus "chunked"
#: (decode attention only)
KERNEL_BACKENDS = _kernel_ops.BACKENDS + ("chunked",)

#: decode kernel families accepted by ``ModelRunnerConfig.decode_kernel``
DECODE_KERNELS = ("ragged", "dense")


@dataclasses.dataclass(frozen=True)
class ModelRunnerConfig:
    """Fixed device-step shapes and numerics."""
    prefill_rows: int = 4
    prefill_len: int = 128
    dtype: str = "float32"
    measure_phases: bool = False     # block per phase for timing benches
    # kernel dispatch (repro.kernels.ops / docs/KERNELS.md): "auto" resolves
    # to pallas-tpu on TPU hosts and the jnp reference elsewhere;
    # "pallas-interpret" forces the Pallas kernels through the interpreter
    # (CPU correctness path — slow, never auto-selected)
    kernel_backend: str = "auto"
    # decode kernel family (docs/KERNELS.md "Ragged decode"): "ragged"
    # scales each slot's attention work with its live page count —
    # padded and evicted pages are never fetched; "dense" restores the
    # pool-wide-grid kernel. Token streams are bit-identical either way,
    # so this is a fallback/ablation switch, not a numerics choice.
    decode_kernel: str = "ragged"
    # decode hot path (docs/PERF.md): fuse_sampling runs the per-slot
    # sampler inside the jitted decode step (tokens never leave the
    # device between steps); decode_steps > 1 additionally runs up to
    # that many decode+sample iterations per dispatch, bounded by the
    # scheduler's quiescent horizon. decode_steps > 1 requires
    # fuse_sampling; token streams are identical either way.
    fuse_sampling: bool = True
    decode_steps: int = 1


_CONFIG_TYPES = (CacheConfig, SchedulerConfig, ModelRunnerConfig)
_FIELD_OWNER = {f.name: t for t in _CONFIG_TYPES
                for f in dataclasses.fields(t)}


def route_overrides(cache: Optional[CacheConfig] = None,
                    scheduler: Optional[SchedulerConfig] = None,
                    runner: Optional[ModelRunnerConfig] = None,
                    **overrides
                    ) -> Tuple[CacheConfig, SchedulerConfig,
                               ModelRunnerConfig]:
    """Apply flat field overrides on top of (possibly defaulted) configs."""
    by_type = {CacheConfig: dict(), SchedulerConfig: dict(),
               ModelRunnerConfig: dict()}
    for k, v in overrides.items():
        owner = _FIELD_OWNER.get(k)
        if owner is None:
            if k in ("temperature", "seed", "top_k", "top_p"):
                raise TypeError(
                    f"{k!r} is per-request now — pass it via "
                    "SamplingParams, not the engine config")
            raise TypeError(f"unknown engine config field {k!r}")
        by_type[owner][k] = v
    cache = dataclasses.replace(cache or CacheConfig(),
                                **by_type[CacheConfig])
    scheduler = dataclasses.replace(scheduler or SchedulerConfig(),
                                    **by_type[SchedulerConfig])
    runner = dataclasses.replace(runner or ModelRunnerConfig(),
                                 **by_type[ModelRunnerConfig])
    return cache, scheduler, runner


def build_engine_options(cache: CacheConfig, scheduler: SchedulerConfig,
                         runner: ModelRunnerConfig) -> EngineOptions:
    if runner.kernel_backend not in KERNEL_BACKENDS:
        raise ValueError(
            f"unknown kernel_backend {runner.kernel_backend!r}; expected "
            f"one of {KERNEL_BACKENDS}")
    if runner.decode_kernel not in DECODE_KERNELS:
        raise ValueError(
            f"unknown decode_kernel {runner.decode_kernel!r}; expected "
            f"one of {DECODE_KERNELS}")
    compress = cache.compress
    if compress is None:
        compress = CompressOptions(window=cache.window)
    elif compress.window != cache.window:
        raise ValueError(
            f"CacheConfig.window ({cache.window}) must match "
            f"compress.window ({compress.window}); set both, or pass only "
            "compress and window together")
    # policy names, token_budget >= max_batch and admission_margin bounds
    # are validated by repro.core.scheduler (Scheduler.__init__ /
    # make_policy), which the engine constructs before any device work
    return EngineOptions(
        block_size=cache.block_size,
        n_total_blocks=cache.n_total_blocks,
        max_batch=scheduler.max_batch,
        m_qslots=scheduler.m_qslots,
        n_max=cache.n_max,
        window=cache.window,
        scheduling=scheduler.scheduling,
        prefix_caching=cache.prefix_caching,
        prefix_cache_policy=cache.prefix_cache_policy,
        prefix_cache_watermark=cache.prefix_cache_watermark,
        cache_compressed_prefixes=cache.cache_compressed_prefixes,
        async_compression=scheduler.async_compression,
        policy=scheduler.policy,
        preemption=scheduler.preemption,
        preemption_mode=scheduler.preemption_mode,
        swap_cost_per_token=scheduler.swap_cost_per_token,
        swap_space_blocks=cache.swap_space_blocks,
        token_budget=scheduler.token_budget,
        max_prefill_chunk=scheduler.max_prefill_chunk,
        admission_margin=scheduler.admission_margin,
        quality_aware=scheduler.quality_aware,
        compression_deferral=scheduler.compression_deferral,
        quality_defer_min_free=scheduler.quality_defer_min_free,
        quality_entropy_threshold=scheduler.quality_entropy_threshold,
        compress=compress,
        max_model_len=cache.max_model_len,
        prefill_rows=runner.prefill_rows,
        prefill_len=runner.prefill_len,
        dtype=runner.dtype,
        measure_phases=runner.measure_phases,
        kernel_backend=runner.kernel_backend,
        decode_kernel=runner.decode_kernel,
        fuse_sampling=runner.fuse_sampling,
        decode_steps=runner.decode_steps)
