"""``Zipage`` — the serving facade (the public face of the engine).

This is the only layer examples, benchmarks and launchers talk to; the
host scheduler (``repro.core.engine.ZipageEngine``) is internal. The facade
adds the request-scoped contract production engines expose:

  * per-request :class:`SamplingParams` (temperature/top-k/top-p/seed/stop),
  * incremental ``add_request()`` / ``step()`` streaming over continuous
    batching, emitting :class:`RequestOutput` snapshots with
    :class:`CompletionChunk` deltas as tokens land,
  * blocking batch ``generate(prompts, params)``,
  * mid-flight ``abort(request_id)`` that returns blocks to the pool,
  * ``Zipage.from_config("tiny-lm", block_size=8, ...)`` one-line bring-up
    with the CacheConfig / SchedulerConfig / ModelRunnerConfig split.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Set, Union

from repro.api.config import (CacheConfig, ModelRunnerConfig,
                              SchedulerConfig, build_engine_options,
                              route_overrides)
from repro.api.outputs import (CompletionChunk, RequestOutput, UsageInfo,
                               snapshot_request)
from repro.core.engine import ZipageEngine
from repro.core.request import Request
from repro.core.sampling import SamplingParams


class Zipage:
    def __init__(self, cfg, params,
                 cache: Optional[CacheConfig] = None,
                 scheduler: Optional[SchedulerConfig] = None,
                 runner: Optional[ModelRunnerConfig] = None,
                 **overrides):
        """Wrap a model (ArchConfig + params) in the serving facade.

        ``overrides`` are flat config fields routed to the owning config
        (``block_size`` -> CacheConfig, ``max_batch`` -> SchedulerConfig,
        ...); explicit config objects provide the bases they override.
        """
        self.cache_config, self.scheduler_config, self.runner_config = \
            route_overrides(cache, scheduler, runner, **overrides)
        self.cfg = cfg
        self.engine = ZipageEngine(cfg, params, build_engine_options(
            self.cache_config, self.scheduler_config, self.runner_config))
        self._requests: Dict[int, Request] = {}
        self._emitted: Dict[int, int] = {}       # tokens already streamed
        self._undrained: Set[int] = set()        # rids _drain still watches
        self._queued: List[RequestOutput] = []   # outputs consumed by an
        #                                          interleaved generate()
        self._listeners: List[Callable[[List[RequestOutput]], None]] = []
        self._aio = None          # lazily-started AsyncEngineLoop

    # ------------------------------------------------------------------
    @classmethod
    def from_config(cls, arch_name: str, *, params=None, param_seed: int = 0,
                    reduce: bool = False,
                    cache: Optional[CacheConfig] = None,
                    scheduler: Optional[SchedulerConfig] = None,
                    runner: Optional[ModelRunnerConfig] = None,
                    **overrides) -> "Zipage":
        """One-line bring-up: resolve the architecture by name, initialise
        (or accept) params, and build the engine. ``reduce=True`` derives
        the family-preserving tiny config for CPU smoke runs."""
        import jax

        from repro.configs import get_config
        from repro.models import lm

        cache, scheduler, runner = route_overrides(
            cache, scheduler, runner, **overrides)
        cfg = get_config(arch_name)
        if reduce:
            cfg = cfg.reduced()
        cfg = dataclasses.replace(cfg, dtype=runner.dtype)
        if params is None:
            params = lm.init(cfg, jax.random.key(param_seed))
        return cls(cfg, params, cache=cache, scheduler=scheduler,
                   runner=runner)

    # ------------------------------------------------------------------
    # request lifecycle

    def add_request(self, prompt: Sequence[int],
                    params: Optional[SamplingParams] = None,
                    priority: int = 0) -> int:
        """Enqueue a request; returns its request id immediately. Tokens
        arrive through subsequent ``step()`` calls. ``priority`` orders
        admission (and inversely, preemption) under the "priority"
        scheduler policy — higher runs first; other policies ignore it."""
        params = params or SamplingParams()
        rid = self.engine.add_request(prompt, params, priority=priority)
        self._requests[rid] = self.engine.waiting[-1]
        self._emitted[rid] = 0
        self._undrained.add(rid)
        return rid

    def step(self) -> List[RequestOutput]:
        """Advance the engine one scheduling step (admit + prefill +
        compress + decode) and return a RequestOutput for every request
        that made progress — its ``chunk`` carries the new tokens, in
        generation order. Finished requests appear exactly once with
        ``finished=True``."""
        if self.has_unfinished():
            self.engine.step()
        queued, self._queued = self._queued, []
        outs = queued + self._drain()
        if outs:
            for fn in list(self._listeners):
                fn(outs)
        return outs

    def add_listener(self,
                     fn: Callable[[List[RequestOutput]], None]) -> None:
        """Register a step listener: called with every non-empty output
        batch ``step()`` produces (including steps driven by an
        interleaved ``generate()``). The async surface (``repro.api.aio``)
        uses this for per-request fan-out; listeners must not call back
        into the facade."""
        self._listeners.append(fn)

    def remove_listener(self, fn) -> None:
        if fn in self._listeners:
            self._listeners.remove(fn)

    def generate(self,
                 prompts: Sequence[Sequence[int]],
                 params: Union[SamplingParams, Sequence[SamplingParams],
                               None] = None,
                 max_steps: int = 100_000) -> List[RequestOutput]:
        """Blocking batch mode: submit all prompts (each with its own
        SamplingParams — pass a list — or one shared instance) and run the
        continuous-batching loop until they all finish. Returns final
        RequestOutputs in prompt order."""
        if params is None or isinstance(params, SamplingParams):
            params = [params] * len(prompts)
        if len(params) != len(prompts):
            raise ValueError("one SamplingParams per prompt required")
        rids = [self.add_request(p, sp) for p, sp in zip(prompts, params)]
        mine = set(rids)
        pending = set(rids)
        for _ in range(max_steps):
            if not pending:
                break
            # re-queue outputs belonging to interleaved streaming requests
            # so the caller's next step() still sees their chunks
            # (step() replaces self._queued, so it must run before extend
            # resolves the list)
            outs = self.step()
            self._queued.extend(o for o in outs
                                if o.request_id not in mine)
            pending = {rid for rid in pending
                       if self._requests[rid].finish_reason is None}
        if pending:
            # don't leave orphans holding slots/blocks the caller can't
            # reach — abort them before surfacing the failure
            for rid in sorted(pending):
                self.abort(rid)
            raise RuntimeError(
                f"generate() exceeded {max_steps} steps; aborted unfinished "
                f"requests {sorted(pending)}")
        return [self.output(rid) for rid in rids]

    # ------------------------------------------------------------------
    # async surface (docs/SERVING.md) — same background loop the HTTP
    # tier uses, so sync and async callers share one scheduler

    async def _ensure_aio(self):
        import asyncio

        from repro.api.aio import AsyncEngineLoop
        loop = asyncio.get_running_loop()
        if self._aio is not None and (self._aio._loop is not loop
                                      or not self._aio.started):
            self._aio._teardown()     # stale: bound to a finished loop
            self._aio = None
        if self._aio is None:
            self._aio = await AsyncEngineLoop(self).start()
        return self._aio

    async def generate_async(self, prompt: Sequence[int],
                             params: Optional[SamplingParams] = None,
                             priority: int = 0) -> RequestOutput:
        """Async ``generate`` for one prompt: admit on the background
        continuous-batching loop and await the final RequestOutput.
        Concurrent callers batch together on the same loop."""
        aio = await self._ensure_aio()
        return await aio.generate(prompt, params, priority)

    async def stream(self, prompt: Sequence[int],
                     params: Optional[SamplingParams] = None,
                     priority: int = 0):
        """``async for chunk in zipage.stream(prompt, params)``: yields a
        :class:`CompletionChunk` per engine step that grew the request;
        the terminal chunk carries ``finish_reason`` + ``usage``."""
        aio = await self._ensure_aio()
        rid = await aio.add_request(prompt, params, priority)
        async for out in aio.stream_outputs(rid):
            chunk = out.chunk
            if chunk is None:         # abort-path terminal snapshot
                chunk = CompletionChunk(
                    request_id=out.request_id, index=len(out.token_ids),
                    token_ids=[], logprobs=None,
                    finish_reason=out.finish_reason, usage=out.usage)
            yield chunk

    def abort(self, request_id: int) -> Optional[RequestOutput]:
        """Cancel a waiting or running request mid-flight. Its blocks are
        returned to the BlockManager immediately; the final RequestOutput
        (finish_reason="abort") is returned, or None for unknown/finished
        ids."""
        if not self.engine.abort(request_id):
            return None
        r = self._requests.get(request_id)
        if r is None:                 # submitted directly on the engine
            return snapshot_request(self.engine.finished[request_id],
                                    self.kv_budget_tokens)
        self._emitted[request_id] = len(r.output)
        self._undrained.discard(request_id)
        # drop any chunks a concurrent generate() re-queued: the abort
        # snapshot is this request's terminal (and only further) emission
        self._queued = [o for o in self._queued
                        if o.request_id != request_id]
        return snapshot_request(r, self.kv_budget_tokens)

    def output(self, request_id: int) -> RequestOutput:
        """Current snapshot of any known request (no chunk); also resolves
        ids submitted directly on the wrapped engine once finished."""
        r = self._requests.get(request_id) \
            or self.engine.finished.get(request_id)
        if r is None:
            raise KeyError(f"unknown request id {request_id}")
        return snapshot_request(r, self.kv_budget_tokens)

    def has_unfinished(self) -> bool:
        return bool(self.engine.waiting or self.engine.running)

    # ------------------------------------------------------------------
    # engine passthroughs (read-only views)

    @property
    def kv_budget_tokens(self) -> Optional[int]:
        """Per-request KV budget ((n_max-1)*block_size), None = full KV."""
        if not self.engine.compression_enabled:
            return None
        return self.engine.budget_blocks * self.cache_config.block_size

    @property
    def metrics(self) -> List[dict]:
        return self.engine.metrics

    @property
    def scheduler_stats(self) -> Optional[dict]:
        """Last step's scheduler telemetry (docs/SCHEDULER.md): policy,
        admitted/preempted/blocked/finished counts, prefill and scheduled
        token counts, token-budget utilization, free blocks, the
        straggler-aware admission scale, and the cumulative prefix-cache
        counters (docs/CACHING.md "Telemetry"). None before the first
        step."""
        if not self.engine.metrics:
            return None
        m = self.engine.metrics[-1]
        return {k: m[k] for k in (
            "policy", "preemption_mode", "n_admitted", "n_preempted",
            "n_swapped_out", "n_swapped_in", "n_swapped", "swap_bytes",
            "swap_util", "n_blocked",
            "n_finished", "n_prefill_tokens", "n_scheduled_tokens",
            "token_budget", "budget_util", "free_blocks",
            "admission_scale", "t_host", "t_device",
            "decode_horizon",
            "quality_aware", "n_comp_default", "n_comp_protect",
            "n_comp_aggressive", "n_comp_deferred",
            "prefix_cache_policy", "prefix_lookups", "prefix_hits",
            "prefix_hit_tokens", "prefix_segment_hits",
            "prefix_evictions", "prefix_cached_blocks",
            "prefix_cached_tokens", "cached_tokens_per_block") if k in m}

    @property
    def step_count(self) -> int:
        return self.engine.step_count

    @property
    def bm(self):
        return self.engine.bm

    @property
    def num_free_blocks(self) -> int:
        return self.engine.bm.num_free

    # ------------------------------------------------------------------
    def _drain(self) -> List[RequestOutput]:
        outs = []
        # only unfinalized requests are scanned, so long-running serving
        # loops don't pay per-step cost for completed history
        for rid in sorted(self._undrained):
            r = self._requests[rid]
            n_seen = self._emitted[rid]
            finished = r.finish_reason is not None
            if len(r.output) <= n_seen and not finished:
                continue
            # stop-sequence truncation can shrink the output below what
            # streaming already emitted; the final snapshot is
            # authoritative and the chunk simply comes up empty
            new = list(r.output[n_seen:])
            lps = (list(r.logprobs[n_seen:len(r.output)])
                   if r.sampling.logprobs else None)
            chunk = CompletionChunk(
                request_id=rid, index=n_seen, token_ids=new, logprobs=lps,
                # terminal chunk carries the OpenAI last-chunk markers so
                # streaming layers need no second lookup (docs/SERVING.md)
                finish_reason=r.finish_reason if finished else None,
                usage=(UsageInfo.of(len(r.prompt), len(r.output))
                       if finished else None))
            self._emitted[rid] = len(r.output)
            outs.append(snapshot_request(r, self.kv_budget_tokens, chunk))
            if finished:
                self._undrained.discard(rid)
        return outs
