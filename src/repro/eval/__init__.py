"""``repro.eval`` — seeded reasoning eval harness (docs/EVAL.md).

An LM-eval-harness-style generation-task runner over deterministic
synthetic reasoning traces (associative recall, running-sum arithmetic
chains, copy chains — every example has a checkable final answer), small
enough to train and serve tiny-lm on a CPU CI worker. It reports
accuracy-vs-throughput across compression budgets (``n_max`` × window,
against the Full-KV baseline) and emits a ``zipage-eval/v1`` JSON that
``tools/bench_trend.py`` gates across PRs — turning the paper's "~95% of
Full-KV quality" claim into a tracked number.

Run it:

    python -m repro.eval --smoke --out eval-smoke.json
"""
from repro.eval.tasks import TASK_KINDS, make_example, train_batch  # noqa
from repro.eval.runner import (  # noqa: F401
    EVAL_SCHEMA, run_eval, token_agreement, trained_params)
