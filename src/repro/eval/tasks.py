"""Seeded synthetic reasoning tasks with checkable final answers.

Three generation-task families over tiny-lm's 512-token vocabulary, all
designed so the answer depends on tokens spread across the *whole*
prompt — exactly the KV entries a compression budget puts at risk
(docs/EVAL.md "Task format"):

* ``recall``     — associative recall: key/value pairs early in the
                   prompt, one queried key at the end; the value's KV
                   entry must survive eviction.
* ``chain_add``  — running-sum arithmetic chain: a start digit and
                   marked deltas interleaved with noise; the answer is
                   the *trace* of mod-10 running sums, so step *j* of
                   the answer needs delta *j*'s KV entry deep in the
                   prompt (plus the model's own previous output).
* ``chain_copy`` — copy chain: reproduce a marked digit sequence; token
                   *i* of the answer needs prompt position *i*'s KV.

Everything is driven by ``numpy.random.Generator`` instances seeded from
``SeedSequence`` namespaces, so example streams are deterministic across
processes and platforms; training draws (``train_batch``) and eval draws
(``eval_set``) live in disjoint seed namespaces.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

# token-id layout inside the 512-wide vocab (docs/EVAL.md)
COPY, SEP, QUERY, EQ, KMARK, DMARK, VMARK, CMARK = 2, 3, 4, 5, 6, 7, 8, 9
DIGIT0 = 10        # digits 0..9 -> ids 10..19
KEY0, N_KEYS = 100, 100
NOISE0, N_NOISE = 200, 100

TASK_KINDS = ("recall", "chain_add", "chain_copy")

# per-kind shape knobs (smoke defaults; sized so prompts span several
# 8-token blocks and n_max ∈ {2,3,4} budgets actually bite)
RECALL_PAIRS = 12
CHAIN_DELTAS = 9
COPY_LEN = 16


def _digit(d: int) -> int:
    return DIGIT0 + int(d) % 10


def make_example(kind: str, rng: np.random.Generator
                 ) -> Tuple[List[int], List[int]]:
    """One (prompt_tokens, answer_tokens) example of ``kind``."""
    if kind == "recall":
        keys = rng.choice(N_KEYS, size=RECALL_PAIRS, replace=False)
        vals = rng.integers(0, 10, size=RECALL_PAIRS)
        prompt = []
        for k, v in zip(keys, vals):
            prompt += [KMARK, KEY0 + int(k), VMARK, _digit(v)]
        q = int(rng.integers(0, RECALL_PAIRS))
        prompt += [QUERY, KEY0 + int(keys[q]), EQ]
        return prompt, [_digit(vals[q])]
    if kind == "chain_add":
        v0 = int(rng.integers(0, 10))
        deltas = rng.integers(0, 10, size=CHAIN_DELTAS)
        prompt = [CMARK, DMARK, _digit(v0)]
        for d in deltas:
            noise = rng.integers(0, N_NOISE, size=3)
            prompt += [NOISE0 + int(n) for n in noise]
            prompt += [DMARK, _digit(d)]
        prompt += [EQ]
        sums, acc = [], v0
        for d in deltas:
            acc += int(d)
            sums.append(_digit(acc))
        return prompt, sums
    if kind == "chain_copy":
        seq = rng.integers(0, 10, size=COPY_LEN)
        prompt = [COPY] + [_digit(d) for d in seq] + [EQ]
        return prompt, [_digit(d) for d in seq]
    raise ValueError(f"unknown eval task kind {kind!r}; "
                     f"expected one of {TASK_KINDS}")


def eval_set(n: int, seed: int) -> List[Tuple[str, List[int], List[int]]]:
    """``n`` deterministic eval examples, kinds round-robin. Each example
    draws from its own ``SeedSequence([seed, 1, i])`` stream so the set is
    stable under reordering or resizing."""
    out = []
    for i in range(n):
        kind = TASK_KINDS[i % len(TASK_KINDS)]
        rng = np.random.default_rng(np.random.SeedSequence([seed, 1, i]))
        prompt, answer = make_example(kind, rng)
        out.append((kind, prompt, answer))
    return out


IGNORE = -100   # chunked_xent's ignore_id: no loss at that position


def train_batch(step: int, *, seq_len: int, batch: int, seed: int) -> dict:
    """One packed LM training batch ``{"tokens", "labels"}`` (the
    ``repro.training`` batch contract) drawn from the same task
    distribution as ``eval_set`` but in the disjoint
    ``SeedSequence([seed, 0, step, row])`` namespace: rows concatenate
    whole examples (prompt + answer) back-to-back and truncate to
    ``seq_len + 1``. Loss is masked (``IGNORE``) everywhere except
    answer positions — the prompt tokens are high-entropy random draws
    whose irreducible loss would drown the reasoning signal, and eval
    only ever scores answer positions (prompts are forced)."""
    rows = np.zeros((batch, seq_len + 1), np.int32)
    mask = np.zeros((batch, seq_len + 1), bool)
    for b in range(batch):
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, 0, step, b]))
        kind = TASK_KINDS[(step * batch + b) % len(TASK_KINDS)]
        stream: List[int] = []
        answer_pos: List[int] = []
        while len(stream) < seq_len + 1:
            prompt, answer = make_example(kind, rng)
            answer_pos += range(len(stream) + len(prompt),
                                len(stream) + len(prompt) + len(answer))
            stream += prompt + answer + [SEP]
        rows[b] = stream[:seq_len + 1]
        for pos in answer_pos:
            if pos <= seq_len:
                mask[b, pos] = True
    labels = np.where(mask, rows, IGNORE).astype(np.int32)
    return {"tokens": rows[:, :-1], "labels": labels[:, 1:]}
