"""CLI for the seeded reasoning eval harness (docs/EVAL.md).

    python -m repro.eval --smoke --out eval-smoke.json

Prints an accuracy-vs-throughput table per compression budget and, with
``--out``, writes the byte-deterministic ``zipage-eval/v1`` JSON that
``tools/bench_trend.py`` gates across PRs.
"""
from __future__ import annotations

import argparse
import sys

from repro.eval.runner import render_report, run_eval, summary_table


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.eval",
        description="Seeded reasoning eval across compression budgets.")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (default when --full is absent)")
    ap.add_argument("--full", action="store_true",
                    help="larger eval set plus window-8 budget rows")
    ap.add_argument("--out", default=None,
                    help="write the zipage-eval/v1 JSON report here")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=None,
                    help="eval-set size (default: 18 smoke / 48 full)")
    ap.add_argument("--train-steps", type=int, default=None,
                    help="tiny-lm training steps (default: 300 smoke / "
                         "600 full)")
    args = ap.parse_args(argv)

    full = args.full and not args.smoke
    n_requests = args.requests if args.requests is not None else (
        48 if full else 18)
    train_steps = args.train_steps if args.train_steps is not None else (
        600 if full else 300)

    report = run_eval(seed=args.seed, n_requests=n_requests,
                      train_steps=train_steps, full=full, smoke=not full)
    print("\n".join(summary_table(report)))
    if args.out:
        with open(args.out, "w") as f:
            f.write(render_report(report))
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
