"""Eval runner: train tiny-lm on the task distribution, serve the eval
set across compression budgets, score against Full-KV (docs/EVAL.md).

Every number in the emitted ``zipage-eval/v1`` report is deterministic —
seeded data, greedy decoding, and *step-count-based* throughput proxies
(tokens/step, compressions, block utilization) instead of wall-clock —
so two runs of ``python -m repro.eval --smoke`` produce byte-identical
JSON and ``tools/bench_trend.py`` can gate accuracy across PRs.
"""
from __future__ import annotations

import dataclasses
import json
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs import get_config
from repro.eval import tasks

EVAL_SCHEMA = "zipage-eval/v1"

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")

#: (row name, n_max, window, quality_aware). Full-KV must stay first —
#: it is the reference the other rows are scored against. The ``_qa`` row
#: runs the same budget with the quality-aware planner on, demonstrating
#: the telemetry feedback loop on the same traces.
BUDGETS_SMOKE: Tuple = (
    ("full_kv", None, 4, False),
    ("n2_w4", 2, 4, False),
    ("n3_w4", 3, 4, False),
    ("n4_w4", 4, 4, False),
    ("n3_w4_qa", 3, 4, True),
)
BUDGETS_FULL: Tuple = BUDGETS_SMOKE + (
    ("n3_w8", 3, 8, False),
    ("n4_w8", 4, 8, False),
)

#: serving config shared by every row (only n_max / window / the quality
#: knobs vary): pool sized so the Full-KV baseline never preempts, prefix
#: caching off so rows share nothing, float32 + greedy for determinism
ENGINE_KW = dict(
    block_size=8, n_total_blocks=192, max_batch=16, m_qslots=16,
    scheduling="hybrid", prefix_caching=False, async_compression=True,
    max_model_len=256, prefill_rows=4, prefill_len=64,
    fuse_sampling=True, decode_steps=4, dtype="float32")

TRAIN_SEQ_LEN = 80
TRAIN_BATCH = 16

_train_cache = {}


def trained_params(train_steps: int = 300, seed: int = 0):
    """tiny-lm briefly trained on the eval task distribution (disjoint
    seed namespace from the eval set — ``tasks.train_batch``), cached
    process-wide per (steps, seed)."""
    import jax
    import jax.numpy as jnp
    from repro.models import lm
    from repro.training import optimizer as opt
    from repro.training.train_loop import build_train_step

    key = (train_steps, seed)
    if key not in _train_cache:
        adamw = opt.AdamWConfig(lr=3e-3, warmup_steps=20,
                                total_steps=train_steps)
        step = jax.jit(build_train_step(CFG, adamw, vocab_chunk=64))
        params = lm.init(CFG, jax.random.key(seed))
        state = opt.init_opt_state(params)
        for i in range(train_steps):
            batch = jax.tree.map(jnp.asarray, tasks.train_batch(
                i, seq_len=TRAIN_SEQ_LEN, batch=TRAIN_BATCH, seed=seed))
            params, state, _, _m = step(params, state, None, batch)
        _train_cache[key] = params
    return _train_cache[key]


def token_agreement(pred: Sequence[int], ref: Sequence[int]) -> float:
    """Top-1 agreement scored over the *reference* length: positions the
    candidate never produced count as disagreement, so a stream that
    stops early is penalised rather than scored on its shared prefix
    (the ``benchmarks/bench_quality_proxy.py`` fix, same semantics)."""
    if not ref:
        return 1.0
    hits = sum(1 for i, t in enumerate(ref)
               if i < len(pred) and pred[i] == t)
    return hits / len(ref)


def _round(x: float, nd: int = 6) -> float:
    return round(float(x), nd)


def _run_budget(params, examples, *, name: str, n_max: Optional[int],
                window: int, quality_aware: bool) -> dict:
    """Serve the eval set under one compression budget; returns the
    result row (reference-relative fields filled in by ``run_eval``)."""
    from repro.api import SamplingParams, Zipage

    kw = dict(ENGINE_KW, n_max=n_max, window=window)
    if quality_aware:
        kw.update(quality_aware=True, quality_defer_min_free=8)
    z = Zipage(CFG, params, **kw)
    prompts = [p for _k, p, _a in examples]
    sp = [SamplingParams(max_new_tokens=len(a), seed=0)
          for _k, _p, a in examples]
    outs = z.generate(prompts, sp, max_steps=20_000)

    per_task = {k: [0, 0] for k in tasks.TASK_KINDS}
    n_correct, tok_hits, tok_total = 0, 0, 0
    preds = []
    for (kind, _prompt, answer), out in zip(examples, outs):
        pred = list(out.token_ids)
        preds.append(pred)
        exact = pred == list(answer)
        n_correct += exact
        per_task[kind][0] += exact
        per_task[kind][1] += 1
        tok_hits += sum(1 for i, t in enumerate(answer)
                        if i < len(pred) and pred[i] == t)
        tok_total += len(answer)
    st = z.scheduler_stats
    finished = z.engine.scheduler.finished
    return {
        "name": name,
        "n_max": n_max,
        "window": window,
        "quality_aware": quality_aware,
        "n": len(examples),
        "n_correct": n_correct,
        "accuracy": _round(n_correct / len(examples)),
        "token_accuracy": _round(tok_hits / max(tok_total, 1)),
        "accuracy_by_task": {
            k: _round(c / max(n, 1)) for k, (c, n) in per_task.items()},
        # deterministic throughput proxies (no wall-clock — docstring)
        "steps": z.step_count,
        "tokens": sum(o.usage.completion_tokens for o in outs),
        "tokens_per_step": _round(
            sum(o.usage.completion_tokens for o in outs) / max(z.step_count, 1), 4),
        "compressions": sum(r.n_compressions for r in finished.values()),
        "n_comp_deferred": st["n_comp_deferred"],
        "block_util": _round(np.mean([m["block_util"]
                                      for m in z.metrics]), 4),
        "_preds": preds,
    }


def run_eval(*, seed: int = 0, n_requests: int = 18,
             train_steps: int = 300, full: bool = False,
             smoke: bool = True) -> dict:
    """Train, serve every budget row, score against the Full-KV
    reference; returns the ``zipage-eval/v1`` report dict."""
    budgets = BUDGETS_FULL if full else BUDGETS_SMOKE
    examples = tasks.eval_set(n_requests, seed)
    params = trained_params(train_steps, seed)
    rows = [
        _run_budget(params, examples, name=name, n_max=n_max,
                    window=window, quality_aware=qa)
        for name, n_max, window, qa in budgets]
    ref = rows[0]
    for row in rows:
        row["agreement_vs_full"] = _round(float(np.mean(
            [token_agreement(p, rp)
             for p, rp in zip(row["_preds"], ref["_preds"])])))
        row["accuracy_vs_full"] = (
            _round(row["accuracy"] / ref["accuracy"])
            if ref["accuracy"] else None)
    for row in rows:
        del row["_preds"]
    return {
        "schema": EVAL_SCHEMA,
        "model": "tiny-lm",
        "smoke": bool(smoke),
        "config": {
            "seed": seed,
            "n_requests": n_requests,
            "train_steps": train_steps,
            "tasks": list(tasks.TASK_KINDS),
            "block_size": ENGINE_KW["block_size"],
        },
        "results": rows,
    }


def render_report(report: dict) -> str:
    """Byte-stable JSON serialization (sorted keys, trailing newline)."""
    return json.dumps(report, indent=2, sort_keys=True) + "\n"


def summary_table(report: dict) -> List[str]:
    lines = ["| budget | acc | tok acc | vs full | agree | tok/step "
             "| compressions |",
             "|---|---|---|---|---|---|---|"]
    for r in report["results"]:
        lines.append(
            f"| {r['name']} | {r['accuracy']} | {r['token_accuracy']} "
            f"| {r['accuracy_vs_full']} | {r['agreement_vs_full']} "
            f"| {r['tokens_per_step']} | {r['compressions']} |")
    return lines
