"""Shared model primitives: norms, activations, RoPE, init helpers.

Pure-function style (no flax): params are plain pytrees of jnp arrays; every
layer is ``apply(params, x, ...)``. Compute dtype is bf16 with fp32 norms /
softmax accumulators, matching production TPU practice.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ----------------------------------------------------------------------
# init

def dense_init(key, shape, in_axis=0, dtype=jnp.float32, scale=1.0):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = scale / np.sqrt(fan_in)
    return (jax.random.normal(key, shape) * std).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))


# ----------------------------------------------------------------------
# norms

def init_norm(cfg, d):
    if cfg.norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), jnp.float32)}
    if cfg.norm_type == "layernorm":
        return {"scale": jnp.ones((d,), jnp.float32),
                "bias": jnp.zeros((d,), jnp.float32)}
    if cfg.norm_type == "nonparam_ln":   # OLMo: no affine params
        return {}
    raise ValueError(cfg.norm_type)


def apply_norm(cfg, p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "rmsnorm":
        xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
        xf = xf * p["scale"]
    else:  # layernorm / nonparam_ln
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), -1, keepdims=True)
        xf = (xf - mu) * jax.lax.rsqrt(var + eps)
        if p:
            xf = xf * p["scale"] + p["bias"]
    return xf.astype(x.dtype)


def rms_head_norm(scale, x, eps=1e-6):
    """Per-head q/k norm (Qwen3-style); x: (..., d_head)."""
    xf = x.astype(jnp.float32)
    xf = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (xf * scale).astype(x.dtype)


# ----------------------------------------------------------------------
# activations

def ffn_act_fn(name):
    if name in ("silu_glu", "gelu_glu"):
        base = jax.nn.silu if name == "silu_glu" else jax.nn.gelu
        return lambda a, b: base(a) * b          # gated
    if name == "sq_relu":
        return lambda a, _b: jnp.square(jax.nn.relu(a))
    if name == "gelu":
        return lambda a, _b: jax.nn.gelu(a)
    raise ValueError(name)


def is_gated(name):
    return name.endswith("_glu")


# ----------------------------------------------------------------------
# RoPE

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., S, H, D) or (..., H, D) with positions broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, d/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                            # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# chunked (flash-style) causal attention — pure JAX, O(S·chunk) memory.

NEG_INF = -1e30


def chunked_causal_attention(q, k, v, *, q_start=0, kv_len=None,
                             local_window=0, chunk=512):
    """Causal multi-head attention, chunked over KV for memory.

    q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D). q position i attends kv
    positions <= q_start + i (absolute kv index). GQA via head repeat.
    local_window > 0 limits attention to the last ``local_window`` positions.
    Returns (B, Sq, Hq, D).
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    if kv_len is None:
        kv_len = Sk
    g = Hq // Hkv
    scale = 1.0 / np.sqrt(D)
    qpos = q_start + jnp.arange(Sq)

    nchunks = -(-Sk // chunk)
    pad = nchunks * chunk - Sk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kp = kp.reshape(B, nchunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)
    vp = vp.reshape(B, nchunks, chunk, Hkv, D).transpose(1, 0, 2, 3, 4)

    def body(carry, kv):
        m, l, acc, cidx = carry
        kc, vc = kv                       # (B, chunk, Hkv, D)
        kpos = cidx * chunk + jnp.arange(chunk)
        # scores: (B, Hkv, g, Sq, chunk)
        qg = q.reshape(B, Sq, Hkv, g, D)
        s = jnp.einsum("bshgd,bchd->bhgsc", qg.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        mask = kpos[None, :] <= qpos[:, None]          # (Sq, chunk)
        if local_window:
            mask &= kpos[None, :] > qpos[:, None] - local_window
        mask &= (kpos < kv_len)[None, :]
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bhgsc,bchd->bhgsd", p, vc.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new, cidx + 1), None

    m0 = jnp.full((B, Hkv, g, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, g, Sq, D), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(body, (m0, l0, a0, 0), (kp, vp))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, Hq, D)
    return out.astype(q.dtype)
