"""Context for distribution-aware MoE dispatch (set by launchers/dry-run).

``dispatch_groups`` — number of data shards: the GShard dispatch computes
routing/capacity per group so the token gather stays shard-local and only the
(E, C_local, d) dispatch buffers cross the mesh (EXPERIMENTS.md §Perf
iteration B). ``dispatch_spec``/``combine_spec`` — optional PartitionSpecs
applied via with_sharding_constraint (requires an ambient mesh).
"""
import contextvars

dispatch_groups = contextvars.ContextVar("moe_dispatch_groups", default=1)
dispatch_spec = contextvars.ContextVar("moe_dispatch_spec", default=None)

# MLA serving: PartitionSpec for the (B, hq, r+dr) absorbed queries. Without
# it, q is head-sharded while the latent cache is width-sharded (both on
# "model") and GSPMD all-gathers the cache to resolve the conflict —
# ~0.6 GB/chip/layer at decode_32k (§Perf iteration D2).
mla_q_spec = contextvars.ContextVar("mla_q_spec", default=None)


class moe_partitioning:
    """Context manager used by launchers: with moe_partitioning(16, spec)."""

    def __init__(self, groups, spec=None):
        self.groups, self.spec = groups, spec

    def __enter__(self):
        self._tg = dispatch_groups.set(self.groups)
        self._ts = dispatch_spec.set(self.spec)
        return self

    def __exit__(self, *a):
        dispatch_groups.reset(self._tg)
        dispatch_spec.reset(self._ts)
