"""Generic LM built from an ArchConfig.

Layer stacking strategy (compile-time critical for 26–48 layer archs):
consecutive layers with the same (mixer, ffn) spec pattern are grouped into
*stages*; a stage of n pattern-units is a ``lax.scan`` over stacked params
with an optionally remat'ed body. Heterogeneous patterns (recurrentgemma's
(rglru, rglru, attn)) scan over whole pattern units; remainders unroll.

The training/prefill forward lives here; paged decode lives in repro.core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models.common import apply_norm, dense_init, init_norm, split_keys


# ----------------------------------------------------------------------
# layer plan

def layer_specs(cfg: ArchConfig):
    """Per-layer (mixer_kind, ffn_kind)."""
    kinds = cfg.layer_kinds()
    specs = []
    for i, kind in enumerate(kinds):
        if cfg.num_experts > 0 and i >= cfg.first_dense_layers:
            specs.append((kind, "moe"))
        else:
            specs.append((kind, "dense"))
    return specs


def build_plan(cfg: ArchConfig):
    """Split layers into head (unrolled), main (scanned units), tail (unrolled)."""
    specs = layer_specs(cfg)
    p = len(cfg.block_pattern)
    head = specs[:cfg.first_dense_layers]
    rest = specs[cfg.first_dense_layers:]
    n_units = len(rest) // p
    main_units = [rest[i * p:(i + 1) * p] for i in range(n_units)]
    tail = rest[n_units * p:]
    # all units must be identical specs for stacking
    if main_units and any(u != main_units[0] for u in main_units):
        # fall back: unroll everything (never triggers for assigned archs)
        return {"head": specs, "unit": [], "n_units": 0, "tail": []}
    return {"head": head, "unit": main_units[0] if main_units else [],
            "n_units": n_units, "tail": tail}


# ----------------------------------------------------------------------
# init

def _init_unit(cfg, key, unit_specs, with_cross=False):
    ks = split_keys(key, max(1, len(unit_specs)))
    return {str(i): L.init_layer(cfg, ks[i], kind, ffn, with_cross=with_cross)
            for i, (kind, ffn) in enumerate(unit_specs)}


def init(cfg: ArchConfig, key) -> dict:
    plan = build_plan(cfg)
    ks = split_keys(key, 8)
    dt = jnp.dtype(cfg.dtype)
    params = {
        "embed": dense_init(ks[0], (cfg.vocab_size, cfg.d_model), in_axis=1,
                            dtype=jnp.float32),
        "final_norm": init_norm(cfg, cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size))
    with_cross = cfg.is_enc_dec
    if plan["head"]:
        hk = split_keys(ks[2], len(plan["head"]))
        params["head"] = [L.init_layer(cfg, hk[i], kind, ffn,
                                       with_cross=with_cross)
                          for i, (kind, ffn) in enumerate(plan["head"])]
    if plan["n_units"]:
        uk = split_keys(ks[3], plan["n_units"])
        units = [_init_unit(cfg, k, plan["unit"], with_cross) for k in uk]
        params["main"] = jax.tree.map(lambda *xs: jnp.stack(xs), *units)
    if plan["tail"]:
        tk = split_keys(ks[4], len(plan["tail"]))
        params["tail"] = [L.init_layer(cfg, tk[i], kind, ffn,
                                       with_cross=with_cross)
                          for i, (kind, ffn) in enumerate(plan["tail"])]
    if cfg.is_enc_dec:
        ek = split_keys(ks[5], cfg.encoder_layers)
        enc_units = [{"0": L.init_layer(cfg, k, "attn", "dense")} for k in ek]
        params["encoder"] = {
            "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc_units),
            "final_norm": init_norm(cfg, cfg.d_model),
        }
    return params


def param_specs(cfg: ArchConfig):
    """Shape/dtype tree without allocating (dry-run path)."""
    return jax.eval_shape(lambda: init(cfg, jax.random.key(0)))


# ----------------------------------------------------------------------
# forward (training / prefill)

def apply_layer(cfg, p, x, positions, kind, ffn_kind, *, memory=None,
                local_window=None):
    h = apply_norm(cfg, p["ln1"], x)
    if kind == "attn":
        if cfg.attn_type == "mla":
            mix = L.mla_forward(cfg, p["attn"], h, positions)
        else:
            mix = L.attn_forward(cfg, p["attn"], h, positions,
                                 local_window=local_window)
    elif kind == "rglru":
        mix = L.rglru_forward(cfg, p["rglru"], h)
    elif kind == "rwkv":
        mix = L.rwkv_forward(cfg, p["rwkv"], h)
    else:
        raise ValueError(kind)
    x = x + mix
    if memory is not None and "cross" in p:
        x = x + L.cross_attn_forward(cfg, p["cross"],
                                     apply_norm(cfg, p["ln_x"], x), memory)
    h2 = apply_norm(cfg, p["ln2"], x)
    if ffn_kind == "moe":
        x = x + L.moe_forward(cfg, p["moe"], h2)
    else:
        x = x + L.ffn_forward(cfg, p["ffn"], h2)
    return x


def _unit_body(cfg, unit_specs, remat, memory=None):
    def body(x_pos, unit_p):
        x, positions = x_pos
        for i, (kind, ffn) in enumerate(unit_specs):
            x = apply_layer(cfg, unit_p[str(i)], x, positions, kind, ffn,
                            memory=memory)
        return (x, positions), None
    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    return body


def encode(cfg, params, frame_embeds):
    """Whisper encoder: bidirectional self-attention over frame embeddings."""
    x = frame_embeds.astype(jnp.dtype(cfg.dtype))
    enc = params["encoder"]

    def body(x, lp):
        p = lp["0"]
        h = apply_norm(cfg, p["ln1"], x)
        x = x + L.cross_attn_forward(cfg, p["attn"], h, h)   # unmasked self
        h2 = apply_norm(cfg, p["ln2"], x)
        x = x + L.ffn_forward(cfg, p["ffn"], h2)
        return x, None

    x, _ = jax.lax.scan(jax.checkpoint(body, prevent_cse=False), x,
                        enc["layers"])
    return apply_norm(cfg, enc["final_norm"], x)


def forward_hidden(cfg: ArchConfig, params, tokens, *, positions=None,
                   prefix_embeds=None, frame_embeds=None, remat=True):
    """Token ids -> final hidden states (B, S_total, d)."""
    dt = jnp.dtype(cfg.dtype)
    x = params["embed"].astype(dt)[tokens]
    if prefix_embeds is not None:                       # VLM patch prefix
        x = jnp.concatenate([prefix_embeds.astype(dt), x], axis=1)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    memory = None
    if cfg.is_enc_dec:
        if frame_embeds is None:
            raise ValueError("enc-dec arch requires frame_embeds")
        memory = encode(cfg, params, frame_embeds)
    plan = build_plan(cfg)
    for p_, (kind, ffn) in zip(params.get("head", []), plan["head"]):
        x = apply_layer(cfg, p_, x, positions, kind, ffn, memory=memory)
    if plan["n_units"]:
        body = _unit_body(cfg, plan["unit"], remat, memory=memory)
        (x, _), _ = jax.lax.scan(body, (x, positions), params["main"])
    for p_, (kind, ffn) in zip(params.get("tail", []), plan["tail"]):
        x = apply_layer(cfg, p_, x, positions, kind, ffn, memory=memory)
    return apply_norm(cfg, params["final_norm"], x)


def unembed_matrix(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def forward(cfg, params, tokens, **kw):
    h = forward_hidden(cfg, params, tokens, **kw)
    w = unembed_matrix(cfg, params).astype(h.dtype)
    return h @ w


# ----------------------------------------------------------------------
# chunked-vocab cross-entropy: never materializes (B, S, V) logits.

def chunked_xent(cfg, params, hidden, labels, *, chunk=256, ignore_id=-100):
    """hidden: (B, S, d); labels: (B, S). Returns (sum_loss, n_tokens)."""
    B, S, d = hidden.shape
    W = unembed_matrix(cfg, params)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    h = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
    lb = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=ignore_id)
    h = h.reshape(B, nc, chunk, d).transpose(1, 0, 2, 3)
    lb = lb.reshape(B, nc, chunk).transpose(1, 0, 2)

    @functools.partial(jax.checkpoint, prevent_cse=False)
    def body(carry, inp):
        loss_sum, n = carry
        hc, lc = inp
        logits = (hc @ W.astype(hc.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, -1)
        tgt = jnp.take_along_axis(
            logits, jnp.maximum(lc, 0)[..., None], -1)[..., 0]
        valid = lc != ignore_id
        loss_sum = loss_sum + jnp.sum(jnp.where(valid, lse - tgt, 0.0))
        n = n + jnp.sum(valid)
        return (loss_sum, n), None

    (loss_sum, n), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), (h, lb))
    return loss_sum, n


def lm_loss(cfg, params, batch, *, vocab_chunk=256):
    """batch: {"tokens": (B,S), "labels": (B,S), optional frontend embeds}."""
    hidden = forward_hidden(
        cfg, params, batch["tokens"],
        prefix_embeds=batch.get("prefix_embeds"),
        frame_embeds=batch.get("frame_embeds"))
    labels = batch["labels"]
    if "prefix_embeds" in batch:                 # loss only over text tokens
        P = batch["prefix_embeds"].shape[1]
        hidden = hidden[:, P:]
    loss_sum, n = chunked_xent(cfg, params, hidden, labels, chunk=vocab_chunk)
    return loss_sum / jnp.maximum(n, 1)
