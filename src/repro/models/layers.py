"""Layer implementations for every assigned family.

Each layer is (init_*, *_forward) with pure pytree params. Forward paths here
are the *training / prefill* (full-sequence) paths; single-token decode for
recurrent mixers (`rglru_step`, `rwkv_step`) also lives here, while paged
attention decode lives in `repro.core` (it owns the paged cache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.common import (
    apply_rope, chunked_causal_attention, dense_init, ffn_act_fn,
    init_norm, is_gated, rms_head_norm, split_keys,
)

# ======================================================================
# GQA attention

def init_attn(cfg, key, cross=False):
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, hq * dh)),
        "wk": dense_init(ks[1], (d, hkv * dh)),
        "wv": dense_init(ks[2], (d, hkv * dh)),
        "wo": dense_init(ks[3], (hq * dh, d)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((hq * dh,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * dh,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), jnp.float32)
        p["k_norm"] = jnp.ones((dh,), jnp.float32)
    return p


def attn_qkv(cfg, p, x):
    """Project x -> (q, k, v) with per-head layout (..., H, D)."""
    B = x.shape[:-1]
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = x @ p["wq"].astype(x.dtype)
    k = x @ p["wk"].astype(x.dtype)
    v = x @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = q.reshape(*B, hq, dh)
    k = k.reshape(*B, hkv, dh)
    v = v.reshape(*B, hkv, dh)
    if cfg.qk_norm:
        q = rms_head_norm(p["q_norm"], q)
        k = rms_head_norm(p["k_norm"], k)
    return q, k, v


def attn_forward(cfg, p, x, positions, *, local_window=None):
    """Full-sequence causal attention. x: (B, S, d)."""
    B, S, _ = x.shape
    q, k, v = attn_qkv(cfg, p, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    lw = cfg.local_window if local_window is None else local_window
    o = chunked_causal_attention(q, k, v, local_window=lw)
    o = o.reshape(B, S, cfg.num_heads * cfg.head_dim)
    return o @ p["wo"].astype(x.dtype)


def cross_attn_forward(cfg, p, x, memory):
    """Encoder-decoder cross attention (no mask). memory: (B, Sm, d)."""
    B, S, _ = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, hq, dh)
    k = (memory @ p["wk"].astype(x.dtype)).reshape(B, -1, hkv, dh)
    v = (memory @ p["wv"].astype(x.dtype)).reshape(B, -1, hkv, dh)
    g = hq // hkv
    qg = q.reshape(B, S, hkv, g, dh)
    s = jnp.einsum("bshgd,bmhd->bhgsm", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    a = jax.nn.softmax(s, -1)
    o = jnp.einsum("bhgsm,bmhd->bshgd", a, v.astype(jnp.float32))
    o = o.reshape(B, S, hq * dh).astype(x.dtype)
    return o @ p["wo"].astype(x.dtype)


# ======================================================================
# MLA (DeepSeek-V2): latent KV with decoupled RoPE.

def init_mla(cfg, key):
    d, hq = cfg.d_model, cfg.num_heads
    dh, dr, dv, r = cfg.head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    ks = split_keys(key, 4)
    return {
        "wq": dense_init(ks[0], (d, hq * (dh + dr))),
        "w_dkv": dense_init(ks[1], (d, r + dr)),        # down: latent + rope key
        "kv_norm": jnp.ones((r,), jnp.float32),
        "w_uk": dense_init(ks[2], (r, hq * dh)),        # latent -> per-head keys
        "w_uv": dense_init(ks[2], (r, hq * dv)),
        "wo": dense_init(ks[3], (hq * dv, d)),
    }


def mla_latent(cfg, p, x, positions):
    """Compute per-token latent cache entry: (c_kv normed, k_rope roped)."""
    r, dr = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    dkv = x @ p["w_dkv"].astype(x.dtype)
    c, k_rope = dkv[..., :r], dkv[..., r:]
    cf = c.astype(jnp.float32)
    cf = cf * jax.lax.rsqrt(jnp.mean(cf * cf, -1, keepdims=True) + 1e-6)
    c = (cf * p["kv_norm"]).astype(x.dtype)
    k_rope = apply_rope(k_rope[..., None, :], positions, cfg.rope_theta)[..., 0, :]
    return c, k_rope


def mla_queries(cfg, p, x, positions):
    hq, dh, dr = cfg.num_heads, cfg.head_dim, cfg.qk_rope_head_dim
    q = (x @ p["wq"].astype(x.dtype)).reshape(*x.shape[:-1], hq, dh + dr)
    q_nope, q_rope = q[..., :dh], q[..., dh:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_forward(cfg, p, x, positions):
    """Full-sequence MLA (expanded form, efficient for prefill)."""
    B, S, _ = x.shape
    hq, dh, dv, r = cfg.num_heads, cfg.head_dim, cfg.v_head_dim, cfg.kv_lora_rank
    dr = cfg.qk_rope_head_dim
    q_nope, q_rope = mla_queries(cfg, p, x, positions)
    c, k_rope = mla_latent(cfg, p, x, positions)
    k_nope = (c @ p["w_uk"].astype(x.dtype)).reshape(B, S, hq, dh)
    v = (c @ p["w_uv"].astype(x.dtype)).reshape(B, S, hq, dv)
    # concat nope+rope into one dot space; rope part shared across heads
    q = jnp.concatenate([q_nope, q_rope], -1) / np.sqrt(dh + dr)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                                  (B, S, hq, dr))], -1)
    # pad v to qk width so one chunked kernel serves both (common trick)
    o = chunked_causal_attention(q * np.sqrt(dh + dr), k,
                                 jnp.pad(v, ((0, 0),) * 3 + ((0, dh + dr - dv),)))
    o = o[..., :dv].reshape(B, S, hq * dv)
    return o @ p["wo"].astype(x.dtype)


# ======================================================================
# RG-LRU block (RecurrentGemma)

def init_rglru(cfg, key):
    d, w = cfg.d_model, cfg.lru_width or cfg.d_model
    h = cfg.num_heads
    wb = w // h
    ks = split_keys(key, 6)
    # constant-time-scale init: a in (0.9, 0.999)
    a_init = jnp.log(jnp.expm1(
        -jnp.log(jnp.linspace(0.9, 0.999, w))))  # softplus^-1 of -log a
    return {
        "wx": dense_init(ks[0], (d, w)),
        "wy_gate": dense_init(ks[1], (d, w)),           # output gate branch
        "conv_w": dense_init(ks[2], (cfg.conv1d_width, w)),
        "conv_b": jnp.zeros((w,), jnp.float32),
        "w_in_gate": dense_init(ks[3], (h, wb, wb), in_axis=1),
        "w_rec_gate": dense_init(ks[4], (h, wb, wb), in_axis=1),
        "a_param": a_init.astype(jnp.float32),
        "wo": dense_init(ks[5], (w, d)),
    }


_C_RGLRU = 8.0


def _rglru_gates(cfg, p, xw):
    """Per-step gate computation. xw: (..., w) post-conv activations."""
    h = cfg.num_heads
    w = xw.shape[-1]
    wb = w // h
    xh = xw.reshape(*xw.shape[:-1], h, wb)
    i_gate = jax.nn.sigmoid(jnp.einsum("...hb,hbc->...hc", xh.astype(jnp.float32),
                                       p["w_in_gate"]))
    r_gate = jax.nn.sigmoid(jnp.einsum("...hb,hbc->...hc", xh.astype(jnp.float32),
                                       p["w_rec_gate"]))
    i_gate = i_gate.reshape(*xw.shape[:-1], w)
    r_gate = r_gate.reshape(*xw.shape[:-1], w)
    log_a = -_C_RGLRU * r_gate * jax.nn.softplus(p["a_param"])
    a = jnp.exp(log_a)
    gated_x = xw.astype(jnp.float32) * i_gate
    multiplier = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a, gated_x * multiplier


def causal_conv1d(p, x):
    """Depthwise causal conv, width cw. x: (B, S, w)."""
    cw = p["conv_w"].shape[0]
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(cw):
        shifted = jnp.pad(x, ((0, 0), (cw - 1 - i, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted.astype(jnp.float32) * p["conv_w"][i]
    return (out + p["conv_b"]).astype(x.dtype)


def rglru_forward(cfg, p, x):
    """Full-sequence RG-LRU block. x: (B, S, d) -> (B, S, d)."""
    xw = (x @ p["wx"].astype(x.dtype))
    xw = causal_conv1d(p, xw)
    a, b = _rglru_gates(cfg, p, xw)          # h_t = a_t h_{t-1} + b_t
    def comb(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br
    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    gate = jax.nn.gelu((x @ p["wy_gate"].astype(x.dtype)).astype(jnp.float32))
    out = (h * gate).astype(x.dtype)
    return out @ p["wo"].astype(x.dtype)


def rglru_step(cfg, p, x, state):
    """Single-token step. x: (B, d); state: {"h": (B,w), "conv": (B,cw-1,w)}."""
    xw = x @ p["wx"].astype(x.dtype)
    cw = p["conv_w"].shape[0]
    hist = jnp.concatenate([state["conv"], xw[:, None]], 1)   # (B, cw, w)
    xc = (jnp.einsum("bcw,cw->bw", hist.astype(jnp.float32), p["conv_w"])
          + p["conv_b"]).astype(x.dtype)
    a, b = _rglru_gates(cfg, p, xc)
    h = a * state["h"] + b
    gate = jax.nn.gelu((x @ p["wy_gate"].astype(x.dtype)).astype(jnp.float32))
    out = (h * gate).astype(x.dtype) @ p["wo"].astype(x.dtype)
    return out, {"h": h, "conv": hist[:, 1:]}


def rglru_init_state(cfg, B, dtype):
    w = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((B, w), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv1d_width - 1, w), dtype)}


# ======================================================================
# RWKV-6 (Finch) time mixing: data-dependent decay.

_DECAY_LORA = 64


def init_rwkv(cfg, key):
    d = cfg.d_model
    h, K = cfg.num_heads, cfg.head_dim
    ks = split_keys(key, 8)
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),   # token-shift mix r,k,v,g,w
        "w_r": dense_init(ks[0], (d, d)),
        "w_k": dense_init(ks[1], (d, d)),
        "w_v": dense_init(ks[2], (d, d)),
        "w_g": dense_init(ks[3], (d, d)),
        "w0": jnp.full((d,), -6.0, jnp.float32),      # base decay (w≈exp(-exp(w0)))
        "w_lora_a": dense_init(ks[4], (d, _DECAY_LORA)),
        "w_lora_b": dense_init(ks[5], (_DECAY_LORA, d), scale=0.1),
        "u": dense_init(ks[6], (h, K), scale=1.0),    # bonus for current token
        "ln_x_scale": jnp.ones((d,), jnp.float32),
        "ln_x_bias": jnp.zeros((d,), jnp.float32),
        "w_o": dense_init(ks[7], (d, d)),
    }


def _rwkv_proj(cfg, p, x, x_prev):
    """Token-shift lerp + projections. x: (..., d); x_prev same shape."""
    mixed = [x + (x_prev - x) * p["mu"][i].astype(x.dtype) for i in range(5)]
    xr, xk, xv, xg, xw = mixed
    r = xr @ p["w_r"].astype(x.dtype)
    k = xk @ p["w_k"].astype(x.dtype)
    v = xv @ p["w_v"].astype(x.dtype)
    g = xg @ p["w_g"].astype(x.dtype)
    # data-dependent decay (the Finch contribution)
    lora = jnp.tanh(xw.astype(jnp.float32) @ p["w_lora_a"]) @ p["w_lora_b"]
    logw = -jnp.exp(jnp.clip(p["w0"] + lora, -20.0, 2.0))   # log(decay) in (-inf, 0)
    return r, k, v, g, logw


def _rwkv_out(cfg, p, y, g, B, S):
    """Head-group norm + gate + output proj. y: (B,S,h,K) fp32."""
    h, K = cfg.num_heads, cfg.head_dim
    mu = jnp.mean(y, -1, keepdims=True)
    var = jnp.var(y, -1, keepdims=True)
    y = (y - mu) * jax.lax.rsqrt(var + 64e-5)
    y = y.reshape(B, S, h * K) * p["ln_x_scale"] + p["ln_x_bias"]
    y = y * jax.nn.silu(g.astype(jnp.float32))
    return y.astype(g.dtype) @ p["w_o"].astype(g.dtype)


def rwkv_forward_naive(cfg, p, x):
    """Reference O(T) scan — oracle for the chunked path. x: (B,S,d)."""
    B, S, d = x.shape
    h, K = cfg.num_heads, cfg.head_dim
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, logw = _rwkv_proj(cfg, p, x, x_prev)
    rh = r.reshape(B, S, h, K).astype(jnp.float32)
    kh = k.reshape(B, S, h, K).astype(jnp.float32)
    vh = v.reshape(B, S, h, K).astype(jnp.float32)
    wh = jnp.exp(logw.reshape(B, S, h, K))
    u = p["u"]

    def step(S_state, inp):
        rt, kt, vt, wt = inp
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        yt = jnp.einsum("bhk,bhkv->bhv", rt, S_state + u[None, :, :, None] * kv)
        S_new = wt[..., None] * S_state + kv
        return S_new, yt

    S0 = jnp.zeros((B, h, K, K), jnp.float32)
    _, y = jax.lax.scan(step, S0,
                        (rh.transpose(1, 0, 2, 3), kh.transpose(1, 0, 2, 3),
                         vh.transpose(1, 0, 2, 3), wh.transpose(1, 0, 2, 3)))
    y = y.transpose(1, 0, 2, 3)                   # (B,S,h,K)
    return _rwkv_out(cfg, p, y, g, B, S)


def rwkv_forward(cfg, p, x, *, chunk=32, remat_groups=8, valid=None,
                 return_state=False):
    """Chunked-parallel WKV6 (matmul form), numerically safe: within-chunk
    decay factors are exp of non-positive sums. x: (B,S,d).

    ``valid`` (B,S) masks padding (identity state updates: w=1, k=0), so the
    final carry equals the state at the last valid token — the serving
    prefill path uses this (``return_state=True``) instead of the O(S)
    token scan (EXPERIMENTS.md §Perf iteration A)."""
    B, S, d = x.shape
    h, K = cfg.num_heads, cfg.head_dim
    if S % chunk != 0:
        assert not return_state and valid is None
        return rwkv_forward_naive(cfg, p, x)
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, g, logw = _rwkv_proj(cfg, p, x, x_prev)
    if valid is not None:
        logw = jnp.where(valid[..., None], logw, 0.0)
        k = jnp.where(valid[..., None], k, 0.0)
    nC = S // chunk
    # keep r/k/v in the compute dtype across the scan boundary — the
    # resharding collectives around the misaligned head dim then move half
    # the bytes (§Perf iteration A5); cast to f32 per-chunk inside the body.
    rs = r.reshape(B, nC, chunk, h, K)
    ks_ = k.reshape(B, nC, chunk, h, K)
    vs = v.reshape(B, nC, chunk, h, K)
    lw = logw.reshape(B, nC, chunk, h, K)
    u = p["u"]

    def chunk_body(S_state, inp):
        rc, kc, vc, lwc = inp                     # (B, c, h, K)
        rc = rc.astype(jnp.float32)
        kc = kc.astype(jnp.float32)
        vc = vc.astype(jnp.float32)
        L = jnp.cumsum(lwc, axis=1)               # inclusive logP_t
        Lprev = L - lwc                           # logP_{t-1}
        # inter-chunk: y_t += (r_t * exp(Lprev_t)) @ S_state
        q_in = rc * jnp.exp(Lprev)
        y = jnp.einsum("bchk,bhkv->bchv", q_in, S_state)
        # intra-chunk: decay_{t,s,k} = exp(Lprev_t - L_s) for s < t (<=0 safe)
        dec = Lprev[:, :, None] - L[:, None, :]   # (B, t, s, h, K)
        A = jnp.einsum("bthk,bshk,btshk->bhts", rc, ks_chunk_safe(kc),
                       jnp.exp(jnp.minimum(dec, 0.0)))
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
        A = A * tri
        y = y + jnp.einsum("bhts,bshv->bthv", A, vc)
        # current-token bonus
        y = y + jnp.einsum("bchk,bchk,bchv->bchv", rc, u[None, None] * kc, vc)
        # carry: S' = exp(L_end) S + sum_s exp(L_end - L_s) k_s v_s
        Lend = L[:, -1][:, None]                  # (B,1,h,K)
        kdec = kc * jnp.exp(Lend - L)
        S_new = jnp.exp(Lend[:, 0])[..., None] * S_state + \
            jnp.einsum("bshk,bshv->bhkv", kdec, vc)
        return S_new, y

    def ks_chunk_safe(kc):
        return kc

    # group chunks for remat: outer scan over groups, inner over chunks
    grp = max(1, nC // remat_groups)
    while nC % grp != 0:
        grp -= 1
    nG = nC // grp
    stack = lambda a: a.reshape(B, nG, grp, chunk, h, K).transpose(1, 2, 0, 3, 4, 5)
    seq = (stack(rs), stack(ks_), stack(vs), stack(lw))

    @jax.checkpoint
    def group_body(S_state, ginp):
        def inner(Si, ci):
            return chunk_body(Si, ci)
        S_out, ys = jax.lax.scan(inner, S_state, ginp)
        return S_out, ys

    S0 = jnp.zeros((B, h, K, K), jnp.float32)
    S_fin, y = jax.lax.scan(group_body, S0, seq)  # (nG, grp, B, chunk, h, K)
    y = y.transpose(2, 0, 1, 3, 4, 5).reshape(B, S, h, K)
    out = _rwkv_out(cfg, p, y, g, B, S)
    if return_state:
        return out, S_fin
    return out


def rwkv_step(cfg, p, x, state):
    """Single-token step. x: (B,d); state {"S": (B,h,K,K) f32, "shift": (B,d)}."""
    B, d = x.shape
    h, K = cfg.num_heads, cfg.head_dim
    r, k, v, g, logw = _rwkv_proj(cfg, p, x, state["shift"])
    rh = r.reshape(B, h, K).astype(jnp.float32)
    kh = k.reshape(B, h, K).astype(jnp.float32)
    vh = v.reshape(B, h, K).astype(jnp.float32)
    wh = jnp.exp(logw.reshape(B, h, K))
    kv = jnp.einsum("bhk,bhv->bhkv", kh, vh)
    y = jnp.einsum("bhk,bhkv->bhv", rh, state["S"] + p["u"][None, :, :, None] * kv)
    S_new = wh[..., None] * state["S"] + kv
    out = _rwkv_out(cfg, p, y[:, None], g[:, None], B, 1)[:, 0]
    return out, {"S": S_new, "shift": x}


def rwkv_init_state(cfg, B, dtype):
    h, K = cfg.num_heads, cfg.head_dim
    return {"S": jnp.zeros((B, h, K, K), jnp.float32),
            "shift": jnp.zeros((B, cfg.d_model), dtype)}


# ======================================================================
# FFN (dense + MoE)

def init_ffn(cfg, key, d_ff=None):
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = split_keys(key, 3)
    p = {"w1": dense_init(ks[0], (d, f)), "w2": dense_init(ks[1], (f, d))}
    if is_gated(cfg.ffn_act):
        p["w3"] = dense_init(ks[2], (d, f))
    return p


def ffn_forward(cfg, p, x):
    act = ffn_act_fn(cfg.ffn_act)
    a = x @ p["w1"].astype(x.dtype)
    b = x @ p["w3"].astype(x.dtype) if "w3" in p else None
    return act(a, b) @ p["w2"].astype(x.dtype)


def init_moe(cfg, key):
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = split_keys(key, 5)
    p = {
        "router": dense_init(ks[0], (d, E)),
        "w1": dense_init(ks[1], (E, d, f), in_axis=1),
        "w2": dense_init(ks[2], (E, f, d), in_axis=1),
    }
    if is_gated(cfg.ffn_act):
        p["w3"] = dense_init(ks[3], (E, d, f), in_axis=1)
    if cfg.num_shared_experts:
        p["shared"] = init_ffn(cfg, ks[4],
                               d_ff=cfg.moe_d_ff * cfg.num_shared_experts)
    return p


def moe_forward(cfg, p, x, *, capacity_factor=None, valid=None, groups=None):
    """Capacity-based top-k MoE (GShard-style dispatch). x: (B, S, d).

    Experts shard over the "model"/"expert" mesh axis (EP). Dispatch is
    computed per *group* (= data shard, via repro.models.moe_ctx): routing,
    capacity and the token gather then stay shard-local, so only the
    (G, E, C_local, d) dispatch buffers cross the mesh instead of an
    all-gather of the full activations (EXPERIMENTS.md §Perf iteration B).
    groups=1 is the plain single-group GShard dispatch. ``valid`` (B, S)
    masks padding tokens out of the capacity competition (serving path).
    """
    from repro.models import moe_ctx
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    G = groups if groups is not None else moe_ctx.dispatch_groups.get()
    if G < 1 or T % G != 0:
        G = 1
    Tg = T // G
    xt = x.reshape(T, d)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)        # (T, k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    C = int(np.ceil(Tg * k / E * capacity_factor))
    C = max(C, 4)
    flat_e = expert_ids.reshape(G, Tg * k)                 # token-major
    if valid is not None:
        vt = jnp.repeat(valid.reshape(-1), k).reshape(G, Tg * k)
        flat_e = jnp.where(vt, flat_e, E)                  # park on no expert
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (G, Tg*k, E)
    pos_in_e = (jnp.cumsum(onehot, axis=1) - 1)
    pos_in_e = jnp.sum(pos_in_e * onehot, axis=2)          # (G, Tg*k)
    keep = pos_in_e < C
    if valid is not None:
        keep = keep & vt
    slot = jnp.where(keep, flat_e * C + pos_in_e, E * C)   # (G, Tg*k)
    tok_local = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), k)[None], (G, Tg * k))
    # dispatch buffer of LOCAL token ids per group (G, E*C); pad row = Tg
    buf = jnp.full((G, E * C + 1), Tg, jnp.int32)
    buf = buf.at[jnp.arange(G)[:, None], slot].set(tok_local)
    buf_tok = buf[:, :E * C]
    xg = jnp.concatenate(
        [xt.reshape(G, Tg, d), jnp.zeros((G, 1, d), xt.dtype)], axis=1)
    xe = jnp.take_along_axis(xg, buf_tok[..., None], axis=1)  # (G, E*C, d)
    xe = xe.reshape(G, E, C, d)
    spec = moe_ctx.dispatch_spec.get()
    if spec is not None:
        xe = jax.lax.with_sharding_constraint(xe, spec)
    act = ffn_act_fn(cfg.ffn_act)
    a = jnp.einsum("gecd,edf->gecf", xe, p["w1"].astype(x.dtype))
    b = jnp.einsum("gecd,edf->gecf", xe, p["w3"].astype(x.dtype)) \
        if "w3" in p else None
    h = jnp.einsum("gecf,efd->gecd", act(a, b), p["w2"].astype(x.dtype))
    h = h.reshape(G, E * C, d)
    # combine: gather own contributions back per group, weighted by gates
    gflat = (gate_vals.reshape(G, Tg * k) * keep).astype(x.dtype)
    contrib = jnp.take_along_axis(
        h, jnp.where(keep, slot, 0)[..., None], axis=1)    # (G, Tg*k, d)
    contrib = jnp.where(keep[..., None], contrib * gflat[..., None], 0)
    y = jnp.zeros((G, Tg, d), x.dtype).at[
        jnp.arange(G)[:, None], tok_local].add(contrib)
    y = y.reshape(T, d)
    if "shared" in p:
        y = y + ffn_forward(cfg, p["shared"], xt)
    return y.reshape(B, S, d)


# ======================================================================
# layer init dispatch (one transformer block = mixer + ffn)

def init_layer(cfg, key, kind, ffn_kind, *, with_cross=False):
    ks = split_keys(key, 4)
    p = {"ln1": init_norm(cfg, cfg.d_model), "ln2": init_norm(cfg, cfg.d_model)}
    if kind == "attn":
        p["attn"] = init_mla(cfg, ks[0]) if cfg.attn_type == "mla" \
            else init_attn(cfg, ks[0])
    elif kind == "rglru":
        p["rglru"] = init_rglru(cfg, ks[0])
    elif kind == "rwkv":
        p["rwkv"] = init_rwkv(cfg, ks[0])
    else:
        raise ValueError(kind)
    if ffn_kind == "moe":
        p["moe"] = init_moe(cfg, ks[1])
    else:
        p["ffn"] = init_ffn(cfg, ks[1])
    if with_cross:
        p["ln_x"] = init_norm(cfg, cfg.d_model)
        p["cross"] = init_attn(cfg, ks[2], cross=True)
    return p
