"""``repro.serve`` — the OpenAI-compatible HTTP serving tier.

An asyncio front-end (hand-rolled ASGI 3 app, stdlib-only) over the
``repro.api`` async surface: continuous batching, SSE streaming,
bounded backpressure, per-client fairness and graceful drain.  See
docs/SERVING.md for the architecture and ``python -m repro.serve`` for
the CLI.
"""
from repro.serve.app import create_app  # noqa: F401
from repro.serve.config import ServeConfig  # noqa: F401
from repro.serve.state import ServerState  # noqa: F401

__all__ = ["create_app", "ServeConfig", "ServerState"]
