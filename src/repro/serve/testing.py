"""In-process ASGI client — drives the app with no sockets (CI-safe).

``ASGIClient.request`` runs one request/response cycle to completion;
``ASGIClient.stream`` returns a handle that exposes SSE events as they
arrive and can simulate a client disconnect mid-stream (the abort-path
races in tests/test_serve.py depend on that).
"""
from __future__ import annotations

import asyncio
import json as _json
from typing import AsyncIterator, List, Optional, Tuple


class Response:
    def __init__(self, status: int, headers: List[Tuple[bytes, bytes]],
                 body: bytes):
        self.status = status
        self.headers = {k.decode("latin-1").lower(): v.decode("latin-1")
                        for k, v in headers}
        self.body = body

    def json(self):
        return _json.loads(self.body)


class StreamHandle:
    """A streaming response in flight. Use as an async context manager;
    iterate ``events()`` for decoded SSE data payloads (the final
    ``[DONE]`` marker is yielded as the string ``"[DONE]"``)."""

    def __init__(self, client: "ASGIClient", scope: dict, body: bytes):
        self._client = client
        self._scope = scope
        self._request_body = body
        self._in: asyncio.Queue = asyncio.Queue()
        self._out: asyncio.Queue = asyncio.Queue()
        self._task: Optional[asyncio.Task] = None
        self._buffer = b""
        self._pending: List[dict] = []
        self._closed = False
        self.status: Optional[int] = None
        self.headers: dict = {}

    async def __aenter__(self) -> "StreamHandle":
        self._in.put_nowait({"type": "http.request",
                             "body": self._request_body,
                             "more_body": False})
        self._task = asyncio.create_task(
            self._client.app(self._scope, self._in.get, self._send))
        return self

    async def started(self) -> "StreamHandle":
        """Wait for the response head (status + headers). Not awaited by
        disconnect-before-response tests — entering the context does not
        block on the app."""
        while self.status is None:
            msg = await self._next_message()
            if msg["type"] == "http.response.start":
                self.status = msg["status"]
                self.headers = {
                    k.decode("latin-1").lower(): v.decode("latin-1")
                    for k, v in msg.get("headers", [])}
            else:
                self._pending.append(msg)
        return self

    async def __aexit__(self, *exc):
        if not self._task.done():
            self.disconnect()
            try:
                await asyncio.wait_for(asyncio.shield(self._task), 5)
            except (asyncio.TimeoutError, Exception):
                self._task.cancel()
        else:
            self._task.result()      # surface app exceptions

    async def _send(self, msg):
        self._out.put_nowait(msg)

    async def _next_message(self) -> dict:
        get = asyncio.ensure_future(self._out.get())
        done, _ = await asyncio.wait(
            {get, self._task}, return_when=asyncio.FIRST_COMPLETED)
        if get in done:
            return get.result()
        get.cancel()
        self._task.result()          # raises the app's exception
        raise RuntimeError("app exited without completing the response")

    def disconnect(self):
        """Simulate the client going away: the app's ``receive`` yields
        ``http.disconnect`` next."""
        if not self._closed:
            self._closed = True
            self._in.put_nowait({"type": "http.disconnect"})

    async def events(self) -> AsyncIterator:
        """Decoded SSE payloads in arrival order; ends after [DONE] or
        once the app closes the body."""
        await self.started()
        ended = False
        while not ended:
            msg = (self._pending.pop(0) if self._pending
                   else await self._next_message())
            if msg["type"] != "http.response.body":
                continue
            self._buffer += msg.get("body", b"")
            ended = not msg.get("more_body", False)
            while b"\n\n" in self._buffer:
                frame, self._buffer = self._buffer.split(b"\n\n", 1)
                for line in frame.split(b"\n"):
                    if not line.startswith(b"data: "):
                        continue
                    data = line[6:]
                    if data == b"[DONE]":
                        yield "[DONE]"
                        return
                    yield _json.loads(data)


class ASGIClient:
    def __init__(self, app):
        self.app = app

    def _scope(self, method: str, path: str, headers) -> dict:
        hdrs = [(k.lower().encode("latin-1"), v.encode("latin-1"))
                for k, v in (headers or {}).items()]
        return {"type": "http", "asgi": {"version": "3.0"},
                "http_version": "1.1", "method": method.upper(),
                "scheme": "http", "path": path, "raw_path": path.encode(),
                "query_string": b"", "headers": hdrs,
                "client": ("testclient", 0), "server": ("test", 80)}

    async def request(self, method: str, path: str, *, json=None,
                      body: bytes = b"", headers=None) -> Response:
        if json is not None:
            body = _json.dumps(json).encode()
            headers = dict(headers or {})
            headers.setdefault("content-type", "application/json")
        received = {"sent": False}

        async def receive():
            if not received["sent"]:
                received["sent"] = True
                return {"type": "http.request", "body": body,
                        "more_body": False}
            await asyncio.Event().wait()   # park until app completes

        messages: List[dict] = []

        async def send(msg):
            messages.append(msg)

        await self.app(self._scope(method, path, headers), receive, send)
        start = next(m for m in messages
                     if m["type"] == "http.response.start")
        payload = b"".join(m.get("body", b"") for m in messages
                           if m["type"] == "http.response.body")
        return Response(start["status"], start.get("headers", []),
                        payload)

    def stream(self, method: str, path: str, *, json=None,
               headers=None) -> StreamHandle:
        body = _json.dumps(json).encode() if json is not None else b""
        headers = dict(headers or {})
        headers.setdefault("content-type", "application/json")
        return StreamHandle(self, self._scope(method, path, headers),
                            body)
