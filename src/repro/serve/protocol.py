"""OpenAI wire protocol: request parsing/validation and response shaping.

The repo has no tokenizer — requests carry token ids directly, either as
JSON integer lists or as whitespace-separated integer strings ("1 2 3"),
and response ``text`` renders ids back as the same string form
(docs/SERVING.md "Token codec"). Everything else follows the OpenAI
completions/chat schema closely enough that off-the-shelf clients work
once their tokenizer step is bypassed.

Validation is strict and actionable: unknown body fields get a
did-you-mean 400 (mirroring ``SamplingParams``' own kwarg checking),
and engine-capacity violations (prompt too long, cap exceeded) are
rejected here — before admission — so a malformed request can never
trip an assertion inside the background engine loop.
"""
from __future__ import annotations

import difflib
import json
from typing import Dict, List, Optional, Sequence, Tuple

from repro.api import SamplingParams, UsageInfo


class ProtocolError(Exception):
    """Maps to an OpenAI-style 400 error body."""

    def __init__(self, message: str, param: Optional[str] = None,
                 status: int = 400):
        super().__init__(message)
        self.message = message
        self.param = param
        self.status = status


def error_body(message: str, *, err_type: str = "invalid_request_error",
               param: Optional[str] = None, code: Optional[str] = None
               ) -> dict:
    return {"error": {"message": message, "type": err_type,
                      "param": param, "code": code}}


# ----------------------------------------------------------------------
# token codec

def parse_token_ids(value, field: str) -> List[int]:
    """Accept a token-id list or a whitespace-separated int string."""
    if isinstance(value, str):
        try:
            ids = [int(t) for t in value.split()]
        except ValueError:
            raise ProtocolError(
                f"'{field}' must be token ids: a list of ints or a "
                f"whitespace-separated int string (got {value!r})",
                param=field) from None
    elif isinstance(value, (list, tuple)) \
            and all(isinstance(t, int) and not isinstance(t, bool)
                    for t in value):
        ids = list(value)
    else:
        raise ProtocolError(
            f"'{field}' must be a list of token ids or a whitespace-"
            "separated int string", param=field)
    if not ids:
        raise ProtocolError(f"'{field}' must not be empty", param=field)
    return ids


def render_text(ids: Sequence[int]) -> str:
    return " ".join(str(i) for i in ids)


# ----------------------------------------------------------------------
# request models

_COMMON_FIELDS = (
    "model", "max_tokens", "temperature", "top_p", "top_k", "seed",
    "stop", "stream", "stream_options", "n", "logprobs", "user",
)
COMPLETION_FIELDS = _COMMON_FIELDS + ("prompt",)
CHAT_FIELDS = _COMMON_FIELDS + ("messages",)


def _check_fields(body: dict, known: Tuple[str, ...], endpoint: str):
    unknown = [k for k in body if k not in known]
    if not unknown:
        return
    hints = []
    for k in unknown:
        close = difflib.get_close_matches(k, known, n=1)
        hints.append(f"{k!r}" + (f" (did you mean {close[0]!r}?)"
                                 if close else ""))
    raise ProtocolError(
        f"unknown field(s) for {endpoint}: {', '.join(hints)}; known "
        f"fields: {', '.join(known)}", param=unknown[0])


def _parse_stop(value) -> Tuple[Tuple[int, ...], ...]:
    if value is None:
        return ()
    if isinstance(value, str) or (isinstance(value, (list, tuple))
                                  and value
                                  and isinstance(value[0], int)):
        value = [value]
    return tuple(tuple(parse_token_ids(s, "stop")) for s in value)


class CompletionRequest:
    """A validated /v1/completions (or chat) request, engine-ready."""

    def __init__(self, prompt: List[int], params: SamplingParams,
                 *, model: str, stream: bool, include_usage: bool,
                 echo_chat: bool, client_hint: Optional[str]):
        self.prompt = prompt
        self.params = params
        self.model = model
        self.stream = stream
        self.include_usage = include_usage
        self.chat = echo_chat           # shape the response as chat.*
        self.client_hint = client_hint  # body "user" field, if any

    @classmethod
    def from_body(cls, body, *, chat: bool) -> "CompletionRequest":
        if not isinstance(body, dict):
            raise ProtocolError("request body must be a JSON object")
        endpoint = ("/v1/chat/completions" if chat else "/v1/completions")
        _check_fields(body, CHAT_FIELDS if chat else COMPLETION_FIELDS,
                      endpoint)
        if chat:
            prompt = _prompt_from_messages(body.get("messages"))
        else:
            if "prompt" not in body:
                raise ProtocolError("'prompt' is required", param="prompt")
            prompt = parse_token_ids(body["prompt"], "prompt")

        kwargs = {}
        for k in ("max_tokens", "temperature", "top_p", "top_k",
                  "seed", "n"):
            if body.get(k) is not None:
                kwargs[k] = body[k]
        if body.get("stop") is not None:
            kwargs["stop"] = _parse_stop(body["stop"])
        if body.get("logprobs"):
            kwargs["logprobs"] = True
        try:
            params = SamplingParams(**kwargs)
        except (TypeError, ValueError) as e:
            raise ProtocolError(str(e)) from None

        stream = bool(body.get("stream", False))
        opts = body.get("stream_options") or {}
        if not isinstance(opts, dict):
            raise ProtocolError("'stream_options' must be an object",
                                param="stream_options")
        include_usage = bool(opts.get("include_usage", False))
        user = body.get("user")
        if user is not None and not isinstance(user, str):
            raise ProtocolError("'user' must be a string", param="user")
        return cls(prompt, params, model=str(body.get("model", "")),
                   stream=stream, include_usage=include_usage,
                   echo_chat=chat, client_hint=user)

    def check_capacity(self, *, vocab_size: int, max_model_len: int,
                       max_tokens_limit: Optional[int]):
        """Engine-capacity validation, done before admission so a bad
        request 400s instead of tripping engine assertions."""
        bad = [t for t in self.prompt if not 0 <= t < vocab_size]
        if bad:
            raise ProtocolError(
                f"prompt token id {bad[0]} outside the model vocabulary "
                f"[0, {vocab_size})", param="prompt")
        if max_tokens_limit is not None \
                and self.params.max_new_tokens > max_tokens_limit:
            raise ProtocolError(
                f"max_tokens={self.params.max_new_tokens} exceeds this "
                f"server's limit of {max_tokens_limit}",
                param="max_tokens")
        total = len(self.prompt) + self.params.max_new_tokens
        if total > max_model_len:
            raise ProtocolError(
                f"prompt ({len(self.prompt)} tokens) + max_tokens "
                f"({self.params.max_new_tokens}) = {total} exceeds "
                f"max_model_len={max_model_len}", param="max_tokens")


def _prompt_from_messages(messages) -> List[int]:
    if not isinstance(messages, list) or not messages:
        raise ProtocolError("'messages' must be a non-empty array",
                            param="messages")
    prompt: List[int] = []
    for i, m in enumerate(messages):
        if not isinstance(m, dict) or "role" not in m \
                or "content" not in m:
            raise ProtocolError(
                f"messages[{i}] must be an object with 'role' and "
                "'content'", param="messages")
        if m["role"] not in ("system", "user", "assistant"):
            raise ProtocolError(
                f"messages[{i}].role must be system|user|assistant",
                param="messages")
        # no tokenizer: message contents are token ids and the chat
        # template is plain concatenation in message order
        prompt.extend(parse_token_ids(m["content"],
                                      f"messages[{i}].content"))
    return prompt


# ----------------------------------------------------------------------
# response shaping

def usage_dict(usage: Optional[UsageInfo]) -> Optional[dict]:
    if usage is None:
        return None
    return {"prompt_tokens": usage.prompt_tokens,
            "completion_tokens": usage.completion_tokens,
            "total_tokens": usage.total_tokens}


def completion_response(req: CompletionRequest, out, created: int) -> dict:
    """Final (non-streaming) response for either endpoint."""
    if req.chat:
        choice = {"index": 0,
                  "message": {"role": "assistant",
                              "content": render_text(out.token_ids),
                              "token_ids": list(out.token_ids)},
                  "finish_reason": out.finish_reason}
        obj = "chat.completion"
    else:
        choice = {"index": 0, "text": render_text(out.token_ids),
                  "token_ids": list(out.token_ids),
                  "finish_reason": out.finish_reason}
        obj = "text_completion"
    return {"id": f"cmpl-{out.request_id}", "object": obj,
            "created": created, "model": req.model,
            "choices": [choice], "usage": usage_dict(out.usage)}


def chunk_payload(req: CompletionRequest, rid: int, token_ids,
                  finish_reason: Optional[str], created: int,
                  *, first: bool) -> dict:
    """One SSE data payload for a streamed delta."""
    if req.chat:
        delta: Dict[str, object] = {}
        if first:
            delta["role"] = "assistant"
        if token_ids:
            delta["content"] = render_text(token_ids)
            delta["token_ids"] = list(token_ids)
        choice = {"index": 0, "delta": delta,
                  "finish_reason": finish_reason}
        obj = "chat.completion.chunk"
    else:
        choice = {"index": 0, "text": render_text(token_ids),
                  "token_ids": list(token_ids),
                  "finish_reason": finish_reason}
        obj = "text_completion"
    return {"id": f"cmpl-{rid}", "object": obj, "created": created,
            "model": req.model, "choices": [choice]}


def usage_chunk_payload(req: CompletionRequest, rid: int,
                        usage: Optional[UsageInfo], created: int) -> dict:
    """OpenAI stream_options.include_usage: a final chunk with empty
    choices carrying the usage record."""
    return {"id": f"cmpl-{rid}",
            "object": ("chat.completion.chunk" if req.chat
                       else "text_completion"),
            "created": created, "model": req.model, "choices": [],
            "usage": usage_dict(usage)}


def dumps(payload: dict) -> bytes:
    return json.dumps(payload, separators=(",", ":")).encode()
