"""CLI entry point: ``python -m repro.serve --model tiny-lm --port 8000``.

Brings up the engine, hosts the ASGI app on the stdlib HTTP bridge and
wires SIGTERM/SIGINT to graceful drain: intake closes (new requests get
503), running requests finish and flush their streams, then the process
exits.
"""
from __future__ import annotations

import argparse
import asyncio
import signal

from repro.serve.app import create_app
from repro.serve.config import ServeConfig
from repro.serve.http import run_server


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="OpenAI-compatible serving tier for the Zipage engine")
    p.add_argument("--model", default="tiny-lm")
    p.add_argument("--full-size", action="store_true",
                   help="use the full architecture (default: reduced)")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8000)
    p.add_argument("--max-queued-requests", type=int, default=64)
    p.add_argument("--max-tokens-limit", type=int, default=512)
    p.add_argument("--no-fairness", action="store_true")
    p.add_argument("--policy", default="priority",
                   help="scheduler admission policy (priority enables "
                        "per-client fairness)")
    p.add_argument("--override", action="append", default=[],
                   metavar="KEY=VALUE",
                   help="flat engine-config override, repeatable "
                        "(e.g. --override n_total_blocks=128)")
    return p


def _parse_overrides(pairs) -> dict:
    out = {}
    for pair in pairs:
        key, _, value = pair.partition("=")
        if not _ or not key:
            raise SystemExit(f"--override expects KEY=VALUE, got {pair!r}")
        try:
            out[key] = int(value)
        except ValueError:
            try:
                out[key] = float(value)
            except ValueError:
                out[key] = {"true": True, "false": False,
                            "none": None}.get(value.lower(), value)
    return out


def config_from_args(args) -> ServeConfig:
    return ServeConfig(
        model=args.model, reduce=not args.full_size, host=args.host,
        port=args.port, max_queued_requests=args.max_queued_requests,
        max_tokens_limit=args.max_tokens_limit,
        fairness=not args.no_fairness, policy=args.policy,
        engine_overrides=_parse_overrides(args.override))


async def amain(config: ServeConfig) -> None:
    app = create_app(config)
    loop = asyncio.get_running_loop()
    server_task = asyncio.create_task(
        run_server(app, config.host, config.port))
    stop = asyncio.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        loop.add_signal_handler(sig, stop.set)
    print(f"repro.serve: listening on http://{config.host}:{config.port} "
          f"(model={config.model}, max_queued={config.max_queued_requests})")
    await stop.wait()
    print("repro.serve: draining (finishing running requests, "
          "rejecting new ones)...")
    await app.state.drain()               # graceful: flush, then stop
    server_task.cancel()
    try:
        await server_task
    except asyncio.CancelledError:
        pass
    print("repro.serve: drained, bye")


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    asyncio.run(amain(config_from_args(args)))
    return 0
