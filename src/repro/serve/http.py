"""Minimal asyncio HTTP/1.1 host for the ASGI app (stdlib-only).

The container ships no ASGI server, so ``python -m repro.serve`` hosts
the app on a tiny HTTP/1.1 bridge: one request per connection
(``Connection: close``), chunked transfer for streaming responses, and
connection-EOF surfaced as ``http.disconnect`` so client hang-ups abort
their requests. Production deployments would mount ``create_app()`` on
a real ASGI server instead; CI never opens a socket (tests and
``bench_serving`` use ``repro.serve.testing.ASGIClient``).
"""
from __future__ import annotations

import asyncio

MAX_HEADER_BYTES = 64 * 1024
MAX_BODY_BYTES = 8 * 1024 * 1024

REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
           405: "Method Not Allowed", 429: "Too Many Requests",
           500: "Internal Server Error", 503: "Service Unavailable"}


async def _handle(app, reader: asyncio.StreamReader,
                  writer: asyncio.StreamWriter):
    try:
        head = await reader.readuntil(b"\r\n\r\n")
    except (asyncio.IncompleteReadError, asyncio.LimitOverrunError):
        writer.close()
        return
    lines = head.decode("latin-1").split("\r\n")
    try:
        method, target, _version = lines[0].split(" ", 2)
    except ValueError:
        writer.close()
        return
    headers = []
    for line in lines[1:]:
        if ":" in line:
            k, v = line.split(":", 1)
            headers.append((k.strip().lower().encode("latin-1"),
                            v.strip().encode("latin-1")))
    length = int(dict(headers).get(b"content-length", b"0"))
    body = await reader.readexactly(min(length, MAX_BODY_BYTES)) \
        if length else b""
    path, _, query = target.partition("?")
    scope = {"type": "http", "asgi": {"version": "3.0"},
             "http_version": "1.1", "method": method, "scheme": "http",
             "path": path, "raw_path": path.encode("latin-1"),
             "query_string": query.encode("latin-1"), "headers": headers,
             "client": writer.get_extra_info("peername"),
             "server": writer.get_extra_info("sockname")}

    sent_body = False

    async def receive():
        nonlocal sent_body
        if not sent_body:
            sent_body = True
            return {"type": "http.request", "body": body,
                    "more_body": False}
        # after the body, the only further event is the peer closing the
        # connection — a read returning EOF means the client went away
        try:
            data = await reader.read(1)
        except ConnectionError:
            data = b""
        if data == b"":
            return {"type": "http.disconnect"}
        return {"type": "http.disconnect"}   # pipelining unsupported

    started = False

    async def send(msg):
        nonlocal started
        if msg["type"] == "http.response.start":
            started = True
            status = msg["status"]
            reason = REASONS.get(status, "Unknown")
            hdrs = list(msg.get("headers", []))
            names = {k.lower() for k, _ in hdrs}
            if b"content-length" not in names:
                hdrs.append((b"transfer-encoding", b"chunked"))
            hdrs.append((b"connection", b"close"))
            writer.write(f"HTTP/1.1 {status} {reason}\r\n".encode())
            for k, v in hdrs:
                writer.write(k + b": " + v + b"\r\n")
            writer.write(b"\r\n")
            send.chunked = b"transfer-encoding" not in names \
                and b"content-length" not in names
        elif msg["type"] == "http.response.body":
            data = msg.get("body", b"")
            if getattr(send, "chunked", False):
                if data:
                    writer.write(f"{len(data):x}\r\n".encode() + data
                                 + b"\r\n")
                if not msg.get("more_body", False):
                    writer.write(b"0\r\n\r\n")
            else:
                writer.write(data)
            await writer.drain()
        else:
            raise RuntimeError(f"unexpected ASGI message {msg['type']!r}")

    try:
        await app(scope, receive, send)
    except ConnectionError:
        pass
    except Exception:
        if not started:
            writer.write(b"HTTP/1.1 500 Internal Server Error\r\n"
                         b"content-length: 0\r\nconnection: close\r\n"
                         b"\r\n")
        raise
    finally:
        try:
            writer.close()
            await writer.wait_closed()
        except ConnectionError:
            pass


async def run_server(app, host: str, port: int,
                     ready: asyncio.Event = None) -> None:
    """Serve until cancelled (the CLI wires SIGTERM/SIGINT to drain)."""
    server = await asyncio.start_server(
        lambda r, w: _handle(app, r, w), host, port)
    if ready is not None:
        ready.set()
    async with server:
        await server.serve_forever()
