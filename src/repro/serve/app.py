"""The ASGI application: OpenAI endpoints over the background engine loop.

Hand-rolled ASGI 3 (stdlib-only — the container ships no web framework);
any ASGI server can host it, the bundled ``repro.serve.http`` bridge and
``repro.serve.testing.ASGIClient`` being the two in-repo hosts.

Request lifecycle (docs/SERVING.md):

  parse/validate (400) -> fairness priority -> admit
    -> saturated?  429 + Retry-After (load-aware estimate)
    -> draining?   503
    -> stream? SSE frames per engine step, [DONE] terminator
    -> else await the final snapshot, one JSON body

A client disconnect at any point after admission aborts the request —
its slot and blocks return to the pool immediately.
"""
from __future__ import annotations

import asyncio
import json
import math
import time
from typing import Optional

from repro.api.aio import EngineDraining, EngineSaturated
from repro.serve import protocol, streaming
from repro.serve.config import ServeConfig
from repro.serve.protocol import CompletionRequest, ProtocolError
from repro.serve.state import ServerState

JSON_HEADERS = ((b"content-type", b"application/json"),)


async def _send_json(send, status: int, payload: dict, headers=()):
    body = protocol.dumps(payload)
    await send({"type": "http.response.start", "status": status,
                "headers": list(JSON_HEADERS) + list(headers)
                + [(b"content-length", str(len(body)).encode())]})
    await send({"type": "http.response.body", "body": body})


async def _read_body(receive) -> Optional[bytes]:
    """Drain the request body; None if the client already disconnected."""
    chunks = []
    while True:
        msg = await receive()
        if msg["type"] == "http.disconnect":
            return None
        chunks.append(msg.get("body", b""))
        if not msg.get("more_body", False):
            return b"".join(chunks)


async def _watch_disconnect(receive):
    while True:
        msg = await receive()
        if msg["type"] == "http.disconnect":
            return


class ASGIApp:
    """The OpenAI-compatible app. ``app.state`` exposes the engine loop
    to in-process hosts (tests, ``bench_serving``, the CLI)."""

    def __init__(self, state: ServerState):
        self.state = state

    async def __call__(self, scope, receive, send):
        if scope["type"] == "lifespan":
            await self._lifespan(receive, send)
            return
        if scope["type"] != "http":
            raise RuntimeError(f"unsupported scope {scope['type']!r}")
        method, path = scope["method"], scope["path"]
        if path == "/health" and method == "GET":
            stats = self.state.stats()
            await _send_json(send, 503 if stats["draining"] else 200,
                             stats)
        elif path == "/v1/models" and method == "GET":
            await _send_json(send, 200, {"object": "list", "data": [
                {"id": self.state.config.model, "object": "model",
                 "owned_by": "zipage"}]})
        elif path in ("/v1/completions", "/v1/chat/completions"):
            if method != "POST":
                await _send_json(send, 405, protocol.error_body(
                    f"method {method} not allowed; POST only"))
                return
            await self._completions(scope, receive, send,
                                    chat=path.endswith("chat/completions"))
        else:
            await _send_json(send, 404, protocol.error_body(
                f"no route for {method} {path}", code="not_found"))

    async def _lifespan(self, receive, send):
        while True:
            msg = await receive()
            if msg["type"] == "lifespan.startup":
                await send({"type": "lifespan.startup.complete"})
            elif msg["type"] == "lifespan.shutdown":
                await self.state.drain()
                await send({"type": "lifespan.shutdown.complete"})
                return

    # ------------------------------------------------------------------
    def _client_id(self, scope, req: CompletionRequest) -> str:
        headers = {k.decode("latin-1").lower(): v.decode("latin-1")
                   for k, v in scope.get("headers", [])}
        auth = headers.get("authorization", "")
        if auth.lower().startswith("bearer "):
            return auth[7:].strip()
        return (headers.get("x-client-id") or req.client_hint
                or "anonymous")

    async def _completions(self, scope, receive, send, *, chat: bool):
        state = self.state
        body = await _read_body(receive)
        if body is None:
            return                         # gone before we even parsed
        try:
            try:
                parsed = json.loads(body or b"null")
            except ValueError:
                raise ProtocolError("request body is not valid JSON") \
                    from None
            req = CompletionRequest.from_body(parsed, chat=chat)
            state.validate(req)
        except ProtocolError as e:
            await _send_json(send, e.status, protocol.error_body(
                e.message, param=e.param))
            return

        client = self._client_id(scope, req)
        created = int(time.time())
        try:
            rid = await state.admit(req, client)
        except EngineSaturated as e:
            retry = max(1, math.ceil(e.retry_after))
            await _send_json(
                send, 429, protocol.error_body(
                    str(e), err_type="rate_limit_error",
                    code="engine_saturated"),
                headers=[(b"retry-after", str(retry).encode())])
            return
        except EngineDraining:
            await _send_json(send, 503, protocol.error_body(
                "server is draining; retry against another replica",
                err_type="service_unavailable", code="draining"))
            return

        watcher = asyncio.create_task(_watch_disconnect(receive))
        try:
            if req.stream:
                await self._stream_response(send, req, rid, created,
                                            watcher)
            else:
                await self._unary_response(send, req, rid, created,
                                           watcher)
        finally:
            watcher.cancel()
            state.release(client)

    async def _unary_response(self, send, req, rid, created, watcher):
        state = self.state

        async def last_output():
            final = None
            async for out in state.loop.stream_outputs(rid):
                final = out
            return final

        result = asyncio.create_task(last_output())
        done, _ = await asyncio.wait({result, watcher},
                                     return_when=asyncio.FIRST_COMPLETED)
        if result not in done:             # client went away: reclaim
            result.cancel()
            await state.loop.abort(rid)
            return
        await _send_json(send, 200, protocol.completion_response(
            req, result.result(), created))

    async def _stream_response(self, send, req, rid, created, watcher):
        await send({"type": "http.response.start", "status": 200,
                    "headers": list(streaming.SSE_HEADERS)})
        gen = streaming.sse_events(self.state, req, rid, created)
        try:
            while True:
                nxt = asyncio.create_task(anext(gen))
                done, _ = await asyncio.wait(
                    {nxt, watcher}, return_when=asyncio.FIRST_COMPLETED)
                if nxt not in done:        # disconnect mid-stream
                    nxt.cancel()
                    await self.state.loop.abort(rid)
                    return
                try:
                    data = nxt.result()
                except StopAsyncIteration:
                    break
                await send({"type": "http.response.body", "body": data,
                            "more_body": True})
            await send({"type": "http.response.body", "body": b""})
        finally:
            await gen.aclose()


def create_app(config: Optional[ServeConfig] = None,
               zipage=None) -> ASGIApp:
    """Build the serving app. ``zipage`` lets tests/benchmarks inject a
    pre-built facade (skipping model bring-up); otherwise the engine is
    constructed from ``config``."""
    return ASGIApp(ServerState(config or ServeConfig(), zipage))
