"""Server state: the engine, its background loop, and admission policy.

``ServerState`` is the seam between the protocol layer and the engine:
it owns the ``Zipage`` facade, the ``AsyncEngineLoop`` driving it, and
the fairness ledger, and exposes exactly the operations the ASGI app
needs — validated admission, streaming, abort, drain, stats.
"""
from __future__ import annotations

from typing import Optional

from repro.api import Zipage
from repro.api.aio import AsyncEngineLoop
from repro.serve.config import ServeConfig
from repro.serve.fairness import ClientFairness
from repro.serve.protocol import CompletionRequest


class ServerState:
    def __init__(self, config: ServeConfig,
                 zipage: Optional[Zipage] = None):
        self.config = config
        if zipage is None:
            zipage = Zipage.from_config(
                config.model, reduce=config.reduce,
                policy=config.policy, **config.engine_overrides)
        self.zipage = zipage
        self.loop = AsyncEngineLoop(
            zipage, max_queued_requests=config.max_queued_requests)
        self.fairness = ClientFairness() if config.fairness else None

    # ------------------------------------------------------------------
    @property
    def vocab_size(self) -> int:
        return self.zipage.cfg.vocab_size

    @property
    def max_model_len(self) -> int:
        return self.zipage.engine.opts.max_model_len

    def validate(self, req: CompletionRequest) -> None:
        req.check_capacity(
            vocab_size=self.vocab_size,
            max_model_len=self.max_model_len,
            max_tokens_limit=self.config.max_tokens_limit)

    async def admit(self, req: CompletionRequest, client: str) -> int:
        """Admit a validated request; returns its request id.

        Raises ``EngineSaturated`` / ``EngineDraining`` (mapped to
        429 / 503 by the app). Fairness accounting is undone by
        ``release()`` when the request's stream closes.
        """
        priority = self.fairness.admit(client) if self.fairness else 0
        try:
            return await self.loop.add_request(
                req.prompt, req.params, priority=priority)
        except BaseException:
            if self.fairness:
                self.fairness.release(client)
            raise

    def release(self, client: str) -> None:
        if self.fairness:
            self.fairness.release(client)

    async def drain(self) -> None:
        await self.loop.drain()

    def stats(self) -> dict:
        eng = self.zipage.engine
        return {
            "draining": self.loop.draining,
            "backlog": self.loop.backlog,
            "max_queued_requests": self.loop.max_queued_requests,
            "n_running": len(eng.running),
            "n_waiting": len(eng.waiting),
            "free_blocks": eng.bm.num_free,
            "step_count": eng.step_count,
            "clients_inflight": (self.fairness.snapshot()
                                 if self.fairness else {}),
        }
