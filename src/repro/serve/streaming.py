"""Server-sent events over the engine's per-request output stream.

``sse_events`` adapts ``AsyncEngineLoop.stream_outputs`` to the OpenAI
SSE wire format: one ``data: {json}\\n\\n`` frame per engine step that
grew the request, ``finish_reason`` on the terminal frame, an optional
trailing usage frame (``stream_options.include_usage``), then the
literal ``data: [DONE]`` terminator.
"""
from __future__ import annotations

from typing import AsyncIterator

from repro.serve import protocol

SSE_HEADERS = ((b"content-type", b"text/event-stream; charset=utf-8"),
               (b"cache-control", b"no-cache"),
               (b"connection", b"keep-alive"))
DONE_FRAME = b"data: [DONE]\n\n"


def frame(payload: dict) -> bytes:
    return b"data: " + protocol.dumps(payload) + b"\n\n"


async def sse_events(state, req: protocol.CompletionRequest, rid: int,
                     created: int) -> AsyncIterator[bytes]:
    """Yield SSE frames for one admitted request until it finishes."""
    first = True
    usage = None
    async for out in state.loop.stream_outputs(rid):
        chunk = out.chunk
        tokens = chunk.token_ids if chunk is not None else []
        reason = out.finish_reason if out.finished else None
        if chunk is not None and chunk.usage is not None:
            usage = chunk.usage
        elif out.finished:
            usage = out.usage
        if not tokens and not out.finished and not first:
            continue                      # empty intermediate: drop
        yield frame(protocol.chunk_payload(
            req, rid, tokens, reason, created, first=first))
        first = False
    if req.include_usage:
        yield frame(protocol.usage_chunk_payload(req, rid, usage, created))
    yield DONE_FRAME
