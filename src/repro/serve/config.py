"""Server-tier configuration (everything the HTTP layer owns).

Engine-side knobs stay in the ``repro.api`` config split; ``ServeConfig``
only holds what the serving tier itself decides: the model to bring up,
intake bounds, fairness, and the bind address.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Configuration for ``repro.serve`` (docs/SERVING.md)."""
    model: str = "tiny-lm"           # architecture name (repro.configs)
    reduce: bool = True              # family-preserving tiny config
    host: str = "127.0.0.1"
    port: int = 8000
    # waiting-backlog bound: intake + scheduler waiting queue; beyond it
    # add_request raises EngineSaturated -> HTTP 429 + Retry-After
    max_queued_requests: int = 64
    # per-client fairness: map client identity (Authorization bearer key,
    # x-client-id, or body "user") onto Request.priority = -inflight so
    # the "priority" scheduler policy round-robins across clients
    fairness: bool = True
    # scheduler admission policy the engine is built with (fairness wants
    # "priority"; see SchedulerConfig.policy for the full list)
    policy: str = "priority"
    # hard per-request output cap the protocol enforces before admission
    # (None = bounded only by max_model_len)
    max_tokens_limit: Optional[int] = 512
    # flat engine-config overrides routed through the repro.api config
    # split at bring-up, e.g. {"block_size": 8, "n_total_blocks": 64}
    engine_overrides: dict = dataclasses.field(default_factory=dict)
