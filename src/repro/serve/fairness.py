"""Per-client fairness: map client identity onto scheduler priority.

One aggressive client must not starve the rest. Each admission is
tagged ``priority = -inflight(client)`` (the count *before* this
request), so under the engine's "priority" admission policy a client's
second queued request sorts behind every other client's first — an
approximate least-loaded round-robin with zero new scheduler machinery
(docs/SERVING.md "Fairness").
"""
from __future__ import annotations

from typing import Dict


class ClientFairness:
    def __init__(self):
        self._inflight: Dict[str, int] = {}

    def admit(self, client: str) -> int:
        """Account an admission; returns the priority for this request."""
        n = self._inflight.get(client, 0)
        self._inflight[client] = n + 1
        return -n

    def release(self, client: str) -> None:
        n = self._inflight.get(client, 0) - 1
        if n <= 0:
            self._inflight.pop(client, None)
        else:
            self._inflight[client] = n

    def inflight(self, client: str) -> int:
        return self._inflight.get(client, 0)

    def snapshot(self) -> Dict[str, int]:
        return dict(self._inflight)
