import sys

from repro.serve.cli import main

sys.exit(main())
