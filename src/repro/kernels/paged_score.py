"""Pallas TPU kernel: paged observation-window attention logits (paper Alg. 1).

Computes per-page logit tiles A' = Q_win · K_page^T / sqrt(d) with the
last-block causal mask, exactly as the paper stores them (App. C.2: logits
are materialized contiguously, then softmax/GQA-max/window-mean run on the
dense layout — those reductions are in ops.py). One grid step = one page DMA,
one (g·w × d)·(d × b) MXU product.

Grid: (n, h_kv, max_blocks).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat

NEG_INF = -1e30


def _kernel(block_tables, seq_lens,          # scalar prefetch
            q_ref, k_ref, o_ref, *, block_size, scale, window):
    ib = pl.program_id(0)
    i = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32)      # (g*w, d)
    k = k_ref[0, :, 0].astype(jnp.float32)   # (b, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # causal mask: query u sits at cache pos seq_len - w + u
    gw = s.shape[0]
    u = jax.lax.broadcasted_iota(jnp.int32, (gw, block_size), 0) % window
    qpos = seq_lens[ib] - window + u
    kpos = i * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (gw, block_size), 1)
    mask = (kpos <= qpos) & (kpos < seq_lens[ib])
    o_ref[0, 0] = jnp.where(mask, s, NEG_INF).astype(o_ref.dtype)


def paged_score_logits(q_win, k_pages, block_tables, seq_lens, *,
                       interpret=True):
    """q_win: (n, w, h_q, d) chronological window queries;
    k_pages: (N, b, h_kv, d); block_tables: (n, mb); seq_lens: (n,).
    Returns logits (n, h_kv, g, w, mb*b) fp32 with causal+validity mask
    already applied (NEG_INF)."""
    n, w, hq, d = q_win.shape
    N, b, hkv, _ = k_pages.shape
    g = hq // hkv
    mb = block_tables.shape[1]
    scale = 1.0 / np.sqrt(d)
    # (n, hkv, g*w, d): row-major (g, w) so kernel iota %w recovers u
    qr = q_win.transpose(0, 2, 1, 3).reshape(n, hkv, g, w, d) \
        .reshape(n, hkv, g * w, d)
    bt = jnp.maximum(block_tables, 0).astype(jnp.int32)

    grid_spec = pallas_compat.prefetch_grid_spec(
        num_scalar_prefetch=2,
        grid=(n, hkv, mb),
        in_specs=[
            pl.BlockSpec((1, 1, g * w, d),
                         lambda ib, ih, i, bt, sl: (ib, ih, 0, 0)),
            pl.BlockSpec((1, b, 1, d),
                         lambda ib, ih, i, bt, sl: (bt[ib, i], 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g * w, b),
                               lambda ib, ih, i, bt, sl: (ib, ih, 0, i)),
    )
    out = pallas_compat.pallas_call(
        functools.partial(_kernel, block_size=b, scale=scale, window=w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, hkv, g * w, mb * b), jnp.float32),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(bt, seq_lens, qr, k_pages)
    return out.reshape(n, hkv, g, w, mb * b)
