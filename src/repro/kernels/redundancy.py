"""Pallas TPU kernels: key-state redundancy scores (paper App. C.5/C.7).

``lightning_redundancy``: the paper's novel O(N·b²) score — one grid step
loads one page, computes the (b×b) block-local cosine similarity entirely in
VMEM (one MXU tile), applies the diag-zero and per-column last-above-p
zero-out, and writes only the (b,) row sums. Memory O(N·b).

``flash_redundancy``: the faithful O(N²·b²) baseline (paper Alg. 3) — for a
fixed column block m, an inner loop walks row blocks i = N-1..0 with the
zero-out tag held in VMEM across iterations; only per-(i,m) row-sums reach
HBM (memory O(N²·b)).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat


def _zero_last_above(c, p_thresh, already=None):
    """Zero, per column, the last (highest-row) entry > p; honor/update the
    cross-block tag ``already`` (cols already zeroed in a newer block)."""
    b_rows = c.shape[0]
    above = c > p_thresh
    if already is not None:
        above = above & jnp.logical_not(already)[None, :]
    has = above.any(axis=0)
    rows = jax.lax.broadcasted_iota(jnp.int32, above.shape, 0)
    last = jnp.max(jnp.where(above, rows, -1), axis=0)          # (b,)
    hit = (rows == last[None, :]) & has[None, :]
    c = jnp.where(hit, 0.0, c)
    new_already = has if already is None else (already | has)
    return c, new_already


def _lightning_kernel(block_tables, seq_lens, k_ref, o_ref, *, block_size,
                      p_thresh, eps=1e-12):
    i = pl.program_id(2)
    k = k_ref[0, :, 0].astype(jnp.float32)                      # (b, d)
    norm = jnp.sqrt(jnp.sum(k * k, axis=1, keepdims=True))
    khat = k / jnp.maximum(norm, eps)
    c = jax.lax.dot_general(khat, khat, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    b = block_size
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    # validity: entries at cache pos >= seq_len contribute nothing
    ib = pl.program_id(0)
    pos_r = i * b + rows
    pos_c = i * b + cols
    vm = (pos_r < seq_lens[ib]) & (pos_c < seq_lens[ib])
    c = jnp.where(vm & (rows != cols), c, 0.0)
    c, _ = _zero_last_above(c, p_thresh)
    o_ref[0, 0] = (jnp.sum(c, axis=1) / b).astype(o_ref.dtype)


def lightning_redundancy(k_pages, block_tables, seq_lens, *, p_thresh=0.8,
                         interpret=True):
    """k_pages: (N, b, h, d); block_tables: (n, mb); seq_lens: (n,).
    Returns raw row-sum scores (n, mb*b, h) (normalized by b), matching
    ``scoring.redundancy_lightning`` on the gathered layout."""
    N, b, h, d = k_pages.shape
    n, mb = block_tables.shape
    bt = jnp.maximum(block_tables, 0).astype(jnp.int32)
    grid_spec = pallas_compat.prefetch_grid_spec(
        num_scalar_prefetch=2,
        grid=(n, h, mb),
        in_specs=[pl.BlockSpec((1, b, 1, d),
                               lambda ib, ih, i, bt, sl: (bt[ib, i], 0, ih, 0))],
        out_specs=pl.BlockSpec((1, 1, b),
                               lambda ib, ih, i, bt, sl: (ib, ih, i)),
    )
    out = pallas_compat.pallas_call(
        functools.partial(_lightning_kernel, block_size=b, p_thresh=p_thresh),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, mb * b), jnp.float32),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(bt, seq_lens, k_pages)
    return out.transpose(0, 2, 1)                                # (n, T, h)


# ----------------------------------------------------------------------
def _flash_kernel(block_tables, seq_lens, km_ref, kall_ref, o_ref,
                  *, block_size, max_blocks, p_thresh, eps=1e-12):
    """Grid (n, h, m): column block m fixed; inner loop over row blocks
    i = N-1..0 (paper Alg. 3). Per-(i,m) row sums are accumulated into the
    request's (mb, b) output tile, which is revisited (same index_map block)
    across the sequential m dimension."""
    ib = pl.program_id(0)
    m = pl.program_id(2)
    b = block_size

    @pl.when(m == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    km = km_ref[0, :, 0].astype(jnp.float32)                    # (b, d)
    km = km / jnp.maximum(jnp.sqrt(jnp.sum(km * km, 1, keepdims=True)), eps)
    cols = jax.lax.broadcasted_iota(jnp.int32, (b, b), 1)
    rows = jax.lax.broadcasted_iota(jnp.int32, (b, b), 0)
    pos_c = m * b + cols

    def body(t, z):
        i = max_blocks - 1 - t
        ki = kall_ref[0, i, :, 0].astype(jnp.float32)
        ki = ki / jnp.maximum(jnp.sqrt(jnp.sum(ki * ki, 1, keepdims=True)),
                              eps)
        c = jax.lax.dot_general(ki, km, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos_r = i * b + rows
        vm = (pos_r < seq_lens[ib]) & (pos_c < seq_lens[ib])
        c = jnp.where(vm & (pos_r != pos_c), c, 0.0)
        c, z = _zero_last_above(c, p_thresh, already=z)
        o_ref[0, 0, i] = o_ref[0, 0, i] + jnp.sum(c, axis=1)
        return z

    jax.lax.fori_loop(0, max_blocks, body, jnp.zeros((b,), bool))


def flash_redundancy(k_pages, block_tables, seq_lens, *, p_thresh=0.8,
                     interpret=True):
    """Faithful Alg. 3. Returns raw row sums (n, mb*b, h) normalized by the
    valid length (matching ``scoring.redundancy_full``).

    The row blocks K_i are served from a VMEM-resident gather of the
    request's pages (the paper's Triton kernel re-reads K_i from HBM; on TPU
    the small-N compression regime fits VMEM — a production variant would
    stream pages with double-buffered DMA for very large N)."""
    N, b, h, d = k_pages.shape
    n, mb = block_tables.shape
    bt = jnp.maximum(block_tables, 0).astype(jnp.int32)
    gathered = k_pages[bt]                                       # (n, mb, b, h, d)

    grid_spec = pallas_compat.prefetch_grid_spec(
        num_scalar_prefetch=2,
        grid=(n, h, mb),
        in_specs=[
            pl.BlockSpec((1, b, 1, d),
                         lambda ib, ih, m, bt, sl: (bt[ib, m], 0, ih, 0)),
            pl.BlockSpec((1, mb, b, 1, d),
                         lambda ib, ih, m, bt, sl: (ib, 0, 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, mb, b),
                               lambda ib, ih, m, bt, sl: (ib, ih, 0, 0)),
    )

    def kernel(bt_ref, sl_ref, km_ref, kall_ref, o_ref):
        _flash_kernel(bt_ref, sl_ref, km_ref, kall_ref, o_ref,
                      block_size=b, max_blocks=mb, p_thresh=p_thresh)

    outs = pallas_compat.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n, h, mb, b), jnp.float32),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(bt, seq_lens, k_pages, gathered)
    r = outs.reshape(n, h, mb * b)
    nvalid = jnp.maximum(seq_lens, 1).astype(jnp.float32)
    return (r / nvalid[:, None, None]).transpose(0, 2, 1)
