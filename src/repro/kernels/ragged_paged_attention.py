"""Pallas TPU kernel: ragged paged decode attention (ROADMAP item 4).

The dense kernel (``paged_attention.py``) runs a ``(B, h_kv, max_blocks)``
grid: every slot pays the pool-wide table width in DMA'd page stripes and
``-1`` padding entries are clamped to real page 0 before the mask kills
their contribution. This kernel makes the per-slot work proportional to
the slot's *live* block count instead:

  * the grid drops to ``(B, max_blocks)`` with the block dim sequential;
    per-slot block counts ``nb = ceil(seq_len / b)`` are scalar-prefetched
    and gate every compute step with ``@pl.when(i < nb[ib])``,
  * the K/V ``index_map`` reads a table whose padded tail is clamped to
    the row's *last live* block — consecutive grid steps that map the same
    page issue no new DMA (Mosaic's revisit elision), so padded and
    evicted blocks are never fetched. Page 0 is mapped only when a row is
    fully inactive (``seq_len == 0``, no live block to clamp to) and even
    then never read: the row's output is written as exact zeros,
  * GQA head tiling: one grid step DMAs the whole ``(b, h_kv·d)``
    contiguous page stripe once and contracts *all* ``h_q = h_kv·g`` query
    heads against it in a single kv-head-batched MXU op — the dense
    kernel's per-head ``(g, d)`` slivers (g = 4–8 for the 8B-class
    configs) and its ``h_kv`` strided sub-stripe DMAs per page collapse
    into one fused ``(h_kv·g, d)·(d, b)`` pass per page.

Per-block online-softmax math is identical to the dense kernel, so for
rows with ``seq_len > 0`` the two kernels are bit-identical (a skipped
block is exactly the dense kernel's no-op update: ``corr = 1``, zero
probability mass); ``seq_len == 0`` rows return exact zeros instead of
the dense reference's garbage. Compressed rows need nothing special:
compression shrinks ``seq_lens`` (their rotary positions run ahead via
``Request.pos_gap``, which is applied outside the kernel), so fewer pages
are visited — the memory win becomes a decode-latency win.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat

NEG_INF = -1e30


def _kernel(bt, nb, seq_lens,            # scalar prefetch
            q_ref, k_ref, v_ref,         # VMEM tiles
            o_ref,                       # output tile
            m_s, l_s, acc_s,             # scratch
            *, block_size, scale):
    ib = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)
        # rows with no live blocks never reach _finish: define their
        # output as exact zeros (the jnp oracle matches this contract)
        o_ref[...] = jnp.zeros_like(o_ref)

    hkv, g = q_ref.shape[1], q_ref.shape[2]

    @pl.when(i < nb[ib])
    def _compute():
        q = q_ref[0].astype(jnp.float32)                # (hkv, g, d)
        k = k_ref[0].astype(jnp.float32)                # (b, hkv, d)
        v = v_ref[0].astype(jnp.float32)                # (b, hkv, d)
        if g > 1:
            # GQA: all h_q heads against the whole page in one
            # kv-head-batched MXU pass
            s = jax.lax.dot_general(
                q, k, (((2,), (2,)), ((0,), (1,))),
                preferred_element_type=jnp.float32) * scale  # (hkv, g, b)
            kpos = i * block_size + jax.lax.broadcasted_iota(
                jnp.int32, (1, 1, block_size), 2)
            valid = kpos < seq_lens[ib]
            s = jnp.where(valid, s, NEG_INF)

            m_prev = m_s[...]
            m_new = jnp.maximum(m_prev, s.max(axis=2, keepdims=True))
            p = jnp.exp(s - m_new)
            p = jnp.where(valid, p, 0.0)
            # a partially-filled last block holds stale pool data past
            # seq_len (NaNs included); p is 0 there but 0·NaN = NaN, so
            # zero V too
            v = jnp.where(valid.reshape(block_size, 1, 1), v, 0.0)
            corr = jnp.exp(m_prev - m_new)
            l_s[...] = l_s[...] * corr + p.sum(axis=2, keepdims=True)
            acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
                p, v, (((2,), (0,)), ((0,), (1,))),
                preferred_element_type=jnp.float32)
            m_s[...] = m_new
        else:
            # MHA (g == 1): the batched form is a stack of (1, d) matvecs
            # — no MXU win, and XLA lowers stacked small ops differently
            # from the dense kernel's per-head 2D graph, breaking bitwise
            # identity. Unroll heads with the dense kernel's exact ops so
            # every shape stays bit-identical to the dense path.
            kpos = i * block_size + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_size), 1)
            valid = kpos < seq_lens[ib]
            for h in range(hkv):
                s = jax.lax.dot_general(
                    q[h], k[:, h], (((1,), (1,)), ((), ())),
                    preferred_element_type=jnp.float32) * scale  # (g, b)
                s = jnp.where(valid, s, NEG_INF)
                m_prev = m_s[h]
                m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
                p = jnp.exp(s - m_new)
                p = jnp.where(valid, p, 0.0)
                vh = jnp.where(valid.reshape(block_size, 1), v[:, h], 0.0)
                corr = jnp.exp(m_prev - m_new)
                l_s[h] = l_s[h] * corr + p.sum(axis=1, keepdims=True)
                acc_s[h] = acc_s[h] * corr + jax.lax.dot_general(
                    p, vh, (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                m_s[h] = m_new

    @pl.when(i == nb[ib] - 1)
    def _finish():
        o_ref[0] = (acc_s[...] /
                    jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def ragged_paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                           interpret=True):
    """q: (B, h_q, d); pools: (N, b, h_kv, d); block_tables: (B, mb) with
    ``-1`` padding; seq_lens: (B,). Returns (B, h_q, d); rows with
    ``seq_len == 0`` are exact zeros."""
    B, hq, d = q.shape
    N, b, hkv, _ = k_pages.shape
    g = hq // hkv
    mb = block_tables.shape[1]
    scale = 1.0 / np.sqrt(d)
    qr = q.reshape(B, hkv, g, d)
    seq_lens = seq_lens.astype(jnp.int32)
    nb = (seq_lens + (b - 1)) // b                       # live blocks/row
    # clamp the padded tail to each row's last live block so revisited
    # steps issue no DMA; only fully-inactive rows (nb == 0, all -1) fall
    # back to page 0, and those never read or write from it
    col = jnp.minimum(jnp.arange(mb, dtype=jnp.int32)[None, :],
                      jnp.maximum(nb - 1, 0)[:, None])
    bt = jnp.take_along_axis(block_tables.astype(jnp.int32), col, axis=1)
    bt = jnp.maximum(bt, 0)

    grid_spec = pallas_compat.prefetch_grid_spec(
        num_scalar_prefetch=3,
        grid=(B, mb),
        in_specs=[
            pl.BlockSpec((1, hkv, g, d),
                         lambda ib, i, bt, nb, sl: (ib, 0, 0, 0)),
            pl.BlockSpec((1, b, hkv, d),
                         lambda ib, i, bt, nb, sl: (bt[ib, i], 0, 0, 0)),
            pl.BlockSpec((1, b, hkv, d),
                         lambda ib, i, bt, nb, sl: (bt[ib, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, hkv, g, d),
                               lambda ib, i, bt, nb, sl: (ib, 0, 0, 0)),
        scratch_shapes=[
            pallas_compat.vmem_scratch((hkv, g, 1), jnp.float32),
            pallas_compat.vmem_scratch((hkv, g, 1), jnp.float32),
            pallas_compat.vmem_scratch((hkv, g, d), jnp.float32),
        ],
    )
    out = pallas_compat.pallas_call(
        functools.partial(_kernel, block_size=b, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, hkv, g, d), q.dtype),
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
    )(bt, nb, seq_lens, qr, k_pages, v_pages)
    return out.reshape(B, hq, d)
