"""Pallas TPU kernels for the compute hot-spots the paper optimizes
(App. C): paged decode attention, paged observation-window scoring (Alg. 1),
lightning + flash redundancy (C.7 / Alg. 3), KV compaction (Alg. 4).

Each kernel: <name>.py (pl.pallas_call + explicit BlockSpec VMEM tiling),
ops.py (jit'd wrappers + the versioned backend dispatch:
auto | jnp | pallas-interpret | pallas-tpu), ref.py (pure-jnp oracles),
pallas_compat.py (JAX/Pallas API-drift shim — kernels never touch pltpu
attributes directly). Validated with pallas-interpret on CPU; TPU is the
target. See docs/KERNELS.md.
"""
