"""Jit'd wrappers + versioned backend dispatch for the Pallas kernels.

Every op takes ``backend`` in {"auto", "jnp", "pallas-interpret",
"pallas-tpu"} (plus the deprecated alias "pallas"). ``resolve_backend``
canonicalises once per process:

  * ``auto``             -> ``pallas-tpu`` on TPU hosts, ``jnp`` elsewhere
                            (interpret mode is a correctness path, not a
                            fast path — never auto-selected),
  * ``pallas``           -> ``pallas-tpu`` on TPU, ``pallas-interpret`` on
                            CPU (the historical ``set_interpret`` behavior),
  * canonical names pass through unchanged.

The engine/compression layers call through these so the backend is one
switch (``ModelRunnerConfig.kernel_backend`` on the ``repro.api`` facade).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import compaction, paged_attention as pa, paged_score, \
    redundancy
from repro.kernels import pallas_compat, ref
from repro.core import paged as paged_ref

BACKENDS = ("auto", "jnp", "pallas-interpret", "pallas-tpu", "pallas")
_CANONICAL = ("jnp", "pallas-interpret", "pallas-tpu")


@functools.lru_cache(maxsize=None)
def resolve_backend(backend: str = "auto") -> str:
    """Canonicalise a backend name for the current platform (cached: the
    platform does not change within a process)."""
    if backend is None or backend == "auto":
        return "pallas-tpu" if pallas_compat.has_tpu() else "jnp"
    if backend == "pallas":                    # deprecated alias
        return "pallas-tpu" if pallas_compat.has_tpu() else "pallas-interpret"
    if backend not in _CANONICAL:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS}")
    return backend


def _is_pallas(backend: str) -> bool:
    return backend.startswith("pallas")


def _interpret(backend: str) -> bool:
    return backend == "pallas-interpret"


# ----------------------------------------------------------------------
# dispatch wrappers: resolve once, then jit with the canonical name static


def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens,
                           backend="auto"):
    return _paged_decode_attention(q, k_pages, v_pages, block_tables,
                                   seq_lens, backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                            backend):
    if _is_pallas(backend):
        return pa.paged_attention(q, k_pages, v_pages, block_tables,
                                  seq_lens, interpret=_interpret(backend))
    return paged_ref.paged_decode_attention(q, k_pages, v_pages,
                                            block_tables, seq_lens)


def score_logits(q_win, k_pages, block_tables, seq_lens, backend="auto"):
    return _score_logits(q_win, k_pages, block_tables, seq_lens,
                         backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _score_logits(q_win, k_pages, block_tables, seq_lens, *, backend):
    if _is_pallas(backend):
        return paged_score.paged_score_logits(
            q_win, k_pages, block_tables, seq_lens,
            interpret=_interpret(backend))
    return ref.paged_score_logits_ref(q_win, k_pages, block_tables, seq_lens)


def attention_scores_from_logits(logits, seq_lens):
    """Softmax over T, GQA max over g, mean over w (paper App. C.2).
    logits: (n, h, g, w, T) masked with NEG_INF. Returns (n, T, h)."""
    p = jax.nn.softmax(logits, axis=-1)
    T = logits.shape[-1]
    valid = jnp.arange(T)[None] < seq_lens[:, None]
    p = jnp.where(valid[:, None, None, None], p, 0.0)
    return p.max(axis=2).mean(axis=2).transpose(0, 2, 1)


def lightning_redundancy(k_pages, block_tables, seq_lens, p_thresh=0.8,
                         backend="auto"):
    return _lightning_redundancy(k_pages, block_tables, seq_lens,
                                 p_thresh=p_thresh,
                                 backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend", "p_thresh"))
def _lightning_redundancy(k_pages, block_tables, seq_lens, *, p_thresh,
                          backend):
    if _is_pallas(backend):
        return redundancy.lightning_redundancy(
            k_pages, block_tables, seq_lens, p_thresh=p_thresh,
            interpret=_interpret(backend))
    return ref.lightning_redundancy_ref(k_pages, block_tables, seq_lens,
                                        p_thresh=p_thresh)


def flash_redundancy(k_pages, block_tables, seq_lens, p_thresh=0.8,
                     backend="auto"):
    return _flash_redundancy(k_pages, block_tables, seq_lens,
                             p_thresh=p_thresh,
                             backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend", "p_thresh"))
def _flash_redundancy(k_pages, block_tables, seq_lens, *, p_thresh, backend):
    if _is_pallas(backend):
        return redundancy.flash_redundancy(
            k_pages, block_tables, seq_lens, p_thresh=p_thresh,
            interpret=_interpret(backend))
    return ref.flash_redundancy_ref(k_pages, block_tables, seq_lens,
                                    p_thresh=p_thresh)


def gather_kv_blocks(pool, block_ids, backend="auto"):
    """Batched whole-block gather for the host swap tier (swap-out half).
    All backends lower to the same dense gather — a block copy is pure
    bandwidth, so the Pallas tiers add nothing over the jnp reference —
    but dispatch still resolves through ``resolve_backend`` so an
    accelerator-specific copy kernel can slot in per backend later."""
    return _gather_kv_blocks(pool, block_ids,
                             backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _gather_kv_blocks(pool, block_ids, *, backend):
    del backend                      # memcpy-bound: one implementation
    return paged_ref.gather_kv_blocks(pool, block_ids)


def scatter_kv_blocks(pool, block_ids, values, backend="auto"):
    """Swap-in half: write gathered blocks back at ``block_ids``. The pool
    is donated — swap-in restores KV in place without doubling the pool's
    footprint."""
    return _scatter_kv_blocks(pool, block_ids, values,
                              backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",),
                   donate_argnums=(0,))
def _scatter_kv_blocks(pool, block_ids, values, *, backend):
    del backend
    return paged_ref.scatter_kv_blocks(pool, block_ids, values)


def compact_gather(pool_flat, src_slots, backend="auto"):
    return _compact_gather(pool_flat, src_slots,
                           backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _compact_gather(pool_flat, src_slots, *, backend):
    if _is_pallas(backend):
        return compaction.compact_gather(pool_flat, src_slots,
                                         interpret=_interpret(backend))
    return ref.compact_gather_ref(pool_flat, src_slots)
