"""Jit'd wrappers + backend dispatch for the Pallas kernels.

``backend="pallas"`` routes through the TPU kernels (interpret=True on CPU);
``backend="jnp"`` uses the pure-jnp references. The engine/compression layers
call through these so the backend is one switch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import compaction, paged_attention as pa, paged_score, \
    redundancy
from repro.kernels import ref
from repro.core import paged as paged_ref

_INTERPRET = True  # CPU container; real TPU would set False


def set_interpret(flag: bool):
    global _INTERPRET
    _INTERPRET = flag


@functools.partial(jax.jit, static_argnames=("backend",))
def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens,
                           backend="pallas"):
    if backend == "pallas":
        return pa.paged_attention(q, k_pages, v_pages, block_tables,
                                  seq_lens, interpret=_INTERPRET)
    return paged_ref.paged_decode_attention(q, k_pages, v_pages,
                                            block_tables, seq_lens)


@functools.partial(jax.jit, static_argnames=("backend",))
def score_logits(q_win, k_pages, block_tables, seq_lens, backend="pallas"):
    if backend == "pallas":
        return paged_score.paged_score_logits(q_win, k_pages, block_tables,
                                              seq_lens, interpret=_INTERPRET)
    return ref.paged_score_logits_ref(q_win, k_pages, block_tables, seq_lens)


def attention_scores_from_logits(logits, seq_lens):
    """Softmax over T, GQA max over g, mean over w (paper App. C.2).
    logits: (n, h, g, w, T) masked with NEG_INF. Returns (n, T, h)."""
    p = jax.nn.softmax(logits, axis=-1)
    T = logits.shape[-1]
    valid = jnp.arange(T)[None] < seq_lens[:, None]
    p = jnp.where(valid[:, None, None, None], p, 0.0)
    return p.max(axis=2).mean(axis=2).transpose(0, 2, 1)


@functools.partial(jax.jit, static_argnames=("backend", "p_thresh"))
def lightning_redundancy(k_pages, block_tables, seq_lens, p_thresh=0.8,
                         backend="pallas"):
    if backend == "pallas":
        return redundancy.lightning_redundancy(
            k_pages, block_tables, seq_lens, p_thresh=p_thresh,
            interpret=_INTERPRET)
    return ref.lightning_redundancy_ref(k_pages, block_tables, seq_lens,
                                        p_thresh=p_thresh)


@functools.partial(jax.jit, static_argnames=("backend", "p_thresh"))
def flash_redundancy(k_pages, block_tables, seq_lens, p_thresh=0.8,
                     backend="pallas"):
    if backend == "pallas":
        return redundancy.flash_redundancy(
            k_pages, block_tables, seq_lens, p_thresh=p_thresh,
            interpret=_INTERPRET)
    return ref.flash_redundancy_ref(k_pages, block_tables, seq_lens,
                                    p_thresh=p_thresh)


@functools.partial(jax.jit, static_argnames=("backend",))
def compact_gather(pool_flat, src_slots, backend="pallas"):
    if backend == "pallas":
        return compaction.compact_gather(pool_flat, src_slots,
                                         interpret=_INTERPRET)
    return ref.compact_gather_ref(pool_flat, src_slots)
