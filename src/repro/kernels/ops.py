"""Jit'd wrappers + versioned backend dispatch for the Pallas kernels.

Every op takes ``backend`` in {"auto", "jnp", "pallas-interpret",
"pallas-tpu"} (plus the deprecated alias "pallas"). ``resolve_backend``
canonicalises once per process:

  * ``auto``             -> ``pallas-tpu`` on TPU hosts, ``jnp`` elsewhere
                            (interpret mode is a correctness path, not a
                            fast path — never auto-selected),
  * ``pallas``           -> ``pallas-tpu`` on TPU, ``pallas-interpret`` on
                            CPU (the historical ``set_interpret`` behavior),
  * canonical names pass through unchanged.

The engine/compression layers call through these so the backend is one
switch (``ModelRunnerConfig.kernel_backend`` on the ``repro.api`` facade).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import compaction, paged_attention as pa, paged_score, \
    ragged_paged_attention as rpa, redundancy
from repro.kernels import pallas_compat, ref
from repro.core import paged as paged_ref

BACKENDS = ("auto", "jnp", "pallas-interpret", "pallas-tpu", "pallas")
_CANONICAL = ("jnp", "pallas-interpret", "pallas-tpu")


@functools.lru_cache(maxsize=None)
def resolve_backend(backend: str = "auto") -> str:
    """Canonicalise a backend name for the current platform (cached: the
    platform does not change within a process)."""
    if backend is None or backend == "auto":
        return "pallas-tpu" if pallas_compat.has_tpu() else "jnp"
    if backend == "pallas":                    # deprecated alias
        return "pallas-tpu" if pallas_compat.has_tpu() else "pallas-interpret"
    if backend not in _CANONICAL:
        raise ValueError(
            f"unknown kernel backend {backend!r}; expected one of {BACKENDS}")
    return backend


def _is_pallas(backend: str) -> bool:
    return backend.startswith("pallas")


def _interpret(backend: str) -> bool:
    return backend == "pallas-interpret"


# ----------------------------------------------------------------------
# host-side block-table width trim (shared by every dense-grid caller)


def block_table_width(max_used_blocks, table_width, *, bucket=True,
                      min_width=1):
    """Width policy for host-side block-table trims: the batch's max used
    block count, optionally rounded up to a power of two so only
    O(log table_width) widths are ever traced/compiled, capped at the
    table's own width."""
    w = max(int(min_width), int(max_used_blocks))
    if bucket:
        w = 1 << max(0, w - 1).bit_length()
    return min(w, int(table_width))


def trim_block_tables(block_tables, seq_lens, block_size, *, bucket=True,
                      min_width=1):
    """Slice ``block_tables`` (host-side, numpy) to the batch's max used
    block count before dispatch, so dense-grid kernels (paged_score,
    redundancy, the dense decode kernel) stop iterating pool-wide
    ``max_blocks``. Returns ``(trimmed_view, width)``. Call with concrete
    host arrays — inside jit the width would be traced and useless."""
    bt = np.asarray(block_tables)
    sl = np.asarray(seq_lens)
    used = int(-(-sl.max(initial=0) // block_size)) if sl.size else 0
    width = block_table_width(used, bt.shape[1], bucket=bucket,
                              min_width=min_width)
    return bt[:, :width], width


# ----------------------------------------------------------------------
# dispatch wrappers: resolve once, then jit with the canonical name static


def paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens,
                           backend="auto"):
    return _paged_decode_attention(q, k_pages, v_pages, block_tables,
                                   seq_lens, backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _paged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                            backend):
    if _is_pallas(backend):
        return pa.paged_attention(q, k_pages, v_pages, block_tables,
                                  seq_lens, interpret=_interpret(backend))
    return paged_ref.paged_decode_attention(q, k_pages, v_pages,
                                            block_tables, seq_lens)


def ragged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens,
                            backend="auto"):
    """Length-aware decode attention (docs/KERNELS.md "Ragged decode"):
    per-slot work proportional to the slot's live block count; rows with
    ``seq_len == 0`` return exact zeros. The jnp path shares the dense
    reference math (bit-identical for live rows), so flipping
    ragged<->dense never changes a token stream."""
    return _ragged_decode_attention(q, k_pages, v_pages, block_tables,
                                    seq_lens,
                                    backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _ragged_decode_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                             backend):
    if _is_pallas(backend):
        return rpa.ragged_paged_attention(q, k_pages, v_pages, block_tables,
                                          seq_lens,
                                          interpret=_interpret(backend))
    return ref.ragged_paged_attention_ref(q, k_pages, v_pages, block_tables,
                                          seq_lens)


def score_logits(q_win, k_pages, block_tables, seq_lens, backend="auto"):
    return _score_logits(q_win, k_pages, block_tables, seq_lens,
                         backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _score_logits(q_win, k_pages, block_tables, seq_lens, *, backend):
    if _is_pallas(backend):
        return paged_score.paged_score_logits(
            q_win, k_pages, block_tables, seq_lens,
            interpret=_interpret(backend))
    return ref.paged_score_logits_ref(q_win, k_pages, block_tables, seq_lens)


def attention_scores_from_logits(logits, seq_lens):
    """Softmax over T, GQA max over g, mean over w (paper App. C.2).
    logits: (n, h, g, w, T) masked with NEG_INF. Returns (n, T, h)."""
    p = jax.nn.softmax(logits, axis=-1)
    T = logits.shape[-1]
    valid = jnp.arange(T)[None] < seq_lens[:, None]
    p = jnp.where(valid[:, None, None, None], p, 0.0)
    return p.max(axis=2).mean(axis=2).transpose(0, 2, 1)


def lightning_redundancy(k_pages, block_tables, seq_lens, p_thresh=0.8,
                         backend="auto"):
    return _lightning_redundancy(k_pages, block_tables, seq_lens,
                                 p_thresh=p_thresh,
                                 backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend", "p_thresh"))
def _lightning_redundancy(k_pages, block_tables, seq_lens, *, p_thresh,
                          backend):
    if _is_pallas(backend):
        return redundancy.lightning_redundancy(
            k_pages, block_tables, seq_lens, p_thresh=p_thresh,
            interpret=_interpret(backend))
    return ref.lightning_redundancy_ref(k_pages, block_tables, seq_lens,
                                        p_thresh=p_thresh)


def flash_redundancy(k_pages, block_tables, seq_lens, p_thresh=0.8,
                     backend="auto"):
    return _flash_redundancy(k_pages, block_tables, seq_lens,
                             p_thresh=p_thresh,
                             backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend", "p_thresh"))
def _flash_redundancy(k_pages, block_tables, seq_lens, *, p_thresh, backend):
    if _is_pallas(backend):
        return redundancy.flash_redundancy(
            k_pages, block_tables, seq_lens, p_thresh=p_thresh,
            interpret=_interpret(backend))
    return ref.flash_redundancy_ref(k_pages, block_tables, seq_lens,
                                    p_thresh=p_thresh)


def gather_kv_blocks(pool, block_ids, backend="auto"):
    """Batched whole-block gather for the host swap tier (swap-out half).
    All backends lower to the same dense gather — a block copy is pure
    bandwidth, so the Pallas tiers add nothing over the jnp reference —
    but dispatch still resolves through ``resolve_backend`` so an
    accelerator-specific copy kernel can slot in per backend later."""
    return _gather_kv_blocks(pool, block_ids,
                             backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _gather_kv_blocks(pool, block_ids, *, backend):
    del backend                      # memcpy-bound: one implementation
    return paged_ref.gather_kv_blocks(pool, block_ids)


def scatter_kv_blocks(pool, block_ids, values, backend="auto"):
    """Swap-in half: write gathered blocks back at ``block_ids``. The pool
    is donated — swap-in restores KV in place without doubling the pool's
    footprint."""
    return _scatter_kv_blocks(pool, block_ids, values,
                              backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",),
                   donate_argnums=(0,))
def _scatter_kv_blocks(pool, block_ids, values, *, backend):
    del backend
    return paged_ref.scatter_kv_blocks(pool, block_ids, values)


def compact_gather(pool_flat, src_slots, backend="auto"):
    return _compact_gather(pool_flat, src_slots,
                           backend=resolve_backend(backend))


@functools.partial(jax.jit, static_argnames=("backend",))
def _compact_gather(pool_flat, src_slots, *, backend):
    if _is_pallas(backend):
        return compaction.compact_gather(pool_flat, src_slots,
                                         interpret=_interpret(backend))
    return ref.compact_gather_ref(pool_flat, src_slots)
