"""Feature-detection shim over JAX/Pallas API drift.

The Pallas TPU surface has been renamed across JAX releases:

  * ``pltpu.TPUCompilerParams`` (<= 0.4.x) became ``pltpu.CompilerParams``
    (newer releases keep one, the other, or both with a deprecation),
  * ``pltpu.PrefetchScalarGridSpec`` has moved module homes,
  * VMEM scratch specs are ``pltpu.VMEM`` or ``pltpu.MemorySpace.VMEM``.

Every kernel in this package goes through these helpers instead of touching
``pltpu`` attributes directly, so the same source imports and runs on both
the pinned-minimum and the latest JAX. Resolution happens at call time
against the module object passed in (defaulting to the real ``pltpu``), so
tests can exercise both layouts by passing a fake module.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def compiler_params(dimension_semantics, *, mod=None):
    """Build the TPU compiler-params object under whichever name this JAX
    exposes; returns None (caller omits the argument) if neither exists."""
    m = mod if mod is not None else pltpu
    cls = getattr(m, "CompilerParams", None) \
        or getattr(m, "TPUCompilerParams", None)
    if cls is None:
        return None
    return cls(dimension_semantics=tuple(dimension_semantics))


def prefetch_grid_spec(*, num_scalar_prefetch, grid, in_specs, out_specs,
                       scratch_shapes=(), mod=None):
    """``PrefetchScalarGridSpec`` under whichever home it lives in."""
    m = mod if mod is not None else pltpu
    cls = getattr(m, "PrefetchScalarGridSpec", None)
    if cls is None:
        raise NotImplementedError(
            "this JAX exposes no PrefetchScalarGridSpec; the paged kernels "
            "need scalar-prefetch BlockSpec index_maps — fall back to "
            "backend='jnp' (repro.kernels.ops.resolve_backend)")
    kwargs = {}
    if scratch_shapes:
        kwargs["scratch_shapes"] = list(scratch_shapes)
    return cls(num_scalar_prefetch=num_scalar_prefetch, grid=grid,
               in_specs=in_specs, out_specs=out_specs, **kwargs)


def vmem_scratch(shape, dtype, *, mod=None):
    """VMEM scratch-shape spec (``pltpu.VMEM`` or ``MemorySpace.VMEM``)."""
    m = mod if mod is not None else pltpu
    fn = getattr(m, "VMEM", None)
    if fn is None:
        space = getattr(m, "MemorySpace", None)
        fn = getattr(space, "VMEM", None) if space is not None else None
    if fn is None:
        raise NotImplementedError(
            "this JAX exposes no VMEM scratch spec under "
            f"{getattr(m, '__name__', m)!r}")
    return fn(shape, dtype)


def pallas_call(kernel, *, grid_spec, out_shape, dimension_semantics=None,
                interpret=True):
    """``pl.pallas_call`` with compiler params attached when available.

    In interpret mode ``dimension_semantics`` only documents intent; on a
    real TPU it drives the Mosaic parallelisation, so we always forward it
    when this JAX has a params class to carry it.
    """
    kwargs = {}
    if dimension_semantics is not None:
        cp = compiler_params(dimension_semantics)
        if cp is not None:
            kwargs["compiler_params"] = cp
    return pl.pallas_call(kernel, grid_spec=grid_spec, out_shape=out_shape,
                          interpret=interpret, **kwargs)


@functools.lru_cache(maxsize=None)
def has_tpu() -> bool:
    return jax.default_backend() == "tpu"


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None, check=True,
              mod=None):
    """``shard_map`` under whichever home and spelling this JAX gives it.

    New JAX exposes top-level ``jax.shard_map(..., axis_names=...,
    check_vma=...)``; <= 0.4.x has ``jax.experimental.shard_map.shard_map``
    where the manual-axes set is expressed as its complement (``auto``) and
    the replication check is spelled ``check_rep``. Mid-range releases mix
    the two (top-level home, old spellings), so each kwarg is keyed on the
    resolved function's *signature*, not its home. ``axis_names=None``
    means every mesh axis is manual and ``check=True`` keeps the
    replication/VMA check on (both match upstream defaults)."""
    import inspect

    m = mod if mod is not None else jax
    fn = getattr(m, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn
    params = inspect.signature(fn).parameters
    has_varkw = any(p.kind is inspect.Parameter.VAR_KEYWORD
                    for p in params.values())
    kwargs = {}
    if "check_vma" in params or has_varkw:
        kwargs["check_vma"] = check
    elif "check_rep" in params:
        kwargs["check_rep"] = check
    if axis_names is not None:
        if "axis_names" in params or has_varkw:
            kwargs["axis_names"] = frozenset(axis_names)
        elif "auto" in params:
            kwargs["auto"] = \
                frozenset(mesh.axis_names) - frozenset(axis_names)
    return fn(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              **kwargs)


def mesh_context(mesh, *, mod=None):
    """Context manager making ``mesh`` ambient: ``jax.set_mesh`` (new) or
    the ``Mesh`` object itself, which is a context manager in old JAX."""
    m = mod if mod is not None else jax
    set_mesh = getattr(m, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh
