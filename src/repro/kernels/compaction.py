"""Pallas TPU kernel: KV-cache compaction (paper Alg. 4, TPU re-derivation).

The GPU algorithm is a serial two-pointer walk. On TPU we pre-compute each
survivor's destination (its keep-rank, via the stable keep-first ordering
already produced by the scorer) and turn the move into pure data movement:
grid step (head, dest_row) DMAs exactly one (1, d)-row from the source slot —
the source slot id is read from the scalar-prefetched index array inside the
BlockSpec index_map, so the "pointer chase" costs zero compute.

Semantically identical to Alg. 4: (N_max-1)·b reads+writes per (layer, head),
original order preserved.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat


def _kernel(src_slots, src_ref, o_ref):
    o_ref[0, 0] = src_ref[0, 0]


def compact_gather(pool_flat, src_slots, *, interpret=True):
    """pool_flat: (S, h, d) flattened pool (S = N_total*b);
    src_slots: (h, k) flat source slot per head per destination rank.
    Returns (k, h, d) — the compacted rows in destination order (the caller
    scatters them to the destination blocks, or aliases the output onto the
    destination region)."""
    S, h, d = pool_flat.shape
    k = src_slots.shape[1]
    src = jnp.asarray(src_slots, jnp.int32)

    grid_spec = pallas_compat.prefetch_grid_spec(
        num_scalar_prefetch=1,
        grid=(h, k),
        in_specs=[pl.BlockSpec((1, 1, d),
                               lambda ih, j, src: (src[ih, j], ih, 0))],
        out_specs=pl.BlockSpec((1, 1, d), lambda ih, j, src: (j, ih, 0)),
    )
    return pallas_compat.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((k, h, d), pool_flat.dtype),
        dimension_semantics=("parallel", "arbitrary"),
        interpret=interpret,
    )(src, pool_flat)
