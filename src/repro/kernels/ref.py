"""Pure-jnp oracles for every Pallas kernel (the contracts live in
repro.core.paged / repro.core.scoring; re-exported here so kernel tests read
one import site)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.paged import gather_entries, paged_decode_attention  # noqa: F401
from repro.core import scoring


def ragged_paged_attention_ref(q, k_pages, v_pages, block_tables, seq_lens):
    """Oracle for kernels.ragged_paged_attention: identical math to the
    dense reference (masked lanes carry exactly zero V, so the two are
    bit-identical for rows with ``seq_len > 0``); rows with
    ``seq_len == 0`` return exact zeros — the ragged kernel's contract for
    inactive slots."""
    out = paged_decode_attention(q, k_pages, v_pages, block_tables,
                                 seq_lens)
    return jnp.where((seq_lens > 0)[:, None, None], out,
                     jnp.zeros_like(out))


def paged_score_logits_ref(q_win, k_pages, block_tables, seq_lens):
    """Oracle for kernels.paged_score.paged_score_logits."""
    n, w, hq, d = q_win.shape
    N, b, hkv, _ = k_pages.shape
    g = hq // hkv
    bt = jnp.maximum(block_tables, 0)
    ks = gather_entries(k_pages, bt)                  # (n, T, hkv, d)
    T = ks.shape[1]
    qg = q_win.reshape(n, w, hkv, g, d)
    s = jnp.einsum("nwhgd,nthd->nhgwt", qg.astype(jnp.float32),
                   ks.astype(jnp.float32)) / np.sqrt(d)
    qpos = seq_lens[:, None] - w + jnp.arange(w)[None]            # (n, w)
    kpos = jnp.arange(T)
    mask = (kpos[None, None] <= qpos[..., None]) & \
        (kpos[None, None] < seq_lens[:, None, None])
    return jnp.where(mask[:, None, None], s, -1e30)


def lightning_redundancy_ref(k_pages, block_tables, seq_lens, *, p_thresh=0.8):
    bt = jnp.maximum(block_tables, 0)
    entries = gather_entries(k_pages, bt)             # (n, T, h, d)
    b = k_pages.shape[1]
    T = entries.shape[1]
    valid = jnp.arange(T)[None] < seq_lens[:, None]
    import jax
    return jax.vmap(lambda e, v: scoring.redundancy_lightning(
        e, v, block_size=b, p_thresh=p_thresh))(entries, valid)


def flash_redundancy_ref(k_pages, block_tables, seq_lens, *, p_thresh=0.8):
    """Flash == full-matrix redundancy by construction."""
    bt = jnp.maximum(block_tables, 0)
    entries = gather_entries(k_pages, bt)
    T = entries.shape[1]
    valid = jnp.arange(T)[None] < seq_lens[:, None]
    import jax
    return jax.vmap(lambda e, v: scoring.redundancy_full(
        e, v, p_thresh=p_thresh))(entries, valid)


def compact_gather_ref(pool_flat, src_slots):
    h = pool_flat.shape[1]
    vals = pool_flat[src_slots, jnp.arange(h)[:, None]]   # (h, k, d)
    return vals.transpose(1, 0, 2)
