"""Pallas TPU kernel: paged decode attention (flash-decoding over pages).

TPU adaptation of GPU PagedAttention (DESIGN.md §3): the page indirection
happens at grid-index time — the K/V BlockSpec ``index_map`` reads the
scalar-prefetched block table, so each grid step DMAs one dense
``(b, d)`` page stripe HBM->VMEM and runs the (g×d)·(d×b) product on the MXU
with an online-softmax accumulator held in VMEM scratch.

Grid: (B, h_kv, max_blocks); the last dim is sequential ("arbitrary") so the
scratch accumulators persist across a request's pages.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import pallas_compat

NEG_INF = -1e30


def _kernel(block_tables, seq_lens,      # scalar prefetch
            q_ref, k_ref, v_ref,         # VMEM tiles
            o_ref,                       # output tile
            m_s, l_s, acc_s,             # scratch
            *, block_size, max_blocks, scale):
    ib = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)                 # (g, d)
    k = k_ref[0, :, 0].astype(jnp.float32)              # (b, d)
    v = v_ref[0, :, 0].astype(jnp.float32)              # (b, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = i * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, block_size), 1)
    valid = kpos < seq_lens[ib]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    p = jnp.where(valid, p, 0.0)
    # p is 0 on masked lanes, but padded -1 table entries DMA real page 0
    # and partial blocks hold stale pool data past seq_len — 0·NaN = NaN,
    # so zero the masked V lanes before the contraction
    v = jnp.where(valid.reshape(block_size, 1), v, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1, keepdims=True)
    acc_s[...] = acc_s[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(i == max_blocks - 1)
    def _finish():
        o_ref[0, 0] = (acc_s[...] /
                       jnp.maximum(l_s[...], 1e-30)).astype(o_ref.dtype)


def paged_attention(q, k_pages, v_pages, block_tables, seq_lens, *,
                    interpret=True):
    """q: (B, h_q, d); pools: (N, b, h_kv, d); block_tables: (B, mb);
    seq_lens: (B,). Returns (B, h_q, d)."""
    B, hq, d = q.shape
    N, b, hkv, _ = k_pages.shape
    g = hq // hkv
    mb = block_tables.shape[1]
    scale = 1.0 / np.sqrt(d)
    qr = q.reshape(B, hkv, g, d)
    bt = jnp.maximum(block_tables, 0).astype(jnp.int32)

    grid_spec = pallas_compat.prefetch_grid_spec(
        num_scalar_prefetch=2,
        grid=(B, hkv, mb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda ib, ih, i, bt, sl: (ib, ih, 0, 0)),
            pl.BlockSpec((1, b, 1, d),
                         lambda ib, ih, i, bt, sl: (bt[ib, i], 0, ih, 0)),
            pl.BlockSpec((1, b, 1, d),
                         lambda ib, ih, i, bt, sl: (bt[ib, i], 0, ih, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, d),
                               lambda ib, ih, i, bt, sl: (ib, ih, 0, 0)),
        scratch_shapes=[
            pallas_compat.vmem_scratch((g, 1), jnp.float32),
            pallas_compat.vmem_scratch((g, 1), jnp.float32),
            pallas_compat.vmem_scratch((g, d), jnp.float32),
        ],
    )
    out = pallas_compat.pallas_call(
        functools.partial(_kernel, block_size=b, max_blocks=mb, scale=scale),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, hkv, g, d), q.dtype),
        dimension_semantics=("parallel", "parallel", "arbitrary"),
        interpret=interpret,
    )(bt, seq_lens, qr, k_pages, v_pages)
    return out.reshape(B, hq, d)
