"""Runtime sanitizer tests: deliberately corrupt engine state and assert
``invariants.audit_engine`` reports each corruption with an actionable
message; a healthy run must audit clean at every step; the per-step hook
raises ``InvariantViolation`` when the sanitizer is armed."""
import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.core import invariants
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.core.request import State
from repro.models import lm
from engine_utils import submit

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))

PROMPTS = [[1, 2, 3, 4, 5], [9, 8, 7], [10, 11, 12, 13, 14, 15, 16],
           [20, 21]]


def make_engine(**kw):
    base = dict(block_size=8, n_total_blocks=64, max_batch=4, m_qslots=2,
                n_max=3, window=4, max_model_len=256, prefill_rows=2,
                prefill_len=64, compress=CompressOptions(window=4),
                temperature=0.0)
    base.update(kw)
    return ZipageEngine(CFG, PARAMS, EngineOptions(**base))


def running_engine(steps=3, **kw):
    eng = make_engine(**kw)
    for p in PROMPTS:
        submit(eng, p, 24)
    for _ in range(steps):
        eng.step()
    assert eng.running, "fixture expects live requests"
    return eng


# ----------------------------------------------------------------------
# healthy runs audit clean


def test_healthy_run_audits_clean_every_step():
    eng = make_engine(n_max=3, m_qslots=4)
    for p in PROMPTS:
        submit(eng, p, 30)
    while eng.scheduler.has_work():
        eng.step()
        assert invariants.audit_engine(eng) == []
        assert eng.step_count < 500


def test_healthy_swap_run_audits_clean():
    eng = make_engine(n_total_blocks=10, max_batch=4, m_qslots=4,
                      prefix_caching=False, preemption_mode="swap",
                      swap_space_blocks=16)
    for p in PROMPTS:
        submit(eng, p, 24)
    while eng.scheduler.has_work():
        eng.step()
        assert invariants.audit_engine(eng) == []
        assert eng.step_count < 800


# ----------------------------------------------------------------------
# block refcount corruption


def test_double_free_is_detected():
    eng = running_engine()
    victim = next(r for r in eng.running if r.blocks)
    blk = victim.blocks[0]
    eng.bm.release([blk])                      # rip a ref out from under it
    msgs = invariants.audit_engine(eng)
    assert any("double-free" in m and f"block {blk}" in m for m in msgs), msgs


def test_leaked_reference_is_detected():
    eng = running_engine()
    leaked = eng.bm.allocate(1)[0]             # ref'd but held by nobody
    msgs = invariants.audit_engine(eng)
    assert any("leaked reference" in m and f"block {leaked}" in m
               for m in msgs), msgs


def test_self_aliased_block_table_is_detected():
    eng = running_engine()
    victim = next(r for r in eng.running if r.blocks)
    victim.blocks.append(victim.blocks[0])
    msgs = invariants.audit_engine(eng)
    assert any("more than once" in m and f"rid {victim.rid}" in m
               for m in msgs), msgs


# ----------------------------------------------------------------------
# slot pools


def test_orphaned_slot_is_detected():
    eng = running_engine()
    victim = next(r for r in eng.running if r.slot >= 0)
    eng.scheduler.free_slots.append(victim.slot)   # free while still held
    msgs = invariants.audit_engine(eng)
    assert any("both free and held" in m and str(victim.slot) in m
               for m in msgs), msgs


def test_leaked_slot_is_detected():
    eng = running_engine()
    victim = next(r for r in eng.running if r.slot >= 0)
    slot = victim.slot
    victim.slot = -1                           # drop the handle, no free
    msgs = invariants.audit_engine(eng)
    assert any("leaked" in m and f"[{slot}]" in m for m in msgs), msgs
    victim.slot = slot                         # restore for teardown


# ----------------------------------------------------------------------
# queue discipline


def test_queue_overlap_is_detected():
    eng = running_engine()
    r = eng.running[0]
    eng.scheduler.waiting.append(r)            # now in two queues
    msgs = invariants.audit_engine(eng)
    assert any("queues must be disjoint" in m and f"rid {r.rid}" in m
               for m in msgs), msgs


def test_wrong_state_in_queue_is_detected():
    eng = running_engine()
    r = eng.running[0]
    r.state = State.FINISHED                   # but still in running queue
    msgs = invariants.audit_engine(eng)
    assert any("sits in the 'running' queue with state 'finished'" in m
               for m in msgs), msgs
    r.state = State.RUNNING


def test_waiting_request_holding_blocks_is_detected():
    eng = make_engine()
    rid = submit(eng, [1, 2, 3], 8)
    w = next(r for r in eng.waiting if r.rid == rid)
    w.blocks = [0, 1]                          # waiting must hold nothing
    msgs = invariants.audit_engine(eng)
    assert any("only running requests hold device blocks" in m
               for m in msgs), msgs
    w.blocks = []


# ----------------------------------------------------------------------
# swap pool


def test_swap_pool_leak_is_detected():
    eng = running_engine(preemption_mode="swap", swap_space_blocks=16,
                         prefix_caching=False)
    eng.bm.swapped[9999] = [eng.bm.swap_free.pop()]   # rid not in queue
    msgs = invariants.audit_engine(eng)
    assert any("rid 9999" in m and "swap-pool leak" in m for m in msgs), msgs


# ----------------------------------------------------------------------
# token budget


def test_budget_overdraw_is_detected():
    eng = running_engine(token_budget=16)
    eng.metrics.append({"step": eng.step_count,
                        "n_scheduled_tokens": 99, "token_budget": 16})
    msgs = invariants.audit_engine(eng)
    assert any("overdraw" in m and "99" in m for m in msgs), msgs


# ----------------------------------------------------------------------
# per-request counters


def test_win_count_without_qslot_is_detected():
    eng = running_engine()
    r = eng.running[0]
    old = r.qslot, r.win_count
    r.qslot, r.win_count = -1, 2
    msgs = invariants.audit_engine(eng)
    assert any("without a qslot" in m and f"rid {r.rid}" in m
               for m in msgs), msgs
    r.qslot, r.win_count = old


def test_output_overflow_is_detected():
    eng = running_engine()
    r = eng.running[0]
    r.output = list(range(r.max_new_tokens + 3))
    msgs = invariants.audit_engine(eng)
    assert any("max_new_tokens" in m and f"rid {r.rid}" in m
               for m in msgs), msgs
    r.output = []


def test_prefill_cursor_regression_is_detected():
    eng = running_engine()
    r = eng.running[0]
    old = r.n_prefilled, r.prefill_target
    r.n_prefilled, r.prefill_target = 5, 2      # cursor past target
    msgs = invariants.audit_engine(eng)
    assert any("chunked-prefill bookkeeping" in m for m in msgs), msgs
    r.n_prefilled, r.prefill_target = old


def test_block_cap_violation_is_detected():
    eng = running_engine()
    r = next(x for x in eng.running if x.blocks)
    # fake an uncompressed request hoarding far more blocks than seq_len
    extra = eng.bm.allocate(4)
    r.blocks.extend(extra)
    msgs = invariants.audit_engine(eng)
    assert any("over-allocation" in m and f"rid {r.rid}" in m
               for m in msgs), msgs
    eng.bm.release(extra)
    del r.blocks[-len(extra):]


# ----------------------------------------------------------------------
# qwin ownership (free observation-window rows must stay untouched)


def test_qwin_write_to_free_row_is_detected():
    eng = make_engine(m_qslots=2, max_batch=2)
    assert "qwin" in eng.state
    # no request ever ran: all qslots free, none recently dispatched
    eng.host_qslot.fill(-1)
    assert invariants.audit_engine(eng) == []   # arms the shadows
    q = eng.scheduler.free_qslots[0]
    eng.state["qwin"] = eng.state["qwin"].at[:, q].add(1.0)
    msgs = invariants.audit_engine(eng)
    assert any(f"free qslot {q}" in m and "does not own" in m
               for m in msgs), msgs
    assert invariants.audit_engine(eng) == []   # re-armed, not re-reported


def test_qwin_shadow_retired_for_dispatched_qslots():
    eng = make_engine(m_qslots=2, max_batch=2)
    eng.host_qslot.fill(-1)
    assert invariants.audit_engine(eng) == []
    q = eng.scheduler.free_qslots[0]
    eng.host_qslot[0] = q                       # legitimately dispatched
    eng.state["qwin"] = eng.state["qwin"].at[:, q].add(1.0)
    assert invariants.audit_engine(eng) == []   # no false positive


# ----------------------------------------------------------------------
# the env-gated per-step hook


def test_enabled_parses_env(monkeypatch):
    for v, want in (("1", True), ("true", True), ("ON", True),
                    ("0", False), ("", False)):
        monkeypatch.setenv("ZIPAGE_SANITIZE", v)
        assert invariants.enabled() is want
    monkeypatch.delenv("ZIPAGE_SANITIZE")
    assert invariants.enabled() is False


def test_step_hook_raises_when_armed():
    eng = running_engine()
    eng.sanitize = True                        # as if ZIPAGE_SANITIZE=1
    eng.bm.release([next(r for r in eng.running if r.blocks).blocks[0]])
    # the ripped-out ref surfaces either directly (double-free) or as the
    # block being handed out again while still listed (self-aliased /
    # refcount mismatch) — the hook must raise either way
    with pytest.raises(invariants.InvariantViolation,
                       match="double-free|more than once|holder"):
        eng.step()


def test_step_hook_quiet_when_disarmed(monkeypatch):
    monkeypatch.delenv("ZIPAGE_SANITIZE", raising=False)
    eng = running_engine()
    assert eng.sanitize is False
    # corrupt state exactly as in the armed test: disarmed steps must not
    # audit, even under `make test-sanitize` (env controlled above)
    eng.bm.release([next(r for r in eng.running if r.blocks).blocks[0]])
    eng.step()                                 # no raise


def test_restore_clears_qwin_shadows():
    eng = make_engine(n_max=3, m_qslots=4)
    rids = [submit(eng, p, 24) for p in PROMPTS]
    for _ in range(5):
        eng.step()
    assert invariants.audit_engine(eng) == []  # may arm shadows
    snap = eng.snapshot()
    eng2 = make_engine(n_max=3, m_qslots=4)
    invariants.audit_engine(eng2)              # arm shadows on old state
    eng2.restore(snap)
    assert eng2._qwin_shadow == {}             # stale shadows dropped
    done = eng2.run(max_steps=400)
    for rid in rids:
        assert len(done[rid].output) == 24


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
