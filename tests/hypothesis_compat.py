"""Optional-dependency shim for `hypothesis` (dev-only, see
requirements-dev.txt).

When hypothesis is installed this re-exports the real ``given`` /
``settings`` / ``st``. When it is not, the stand-ins mark the decorated
property tests as skipped while letting the module — and its plain pytest
tests — collect and run normally.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _AnyStrategy:
        """st.<anything>(...) placeholder; never executed, only decorated."""

        def __getattr__(self, _name):
            return lambda *a, **k: self

    st = _AnyStrategy()
