"""The seeded reasoning eval harness (repro.eval; docs/EVAL.md).

Fast host-only tests for the task generators, scoring helpers and the
fixed ``agreement()`` in benchmarks/bench_quality_proxy.py, plus one
small end-to-end determinism test: two ``run_eval`` invocations must
render byte-identical ``zipage-eval/v1`` JSON (the property the CI
accuracy gate relies on).
"""
import json

import numpy as np
import pytest

from benchmarks.bench_quality_proxy import agreement
from repro.eval import runner, tasks
from repro.eval.runner import render_report, run_eval, token_agreement

# ----------------------------------------------------------------------
# task generators


def test_eval_set_deterministic_and_prefix_stable():
    a = tasks.eval_set(9, seed=0)
    assert a == tasks.eval_set(9, seed=0)
    assert a != tasks.eval_set(9, seed=1)
    # per-example seed namespace: resizing the set never reshuffles it
    assert tasks.eval_set(6, seed=0) == a[:6]
    assert [k for k, _, _ in a] == list(tasks.TASK_KINDS) * 3


def test_recall_answer_is_queried_value():
    for i in range(5):
        rng = np.random.default_rng(np.random.SeedSequence([7, 1, i]))
        prompt, answer = tasks.make_example("recall", rng)
        assert len(answer) == 1
        q_key = prompt[-2]
        pairs = {prompt[j + 1]: prompt[j + 3]
                 for j in range(0, 4 * tasks.RECALL_PAIRS, 4)}
        assert answer[0] == pairs[q_key]


def test_chain_add_answer_is_running_sum_trace():
    rng = np.random.default_rng(np.random.SeedSequence([7, 1, 1]))
    prompt, answer = tasks.make_example("chain_add", rng)
    assert len(answer) == tasks.CHAIN_DELTAS
    # digits follow every DMARK: first the start value, then the deltas
    digits = [prompt[j + 1] - tasks.DIGIT0
              for j, t in enumerate(prompt) if t == tasks.DMARK]
    acc = digits[0]
    for d, a in zip(digits[1:], answer):
        acc = (acc + d) % 10
        assert a == tasks.DIGIT0 + acc


def test_chain_copy_answer_is_prompt_payload():
    rng = np.random.default_rng(np.random.SeedSequence([7, 1, 2]))
    prompt, answer = tasks.make_example("chain_copy", rng)
    assert answer == prompt[1:1 + tasks.COPY_LEN]
    assert len(answer) == tasks.COPY_LEN


def test_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown eval task kind"):
        tasks.make_example("sudoku", np.random.default_rng(0))


def test_train_batch_masks_loss_to_answer_positions():
    b = tasks.train_batch(3, seq_len=64, batch=4, seed=0)
    b2 = tasks.train_batch(3, seq_len=64, batch=4, seed=0)
    assert all(np.array_equal(b[k], b2[k]) for k in b)
    assert b["tokens"].shape == (4, 64) and b["labels"].shape == (4, 64)
    scored = b["labels"] != tasks.IGNORE
    assert 0 < scored.sum() < scored.size // 2
    # the mask only hides positions, it never rewrites targets: every
    # scored label is the stream's next token (tokens is rows[:, :-1],
    # labels is rows[:, 1:] masked)
    rows_i, cols = np.nonzero(scored[:, :-1])
    assert np.array_equal(b["labels"][rows_i, cols],
                          b["tokens"][rows_i, cols + 1])
    # prompt noise (irreducible entropy) is never a target
    assert not np.isin(b["labels"][scored],
                       np.arange(tasks.NOISE0,
                                 tasks.NOISE0 + tasks.N_NOISE)).any()


# ----------------------------------------------------------------------
# scoring helpers — incl. the agreement() truncation-bug regression


def test_agreement_scores_over_reference_length():
    assert agreement([1, 2, 3, 4], [1, 2, 3, 4]) == 1.0
    # the old min(len(a), len(b)) truncation returned 1.0 here
    assert agreement([1, 2], [1, 2, 3, 4]) == 0.5
    assert agreement([], [1, 2]) == 0.0
    assert agreement([9, 2, 9, 4], [1, 2, 3, 4]) == 0.5
    # extra predicted tokens beyond the reference don't score either way
    assert agreement([1, 2, 3, 4, 5, 6], [1, 2, 3, 4]) == 1.0
    assert agreement([1, 2], []) == 1.0


def test_token_agreement_matches_semantics():
    assert token_agreement([1, 2], [1, 2, 3, 4]) == 0.5
    assert token_agreement([5], [5]) == 1.0
    assert token_agreement([], []) == 1.0


# ----------------------------------------------------------------------
# end-to-end determinism (small budget: cached trained params make the
# second run serving-only)


def test_eval_report_deterministic_and_schema_shaped():
    kw = dict(seed=0, n_requests=6, train_steps=40)
    r1 = run_eval(**kw)
    r2 = run_eval(**kw)
    s1, s2 = render_report(r1), render_report(r2)
    assert s1 == s2                       # byte-for-byte, what CI gates
    report = json.loads(s1)
    assert report["schema"] == runner.EVAL_SCHEMA
    names = [row["name"] for row in report["results"]]
    assert names[0] == "full_kv" and len(names) >= 4
    full = report["results"][0]
    assert full["compressions"] == 0
    if full["accuracy"]:
        assert full["accuracy_vs_full"] == 1.0
    assert full["agreement_vs_full"] == 1.0
    for row in report["results"]:
        # no wall-clock fields anywhere — the determinism precondition
        assert not any("time" in k or "us_" in k for k in row)
        assert row["n"] == 6
        assert set(row["accuracy_by_task"]) == set(tasks.TASK_KINDS)
