"""zipalint pass tests: every rule has at least one failing fixture
proving it fires, plus a good fixture proving it stays quiet, plus the
waiver mechanics (ZPL000 hygiene) and the zero-findings gate on the real
repo (the same gate CI runs via ``make zipalint``)."""
import importlib.util
import sys
from pathlib import Path

import pytest

_TOOL = Path(__file__).resolve().parent.parent / "tools" / "zipalint.py"
_spec = importlib.util.spec_from_file_location("zipalint", _TOOL)
zl = importlib.util.module_from_spec(_spec)
sys.modules["zipalint"] = zl          # dataclasses resolve annotations here
_spec.loader.exec_module(zl)


def ctx_of(modules, docs=None):
    return zl.Context({p: zl.make_module(p, src)
                       for p, src in modules.items()}, docs or {})


def findings(modules, docs=None, rule=None):
    out = zl.analyze(ctx_of(modules, docs))
    return [f for f in out if rule is None or f.rule == rule]


def checked(modules, docs=None):
    """analyze + waivers, like the CLI does."""
    ctx = ctx_of(modules, docs)
    kept, _ = zl.apply_waivers(zl.analyze(ctx), ctx.modules)
    return kept


# ----------------------------------------------------------------------
# ZPL001 host-purity


def test_zpl001_fires_on_jax_import_in_pure_host_module():
    out = findings({"src/repro/core/scheduler.py":
                    "import jax.numpy as jnp\n"}, rule="ZPL001")
    assert len(out) == 1 and out[0].line == 1
    assert "pure-host" in out[0].msg


def test_zpl001_fires_on_device_module_import():
    out = findings({"src/repro/core/block_manager.py":
                    "from repro.core.engine import ZipageEngine\n"},
                   rule="ZPL001")
    assert out, "importing the engine from a pure-host module must fire"


def test_zpl001_quiet_on_host_imports():
    out = findings({"src/repro/core/request.py":
                    "import numpy as np\nfrom collections import deque\n"},
                   rule="ZPL001")
    assert out == []


def test_zpl001_ignores_non_pure_host_modules():
    out = findings({"src/repro/core/serve_model.py": "import jax\n"},
                   rule="ZPL001")
    assert out == []


# ----------------------------------------------------------------------
# ZPL002 jit-boundary host-sync

_BUILDER = "src/repro/core/serve_model.py"


def test_zpl002_fires_on_item_in_builder():
    src = ("def build_decode_step(cfg, spec):\n"
           "    def step(params, state):\n"
           "        n = state['seq_lens'].item()\n"
           "        return n\n"
           "    return step\n")
    out = findings({_BUILDER: src}, rule="ZPL002")
    assert len(out) == 1 and ".item()" in out[0].msg


def test_zpl002_fires_on_branch_on_traced_value():
    src = ("import jax.numpy as jnp\n"
           "def build_decode_step(cfg, spec):\n"
           "    def step(x):\n"
           "        if jnp.sum(x) > 0:\n"
           "            return x\n"
           "        return -x\n"
           "    return step\n")
    out = findings({_BUILDER: src}, rule="ZPL002")
    assert any("`if` on a traced value" in f.msg for f in out)


def test_zpl002_fires_on_np_asarray_and_block_until_ready():
    src = ("import numpy as np\n"
           "def build_prefill_step(cfg, spec):\n"
           "    def step(x):\n"
           "        y = np.asarray(x)\n"
           "        x.block_until_ready()\n"
           "        return y\n"
           "    return step\n")
    msgs = [f.msg for f in findings({_BUILDER: src}, rule="ZPL002")]
    assert any("np.asarray" in m for m in msgs)
    assert any("block_until_ready" in m for m in msgs)


def test_zpl002_fires_in_jit_decorated_def():
    src = ("import functools, jax\n"
           "@functools.partial(jax.jit, static_argnames=('k',))\n"
           "def f(x, k):\n"
           "    return float(x.sum())\n")
    out = findings({"src/repro/kernels/ops.py": src}, rule="ZPL002")
    assert any("float()" in f.msg for f in out)


def test_zpl002_quiet_on_static_python():
    # int() on a static comparison and np.sqrt on config scalars are
    # trace-time constants, not host syncs
    src = ("import numpy as np\n"
           "def build_decode_step(cfg, spec):\n"
           "    scale = 1.0 / np.sqrt(cfg.head_dim)\n"
           "    causal = int(spec.kind == 'decode')\n"
           "    def step(x):\n"
           "        if causal:\n"
           "            return x * scale\n"
           "        return x\n"
           "    return step\n")
    assert findings({_BUILDER: src}, rule="ZPL002") == []


def test_zpl002_ignores_build_functions_outside_builder_modules():
    src = ("def build_optimizer(cfg):\n"
           "    return float(cfg.lr)\n")
    assert findings({"src/repro/launch/train_loop.py": src},
                    rule="ZPL002") == []


# ----------------------------------------------------------------------
# ZPL003 donation safety

_ENG = "src/repro/core/engine.py"


def test_zpl003_fires_on_use_after_donate_local_jit():
    src = ("import jax\n"
           "def run(step, buf, x):\n"
           "    fn = jax.jit(step, donate_argnums=(0,))\n"
           "    out = fn(buf, x)\n"       # buf not rebound -> hazard
           "    return out, buf\n")
    out = findings({_ENG: src}, rule="ZPL003")
    assert len(out) == 1 and "buf" in out[0].msg \
        and "use-after-donate" in out[0].msg


def test_zpl003_quiet_when_donated_arg_rebound():
    src = ("import jax\n"
           "def run(step, buf, x):\n"
           "    fn = jax.jit(step, donate_argnums=(0,))\n"
           "    buf = fn(buf, x)\n"
           "    return buf\n")
    assert findings({_ENG: src}, rule="ZPL003") == []


def test_zpl003_quiet_on_tuple_rebind_of_self_attr():
    src = ("import jax\n"
           "class E:\n"
           "    def setup(self, fwd):\n"
           "        self._decode = jax.jit(fwd, donate_argnums=(1,))\n"
           "    def run(self, toks):\n"
           "        toks, self.state = self._decode(toks, self.state)\n"
           "        return toks\n")
    assert findings({_ENG: src}, rule="ZPL003") == []


def test_zpl003_fires_on_self_attr_not_rebound():
    src = ("import jax\n"
           "class E:\n"
           "    def setup(self, fwd):\n"
           "        self._decode = jax.jit(fwd, donate_argnums=(1,))\n"
           "    def run(self, toks):\n"
           "        out = self._decode(toks, self.state)\n"
           "        return out\n")
    out = findings({_ENG: src}, rule="ZPL003")
    assert len(out) == 1 and "self.state" in out[0].msg


def test_zpl003_fires_on_mixed_donation_factory():
    src = ("import jax\n"
           "def _swap(kind, a, b):\n"
           "    if kind == 'out':\n"
           "        return jax.jit(a)\n"
           "    return jax.jit(b, donate_argnums=(0,))\n")
    out = findings({_ENG: src}, rule="ZPL003")
    assert any("both donating and non-donating" in f.msg for f in out)


def test_zpl003_fires_on_decorated_def_call_site():
    src = ("import functools, jax\n"
           "@functools.partial(jax.jit, donate_argnums=(0,))\n"
           "def scatter(pool, ids):\n"
           "    return pool\n"
           "def caller(pool, ids):\n"
           "    scatter(pool, ids)\n"    # Expr stmt, pool never rebound
           "    return pool\n")
    out = findings({_ENG: src}, rule="ZPL003")
    assert len(out) == 1 and "pool" in out[0].msg


def test_zpl003_skips_call_sites_inside_jit_scopes():
    # donation is ignored under tracing: a donating helper called from
    # inside another jitted function is not a hazard
    src = ("import functools, jax\n"
           "@functools.partial(jax.jit, donate_argnums=(0,))\n"
           "def scatter(pool, ids):\n"
           "    return pool\n"
           "@jax.jit\n"
           "def outer(pool, ids):\n"
           "    scatter(pool, ids)\n"
           "    return pool\n")
    assert findings({_ENG: src}, rule="ZPL003") == []


# ----------------------------------------------------------------------
# ZPL004 config discipline

_CONF = "src/repro/api/config.py"


def _conf_src(extra_field=""):
    return ("import dataclasses\n"
            "@dataclasses.dataclass(frozen=True)\n"
            "class CacheConfig:\n"
            "    block_size: int = 16\n"
            f"{extra_field}")


def test_zpl004_fires_on_undocumented_field():
    mods = {_CONF: _conf_src(),
            "src/repro/core/engine.py": "def f(c):\n    return c.block_size\n"}
    out = findings(mods, docs={"API.md": "nothing here"}, rule="ZPL004")
    assert len(out) == 1 and "not documented" in out[0].msg


def test_zpl004_fires_on_dead_knob():
    mods = {_CONF: _conf_src("    stride: int = 0\n"),
            "src/repro/core/engine.py": "def f(c):\n    return c.block_size\n"}
    out = findings(mods, docs={"API.md": "`block_size` and `stride`"},
                   rule="ZPL004")
    assert len(out) == 1 and "dead knob" in out[0].msg \
        and "stride" in out[0].msg


def test_zpl004_fires_on_field_dropped_by_facade():
    src = (_conf_src("    stride: int = 0\n")
           + "def build_engine_options(c):\n"
           + "    return dict(block_size=c.block_size)\n")
    mods = {_CONF: src,
            "src/repro/core/engine.py":
            "def f(c):\n    return c.block_size + c.stride\n"}
    out = findings(mods, docs={"API.md": "`block_size` and `stride`"},
                   rule="ZPL004")
    assert len(out) == 1 and "build_engine_options" in out[0].msg


def test_zpl004_quiet_when_documented_consumed_and_routed():
    src = (_conf_src()
           + "def build_engine_options(c):\n"
           + "    return dict(block_size=c.block_size)\n")
    mods = {_CONF: src,
            "src/repro/core/engine.py": "def f(c):\n    return c.block_size\n"}
    assert findings(mods, docs={"API.md": "`block_size`"},
                    rule="ZPL004") == []


def test_zpl004_any_docs_page_counts_as_documentation():
    # the corpus is the union of all doc pages, so a knob documented only
    # in a subsystem page (e.g. docs/CACHING.md) is covered without
    # repeating it in API.md
    src = (_conf_src()
           + "def build_engine_options(c):\n"
           + "    return dict(block_size=c.block_size)\n")
    mods = {_CONF: src,
            "src/repro/core/engine.py": "def f(c):\n    return c.block_size\n"}
    assert findings(mods, docs={"CACHING.md": "knobs: `block_size`"},
                    rule="ZPL004") == []


def test_zpl004_corpus_auto_enrolls_new_docs_pages():
    # load_context globs docs/*.md — a new page joins the ZPL004 corpus
    # with no tool change; the cache knobs added with docs/CACHING.md
    # are documented by exactly that enrollment
    ctx = zl.load_context(zl.REPO)
    assert "CACHING.md" in ctx.docs
    for field in ("prefix_cache_policy", "prefix_cache_watermark",
                  "cache_compressed_prefixes"):
        assert f"`{field}`" in ctx.docs["CACHING.md"]


# ----------------------------------------------------------------------
# ZPL005 engine sync discipline


def test_zpl005_fires_on_device_get_outside_fetch():
    src = ("import jax\n"
           "class E:\n"
           "    def peek(self, x):\n"
           "        return jax.device_get(x)\n")
    out = findings({_ENG: src}, rule="ZPL005")
    assert len(out) == 1 and "_fetch" in out[0].msg


def test_zpl005_fires_on_tree_map_asarray():
    src = ("import jax\nimport numpy as np\n"
           "class E:\n"
           "    def dump(self):\n"
           "        return jax.tree.map(np.asarray, self.state)\n")
    out = findings({_ENG: src}, rule="ZPL005")
    assert len(out) == 1 and "whole-tree" in out[0].msg


def test_zpl005_quiet_inside_sanctioned_sync_points():
    src = ("import jax\n"
           "class E:\n"
           "    def _fetch(self, x):\n"
           "        return jax.device_get(x)\n"
           "    def _block_ready(self, x):\n"
           "        jax.block_until_ready(x)\n")
    assert findings({_ENG: src}, rule="ZPL005") == []


def test_zpl005_only_applies_to_engine_module():
    src = ("import jax\n"
           "def peek(x):\n"
           "    return jax.device_get(x)\n")
    assert findings({"src/repro/launch/serve.py": src},
                    rule="ZPL005") == []


# ----------------------------------------------------------------------
# waivers (ZPL000)


def test_waiver_suppresses_finding():
    src = ("import jax  "
           "# zipalint: waive[ZPL001] -- test fixture exercising waivers\n")
    out = checked({"src/repro/core/scheduler.py": src})
    assert out == []


def test_own_line_waiver_applies_to_next_line():
    src = ("# zipalint: waive[ZPL001] -- fixture\n"
           "import jax\n")
    out = checked({"src/repro/core/scheduler.py": src})
    assert out == []


def test_waiver_without_reason_is_a_finding():
    src = "import jax  # zipalint: waive[ZPL001]\n"
    out = checked({"src/repro/core/scheduler.py": src})
    assert [f.rule for f in out] == ["ZPL000"]
    assert "reason" in out[0].msg


def test_waiver_for_unknown_rule_is_a_finding():
    src = "import os  # zipalint: waive[ZPL999] -- no such rule\n"
    out = checked({"src/repro/core/scheduler.py": src})
    rules = {f.rule for f in out}
    assert rules == {"ZPL000"}
    assert any("unknown rule" in f.msg for f in out)


def test_unused_waiver_is_a_finding():
    src = "import os  # zipalint: waive[ZPL001] -- nothing to waive\n"
    out = checked({"src/repro/core/scheduler.py": src})
    assert [f.rule for f in out] == ["ZPL000"]
    assert "unused waiver" in out[0].msg


def test_waiver_does_not_leak_to_other_lines():
    src = ("import os   # zipalint: waive[ZPL001] -- wrong line\n"
           "import jax\n")
    out = checked({"src/repro/core/scheduler.py": src})
    assert {f.rule for f in out} == {"ZPL000", "ZPL001"}


# ----------------------------------------------------------------------
# the real repo gates at zero findings


def test_repo_is_clean():
    assert zl.main([]) == 0


def test_list_rules_covers_all_passes(capsys):
    assert zl.main(["--list-rules"]) == 0
    text = capsys.readouterr().out
    for rule, _fn in zl.PASSES:
        assert rule in text
    assert len(zl.PASSES) >= 4


def test_findings_render_file_line_rule():
    f = zl.Finding("src/x.py", 3, "ZPL001", "boom")
    assert f.render() == "src/x.py:3: ZPL001 boom"


def test_bad_waiver_syntax_is_not_parsed_as_waiver():
    # regression guard: a comment mentioning zipalint without the exact
    # waive[...] shape must not suppress anything
    src = "import jax  # zipalint waive ZPL001 reasons\n"
    out = checked({"src/repro/core/scheduler.py": src})
    assert [f.rule for f in out] == ["ZPL001"]


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
