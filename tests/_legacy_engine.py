"""FROZEN pre-refactor copy of ``repro.core.engine.ZipageEngine`` (PR 2 state).

Used ONLY by the old-vs-new scheduler parity test
(tests/test_scheduler.py::test_fcfs_parity_with_legacy_engine): the
extracted ``repro.core.scheduler.Scheduler`` with the default FCFS policy
must reproduce this engine's token streams exactly on a mixed concurrent
workload. Do not modify the scheduling logic here; if a future PR changes
shared building blocks (serve_model/BlockManager/compression) in ways that
break this copy, re-freeze it against the then-current engine and re-record
parity. Not part of the public surface.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core import serve_model
from repro.core.block_manager import BlockManager
from repro.core.compression import CompressOptions, build_compress_fn
from repro.core.request import FinishReason, Request, State
from repro.core.sampling import SamplingParams, sample_batch


@dataclasses.dataclass(frozen=True)
class EngineOptions:
    block_size: int = 16
    n_total_blocks: int = 256
    max_batch: int = 16              # decode slots
    m_qslots: int = 8                # paper's M (query-slot pool)
    n_max: Optional[int] = 4         # block cap; None => full-KV baseline
    window: int = 4                  # observation window w
    scheduling: str = "hybrid"       # hybrid | constrained
    prefix_caching: bool = True
    async_compression: bool = True
    compress: CompressOptions = dataclasses.field(
        default_factory=lambda: CompressOptions(window=4))
    max_model_len: int = 512
    prefill_rows: int = 4
    prefill_len: int = 128
    # Deprecated: engine-global sampling knobs, kept as defaults for the
    # legacy ``submit()`` path only. New code passes a per-request
    # ``SamplingParams`` via ``add_request()`` / the ``repro.api`` facade.
    temperature: float = 0.0         # 0 => greedy
    seed: int = 0
    dtype: str = "float32"
    layer_stride: int = 0            # 0 => all layers in one compress call
    measure_phases: bool = False     # block per phase for timing benches
    # engine-wide kernel backend (repro.kernels.ops): auto | jnp |
    # pallas-interpret | pallas-tpu, plus "chunked" (decode attention only).
    # Drives ServeSpec.attn_backend and — when compress.backend is left at
    # "auto" — the compression kernels too.
    kernel_backend: str = "auto"


class LegacyZipageEngine:
    def __init__(self, cfg: ArchConfig, params, opts: EngineOptions):
        # compression inherits the engine-wide kernel backend unless its
        # CompressOptions.backend was configured away from "auto"
        # ("chunked" is decode-attention-only and does not propagate)
        if opts.compress.backend == "auto" \
                and opts.kernel_backend not in ("auto", "chunked"):
            opts = dataclasses.replace(opts, compress=dataclasses.replace(
                opts.compress, backend=opts.kernel_backend))
        self.cfg = cfg
        self.opts = opts
        self.params = params
        b = opts.block_size
        assert opts.window == opts.compress.window
        self.compression_enabled = (
            opts.n_max is not None and not cfg.attention_free
            and not cfg.local_window)
        self.budget_blocks = (opts.n_max - 1) if self.compression_enabled else 0
        self.max_blocks = -(-opts.max_model_len // b)
        self.spec = serve_model.ServeSpec(
            n_slots=opts.max_batch, block_size=b, max_blocks=self.max_blocks,
            n_total_blocks=opts.n_total_blocks, m_qslots=opts.m_qslots,
            window=opts.window, prefill_rows=opts.prefill_rows,
            prefill_len=opts.prefill_len, dtype=opts.dtype,
            attn_backend=opts.kernel_backend)
        prefix_ok = (opts.prefix_caching and not cfg.attention_free
                     and not cfg.local_window and not cfg.is_enc_dec)
        self.bm = BlockManager(opts.n_total_blocks, b,
                               enable_prefix_cache=prefix_ok)
        self.prefix_ok = prefix_ok
        self.state = serve_model.make_state(cfg, self.spec)
        self._decode = jax.jit(serve_model.build_decode_step(cfg, self.spec),
                               donate_argnums=(1,))
        self._prefill = jax.jit(serve_model.build_prefill_step(cfg, self.spec),
                                donate_argnums=(1,))
        self._compress_fns: Dict[int, callable] = {}
        # host mirrors (authoritative for scheduling)
        self.host_bt = np.full((opts.max_batch, self.max_blocks), -1, np.int32)
        self.host_seq = np.zeros((opts.max_batch,), np.int32)
        self.host_pos = np.zeros((opts.max_batch,), np.int32)
        self.host_qslot = np.full((opts.max_batch,), -1, np.int32)
        self.tokens_next = np.zeros((opts.max_batch,), np.int32)

        self.waiting: deque = deque()
        self.running: List[Request] = []     # FCFS order
        self.finished: Dict[int, Request] = {}
        self.free_slots = list(range(opts.max_batch - 1, -1, -1))
        self.free_qslots = list(range(opts.m_qslots - 1, -1, -1))
        self._rid = 0
        self._rng = np.random.default_rng(opts.seed)
        self._sampler = jax.jit(sample_batch)
        self.metrics: List[dict] = []
        self.step_count = 0
        self._ring = (self.spec.ring_blocks(cfg) if cfg.local_window else 0)
        # straggler-aware admission: EWMA of step latency vs baseline
        self._ewma = None
        self.admission_scale = 1.0

    # ------------------------------------------------------------------
    def add_request(self, prompt,
                    sampling: Optional[SamplingParams] = None) -> int:
        """Enqueue a request with per-request ``SamplingParams``. This is
        the primary entry point (the ``repro.api.Zipage`` facade calls it);
        ``submit()`` remains as a deprecated shim."""
        if sampling is None:
            sampling = SamplingParams(temperature=self.opts.temperature,
                                      seed=self._default_seed())
        assert len(prompt) + sampling.max_new_tokens \
            <= self.opts.max_model_len, "request exceeds max_model_len"
        rid = self._rid
        self._rid += 1
        self.waiting.append(Request(
            rid=rid, prompt=list(map(int, prompt)),
            max_new_tokens=sampling.max_new_tokens, sampling=sampling,
            arrival=time.monotonic()))
        return rid

    def _default_seed(self) -> int:
        """Decorrelate per-request streams under the engine-global seed:
        identical seeds would replay identical draws per position."""
        return (self.opts.seed * 1_000_003 + self._rid) & 0xFFFFFFFF

    def submit(self, prompt, max_new_tokens, eos_id=None) -> int:
        """Deprecated: legacy entry point with the ``eos_id=-1`` sentinel
        (which can collide with masked/negative token conventions). Routes
        through :class:`SamplingParams`; prefer ``add_request()`` or the
        ``repro.api.Zipage`` facade. Bare ``submit(prompt, n)`` keeps its
        historical behavior (engine-global temperature/seed, no eos)."""
        if eos_id is not None:
            warnings.warn(
                "submit(..., eos_id=...) is deprecated; pass "
                "SamplingParams(eos_ids=(...)) to add_request() instead "
                "(eos_id=-1 meant 'disabled')", DeprecationWarning,
                stacklevel=2)
        return self.add_request(prompt, SamplingParams.from_legacy(
            max_new_tokens, -1 if eos_id is None else eos_id,
            temperature=self.opts.temperature, seed=self._default_seed()))

    def abort(self, rid: int) -> bool:
        """Cancel a request mid-flight: remove it from the waiting queue or
        the running batch, return its blocks to the pool, and record it as
        finished with reason ``"abort"``. Returns False if the rid is
        unknown or already finished."""
        for r in list(self.waiting):
            if r.rid == rid:
                self.waiting.remove(r)
                break
        else:
            for r in self.running:
                if r.rid == rid:
                    self._release_slots(r)
                    self.running.remove(r)
                    break
            else:
                return False
        r.state = State.FINISHED
        r.finish_reason = FinishReason.ABORT
        r.t_finish = time.monotonic()
        self.finished[rid] = r
        return True

    # ------------------------------------------------------------------
    # scheduling helpers

    def _needed_blocks(self, n_tokens):
        if self.cfg.attention_free:
            return 0
        if self._ring:
            return self._ring
        return -(-n_tokens // self.opts.block_size)

    def _assign_qslots(self):
        """Paper §4.3 rule 3: free query slots go to the foremost running
        requests lacking one (only first M are eligible)."""
        if not self.compression_enabled:
            return
        for i, r in enumerate(self.running):
            if not self.free_qslots:
                break
            if i >= self.opts.m_qslots:
                break
            if r.qslot < 0 and r.state != State.FINISHED:
                r.qslot = self.free_qslots.pop()
                self.host_qslot[r.slot] = r.qslot
                if r.state == State.BLOCKED:
                    r.state = State.RUNNING

    def _can_decode_slotless(self, r: Request) -> bool:
        """Hybrid rule: decode without a qslot while < N_max blocks or
        < b - w tokens in the last block."""
        b, w = self.opts.block_size, self.opts.window
        return (r.n_blocks < self.opts.n_max
                or r.tokens_in_last_block(b) < b - w)

    def _release_slots(self, r: Request):
        """Return r's blocks, decode slot and query slot to their pools and
        clear the host mirrors (shared by preempt/finish/abort)."""
        self.bm.release(r.blocks)
        r.blocks = []
        if r.slot >= 0:
            self.host_bt[r.slot] = -1
            self.host_qslot[r.slot] = -1
            self.free_slots.append(r.slot)
        if r.qslot >= 0:
            self.free_qslots.append(r.qslot)
        r.slot = r.qslot = -1

    def _preempt(self, r: Request):
        self._release_slots(r)
        r.compressed = False
        r.seq_len = r.position = 0
        r.n_cached = 0
        r.win_count = 0
        r.preempt_count += 1
        r.state = State.WAITING
        self.running.remove(r)
        self.waiting.appendleft(r)       # front of waiting queue (§3)

    def _preempt_for_blocks(self, n_needed, requester: Request) -> bool:
        """Free blocks via preemption per §4.3/§4.4 rules. Returns success."""
        while not self.bm.can_allocate(n_needed):
            victim = None
            if self.opts.scheduling == "hybrid":
                for r in reversed(self.running):
                    if r is requester or r.state == State.FINISHED:
                        continue
                    if r.qslot < 0:
                        victim = r
                        break
            if victim is None and self.prefix_ok:
                # §4.4: preempt the last *uncompressed* request
                for r in reversed(self.running):
                    if r is requester or r.state == State.FINISHED:
                        continue
                    if not r.compressed:
                        victim = r
                        break
            if victim is None:
                return False
            self._preempt(victim)
        return True

    # ------------------------------------------------------------------
    def _admit(self):
        admitted = []
        limit = max(1, int(self.opts.prefill_rows * self.admission_scale))
        while (self.waiting and len(admitted) < limit and self.free_slots):
            r = self.waiting[0]
            if self.opts.scheduling == "constrained" \
                    and self.compression_enabled and not self.free_qslots:
                break
            prompt = r.full_prompt
            if self.prefix_ok:
                shared, n_cached, chain = self.bm.lookup_prefix(prompt)
            else:
                shared, n_cached, chain = [], 0, []
            n_new = self._needed_blocks(len(prompt)) - len(shared)
            if not self.bm.can_allocate(n_new):
                # roll back the prefix refs and stop admitting (FCFS)
                if shared:
                    self.bm.release(shared)
                break
            new_blocks = self.bm.allocate(n_new) if n_new else []
            r.blocks = shared + new_blocks
            r.n_cached, r.chain, r.n_shared = n_cached, chain, len(shared)
            if self.prefix_ok and chain:
                self.bm.register_prefix(r.blocks, chain, len(shared))
            r.slot = self.free_slots.pop()
            if self.compression_enabled and self.free_qslots \
                    and len(self.running) < self.opts.m_qslots:
                r.qslot = self.free_qslots.pop()
            r.seq_len = (min(len(prompt), self._ring) if self._ring
                         else (0 if self.cfg.attention_free else len(prompt)))
            r.position = len(prompt)
            r.state = State.RUNNING
            self.host_bt[r.slot] = -1
            self.host_bt[r.slot, :len(r.blocks)] = r.blocks
            self.host_seq[r.slot] = r.seq_len
            self.host_pos[r.slot] = r.position
            self.host_qslot[r.slot] = r.qslot
            self.waiting.popleft()
            self.running.append(r)
            admitted.append(r)
        return admitted

    def _run_prefill(self, admitted):
        """Chunked prefill: suffixes longer than the prefill bucket are fed
        in multiple rounds (the paged prefill step is chunk-capable via
        start_pos — the same mechanism prefix-cache hits use)."""
        P, S = self.opts.prefill_rows, self.opts.prefill_len
        remaining = {r.rid: list(r.full_prompt[r.n_cached:])
                     for r in admitted}
        offset = {r.rid: r.n_cached for r in admitted}
        pending = list(admitted)
        while pending:
            batch = pending[:P]
            toks = np.zeros((P, S), np.int32)
            slot_ids = np.full((P,), -1, np.int32)
            lengths = np.zeros((P,), np.int32)
            start = np.zeros((P,), np.int32)
            kw = {}
            if self.cfg.is_enc_dec:
                kw["frame_embeds"] = jnp.zeros(
                    (P, self.cfg.cross_seq_len, self.cfg.d_model),
                    jnp.float32)
            final = []
            for i, r in enumerate(batch):
                chunk = remaining[r.rid][:S]
                toks[i, :len(chunk)] = chunk
                slot_ids[i] = r.slot
                lengths[i] = len(chunk)
                start[i] = offset[r.rid]
                remaining[r.rid] = remaining[r.rid][len(chunk):]
                offset[r.rid] += len(chunk)
                if not remaining[r.rid]:
                    final.append((i, r, len(chunk)))
            self._push_host_state()
            logits, self.state = self._prefill(
                self.params, self.state, jnp.asarray(toks),
                jnp.asarray(slot_ids), jnp.asarray(lengths),
                jnp.asarray(start), **kw)
            # only rows finishing their last chunk consume a sample
            row_reqs: List[Optional[Request]] = [None] * P
            for i, r, _n in final:
                row_reqs[i] = r
            tok, lp = self._sample_rows(logits, row_reqs)
            for i, r, chunk_len in final:
                self.tokens_next[r.slot] = tok[i]
                self._record_token(r, tok[i], None if lp is None else lp[i])
                if r.qslot >= 0:
                    r.win_count = min(self.opts.window, chunk_len)
            still = [r for r in batch if remaining[r.rid]]
            pending = still + pending[P:]

    # ------------------------------------------------------------------
    def _compress_fn(self, n):
        if n not in self._compress_fns:
            fn = build_compress_fn(
                self.cfg, block_size=self.opts.block_size,
                max_blocks=self.max_blocks,
                budget_blocks=self.budget_blocks, opts=self.opts.compress)
            self._compress_fns[n] = jax.jit(fn)
        return self._compress_fns[n]

    def _detect_compression(self):
        if not self.compression_enabled:
            return []
        b = self.opts.block_size
        out = []
        for r in self.running:
            if (r.state in (State.RUNNING, State.BLOCKED) and r.qslot >= 0
                    and r.n_blocks >= self.opts.n_max
                    and r.seq_len == r.n_blocks * b
                    and r.win_count >= self.opts.window):
                out.append(r)
        return out

    def _plan_compression(self, comp):
        """Choose destination blocks (§4.4) and handle allocation pressure.
        Returns list of (request, dest_blocks, reserved_block, to_release)."""
        planned = []
        nb = self.budget_blocks
        for r in comp:
            shared_idx = [i for i, blk in enumerate(r.blocks)
                          if self.bm.is_shared(blk)]
            n_prefix = len(shared_idx)
            need = 0
            if n_prefix:
                need = min(n_prefix, nb)
                if self.bm.is_shared(r.blocks[min(nb, r.n_blocks - 1)]):
                    need += 1                      # reserved must be fresh too
            if need and not self.bm.can_allocate(need):
                if not self._preempt_for_blocks(need, r):
                    r.state = State.BLOCKED        # retry next step
                    continue
            if n_prefix == 0:
                dest = r.blocks[:nb]
                reserved = r.blocks[nb]
                release = r.blocks[nb + 1:]
            else:
                fresh = self.bm.allocate(min(n_prefix, nb))
                dest = fresh + r.blocks[n_prefix:][:nb - len(fresh)]
                if self.bm.is_shared(r.blocks[min(nb, r.n_blocks - 1)]):
                    reserved = self.bm.allocate(1)[0]
                    keep = set(dest) | {reserved}
                    release = [blk for blk in r.blocks if blk not in keep]
                else:
                    reserved = r.blocks[nb] if len(r.blocks) > nb else \
                        self.bm.allocate(1)[0]
                    keep = set(dest) | {reserved}
                    release = [blk for blk in r.blocks if blk not in keep]
            planned.append((r, dest, reserved, release))
        return planned

    def _launch_compression(self, planned):
        if not planned:
            return None
        n = 1
        while n < len(planned):
            n *= 2
        src_bt = np.full((n, self.max_blocks), -1, np.int32)
        dest_bt = np.full((n, self.budget_blocks), -1, np.int32)
        qslots = np.full((n,), -1, np.int32)
        seq_lens = np.zeros((n,), np.int32)
        hist = np.zeros((n,), np.int32)
        for i, (r, dest, _res, _rel) in enumerate(planned):
            src_bt[i, :r.n_blocks] = r.blocks
            dest_bt[i] = dest
            qslots[i] = r.qslot
            seq_lens[i] = r.seq_len
            hist[i] = self.budget_blocks * self.opts.block_size \
                if r.compressed else 0
        pools = self.state["pools"]
        req = (jnp.asarray(src_bt), jnp.asarray(dest_bt), jnp.asarray(qslots),
               jnp.asarray(seq_lens), jnp.asarray(hist))
        new_pools, *_ = self._compress_fn(n)(pools, self.state["qwin"], req)
        self.state["pools"] = new_pools
        # host bookkeeping is deterministic — apply immediately
        k = self.budget_blocks * self.opts.block_size
        for r, dest, reserved, release in planned:
            shared_released = [blk for blk in release if self.bm.ref[blk] > 1]
            self.bm.release(release)
            r.n_compressions += 1
            r.comp_blocks_freed += len(release) - len(shared_released)
            r.blocks = list(dest) + [reserved]
            r.seq_len = k
            r.compressed = True
            r.n_shared = 0
            self.host_bt[r.slot] = -1
            self.host_bt[r.slot, :len(r.blocks)] = r.blocks
            self.host_seq[r.slot] = r.seq_len
            if self.opts.async_compression:
                r.state = State.COMPRESSING     # sits out this decode step
        return new_pools

    # ------------------------------------------------------------------
    def _prepare_decode(self):
        """Ensure every decodable request has room for one token; apply
        blocking/preemption rules. Returns the active list."""
        b = self.opts.block_size
        active = []
        for r in list(self.running):
            if r.state == State.COMPRESSING:
                continue
            if r.done():
                # already terminated (eos/stop on the prefill-sampled
                # token); decoding again would bury the match under a
                # second token before _finish sees it
                continue
            if r.state == State.BLOCKED:
                r.state = State.RUNNING          # retry below
            if r not in self.running:            # got preempted this step
                continue
            if self.cfg.attention_free:
                active.append(r)
                continue
            if self._ring:
                active.append(r)
                continue
            # hybrid slotless boundary rule
            if (self.compression_enabled and r.qslot < 0
                    and not self._can_decode_slotless(r)):
                r.state = State.BLOCKED
                continue
            if r.seq_len == r.n_blocks * b:      # last block full
                if (self.compression_enabled and r.qslot >= 0
                        and r.n_blocks >= self.opts.n_max
                        and r.win_count >= self.opts.window):
                    # compression will handle it (was detected this step or
                    # will be next step); skip decode if it somehow races
                    r.state = State.BLOCKED
                    continue
                ok = self.bm.can_allocate(1) or \
                    self._preempt_for_blocks(1, r)
                if not ok or r not in self.running:
                    if r in self.running:
                        r.state = State.BLOCKED
                    continue
                blk = self.bm.allocate(1)[0]
                r.blocks.append(blk)
                self.host_bt[r.slot, r.n_blocks - 1] = blk
            active.append(r)
        return [r for r in active if r in self.running]

    def _push_host_state(self):
        self.state["block_tables"] = jnp.asarray(self.host_bt)
        self.state["seq_lens"] = jnp.asarray(self.host_seq)
        self.state["positions"] = jnp.asarray(self.host_pos)
        self.state["qslot"] = jnp.asarray(self.host_qslot)

    def _sample_rows(self, logits, reqs: Sequence[Optional[Request]]):
        """Sample one token per row; ``reqs[i]`` is the request occupying
        row i (None for padding rows). All-greedy batches with no logprob
        consumers take the cheap argmax path; otherwise the jitted
        per-row sampler runs with each request's (seed, n_generated) PRNG
        state, so outputs are independent of batch composition.
        Returns (tokens, logprobs) as numpy; logprobs is None on the
        fast path."""
        if not any(r is not None and (not r.sampling.is_greedy
                                      or r.sampling.logprobs)
                   for r in reqs):
            return np.asarray(jnp.argmax(logits, -1)), None
        n = logits.shape[0]
        seeds = np.zeros((n,), np.uint32)
        counters = np.zeros((n,), np.int32)
        temps = np.zeros((n,), np.float32)
        top_k = np.zeros((n,), np.int32)
        top_p = np.ones((n,), np.float32)
        for i, r in enumerate(reqs):
            if r is None:
                continue
            sp = r.sampling
            seeds[i] = np.uint32(sp.seed & 0xFFFFFFFF)
            counters[i] = len(r.output)
            temps[i] = sp.temperature
            top_k[i] = sp.top_k
            top_p[i] = sp.top_p
        tok, lp = self._sampler(
            logits, jnp.asarray(seeds), jnp.asarray(counters),
            jnp.asarray(temps), jnp.asarray(top_k), jnp.asarray(top_p))
        return np.asarray(tok), np.asarray(lp)

    @staticmethod
    def _record_token(r: Request, tok: int, lp) -> None:
        r.output.append(int(tok))
        if r.sampling.logprobs and lp is not None:
            r.logprobs.append(float(lp))
        if r.t_first_token is None:
            r.t_first_token = time.monotonic()

    def _run_decode(self, active):
        if not active:
            return
        mask = np.zeros((self.opts.max_batch,), bool)
        for r in active:
            mask[r.slot] = True
        self._push_host_state()
        logits, self.state = self._decode(
            self.params, self.state, jnp.asarray(self.tokens_next),
            jnp.asarray(mask))
        slot_reqs: List[Optional[Request]] = [None] * self.opts.max_batch
        for r in active:
            slot_reqs[r.slot] = r
        tok, lp = self._sample_rows(logits, slot_reqs)
        for r in active:
            t = int(tok[r.slot])
            self.tokens_next[r.slot] = t
            self._record_token(r, t, None if lp is None else lp[r.slot])
            if r.qslot >= 0:
                r.win_count = min(self.opts.window, r.win_count + 1)
            r.seq_len = min(r.seq_len + 1, self._ring) if self._ring \
                else (r.seq_len if self.cfg.attention_free else r.seq_len + 1)
            r.position += 1
            self.host_seq[r.slot] = r.seq_len
            self.host_pos[r.slot] = r.position

    def _finish(self):
        for r in list(self.running):
            if r.state != State.COMPRESSING \
                    and (reason := r.check_finish()) is not None:
                r.finish_reason = reason
                r.truncate_stop()
                self._release_slots(r)
                r.state = State.FINISHED
                r.t_finish = time.monotonic()
                self.running.remove(r)
                self.finished[r.rid] = r

    # ------------------------------------------------------------------
    def step(self):
        t0 = time.monotonic()
        self.step_count += 1
        self._assign_qslots()
        admitted = self._admit()
        t_admit = time.monotonic()
        if admitted:
            self._run_prefill(admitted)
            if self.opts.measure_phases:
                jax.block_until_ready(self.state["pools"]
                                      if "pools" in self.state
                                      else self.state["rec"])
        t_prefill = time.monotonic()
        comp = self._detect_compression()
        planned = self._plan_compression(comp) if comp else []
        self._launch_compression(planned)
        if planned and (self.opts.measure_phases
                        or not self.opts.async_compression):
            jax.block_until_ready(self.state["pools"])
            if not self.opts.async_compression:
                for r, *_ in planned:
                    r.state = State.RUNNING      # decode this very step
        t_comp = time.monotonic()
        active = self._prepare_decode()
        self._run_decode(active)
        if self.opts.measure_phases:
            jax.block_until_ready(self.state["pools"]
                                  if "pools" in self.state
                                  else self.state["rec"])
        t_dec = time.monotonic()
        # async-compressed requests rejoin next step
        for r in self.running:
            if r.state == State.COMPRESSING:
                r.state = State.RUNNING
        self._finish()
        used = self.opts.n_total_blocks - self.bm.num_free
        self.metrics.append({
            "step": self.step_count,
            "t_total": t_dec - t0,
            "t_prefill": t_prefill - t_admit,
            "t_compress": t_comp - t_prefill,
            "t_decode": t_dec - t_comp,
            "n_running": len(self.running),
            "n_waiting": len(self.waiting),
            "n_active": len(active),
            "n_compressing": len(planned),
            "n_prefilled": len(admitted),
            "block_util": used / self.opts.n_total_blocks,
            "tokens": len(active) + len(admitted),
        })
        # straggler-aware admission: back off when step latency inflates
        dt = t_dec - t0
        self._ewma = dt if self._ewma is None else 0.9 * self._ewma + 0.1 * dt
        if self._ewma > 0 and dt > 3.0 * self._ewma:
            self.admission_scale = max(0.25, self.admission_scale * 0.5)
        else:
            self.admission_scale = min(1.0, self.admission_scale * 1.1)

    def run(self, max_steps=10_000):
        while (self.waiting or self.running) and self.step_count < max_steps:
            self.step()
        return {r.rid: r for r in self.finished.values()}

    # ------------------------------------------------------------------
    # fault tolerance: full engine snapshot/restore

    def snapshot(self):
        import copy
        dev = {k: jax.tree.map(np.asarray, v) for k, v in self.state.items()}
        return {
            "device": dev,
            "host": copy.deepcopy({
                "bt": self.host_bt, "seq": self.host_seq,
                "pos": self.host_pos, "qslot": self.host_qslot,
                "tokens_next": self.tokens_next,
                "free_slots": self.free_slots,
                "free_qslots": self.free_qslots,
                "rid": self._rid, "step": self.step_count,
            }),
            "requests": copy.deepcopy({
                "waiting": list(self.waiting),
                "running": self.running,
                "finished": self.finished,
            }),
            "bm": copy.deepcopy(self.bm),
        }

    def restore(self, snap):
        import copy
        self.state = {k: jax.tree.map(jnp.asarray, v)
                      for k, v in snap["device"].items()}
        h = copy.deepcopy(snap["host"])
        self.host_bt, self.host_seq = h["bt"], h["seq"]
        self.host_pos, self.host_qslot = h["pos"], h["qslot"]
        self.tokens_next = h["tokens_next"]
        self.free_slots, self.free_qslots = h["free_slots"], h["free_qslots"]
        self._rid, self.step_count = h["rid"], h["step"]
        r = copy.deepcopy(snap["requests"])
        self.waiting = deque(r["waiting"])
        self.running = r["running"]
        self.finished = r["finished"]
        self.bm = copy.deepcopy(snap["bm"])
