"""Block manager + memory planner tests (incl. hypothesis stateful-ish)."""
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.block_manager import BlockManager, OutOfBlocks
from repro.core.memory_planner import plan_memory


def test_alloc_release_roundtrip():
    bm = BlockManager(8, 4)
    a = bm.allocate(3)
    assert bm.num_free == 5
    b = bm.allocate(5)
    assert bm.num_free == 0
    with pytest.raises(OutOfBlocks):
        bm.allocate(1)
    bm.release(a)
    assert bm.num_free == 3
    bm.release(b)
    bm.check_invariants()


def test_prefix_cache_hit_and_refcount():
    bm = BlockManager(16, 4)
    toks = list(range(10))                       # 2 full blocks + 2 tokens
    blocks, matched, chain = bm.lookup_prefix(toks)
    assert matched == 0 and blocks == [] and len(chain) == 2
    alloc = bm.allocate(3)
    bm.register_prefix(alloc, chain, 0)
    # second request with same prefix
    blocks2, matched2, chain2 = bm.lookup_prefix(toks)
    assert matched2 == 8
    assert blocks2 == alloc[:2]
    assert all(bm.is_shared(b) for b in blocks2)
    assert chain2 == chain
    bm.release(blocks2)
    assert not any(bm.is_shared(b) for b in alloc[:2])
    bm.check_invariants()


def test_cached_blocks_survive_release_until_eviction():
    bm = BlockManager(4, 2)
    toks = [1, 2, 3, 4]
    _, _, chain = bm.lookup_prefix(toks)
    alloc = bm.allocate(2)
    bm.register_prefix(alloc, chain, 0)
    bm.release(alloc)
    assert bm.num_free == 4                      # reusable, not lost
    blocks, matched, _ = bm.lookup_prefix(toks)  # resurrect from cached_free
    assert matched == 4 and blocks == alloc
    bm.release(blocks)
    # exhaust memory -> cached blocks get evicted
    other = bm.allocate(4)
    blocks3, matched3, _ = bm.lookup_prefix(toks)
    assert matched3 == 0
    bm.release(other)
    bm.check_invariants()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 5)), min_size=1,
                max_size=40))
def test_property_never_leaks_blocks(ops):
    bm = BlockManager(12, 4)
    held = []
    for is_alloc, n in ops:
        if is_alloc:
            if bm.can_allocate(n):
                held.append(bm.allocate(n))
        elif held:
            bm.release(held.pop())
        bm.check_invariants()
    for h in held:
        bm.release(h)
    bm.check_invariants()
    assert bm.num_free == 12


# ----------------------------------------------------------------------
# radix prefix cache (docs/CACHING.md)


def rbm(n_blocks=16, block_size=4, **kw):
    return BlockManager(n_blocks, block_size,
                        prefix_cache_policy="radix", **kw)


@pytest.mark.parametrize("policy", ["flat", "radix"])
def test_release_cleans_hash_when_cache_disabled(policy):
    """Regression: freeing a registered block after enable_prefix_cache
    was toggled off at runtime (snapshot/restore) used to leave a stale
    hash entry pointing at a raw-free block."""
    bm = BlockManager(8, 4, prefix_cache_policy=policy)
    toks = list(range(8))
    _, _, chain = bm.lookup_prefix(toks)
    alloc = bm.allocate(2)
    bm.register_prefix(alloc, chain, 0)
    bm.enable_prefix_cache = False
    bm.release(alloc)
    assert not bm.block_hash and not bm.hash_to_block
    assert not bm.cached_free and len(bm.free) == 8
    bm.check_invariants()


def test_flat_radix_exact_match_parity():
    """Both policies give byte-identical results through the legacy
    exact-match lookup on shared-prefix prompts."""
    results = {}
    for policy in ("flat", "radix"):
        bm = BlockManager(16, 4, prefix_cache_policy=policy)
        _, _, chain = bm.lookup_prefix(list(range(12)))
        alloc = bm.allocate(3)
        bm.register_prefix(alloc, chain, 0)
        blocks, matched, _ = bm.lookup_prefix(
            list(range(8)) + [99, 98, 97, 96])
        results[policy] = (len(blocks), matched,
                           [alloc.index(b) for b in blocks])
        bm.release(blocks)
        bm.release(alloc)
        bm.check_invariants()
    assert results["flat"] == results["radix"] == (2, 8, [0, 1])


def test_radix_full_hit_capped_one_block():
    """A match covering the whole prompt is capped one block short so the
    final prefill chunk still carries a real token (bit-identical hit vs
    miss streams); the legacy lookup stays uncapped."""
    bm = rbm()
    toks = list(range(8))
    m0 = bm.lookup_prefix_ex(toks)
    assert m0.n_tokens == 0 and m0.blocks == []
    alloc = bm.allocate(2)
    bm.register_prefix(alloc, m0.chain, 0)
    m = bm.lookup_prefix_ex(toks)
    assert m.n_tokens == 4 and m.blocks == alloc[:1] and not m.compressed
    bm.release(m.blocks)
    blocks, matched, _ = bm.lookup_prefix(toks)     # legacy: uncapped
    assert matched == 8
    bm.release(blocks)
    bm.release(alloc)
    bm.check_invariants()


def test_radix_evicts_leaves_before_shared_prefix():
    """LRU eviction under the radix policy is leaf-first: the cold end of
    a cached chain goes before the shared root, even though the root was
    released (and so parked) earliest."""
    bm = rbm(n_blocks=6, block_size=2)
    m = bm.lookup_prefix_ex([1, 2, 3, 4, 5, 6])
    alloc = bm.allocate(3)
    bm.register_prefix(alloc, m.chain, 0)
    bm.release(alloc)                    # root parked first => flat would
    other = bm.allocate(4)               # evict it; radix must take leaf
    assert alloc[2] not in bm.block_hash, "leaf should be evicted"
    assert alloc[0] in bm.block_hash and alloc[1] in bm.block_hash
    assert bm.probe_prefix([1, 2, 3, 4, 5, 6]) == 4
    bm.release(other)
    bm.check_invariants()


def test_invalidate_blocks_drops_subtree():
    bm = rbm(n_blocks=8, block_size=4)
    m = bm.lookup_prefix_ex(list(range(12)))
    alloc = bm.allocate(3)
    bm.register_prefix(alloc, m.chain, 0)
    bm.release(alloc)
    bm.invalidate_blocks([alloc[1]])     # mid-chain: child goes too
    assert alloc[0] in bm.block_hash
    assert alloc[1] not in bm.block_hash and alloc[2] not in bm.block_hash
    # orphans left cached_free for the raw free list
    assert alloc[1] in bm.free and alloc[2] in bm.free
    assert bm.n_invalidated_blocks == 2
    bm.check_invariants()


def test_segment_register_hit_and_eviction():
    """Compressed cached prefix: 12 tokens of history served from 8 KV
    entries; the hit reports the token/entry gap, and allocation pressure
    evicts the payload all-or-none."""
    bm = rbm(n_blocks=8, block_size=4)
    chain = bm._block_chain(list(range(16)))
    payload = bm.allocate(2)
    bm.register_segment(chain[2], payload, 12)
    bm.release(payload)
    prompt2 = list(range(12)) + [7, 7, 7, 7, 9]
    m = bm.lookup_prefix_ex(prompt2, allow_compressed=True)
    assert m.compressed and m.n_tokens == 12 and m.n_entries == 8
    assert m.blocks == payload
    assert all(bm.ref[b] == 1 for b in payload)
    assert bm.cache_stats()["prefix_segment_hits"] == 1
    assert bm.cache_stats()["cached_tokens_per_block"] == 6.0
    bm.release(m.blocks)
    # without the flag the segment is invisible
    m2 = bm.lookup_prefix_ex(prompt2, allow_compressed=False)
    assert not m2.compressed and m2.n_tokens == 0
    bm.check_invariants()
    bm.allocate(8)                       # pressure: whole segment evicted
    assert not bm.segments and not bm.seg_of_block
    bm.check_invariants()


def test_probe_prefix_has_no_side_effects():
    bm = rbm()
    toks = list(range(12))
    m = bm.lookup_prefix_ex(toks + [50])
    alloc = bm.allocate(3)
    bm.register_prefix(alloc, m.chain, 0)
    bm.release(alloc)
    before = (bm.cache_stats(), list(bm.cached_free), list(bm.ref))
    assert bm.probe_prefix(toks + [50]) == 12
    assert bm.probe_prefix(toks) == 11   # full-prompt probe capped len-1
    assert (bm.cache_stats(), list(bm.cached_free), list(bm.ref)) == before
    bm.check_invariants()


def test_watermark_caps_parked_cached_blocks():
    bm = rbm(n_blocks=8, block_size=4, prefix_cache_watermark=0.25)
    m = bm.lookup_prefix_ex(list(range(16)) + [77])
    alloc = bm.allocate(4)
    bm.register_prefix(alloc, m.chain, 0)
    bm.release(alloc)
    assert len(bm.cached_free) <= 2      # int(0.25 * 8)
    assert bm.n_evicted_blocks >= 2
    bm.check_invariants()


def test_cow_protection_marks_radix_registered_blocks():
    bm = rbm(n_blocks=8, block_size=4)
    flat = BlockManager(8, 4)            # flat policy: ref>1 only
    for b in (bm, flat):
        m_or_t = b.lookup_prefix(list(range(8)))
        alloc = b.allocate(2)
        b.register_prefix(alloc, m_or_t[2], 0)
        assert b.is_cow_protected(alloc[0]) == (b is bm)


# ----------------------------------------------------------------------
def test_memory_planner_matches_paper_lp():
    cfg = get_config("llama3-8b")
    GB = 1024**3
    plan = plan_memory(cfg, 40 * GB, n_max=32, block_size=64)
    # constraints of Eq. 1
    assert plan.M * plan.m_q_req + plan.N_total * plan.m_kv_block <= 40 * GB
    assert plan.M <= plan.N_total / 32
    # maximality: one more request would not fit
    assert (plan.M + 1) * (plan.m_kv_block * 32 + plan.m_q_req) > 40 * GB


def test_memory_planner_global_score_overhead():
    cfg = get_config("llama3-8b")
    GB = 1024**3
    with_g = plan_memory(cfg, 40 * GB, n_max=32, block_size=64,
                         with_global=True)
    without = plan_memory(cfg, 40 * GB, n_max=32, block_size=64,
                          with_global=False)
    assert with_g.m_kv_block > without.m_kv_block
    assert with_g.M <= without.M
