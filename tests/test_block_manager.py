"""Block manager + memory planner tests (incl. hypothesis stateful-ish)."""
import pytest
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core.block_manager import BlockManager, OutOfBlocks
from repro.core.memory_planner import plan_memory


def test_alloc_release_roundtrip():
    bm = BlockManager(8, 4)
    a = bm.allocate(3)
    assert bm.num_free == 5
    b = bm.allocate(5)
    assert bm.num_free == 0
    with pytest.raises(OutOfBlocks):
        bm.allocate(1)
    bm.release(a)
    assert bm.num_free == 3
    bm.release(b)
    bm.check_invariants()


def test_prefix_cache_hit_and_refcount():
    bm = BlockManager(16, 4)
    toks = list(range(10))                       # 2 full blocks + 2 tokens
    blocks, matched, chain = bm.lookup_prefix(toks)
    assert matched == 0 and blocks == [] and len(chain) == 2
    alloc = bm.allocate(3)
    bm.register_prefix(alloc, chain, 0)
    # second request with same prefix
    blocks2, matched2, chain2 = bm.lookup_prefix(toks)
    assert matched2 == 8
    assert blocks2 == alloc[:2]
    assert all(bm.is_shared(b) for b in blocks2)
    assert chain2 == chain
    bm.release(blocks2)
    assert not any(bm.is_shared(b) for b in alloc[:2])
    bm.check_invariants()


def test_cached_blocks_survive_release_until_eviction():
    bm = BlockManager(4, 2)
    toks = [1, 2, 3, 4]
    _, _, chain = bm.lookup_prefix(toks)
    alloc = bm.allocate(2)
    bm.register_prefix(alloc, chain, 0)
    bm.release(alloc)
    assert bm.num_free == 4                      # reusable, not lost
    blocks, matched, _ = bm.lookup_prefix(toks)  # resurrect from cached_free
    assert matched == 4 and blocks == alloc
    bm.release(blocks)
    # exhaust memory -> cached blocks get evicted
    other = bm.allocate(4)
    blocks3, matched3, _ = bm.lookup_prefix(toks)
    assert matched3 == 0
    bm.release(other)
    bm.check_invariants()


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(1, 5)), min_size=1,
                max_size=40))
def test_property_never_leaks_blocks(ops):
    bm = BlockManager(12, 4)
    held = []
    for is_alloc, n in ops:
        if is_alloc:
            if bm.can_allocate(n):
                held.append(bm.allocate(n))
        elif held:
            bm.release(held.pop())
        bm.check_invariants()
    for h in held:
        bm.release(h)
    bm.check_invariants()
    assert bm.num_free == 12


# ----------------------------------------------------------------------
def test_memory_planner_matches_paper_lp():
    cfg = get_config("llama3-8b")
    GB = 1024**3
    plan = plan_memory(cfg, 40 * GB, n_max=32, block_size=64)
    # constraints of Eq. 1
    assert plan.M * plan.m_q_req + plan.N_total * plan.m_kv_block <= 40 * GB
    assert plan.M <= plan.N_total / 32
    # maximality: one more request would not fit
    assert (plan.M + 1) * (plan.m_kv_block * 32 + plan.m_q_req) > 40 * GB


def test_memory_planner_global_score_overhead():
    cfg = get_config("llama3-8b")
    GB = 1024**3
    with_g = plan_memory(cfg, 40 * GB, n_max=32, block_size=64,
                         with_global=True)
    without = plan_memory(cfg, 40 * GB, n_max=32, block_size=64,
                          with_global=False)
    assert with_g.m_kv_block > without.m_kv_block
    assert with_g.M <= without.M
