"""pallas_compat drift-shim tests: fake "old" (TPUCompilerParams / VMEM)
and "new" (CompilerParams / MemorySpace.VMEM) pltpu layouts, the shard_map
home bridge, backend resolution, and jnp vs pallas-interpret agreement
through the public ``repro.api`` path."""
import types

import numpy as np
import pytest

from repro.kernels import ops, pallas_compat


class _Params:
    def __init__(self, *, dimension_semantics):
        self.dimension_semantics = dimension_semantics


class _GridSpec:
    def __init__(self, *, num_scalar_prefetch, grid, in_specs, out_specs,
                 scratch_shapes=()):
        self.num_scalar_prefetch = num_scalar_prefetch
        self.grid = grid
        self.in_specs = in_specs
        self.out_specs = out_specs
        self.scratch_shapes = scratch_shapes


def _vmem(shape, dtype):
    return ("vmem", shape, dtype)


OLD_PLTPU = types.SimpleNamespace(
    TPUCompilerParams=_Params, PrefetchScalarGridSpec=_GridSpec, VMEM=_vmem)
NEW_PLTPU = types.SimpleNamespace(
    CompilerParams=_Params, PrefetchScalarGridSpec=_GridSpec,
    MemorySpace=types.SimpleNamespace(VMEM=_vmem))
EMPTY = types.SimpleNamespace(__name__="empty")


@pytest.mark.parametrize("layout", [OLD_PLTPU, NEW_PLTPU])
def test_compiler_params_both_layouts(layout):
    cp = pallas_compat.compiler_params(["parallel", "arbitrary"], mod=layout)
    assert isinstance(cp, _Params)
    assert cp.dimension_semantics == ("parallel", "arbitrary")


def test_compiler_params_missing_is_none():
    assert pallas_compat.compiler_params(("parallel",), mod=EMPTY) is None


def test_monkeypatched_default_module(monkeypatch):
    # resolution happens at call time against the module global, so an
    # upgraded (or downgraded) pltpu is picked up without re-import
    monkeypatch.setattr(pallas_compat, "pltpu", NEW_PLTPU)
    cp = pallas_compat.compiler_params(("parallel",))
    assert isinstance(cp, _Params)
    monkeypatch.setattr(pallas_compat, "pltpu", OLD_PLTPU)
    scratch = pallas_compat.vmem_scratch((4, 4), np.float32)
    assert scratch[0] == "vmem"


@pytest.mark.parametrize("layout", [OLD_PLTPU, NEW_PLTPU])
def test_prefetch_grid_spec_both_layouts(layout):
    gs = pallas_compat.prefetch_grid_spec(
        num_scalar_prefetch=2, grid=(1, 2), in_specs=["i"],
        out_specs="o", scratch_shapes=("s",), mod=layout)
    assert isinstance(gs, _GridSpec)
    assert gs.num_scalar_prefetch == 2 and gs.scratch_shapes == ["s"]


def test_prefetch_grid_spec_missing_raises():
    with pytest.raises(NotImplementedError, match="jnp"):
        pallas_compat.prefetch_grid_spec(
            num_scalar_prefetch=1, grid=(1,), in_specs=[], out_specs=None,
            mod=EMPTY)


@pytest.mark.parametrize("layout", [OLD_PLTPU, NEW_PLTPU])
def test_vmem_scratch_both_layouts(layout):
    assert pallas_compat.vmem_scratch((8,), np.float32, mod=layout) == \
        ("vmem", (8,), np.float32)


def test_vmem_scratch_missing_raises():
    with pytest.raises(NotImplementedError, match="VMEM"):
        pallas_compat.vmem_scratch((8,), np.float32, mod=EMPTY)


def test_real_pltpu_layout_resolves():
    """Whatever JAX this is, the real pltpu must satisfy the shim."""
    assert pallas_compat.compiler_params(("parallel",)) is not None
    pallas_compat.vmem_scratch((8, 8), np.float32)
    pallas_compat.prefetch_grid_spec(
        num_scalar_prefetch=1, grid=(1,), in_specs=[], out_specs=None)


# ----------------------------------------------------------------------
# shard_map / mesh drift


def test_shard_map_new_api_forwarding():
    seen = {}

    def fake_shard_map(f, *, mesh, in_specs, out_specs, check_vma=True,
                       axis_names=None):
        seen.update(check_vma=check_vma, axis_names=axis_names)
        return f

    mod = types.SimpleNamespace(shard_map=fake_shard_map)
    fn = pallas_compat.shard_map(lambda x: x, mesh="m", in_specs=(),
                                 out_specs=(), axis_names={"data"},
                                 check=False, mod=mod)
    assert fn(3) == 3
    assert seen == {"check_vma": False, "axis_names": frozenset({"data"})}


def test_shard_map_midrange_spelling():
    """Top-level home but pre-rename kwargs (check_rep/auto): the shim must
    key each kwarg on the signature, not on where shard_map lives."""
    seen = {}

    class FakeMesh:
        axis_names = ("data", "model")

    def fake_shard_map(f, *, mesh, in_specs, out_specs, check_rep=True,
                       auto=frozenset()):
        seen.update(check_rep=check_rep, auto=auto)
        return f

    mod = types.SimpleNamespace(shard_map=fake_shard_map)
    pallas_compat.shard_map(lambda x: x, mesh=FakeMesh(), in_specs=(),
                            out_specs=(), axis_names={"data"},
                            check=False, mod=mod)
    assert seen == {"check_rep": False, "auto": frozenset({"model"})}


def test_shard_map_legacy_fallback_runs():
    pytest.importorskip("jax.experimental.shard_map")
    import jax
    import jax.numpy as jnp

    mesh = jax.make_mesh((1,), ("x",))
    from jax.sharding import PartitionSpec as P

    def f(v):
        return jax.lax.psum(v, "x")

    # EMPTY has no .shard_map, forcing the jax.experimental legacy home
    fn = pallas_compat.shard_map(f, mesh=mesh, in_specs=(P(),),
                                 out_specs=P(), axis_names={"x"},
                                 check=False, mod=EMPTY)
    out = jax.jit(fn)(jnp.ones((4,)))
    np.testing.assert_allclose(np.asarray(out), np.ones((4,)))


def test_mesh_context():
    entered = {}

    class Ctx:
        def __enter__(self):
            entered["yes"] = True

        def __exit__(self, *a):
            return False

    mod = types.SimpleNamespace(set_mesh=lambda mesh: Ctx())
    with pallas_compat.mesh_context("mesh", mod=mod):
        pass
    assert entered["yes"]
    # without set_mesh the mesh object itself is the context manager
    assert pallas_compat.mesh_context(Ctx(), mod=EMPTY) is not None


# ----------------------------------------------------------------------
# backend resolution


def test_resolve_backend_canonical_passthrough():
    for name in ("jnp", "pallas-interpret", "pallas-tpu"):
        assert ops.resolve_backend(name) == name


def test_resolve_backend_auto_and_alias():
    if pallas_compat.has_tpu():
        assert ops.resolve_backend("auto") == "pallas-tpu"
        assert ops.resolve_backend("pallas") == "pallas-tpu"
    else:
        assert ops.resolve_backend("auto") == "jnp"
        assert ops.resolve_backend("pallas") == "pallas-interpret"


def test_resolve_backend_rejects_unknown():
    with pytest.raises(ValueError, match="unknown kernel backend"):
        ops.resolve_backend("triton")


def test_facade_rejects_unknown_kernel_backend():
    from repro.api import Zipage

    with pytest.raises(ValueError, match="kernel_backend"):
        Zipage.from_config("tiny-lm", kernel_backend="cuda")


# ----------------------------------------------------------------------
# public-API parity: the whole serving stack must agree across backends


def test_api_backend_parity_jnp_vs_pallas_interpret():
    """Greedy generate through ``repro.api`` with compression engaged
    (n_max=3) must be token-identical on jnp and pallas-interpret."""
    from repro.api import SamplingParams, Zipage

    prompts = [[1, 2, 3, 4, 5], [9, 8, 7]]
    outs = {}
    for backend in ("jnp", "pallas-interpret"):
        z = Zipage.from_config(
            "tiny-lm", block_size=8, n_total_blocks=64, max_batch=4,
            m_qslots=4, n_max=3, window=4, max_model_len=128,
            prefill_rows=2, prefill_len=32, kernel_backend=backend)
        assert z.engine.spec.attn_backend == backend
        assert z.engine.opts.compress.backend == backend
        outs[backend] = [o.token_ids for o in z.generate(
            prompts, SamplingParams(max_new_tokens=16))]
    assert outs["jnp"] == outs["pallas-interpret"]
