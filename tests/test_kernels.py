"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracles,
over shapes and dtypes."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

RNG = np.random.default_rng(7)


def make_pool(N, b, h, d, dtype):
    return RNG.normal(size=(N, b, h, d)).astype(dtype)


def make_tables(n, mb, N):
    return np.stack([RNG.choice(N, mb, replace=False)
                     for _ in range(n)]).astype(np.int32)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,hq,hkv,d,b,mb", [
    (2, 4, 2, 16, 8, 3),
    (1, 8, 8, 32, 4, 5),     # MHA
    (3, 8, 1, 64, 8, 2),     # MQA
])
def test_paged_attention_kernel(B, hq, hkv, d, b, mb, dtype):
    N = 16
    q = RNG.normal(size=(B, hq, d)).astype(dtype)
    kp, vp = make_pool(N, b, hkv, d, dtype), make_pool(N, b, hkv, d, dtype)
    bt = make_tables(B, mb, N)
    sl = RNG.integers(1, mb * b + 1, size=(B,)).astype(np.int32)
    got = ops.paged_decode_attention(q, kp, vp, bt, sl, backend="pallas-interpret")
    want = ops.paged_decode_attention(q, kp, vp, bt, sl, backend="jnp")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
@pytest.mark.parametrize("n,w,hq,hkv,d,b,mb", [
    (2, 4, 4, 2, 16, 8, 3),
    (1, 2, 4, 4, 32, 4, 4),
])
def test_paged_score_kernel(n, w, hq, hkv, d, b, mb, dtype):
    N = 16
    q = RNG.normal(size=(n, w, hq, d)).astype(dtype)
    kp = make_pool(N, b, hkv, d, dtype)
    bt = make_tables(n, mb, N)
    sl = np.full((n,), mb * b, np.int32)
    got = ops.score_logits(q, kp, bt, sl, backend="pallas-interpret")
    want = ops.score_logits(q, kp, bt, sl, backend="jnp")
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-5
    g, wv = np.asarray(got, np.float32), np.asarray(want, np.float32)
    # compare only unmasked entries (both use the same big-negative mask)
    m = wv > -1e29
    np.testing.assert_array_equal(g > -1e29, m)
    np.testing.assert_allclose(g[m], wv[m], rtol=tol, atol=tol)
    # and the derived scores
    gs = ops.attention_scores_from_logits(got, jnp.asarray(sl))
    ws = ops.attention_scores_from_logits(want, jnp.asarray(sl))
    np.testing.assert_allclose(np.asarray(gs), np.asarray(ws),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("p_thresh", [0.5, 0.8])
@pytest.mark.parametrize("n,h,d,b,mb", [(2, 2, 16, 8, 3), (1, 4, 32, 4, 4)])
def test_lightning_redundancy_kernel(n, h, d, b, mb, p_thresh):
    N = 16
    kp = make_pool(N, b, h, d, np.float32)
    # plant a near-duplicate pair within one block to exercise zero-out
    kp[0, 1, :, :] = kp[0, 3, :, :] * 1.2
    bt = make_tables(n, mb, N)
    sl = np.array([mb * b] + [max(b, mb * b - b)] * (n - 1), np.int32)
    got = ops.lightning_redundancy(kp, bt, sl, p_thresh=p_thresh,
                                   backend="pallas-interpret")
    want = ops.lightning_redundancy(kp, bt, sl, p_thresh=p_thresh,
                                    backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("n,h,d,b,mb", [(2, 2, 16, 8, 3), (1, 1, 32, 4, 4)])
def test_flash_redundancy_kernel_matches_full_oracle(n, h, d, b, mb):
    """Alg. 3 must reproduce the O(T²) full-matrix redundancy exactly."""
    N = 16
    kp = make_pool(N, b, h, d, np.float32)
    kp[2, 0, :, :] = kp[1, 2, :, :] * 0.9       # cross-block duplicate
    bt = make_tables(n, mb, N)
    sl = np.full((n,), mb * b, np.int32)
    got = ops.flash_redundancy(kp, bt, sl, p_thresh=0.7, backend="pallas-interpret")
    want = ops.flash_redundancy(kp, bt, sl, p_thresh=0.7, backend="jnp")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-6)


@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_compact_gather_kernel(dtype):
    S, h, d, k = 64, 3, 16, 10
    pool = RNG.normal(size=(S, h, d)).astype(dtype)
    src = np.stack([np.sort(RNG.choice(S, k, replace=False))
                    for _ in range(h)]).astype(np.int32)
    got = ops.compact_gather(pool, src, backend="pallas-interpret")
    want = ops.compact_gather(pool, src, backend="jnp")
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))
