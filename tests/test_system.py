"""End-to-end behaviour tests for the paper's system: the full pipeline —
train a tiny model, checkpoint it, restore it, serve it with Compressed
PagedAttention, and verify the served outputs match a reference decode of
the restored weights."""
import dataclasses
import os

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.models import lm
from engine_utils import submit
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.data import DataConfig, batch_at
from repro.training.train_loop import build_train_step

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")


def test_train_checkpoint_serve_roundtrip(tmp_path):
    # 1. train briefly
    dc = DataConfig(seq_len=32, global_batch=8, vocab_size=CFG.vocab_size)
    adamw = opt.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=30)
    step = jax.jit(build_train_step(CFG, adamw, vocab_chunk=32))
    params = lm.init(CFG, jax.random.key(0))
    state = opt.init_opt_state(params)
    first = last = None
    for i in range(30):
        batch = jax.tree.map(jnp.asarray, batch_at(dc, i))
        params, state, _, m = step(params, state, None, batch)
        first = first if first is not None else float(m["loss"])
        last = float(m["loss"])
    assert last < first

    # 2. checkpoint + restore
    d = str(tmp_path / "ck")
    os.makedirs(d)
    ckpt.save(d, 30, {"params": params})
    restored, _ = ckpt.restore(d, 30, {"params": params})
    params = jax.tree.map(jnp.asarray, restored["params"])

    # 3. serve with compression; 4. verify vs reference greedy decode
    eng = ZipageEngine(CFG, params, EngineOptions(
        block_size=8, n_total_blocks=64, max_batch=4, m_qslots=4, n_max=4,
        window=4, compress=CompressOptions(window=4), max_model_len=128,
        prefill_rows=2, prefill_len=32, temperature=0.0))
    prompts = [[1, 2, 3], [7, 8, 9, 10]]
    rids = [submit(eng, p, 12) for p in prompts]       # short: no compression
    done = eng.run(max_steps=200)
    for rid, p in zip(rids, prompts):
        toks = list(p)
        for _ in range(12):
            logits = lm.forward(CFG, params, jnp.asarray([toks]))
            toks.append(int(jnp.argmax(logits[0, -1])))
        assert done[rid].output == toks[len(p):]
    assert eng.bm.num_free == 64
