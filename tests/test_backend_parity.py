"""The Pallas and jnp backends must be interchangeable end-to-end: the full
compression pipeline and decode step produce identical results."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.compression import CompressOptions, build_compress_fn

RNG = np.random.default_rng(3)


def test_compress_fn_backend_parity():
    cfg = dataclasses.replace(get_config("tiny-lm"))
    L, N, b, mb, bb, n, w = 2, 16, 4, 6, 3, 2, 2
    h, d, hq = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    pools = {
        "k": jnp.asarray(RNG.normal(size=(L, N, b, h, d)), jnp.float32),
        "v": jnp.asarray(RNG.normal(size=(L, N, b, h, d)), jnp.float32),
        "f": jnp.asarray(RNG.normal(size=(L, N, b, h)), jnp.float32),
    }
    qwin = jnp.asarray(RNG.normal(size=(L, 3, w, hq, d)), jnp.float32)
    src_bt = np.full((n, mb), -1, np.int32)
    src_bt[0, :5] = [3, 7, 1, 9, 12]
    src_bt[1, :4] = [0, 2, 4, 5]
    dest_bt = np.stack([src_bt[0, :bb], src_bt[1, :bb]])
    req = (jnp.asarray(src_bt), jnp.asarray(dest_bt),
           jnp.asarray([0, 1], np.int32), jnp.asarray([20, 16], np.int32),
           jnp.asarray([bb * b, 0], np.int32))
    outs = {}
    for backend in ("jnp", "pallas-interpret"):
        opts = CompressOptions(window=w, redundancy="lightning",
                               pooling="first", backend=backend)
        fn = jax.jit(build_compress_fn(cfg, block_size=b, max_blocks=mb,
                                       budget_blocks=bb, opts=opts))
        new_pools, new_seq, _ = fn(pools, qwin, req)
        outs[backend] = (jax.tree.map(np.asarray, new_pools),
                         np.asarray(new_seq))
    for key in ("k", "v", "f"):
        np.testing.assert_allclose(outs["jnp"][0][key],
                                   outs["pallas-interpret"][0][key],
                                   rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(outs["jnp"][1], outs["pallas-interpret"][1])


def test_compress_fn_backend_parity_flash():
    cfg = get_config("tiny-lm")
    L, N, b, mb, bb, n, w = 1, 12, 4, 4, 2, 1, 2
    h, d, hq = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    pools = {
        "k": jnp.asarray(RNG.normal(size=(L, N, b, h, d)), jnp.float32),
        "v": jnp.asarray(RNG.normal(size=(L, N, b, h, d)), jnp.float32),
        "f": jnp.zeros((L, N, b, h), jnp.float32),
    }
    qwin = jnp.asarray(RNG.normal(size=(L, 2, w, hq, d)), jnp.float32)
    src_bt = np.full((n, mb), -1, np.int32)
    src_bt[0] = [3, 7, 1, 9]
    req = (jnp.asarray(src_bt), jnp.asarray(src_bt[:, :bb]),
           jnp.asarray([0], np.int32), jnp.asarray([16], np.int32),
           jnp.asarray([0], np.int32))
    outs = {}
    for backend in ("jnp", "pallas-interpret"):
        opts = CompressOptions(window=w, redundancy="flash",
                               pooling="none", backend=backend)
        fn = jax.jit(build_compress_fn(cfg, block_size=b, max_blocks=mb,
                                       budget_blocks=bb, opts=opts))
        new_pools, _, _ = fn(pools, qwin, req)
        outs[backend] = jax.tree.map(np.asarray, new_pools)
    for key in ("k", "v"):
        np.testing.assert_allclose(outs["jnp"][key], outs["pallas-interpret"][key],
                                   rtol=1e-5, atol=1e-6)
