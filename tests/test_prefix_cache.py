"""Radix prefix-cache tests above the block-manager layer
(docs/CACHING.md): cache-aware admission ordering, margin refinement,
multi-turn reuse through the engine, hit-vs-miss stream identity, flat-vs-
radix engine parity, and compressed-segment adoption end to end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.api.config import build_engine_options, route_overrides
from repro.configs import get_config
from repro.core.block_manager import BlockManager
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.core.invariants import audit_engine
from repro.core.request import Request
from repro.core.scheduler import (POLICIES, Scheduler, SchedulerParams,
                                  make_policy)
from repro.models import lm
from engine_utils import submit

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))


def ref_generate(prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        logits = lm.forward(CFG, PARAMS, jnp.asarray([toks]))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def make_engine(**kw):
    base = dict(block_size=4, n_total_blocks=64, max_batch=4, m_qslots=4,
                n_max=3, window=2, max_model_len=256, prefill_rows=2,
                prefill_len=64, prefix_caching=True,
                compress=CompressOptions(window=2), temperature=0.0)
    base.update(kw)
    return ZipageEngine(CFG, PARAMS, EngineOptions(**base))


def run_to_finish(eng, rid, cap=500):
    while rid not in eng.finished:
        eng.step()
        assert eng.step_count < cap
    return eng.finished[rid]


# ----------------------------------------------------------------------
# pure-host: cache-aware admission


def host_sched(**kw):
    base = dict(block_size=4, max_batch=4, m_qslots=4, n_max=3, window=2,
                prefill_rows=4, compression_enabled=True, budget_blocks=2,
                prefix_ok=True, policy="cache_aware")
    n_blocks = kw.pop("n_blocks", 16)
    base.update(kw)
    bm = BlockManager(n_blocks, base["block_size"],
                      prefix_cache_policy="radix")
    return Scheduler(SchedulerParams(**base), bm)


def waiting_request(rid, prompt, n_out=8, arrival=None):
    return Request(rid=rid, prompt=list(prompt), max_new_tokens=n_out,
                   arrival=float(rid if arrival is None else arrival))


def warm_cache(bm, tokens):
    """Register ``tokens``' full blocks and park them unreferenced."""
    chain = bm._block_chain(tokens)
    blocks = bm.allocate(len(chain))
    bm.register_prefix(blocks, chain, 0)
    bm.release(blocks)
    return blocks


def test_cache_aware_admits_hits_first():
    s = host_sched()
    warm_cache(s.bm, list(range(1, 9)))
    s.add_request(waiting_request(0, range(50, 58)))          # miss, earlier
    s.add_request(waiting_request(1, list(range(1, 9)) + [9]))  # 8-token hit
    plan = s.schedule()
    assert [r.rid for r in plan.admitted] == [1, 0]
    assert plan.admitted[0].n_cached == 8
    s.bm.check_invariants()


def test_cache_aware_unbound_degrades_to_fcfs():
    pol = make_policy("cache_aware")
    reqs = [waiting_request(0, range(8)), waiting_request(1, range(8))]
    assert [r.rid for r in pol.admission_order(reqs)] == [0, 1]


def test_make_policy_returns_fresh_instances():
    a, b = make_policy("cache_aware"), make_policy("cache_aware")
    assert a is not b and a is not POLICIES["cache_aware"]


def test_compressed_segments_require_radix():
    with pytest.raises(ValueError):
        Scheduler(SchedulerParams(cache_compressed_prefixes=True),
                  BlockManager(16, 4, prefix_cache_policy="flat"))


def test_margin_shrinks_by_matched_blocks():
    """Cache-aware refinement of the compression-aware admission margin:
    matched blocks are KV the pool already holds, so the reserve shrinks
    by the hit size — the same request that a cold cache rejects is
    admitted warm."""
    prompt = list(range(1, 9))                  # 2 blocks

    def sched_with_running(n_blocks):
        s = host_sched(policy="fcfs", admission_margin=1.0,
                       n_blocks=n_blocks, max_prefill_chunk=None)
        from repro.core.request import State
        r = Request(rid=99, prompt=list(range(90, 98)), max_new_tokens=20,
                    arrival=0.0)
        r.blocks = s.bm.allocate(2)
        r.slot = s.free_slots.pop()
        r.state = State.RUNNING
        r.seq_len = r.position = 8
        r.n_prefilled = r.prefill_target = 8
        s.running.append(r)
        return s

    # pool of 5: the running request holds 2, leaving 3. The candidate
    # needs 3 blocks plus a margin of 1 (the running request's projected
    # post-compression growth) — cold that is 4 > 3; warm, 2 matched
    # blocks cover 2 of the 3 and zero out the margin
    cold = sched_with_running(n_blocks=5)
    cold.add_request(waiting_request(0, prompt + [9], n_out=8))
    warm = sched_with_running(n_blocks=5)
    warm_cache(warm.bm, prompt)
    warm.add_request(waiting_request(0, prompt + [9], n_out=8))
    plan_cold = cold.schedule()
    plan_warm = warm.schedule()
    assert len(plan_warm.admitted) == 1, \
        "matched blocks should offset the admission margin"
    assert len(plan_cold.admitted) == 0, \
        "cold cache must hold the same margin back"


def test_compression_escapes_cow_deadlock():
    """A whole batch can be compression-ready at once with every block
    radix-registered: COW then demands fresh dest blocks, but the pool is
    exhausted and ready peers shield each other from preemption — the
    pre-fix planner blocked every request forever. The planner must
    sacrifice sole-referenced cache registrations and condense in place
    instead of deadlocking."""
    from repro.core.request import State

    s = host_sched(policy="fcfs", n_blocks=6)
    reqs = []
    for rid in range(2):
        prompt = list(range(rid * 100 + 1, rid * 100 + 13))   # 3 blocks
        r = Request(rid=rid, prompt=prompt, max_new_tokens=8,
                    arrival=float(rid))
        r.blocks = s.bm.allocate(3)
        r.chain = s.bm._block_chain(prompt)
        s.bm.register_prefix(r.blocks, r.chain, 0)
        r.slot = s.free_slots.pop()
        r.qslot = s.free_qslots.pop()
        r.state = State.RUNNING
        r.seq_len = r.position = 12
        r.n_prefilled = r.prefill_target = 12
        r.win_count = s.p.window
        s.running.append(r)
        reqs.append(r)
    assert s.bm.num_free == 0
    plan = s.schedule()
    s.plan_compression(plan)
    assert len(plan.compress) == 2, \
        "COW fresh-block demand must not deadlock an exhausted pool"
    assert all(r.state is not State.BLOCKED for r in reqs)
    s.commit_compression(plan)
    s.bm.check_invariants()


# ----------------------------------------------------------------------
# engine-level


def test_multi_turn_reuse_beyond_prompt():
    """Register-at-finish: a finished request's prompt *and* generated
    tokens become reusable, so the next turn of a conversation (prior
    stream + new user tokens) hits past the original prompt boundary."""
    eng = make_engine(n_max=6)                  # 24-token cap: no compress
    prompt = list(range(1, 11))                 # 10 tokens
    r1 = submit(eng, prompt, 6)
    req1 = run_to_finish(eng, r1)
    stream = prompt + req1.output               # 16 tokens
    r2 = submit(eng, stream + [77, 78], 6)
    req2 = run_to_finish(eng, r2)
    # seq 15 entries cached at finish => 3 full blocks = 12 tokens, past
    # the 10-token prompt
    assert req2.n_cached == 12 > len(prompt)
    assert req2.output == ref_generate(stream + [77, 78], 6)
    stats = eng.metrics[-1]
    assert stats["prefix_hits"] >= 1 and stats["prefix_hit_tokens"] >= 12
    assert audit_engine(eng) == []


def test_hit_and_miss_streams_bit_identical():
    """A full-prompt cache hit is capped one block short, so the sampled
    continuation is bit-identical to the cold run of the same prompt."""
    eng = make_engine(n_max=6)
    p = list(range(2, 10))                      # 8 tokens, 2 full blocks
    r1 = submit(eng, p, 8)
    cold = run_to_finish(eng, r1).output
    r2 = submit(eng, p, 8)
    req2 = run_to_finish(eng, r2)
    assert req2.n_cached == 4, "full-prompt hit must leave one real chunk"
    assert req2.output == cold == ref_generate(p, 8)
    assert audit_engine(eng) == []


@pytest.mark.parametrize("n_max", [6, 3])
def test_radix_and_flat_streams_identical(n_max):
    """Engine-level parity on a shared-prefix workload. With compression
    never triggering (n_max=6) flat, radix and the full-KV reference all
    agree. With compression on (n_max=3) the streams are lossy, so the
    bar is hit-vs-miss identity: the radix cache-hit run must match a
    no-cache run of the same requests under the same compression config
    (flat is excluded there: its in-place compression leaves stale cache
    entries — the bug the radix policy fixes)."""
    shared = list(range(1, 13))                 # 3 full blocks of 4
    outs = {}
    policies = ("flat", "radix") if n_max == 6 else ("radix",)
    for pol in policies:
        eng = make_engine(n_max=n_max, m_qslots=4, prefix_cache_policy=pol)
        r1 = submit(eng, shared + [30], 10)
        run_to_finish(eng, r1)
        rids = [submit(eng, shared + [40 + i], 10) for i in range(2)]
        eng.run(max_steps=400)
        outs[pol] = [eng.finished[r].output for r in rids]
        assert all(eng.finished[r].n_cached >= 12 for r in rids)
        assert audit_engine(eng) == []
    if n_max == 6:
        ref = [ref_generate(shared + [40 + i], 10) for i in range(2)]
        assert outs["radix"] == ref and outs["flat"] == ref
    else:
        miss = make_engine(n_max=n_max, m_qslots=4, prefix_caching=False)
        r1 = submit(miss, shared + [30], 10)
        run_to_finish(miss, r1)
        rids = [submit(miss, shared + [40 + i], 10) for i in range(2)]
        miss.run(max_steps=400)
        assert outs["radix"] == [miss.finished[r].output for r in rids]


def test_cached_prefix_survives_compression():
    """The radix policy COW-protects registered blocks: compressing the
    request that registered them moves its KV to fresh blocks and parks
    the raw originals in the cache instead of condensing them in place."""
    eng = make_engine(n_max=3, m_qslots=4)
    shared = list(range(1, 13))
    r1 = submit(eng, shared + [30], 25)
    run_to_finish(eng, r1)
    assert eng.finished[r1].n_compressions > 0
    r2 = submit(eng, shared + [40], 8)
    req2 = run_to_finish(eng, r2)
    assert req2.n_cached >= 12
    assert audit_engine(eng) == []
    # the hit must be invisible in the tokens: same stream as a no-cache
    # run of the same request under the same compression config
    miss = make_engine(n_max=3, m_qslots=4, prefix_caching=False)
    rm = submit(miss, shared + [40], 8)
    assert req2.output == run_to_finish(miss, rm).output


def test_compressed_segment_adoption_end_to_end():
    """cache_compressed_prefixes: a prompt-pure compression registers its
    condensed payload as a segment; once the raw-KV chain is gone (here:
    explicitly invalidated, in production: evicted first since it costs
    more blocks), the next same-prompt request adopts the segment —
    16 tokens of history for 8 KV entries — and decodes to completion."""
    eng = make_engine(n_max=3, m_qslots=4, cache_compressed_prefixes=True)
    prefix = list(range(1, 17))                 # exactly 4 full blocks
    r1 = submit(eng, prefix, 10)
    run_to_finish(eng, r1)
    assert eng.bm.segments, "prompt-pure compression should cache a segment"
    eng.bm.invalidate_blocks(list(eng.bm.block_hash))
    eng.bm.check_invariants()
    r2 = submit(eng, prefix + [60, 61, 62], 8)
    req2 = run_to_finish(eng, r2)
    k = eng.scheduler.p.budget_blocks * eng.opts.block_size
    assert req2.pos_gap == 16 - k
    assert req2.compressed and req2.n_cached == 16
    assert len(req2.output) == 8
    stats = eng.metrics[-1]
    assert stats["prefix_segment_hits"] >= 1
    assert stats["cached_tokens_per_block"] > eng.opts.block_size
    assert audit_engine(eng) == []
    eng.bm.check_invariants()


def test_api_routes_cache_knobs():
    cache, sched, runner = route_overrides(
        prefix_cache_policy="flat", prefix_cache_watermark=0.5,
        cache_compressed_prefixes=False, policy="cache_aware")
    opts = build_engine_options(cache, sched, runner)
    assert opts.prefix_cache_policy == "flat"
    assert opts.prefix_cache_watermark == 0.5
    assert opts.cache_compressed_prefixes is False
    assert opts.policy == "cache_aware"
