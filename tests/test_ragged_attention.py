"""Ragged paged decode attention (ISSUE 9): parity, bitwise identity,
the padded-entry page-0 convention, and the host-side table trim.

The ragged kernel's contract is strict: for rows with ``seq_len > 0`` it
is *bit-identical* to the dense kernel on every backend (flipping
ragged<->dense must never change a token stream), rows with
``seq_len == 0`` return exact zeros, and page 0 — the dense path's
clamp target for ``-1`` padding — is never read, so a poisoned page 0
cannot leak into any output."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import paged
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.core.sampling import SamplingParams
from repro.kernels import ops
from repro.models import lm

BACKENDS = ("jnp", "pallas-interpret")

# GQA shapes from the 8B-class configs: MHA, g=4, and a wide g=4 head
# count (plus tiny-lm's g=2 exercised by the engine tests below)
GQA_SHAPES = [(4, 4), (8, 2), (32, 8)]

# ragged length mixes: inactive slots (0), sub-block rows, block-aligned
# rows, full-table rows and compressed-style short rows (compression
# shrinks seq_len while rotary positions run ahead via Request.pos_gap —
# from the kernel's point of view that is just a shorter row)
LENGTH_MIXES = [
    [0, 1, 7, 24, 13],
    [24, 24, 24, 24, 24],
    [0, 0, 0, 0, 0],
    [3, 8, 9, 16, 0],
    [1, 2, 3, 4, 5],
]


def make_case(hq, hkv, seq_lens, seed=0, d=16, b=4, mb=6, n_pages=64,
              dtype=np.float32):
    rng = np.random.default_rng(seed)
    B = len(seq_lens)
    q = rng.normal(size=(B, hq, d)).astype(dtype)
    kp = rng.normal(size=(n_pages, b, hkv, d)).astype(dtype)
    vp = rng.normal(size=(n_pages, b, hkv, d)).astype(dtype)
    sl = np.asarray(seq_lens, np.int32)
    bt = np.full((B, mb), -1, np.int32)
    pool = list(rng.permutation(np.arange(1, n_pages)))  # never page 0
    for i in range(B):
        for j in range(-(-int(sl[i]) // b)):
            bt[i, j] = pool.pop()
    return q, kp, vp, bt, sl


@pytest.mark.parametrize("hq,hkv", GQA_SHAPES)
@pytest.mark.parametrize("mix", range(len(LENGTH_MIXES)))
def test_ragged_parity_jnp_vs_interpret(hq, hkv, mix):
    q, kp, vp, bt, sl = make_case(hq, hkv, LENGTH_MIXES[mix], seed=mix)
    out = {be: np.asarray(ops.ragged_decode_attention(q, kp, vp, bt, sl,
                                                      backend=be))
           for be in BACKENDS}
    np.testing.assert_allclose(out["jnp"], out["pallas-interpret"],
                               rtol=2e-5, atol=2e-5)
    # inactive rows are exact zeros on every backend
    for o in out.values():
        assert np.all(o[sl == 0] == 0)


@pytest.mark.parametrize("hq,hkv", GQA_SHAPES + [(2, 2), (8, 1), (4, 2)])
def test_ragged_bitwise_identical_to_dense(hq, hkv):
    """The hard guarantee behind the ``decode_kernel`` fallback knob: for
    live rows the ragged kernel is bit-identical to the dense kernel on
    both backends (f32 — no tolerance)."""
    q, kp, vp, bt, sl = make_case(hq, hkv, [0, 1, 7, 24, 13], seed=1)
    live = sl > 0
    for be in BACKENDS:
        r = np.asarray(ops.ragged_decode_attention(q, kp, vp, bt, sl,
                                                   backend=be))
        d = np.asarray(ops.paged_decode_attention(q, kp, vp, bt, sl,
                                                  backend=be))
        assert np.array_equal(r[live], d[live]), be


def test_padded_entries_do_not_fetch_page0():
    """Regression for the ``jnp.maximum(block_tables, 0)`` convention:
    ``-1`` padding clamps to *real* page 0, and before the V-side masking
    fix a NaN-poisoned page 0 leaked through 0·NaN in the contraction.
    Poison page 0 (and each row's stale tail past seq_len) and require
    outputs identical to the clean pool on every backend, dense and
    ragged, plus the chunked jnp reference."""
    hq, hkv, b = 8, 2, 4
    q, kp, vp, bt, sl = make_case(hq, hkv, [0, 1, 7, 24, 13], seed=2)
    kp_bad, vp_bad = kp.copy(), vp.copy()
    kp_bad[0] = np.nan
    vp_bad[0] = np.nan
    # stale garbage past each row's seq_len inside its own last block
    for i, s in enumerate(sl):
        if 0 < s % b:
            blk = bt[i, s // b]
            kp_bad[blk, s % b:] = np.nan
            vp_bad[blk, s % b:] = np.nan
    for fn in (ops.ragged_decode_attention, ops.paged_decode_attention):
        for be in BACKENDS:
            clean = np.asarray(fn(q, kp, vp, bt, sl, backend=be))
            poisoned = np.asarray(fn(q, kp_bad, vp_bad, bt, sl, backend=be))
            rows = (sl > 0) if fn is ops.paged_decode_attention else \
                np.ones_like(sl, bool)
            assert np.array_equal(clean[rows], poisoned[rows]), \
                (fn.__name__, be)
            assert np.all(np.isfinite(poisoned[rows])), (fn.__name__, be)
    clean = np.asarray(paged.paged_decode_attention_chunked(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp),
        jnp.asarray(bt), jnp.asarray(sl)))
    poisoned = np.asarray(paged.paged_decode_attention_chunked(
        jnp.asarray(q), jnp.asarray(kp_bad), jnp.asarray(vp_bad),
        jnp.asarray(bt), jnp.asarray(sl)))
    assert np.array_equal(clean[sl > 0], poisoned[sl > 0])


def test_trim_block_tables():
    bt = np.full((3, 32), -1, np.int32)
    bt[0, :5] = np.arange(5)
    bt[1, :2] = [7, 9]
    sl = np.array([33, 16, 0], np.int32)            # b=8 -> 5 blocks used
    trimmed, width = ops.trim_block_tables(bt, sl, 8)
    assert width == 8                               # 5 -> pow-2 bucket
    assert trimmed.shape == (3, 8)
    assert np.array_equal(trimmed, bt[:, :8])
    trimmed, width = ops.trim_block_tables(bt, sl, 8, bucket=False)
    assert width == 5
    # width never exceeds the table and never goes below min_width
    assert ops.block_table_width(1000, 32) == 32
    assert ops.block_table_width(0, 32, min_width=2) == 2
    _, width = ops.trim_block_tables(bt, np.zeros((3,), np.int32), 8)
    assert width == 1
    # trimmed tables give identical attention output
    q, kp, vp, bt, sl = make_case(8, 2, [0, 1, 7, 24, 13], seed=3)
    tr, _ = ops.trim_block_tables(bt, sl, kp.shape[1])
    for be in BACKENDS:
        full = np.asarray(ops.ragged_decode_attention(q, kp, vp, bt, sl,
                                                      backend=be))
        trim = np.asarray(ops.ragged_decode_attention(q, kp, vp, tr, sl,
                                                      backend=be))
        assert np.array_equal(full, trim)


# ----------------------------------------------------------------------
# engine-level: ragged vs dense token streams are bit-identical

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))
PROMPTS = [[1, 2, 3, 4, 5], [9, 8, 7], [10, 11, 12, 13, 14, 15, 16],
           [20, 21]]
# greedy + seeded top-k/top-p; outputs long enough that compression
# triggers (n_max=3 * block_size=8 = 24-token cap), so compressed rows
# with pos_gap > 0 flow through the ragged kernel
MIXED = [SamplingParams(max_new_tokens=28),
         SamplingParams(max_new_tokens=28, temperature=0.8, top_k=5,
                        seed=7),
         SamplingParams(max_new_tokens=28, temperature=1.1, top_p=0.9,
                        seed=3),
         SamplingParams(max_new_tokens=28, temperature=0.7, seed=11)]


def run_streams(**kw):
    base = dict(block_size=8, n_total_blocks=64, max_batch=4, m_qslots=4,
                n_max=3, window=4, max_model_len=256, prefill_rows=2,
                prefill_len=64, compress=CompressOptions(window=4))
    base.update(kw)
    eng = ZipageEngine(CFG, PARAMS, EngineOptions(**base))
    rids = [eng.add_request(p, sp) for p, sp in zip(PROMPTS, MIXED)]
    done = eng.run(max_steps=500)
    streams = [done[r].output for r in rids]
    assert all(len(s) for s in streams)
    assert sum(m["n_compressing"] for m in eng.metrics) > 0
    return streams, eng


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_streams_bit_identical_ragged_vs_dense(backend):
    ragged, eng = run_streams(kernel_backend=backend,
                              decode_kernel="ragged", decode_steps=4)
    dense, _ = run_streams(kernel_backend=backend,
                           decode_kernel="dense", decode_steps=4)
    assert ragged == dense
    # the ragged path's DMA footprint telemetry is live and sub-dense
    pv = sum(m["pages_visited"] for m in eng.metrics)
    pd = sum(m["pages_dense"] for m in eng.metrics)
    assert 0 < pv < pd


def test_decode_kernel_knob_validated():
    from repro.api.config import (CacheConfig, ModelRunnerConfig,
                                  SchedulerConfig, build_engine_options)
    with pytest.raises(ValueError, match="decode_kernel"):
        build_engine_options(CacheConfig(), SchedulerConfig(),
                             ModelRunnerConfig(decode_kernel="nope"))
    opts = build_engine_options(CacheConfig(), SchedulerConfig(),
                                ModelRunnerConfig(decode_kernel="dense"))
    assert opts.decode_kernel == "dense"
