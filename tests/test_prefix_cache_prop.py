"""Property tests for the prefix cache: random insert / lookup / release
/ fork / invalidate sequences driven against a flat-policy and a
radix-policy ``BlockManager`` in lockstep (hypothesis when installed —
``tests/hypothesis_compat.py`` — plus a seeded fallback soak).

Checked after every op:

* ``BlockManager.check_invariants`` on both managers;
* refcount balance — every block's refcount equals the number of live
  holders (request chains + forks) the model says hold it;
* the ``prefix_cache_watermark`` cap on unreferenced cached blocks is
  never exceeded once enforced (release time);
* with whole-chain releases and no eviction pressure, the radix tree's
  longest-prefix match is never *shorter* than the flat exact-match
  cache's for the same prompt. (Whole chains only: a request that
  releases a strict suffix of its chain early can legitimately leave the
  flat cache with dangling-suffix hashes the radix tree refuses to hold,
  so the oracle comparison is only sound under the engine's actual
  release discipline — requests free their whole chain at once.)
"""
import numpy as np
import pytest

from repro.core.block_manager import BlockManager, OutOfBlocks
from tests.hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st

BS = 4          # block size: small so prompts span several blocks
ALPHABET = 4    # tiny token alphabet -> shared prefixes arise naturally


class DualModel:
    """Applies one abstract op stream to a flat and a radix manager and
    keeps the reference model: rid -> per-side (blocks, chain, prompt)
    plus fork holds. Block ids differ per side; ops are abstract."""

    def __init__(self, n_blocks, watermark=1.0):
        self.bms = {
            "flat": BlockManager(n_blocks, BS, prefix_cache_policy="flat",
                                 prefix_cache_watermark=watermark),
            "radix": BlockManager(n_blocks, BS, prefix_cache_policy="radix",
                                  prefix_cache_watermark=watermark),
        }
        self.watermark = watermark
        self.live = {}           # rid -> {side: (blocks, chain)}
        self.forks = []          # list of {side: block}
        self.prompts = {}        # rid -> prompt tokens
        self._next_rid = 0

    # -- ops ----------------------------------------------------------
    def insert(self, prompt):
        """Admit a request: claim the cached prefix, allocate the rest,
        register the newly filled blocks. Skipped (on both sides) when
        either side lacks free blocks — keeps the sides in lockstep."""
        n_full = len(prompt) // BS
        claimed = {}
        for side, bm in self.bms.items():
            if side == "radix":
                m = bm.lookup_prefix_ex(prompt)
                blocks, chain = list(m.blocks), list(m.chain)
            else:
                blocks, _n, chain = bm.lookup_prefix(prompt)
                blocks = list(blocks)
            claimed[side] = (blocks, chain)
        need = {side: n_full - len(blocks)
                for side, (blocks, _c) in claimed.items()}
        if any(not self.bms[s].can_allocate(n) for s, n in need.items()):
            for side, (blocks, _c) in claimed.items():
                if blocks:
                    self.bms[side].release(blocks)
            return None
        rid = self._next_rid
        self._next_rid += 1
        entry = {}
        for side, (blocks, chain) in claimed.items():
            start = len(blocks)
            blocks = blocks + self.bms[side].allocate(need[side])
            self.bms[side].register_prefix(blocks, chain, start)
            entry[side] = (blocks, chain)
        self.live[rid] = entry
        self.prompts[rid] = list(prompt)
        return rid

    def release(self, rid):
        """Whole-chain release (the engine's discipline — docstring)."""
        for side, (blocks, _chain) in self.live.pop(rid).items():
            self.bms[side].release(blocks)

    def fork(self, rid, j):
        """COW share: take an extra ref on the prefix ``blocks[:j+1]``.
        Whole prefix, never a lone interior block — a real forker claims
        its path root-first (radix path closure: a referenced node's
        parent stays referenced)."""
        hold = {}
        for side, (blocks, _chain) in self.live[rid].items():
            j_side = j % len(blocks)
            hold[side] = [self.bms[side].fork(b)
                          for b in blocks[:j_side + 1]]
        self.forks.append(hold)

    def release_fork(self, i):
        hold = self.forks.pop(i % len(self.forks))
        for side, blocks in hold.items():
            self.bms[side].release(blocks)

    def invalidate(self, rid, k):
        """Pre-overwrite invalidation of the first ``k`` chain blocks
        (what compression does to dest blocks)."""
        for side, (blocks, _chain) in self.live[rid].items():
            self.bms[side].invalidate_blocks(blocks[:max(1, k)])

    # -- checks -------------------------------------------------------
    def check(self):
        expected = {side: {} for side in self.bms}
        for entry in self.live.values():
            for side, (blocks, _chain) in entry.items():
                for b in blocks:
                    expected[side][b] = expected[side].get(b, 0) + 1
        for hold in self.forks:
            for side, blocks in hold.items():
                for b in blocks:
                    expected[side][b] = expected[side].get(b, 0) + 1
        for side, bm in self.bms.items():
            bm.check_invariants()
            for b in range(bm.num_blocks):
                assert bm.ref[b] == expected[side].get(b, 0), (
                    f"{side}: block {b} ref {bm.ref[b]} != "
                    f"{expected[side].get(b, 0)} model holders")
            if self.watermark < 1.0:
                limit = int(self.watermark * bm.num_blocks)
                assert len(bm.cached_free) <= limit

    def check_radix_ge_flat(self, prompt):
        flat = self.bms["flat"].probe_prefix(prompt)
        radix = self.bms["radix"].probe_prefix(prompt)
        assert radix >= flat, (
            f"radix match {radix} < flat match {flat} for {prompt}")


def _rand_prompt(rng, max_blocks=4):
    n = int(rng.integers(1, max_blocks + 1)) * BS
    return [int(t) for t in rng.integers(0, ALPHABET, size=n)]


def _step(model, rng, *, pressure):
    """One random op. With ``pressure`` the pool is small and we add
    churn ops (segment registration, burst alloc/free) that force
    evictions; without it the pool is sized so nothing is ever evicted
    and the radix>=flat oracle holds."""
    rids = list(model.live)
    op = int(rng.integers(0, 8))
    if op <= 2 or not rids:                      # insert (weighted)
        model.insert(_rand_prompt(rng))
    elif op == 3:
        model.release(int(rng.choice(rids)))
    elif op == 4:
        model.fork(int(rng.choice(rids)), int(rng.integers(0, 8)))
    elif op == 5 and model.forks:
        model.release_fork(int(rng.integers(0, len(model.forks))))
    elif op == 6:
        model.invalidate(int(rng.choice(rids)), int(rng.integers(1, 3)))
    elif pressure:                               # churn: burst alloc/free
        for side, bm in model.bms.items():
            n = int(rng.integers(1, 4))
            if bm.can_allocate(n):
                bm.release(bm.allocate(n))
        # park a compressed segment keyed off a live chain (radix only)
        rid = int(rng.choice(rids))
        blocks, chain = model.live[rid]["radix"]
        bm = model.bms["radix"]
        if chain and bm.can_allocate(1):
            j = int(rng.integers(0, len(chain)))
            payload = bm.allocate(1)
            bm.register_segment(chain[j], payload, (j + 1) * BS)
            bm.release(payload)
    model.check()
    if not pressure and model.prompts:
        ks = list(model.prompts)
        model.check_radix_ge_flat(
            model.prompts[int(rng.choice(ks))])


def _run_soak(seed, *, pressure, n_ops=60):
    # no-pressure mode: pool big enough that nothing is ever evicted
    # (<=60 ops x <=4 blocks bounded by insert-skip); pressure mode:
    # small pool + watermark cap so every eviction path runs
    if pressure:
        model = DualModel(24, watermark=0.5)
    else:
        model = DualModel(512)
    rng = np.random.default_rng(seed)
    for _ in range(n_ops):
        _step(model, rng, pressure=pressure)
    for rid in list(model.live):
        model.release(rid)
    while model.forks:
        model.release_fork(0)
    model.check()


# ---------------------------------------------------------------- seeded
@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("pressure", [False, True],
                         ids=["oracle", "pressure"])
def test_prefix_cache_random_soak(seed, pressure):
    """Seeded fallback soak — runs even without hypothesis."""
    _run_soak(seed, pressure=pressure)


def test_out_of_blocks_insert_skipped():
    """Insert degrades to a clean no-op (refs rolled back) when either
    side cannot allocate."""
    model = DualModel(8)
    assert model.insert([0] * (2 * BS)) is not None
    assert model.insert([1] * (4 * BS)) is not None
    assert model.insert([2] * (4 * BS)) is None   # 10 > 8 blocks
    model.check()
    with pytest.raises(OutOfBlocks):
        model.bms["flat"].allocate(99)


# ------------------------------------------------------------ hypothesis
@settings(max_examples=30, deadline=None)
@given(st.data())
def test_prefix_cache_prop_oracle(data):
    """Hypothesis-driven op streams, eviction-free: invariants +
    refcount balance + radix longest-prefix match >= flat match."""
    model = DualModel(512)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    for _ in range(data.draw(st.integers(5, 50))):
        _step(model, rng, pressure=False)


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_prefix_cache_prop_pressure(data):
    """Hypothesis-driven op streams under eviction pressure + watermark:
    invariants, refcount balance, watermark cap, clean teardown."""
    model = DualModel(24, watermark=0.5)
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    for _ in range(data.draw(st.integers(5, 50))):
        _step(model, rng, pressure=True)
    for rid in list(model.live):
        model.release(rid)
    while model.forks:
        model.release_fork(0)
    model.check()


if not HAVE_HYPOTHESIS:
    # the @given shim already marks the two property tests as skipped;
    # nothing else to do — the seeded soak above still runs everywhere
    pass
