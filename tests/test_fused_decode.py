"""Fusion-parity tests (ISSUE 4): the fused on-device sampler and the
multi-step decode horizon must be invisible in the token streams.

The (seed, position)-keyed PRNG makes parity *exact*: for every request,
fused decode (K=1 and K>1) must produce token-for-token (and
logprob-for-logprob) identical output vs the unfused reference path —
greedy, seeded top-k/top-p mixes, eos sets, stop sequences, compression
and all. Snapshot/restore must round-trip mid-horizon."""
import dataclasses

import jax
import pytest

from repro.configs import get_config
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine, \
    _fused_chunk_sizes
from repro.core.sampling import SamplingParams
from repro.models import lm
from engine_utils import submit

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))

PROMPTS = [[1, 2, 3, 4, 5], [9, 8, 7], [10, 11, 12, 13, 14, 15, 16],
           [20, 21]]
# greedy + seeded top-k / top-p mixes, one logprob consumer; long enough
# outputs that compression triggers (n_max=3 * block_size=8 = 24-token cap)
MIXED = [SamplingParams(max_new_tokens=28),
         SamplingParams(max_new_tokens=28, temperature=0.8, top_k=5,
                        seed=7),
         SamplingParams(max_new_tokens=28, temperature=1.1, top_p=0.9,
                        seed=3),
         SamplingParams(max_new_tokens=28, temperature=0.7, seed=11,
                        logprobs=True)]


def make_engine(**kw):
    base = dict(block_size=8, n_total_blocks=64, max_batch=4, m_qslots=4,
                n_max=3, window=4, max_model_len=256, prefill_rows=2,
                prefill_len=64, compress=CompressOptions(window=4))
    base.update(kw)
    return ZipageEngine(CFG, PARAMS, EngineOptions(**base))


def run_mixed(params_list=MIXED, **kw):
    eng = make_engine(**kw)
    rids = [eng.add_request(p, sp) for p, sp in zip(PROMPTS, params_list)]
    done = eng.run(max_steps=500)
    return [(done[r].output, done[r].logprobs, done[r].finish_reason)
            for r in rids], eng


REF, _ = run_mixed(fuse_sampling=False)


@pytest.mark.parametrize("decode_steps", [1, 5, 8])
def test_fused_token_and_logprob_parity(decode_steps):
    out, eng = run_mixed(fuse_sampling=True, decode_steps=decode_steps)
    assert out == REF
    if decode_steps > 1:
        assert max(m["decode_horizon"] for m in eng.metrics) > 1
        assert eng.step_count < 40          # multi-step actually engaged
    # compression ran under the horizon and pool accounting balanced
    assert sum(m["n_compressing"] for m in eng.metrics) > 0
    eng.bm.check_invariants()
    assert eng.bm.num_free == eng.opts.n_total_blocks


def test_fused_matches_naive_reference_greedy():
    """Greedy fused output equals the training-path forward argmax while
    the paged cache is exact (no compression: short outputs)."""
    def ref_generate(prompt, n_new):
        import jax.numpy as jnp
        toks = list(prompt)
        for _ in range(n_new):
            logits = lm.forward(CFG, PARAMS, jnp.asarray([toks]))
            toks.append(int(jnp.argmax(logits[0, -1])))
        return toks[len(prompt):]

    eng = make_engine(n_max=4, decode_steps=8)
    rids = [submit(eng, p, 8) for p in PROMPTS]
    done = eng.run(max_steps=200)
    for rid, p in zip(rids, PROMPTS):
        assert done[rid].output == ref_generate(p, 8)


def test_eos_mid_horizon_parity():
    """A sampled eos inside a fused chunk must stop the stream at exactly
    the same token as the unfused engine (in-scan active-mask gating)."""
    # pick an eos id that fires mid-stream in the reference output
    base_out = REF[0][0]
    eos = base_out[len(base_out) // 2]
    sps = [dataclasses.replace(MIXED[0], eos_ids=(eos,))] + list(MIXED[1:])
    want, _ = run_mixed(sps, fuse_sampling=False)
    assert want[0][2] == "stop" and len(want[0][0]) < len(base_out)
    for k in (1, 8):
        got, _ = run_mixed(sps, fuse_sampling=True, decode_steps=k)
        assert got == want


def test_stop_sequences_force_single_step_horizon():
    """Host-side stop matching caps that request's horizon at 1 token per
    step; outputs (with truncation) still match the unfused path."""
    base_out = REF[0][0]
    stop = tuple(base_out[10:12])
    sps = [dataclasses.replace(MIXED[0], stop=(stop,))] + list(MIXED[1:])
    want, _ = run_mixed(sps, fuse_sampling=False)
    assert want[0][2] == "stop"
    got, eng = run_mixed(sps, fuse_sampling=True, decode_steps=8)
    assert got == want
    # while the stop-bearing request runs, its cap pins K only for itself;
    # after it finishes the batch horizon opens up again
    assert any(m["decode_horizon"] > 1 for m in eng.metrics)


def test_snapshot_restore_mid_horizon():
    """snapshot()/restore() round-trips the device-carried sampling state
    (tokens_next / active_mask / counters) between multi-step dispatches."""
    eng = make_engine(decode_steps=8)
    rids = [eng.add_request(p, sp) for p, sp in zip(PROMPTS, MIXED)]
    for _ in range(3):
        eng.step()
    assert any(len(r.output) for r in eng.running)   # genuinely mid-stream
    snap = eng.snapshot()
    done_a = eng.run(max_steps=500)
    out_a = [(done_a[r].output, done_a[r].logprobs) for r in rids]
    eng2 = make_engine(decode_steps=8)
    eng2.restore(snap)
    done_b = eng2.run(max_steps=500)
    out_b = [(done_b[r].output, done_b[r].logprobs) for r in rids]
    assert out_a == out_b


def test_restore_across_modes():
    """A snapshot taken under the unfused path resumes identically under
    the fused multi-step path (device mirrors are invalidated wholesale)."""
    eng = make_engine(fuse_sampling=False)
    rids = [eng.add_request(p, sp) for p, sp in zip(PROMPTS, MIXED)]
    for _ in range(3):
        eng.step()
    snap = eng.snapshot()
    done_a = eng.run(max_steps=500)
    out_a = [done_a[r].output for r in rids]
    eng2 = make_engine(fuse_sampling=True, decode_steps=8)
    eng2.restore(snap)
    done_b = eng2.run(max_steps=500)
    out_b = [done_b[r].output for r in rids]
    assert out_a == out_b


def test_decode_steps_requires_fusion():
    with pytest.raises(ValueError):
        make_engine(fuse_sampling=False, decode_steps=4)
    with pytest.raises(ValueError):
        make_engine(decode_steps=0)


def test_fused_chunk_sizes_are_pow2_and_cover():
    for k in range(1, 33):
        sizes = _fused_chunk_sizes(k)
        assert sum(sizes) == k
        assert all(s & (s - 1) == 0 for s in sizes)
        if k >= 4:
            assert len(sizes) >= 2       # pipelined fetch has two chunks
