"""Zipage engine end-to-end tests on the tiny LM (CPU).

Reference: naive greedy generation with the training-path forward. Engine
outputs must match it exactly while no compression triggers (paged cache is
exact), and obey structural invariants (block cap, pool accounting) when
compression does trigger.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.models import lm
from engine_utils import submit

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))


def ref_generate(prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        logits = lm.forward(CFG, PARAMS, jnp.asarray([toks]))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


def make_engine(**kw):
    base = dict(block_size=8, n_total_blocks=64, max_batch=4, m_qslots=2,
                n_max=3, window=4, max_model_len=256, prefill_rows=2,
                prefill_len=64, compress=CompressOptions(window=4),
                temperature=0.0)
    base.update(kw)
    return ZipageEngine(CFG, PARAMS, EngineOptions(**base))


PROMPTS = [[1, 2, 3, 4, 5], [9, 8, 7], [10, 11, 12, 13, 14, 15, 16],
           [20, 21]]


def test_no_compression_matches_reference():
    eng = make_engine(n_max=None)            # full-KV baseline
    rids = [submit(eng, p, 8) for p in PROMPTS]
    done = eng.run(max_steps=200)
    for rid, p in zip(rids, PROMPTS):
        assert done[rid].output == ref_generate(p, 8)


def test_zipage_matches_reference_before_budget():
    """With compression on but never triggered (short outputs), Zipage must
    be exact too."""
    eng = make_engine(n_max=4)               # 4 blocks * 8 = 32 > 5+8 tokens
    rids = [submit(eng, p, 8) for p in PROMPTS]
    done = eng.run(max_steps=200)
    for rid, p in zip(rids, PROMPTS):
        assert done[rid].output == ref_generate(p, 8)


def test_compression_triggers_and_caps_blocks():
    eng = make_engine(n_max=3, m_qslots=4)   # cap = 24 tokens
    rids = [submit(eng, p, 40) for p in PROMPTS]
    done = eng.run(max_steps=400)
    comp_steps = sum(m["n_compressing"] for m in eng.metrics)
    assert comp_steps > 0, "compression never triggered"
    for rid in rids:
        r = done[rid]
        assert len(r.output) == 40
    # block cap: after first compression a request holds <= n_max blocks;
    # engine-wide accounting must balance
    eng.bm.check_invariants()
    assert eng.bm.num_free == eng.opts.n_total_blocks


def test_block_cap_invariant_during_run():
    eng = make_engine(n_max=3, m_qslots=4)
    for p in PROMPTS:
        submit(eng, p, 40)
    max_blocks_seen = 0
    while eng.waiting or eng.running:
        eng.step()
        for r in eng.running:
            if r.compressed:
                max_blocks_seen = max(max_blocks_seen, r.n_blocks)
                assert r.n_blocks <= eng.opts.n_max + 1
        assert eng.step_count < 500
    assert max_blocks_seen > 0


def test_async_and_sync_compression_agree():
    outs = {}
    for mode in (True, False):
        eng = make_engine(n_max=3, m_qslots=4, async_compression=mode)
        rids = [submit(eng, p, 30) for p in PROMPTS]
        done = eng.run(max_steps=400)
        outs[mode] = [done[r].output for r in rids]
    assert outs[True] == outs[False]


def test_constrained_respects_M():
    eng = make_engine(scheduling="constrained", m_qslots=2, max_batch=4,
                      n_max=3)
    for i in range(6):
        submit(eng, [1 + i, 2, 3], 20)
    while eng.waiting or eng.running:
        eng.step()
        assert len(eng.running) <= 2          # concurrency capped at M
        assert eng.step_count < 800


def test_hybrid_exceeds_M_with_short_requests():
    eng = make_engine(scheduling="hybrid", m_qslots=1, max_batch=4, n_max=3)
    for i in range(4):
        submit(eng, [1 + i, 2, 3], 6)          # short: never needs a qslot
    peak = 0
    while eng.waiting or eng.running:
        eng.step()
        peak = max(peak, len(eng.running))
        assert eng.step_count < 400
    assert peak > 1, "hybrid scheduling should run slotless requests"


def test_prefix_cache_hits_and_sharing():
    eng = make_engine(n_max=3, prefix_caching=True, block_size=4,
                      window=2, compress=CompressOptions(window=2))
    shared_prefix = list(range(1, 13))        # 3 full blocks of 4
    r1 = submit(eng, shared_prefix + [30], 25)
    done1 = None
    # run until first finishes so its blocks are cached
    while r1 not in eng.finished:
        eng.step()
    r2 = submit(eng, shared_prefix + [40], 25)
    eng.run(max_steps=400)
    req2 = eng.finished[r2]
    assert req2.n_cached >= 4, "prefix cache should have matched blocks"
    eng.bm.check_invariants()
    assert eng.bm.num_free == eng.opts.n_total_blocks


def test_shared_prefix_compression_preserves_sharing():
    """Two live requests share a prefix; compression of one must not corrupt
    the other (compress-into-target-blocks, §4.4)."""
    eng = make_engine(n_max=3, prefix_caching=True, block_size=4,
                      max_batch=4, m_qslots=4, window=2,
                      compress=CompressOptions(window=2))
    shared_prefix = list(range(1, 13))
    r1 = submit(eng, shared_prefix + [30], 30)
    r2 = submit(eng, shared_prefix + [40], 30)
    done = eng.run(max_steps=600)
    assert len(done[r1].output) == 30
    assert len(done[r2].output) == 30
    eng.bm.check_invariants()
    assert eng.bm.num_free == eng.opts.n_total_blocks


def test_preemption_under_block_pressure():
    eng = make_engine(n_total_blocks=10, max_batch=4, m_qslots=4, n_max=3,
                      prefix_caching=False)
    rids = [submit(eng, [1 + i, 2, 3], 30) for i in range(4)]
    done = eng.run(max_steps=1000)
    for rid in rids:
        assert len(done[rid].output) == 30
    assert eng.bm.num_free == 10


def test_snapshot_restore_determinism():
    eng = make_engine(n_max=3, m_qslots=4)
    rids = [submit(eng, p, 24) for p in PROMPTS]
    for _ in range(5):
        eng.step()
    snap = eng.snapshot()
    done_a = eng.run(max_steps=400)
    out_a = [done_a[r].output for r in rids]
    eng2 = make_engine(n_max=3, m_qslots=4)
    eng2.restore(snap)
    done_b = eng2.run(max_steps=400)
    out_b = [done_b[r].output for r in rids]
    assert out_a == out_b
