"""Training substrate tests: optimizer, checkpointing, restart exactness,
grad accumulation, EF-int8 compression."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import lm
from repro.training import checkpoint as ckpt
from repro.training import optimizer as opt
from repro.training.compress_grads import quantize_psum_dequant
from repro.training.data import DataConfig, batch_at
from repro.training.train_loop import build_train_step

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
ADAMW = opt.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=100)
DC = DataConfig(seq_len=32, global_batch=8, vocab_size=CFG.vocab_size)


def test_lr_schedule():
    assert float(opt.lr_at(ADAMW, 0)) == 0.0
    assert float(opt.lr_at(ADAMW, 2)) == pytest.approx(1e-2, rel=1e-5)
    assert float(opt.lr_at(ADAMW, 100)) == pytest.approx(1e-3, rel=1e-3)


def test_loss_decreases():
    params = lm.init(CFG, jax.random.key(0))
    state = opt.init_opt_state(params)
    step = jax.jit(build_train_step(CFG, ADAMW, vocab_chunk=16))
    batch = jax.tree.map(jnp.asarray, batch_at(DC, 0))
    losses = []
    for _i in range(25):
        params, state, _, m = step(params, state, None, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5
    assert np.isfinite(losses).all()


def test_grad_accumulation_equivalence():
    params = lm.init(CFG, jax.random.key(0))
    batch = jax.tree.map(jnp.asarray, batch_at(DC, 0))
    s1 = jax.jit(build_train_step(CFG, ADAMW, accum_steps=1, vocab_chunk=16))
    s2 = jax.jit(build_train_step(CFG, ADAMW, accum_steps=4, vocab_chunk=16))
    p1, _, _, m1 = s1(params, opt.init_opt_state(params), None, batch)
    p2, _, _, m2 = s2(params, opt.init_opt_state(params), None, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    assert float(m1["grad_norm"]) == pytest.approx(float(m2["grad_norm"]),
                                                   rel=1e-4)
    # Adam's first step divides by sqrt(v)≈|g|, amplifying fp reduction-order
    # noise: compare at the update scale (lr=1e-2)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=2e-3)


def test_checkpoint_roundtrip(tmp_path):
    params = lm.init(CFG, jax.random.key(0))
    state = opt.init_opt_state(params)
    tree = {"params": params, "opt": state}
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    ckpt.save(d, 3, tree, extra={"data_step": 3})
    ckpt.save(d, 7, tree, extra={"data_step": 7})
    assert ckpt.latest_step(d) == 7
    restored, extra = ckpt.restore(d, 7, tree)
    assert extra["data_step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_atomicity(tmp_path):
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    tree = {"x": jnp.arange(4.0)}
    for s in range(6):
        ckpt.save(d, s, tree, keep=2)
    steps = sorted(int(x.split("_")[1]) for x in os.listdir(d))
    assert steps == [4, 5]
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_restart_exactness(tmp_path):
    """Crash at step 5, restore, continue — must equal the uninterrupted
    run bit-for-bit (deterministic stateless data pipeline)."""
    d = str(tmp_path / "ckpt")
    os.makedirs(d)
    step_fn = jax.jit(build_train_step(CFG, ADAMW, vocab_chunk=16))

    def run(n, params, state, start=0):
        for i in range(start, n):
            batch = jax.tree.map(jnp.asarray, batch_at(DC, i))
            params, state, _, _ = step_fn(params, state, None, batch)
        return params, state

    p0 = lm.init(CFG, jax.random.key(0))
    s0 = opt.init_opt_state(p0)
    p_ref, _ = run(10, p0, s0)

    p, s = run(5, lm.init(CFG, jax.random.key(0)), opt.init_opt_state(p0))
    ckpt.save(d, 5, {"params": p, "opt": s}, extra={"data_step": 5})
    restored, extra = ckpt.restore(d, 5, {"params": p, "opt": s})
    restored = jax.tree.map(jnp.asarray, restored)
    p2, _ = run(10, restored["params"], restored["opt"],
                start=extra["data_step"])
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ef_int8_quantization_error_feedback():
    """Residual bookkeeping: applied + err' == g + err (exactly)."""
    mesh = jax.make_mesh((1,), ("pod",))
    g = jnp.asarray(np.random.default_rng(0).normal(size=(64,)), jnp.float32)
    e = jnp.asarray(np.random.default_rng(1).normal(size=(64,)) * 0.01,
                    jnp.float32)

    def f(g, e):
        return quantize_psum_dequant(g, e, "pod")

    from jax.sharding import PartitionSpec as P

    from repro.kernels.pallas_compat import shard_map
    out, new_err = jax.jit(shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
        check=False))(g, e)
    out, new_err = np.asarray(out), np.asarray(new_err)
    np.testing.assert_allclose(out + new_err, np.asarray(g) + np.asarray(e),
                               rtol=1e-5, atol=1e-6)
    # quantization error bounded by scale/2
    scale = np.abs(np.asarray(g) + np.asarray(e)).max() / 127
    assert np.abs(new_err).max() <= scale / 2 + 1e-7
