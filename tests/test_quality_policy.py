"""Quality-aware compression planner tests (docs/EVAL.md).

Two layers:
  * pure-host unit tests driving ``Scheduler`` directly — the effective
    per-request cap (``_n_max_cap`` incl. the sanitizer's worst-case
    envelope), the shared due-predicate, victim shielding, the
    lowest-redundancy-first candidate order, and the deferral counter;
  * engine-level tests through the tiny LM — ``default`` policy is
    bit-identical to omitting the field, "protect"/"aggressive"
    measurably shift per-request compression counts and land in the
    right ``scheduler_stats`` buckets, and ``quality_aware=True``
    defers compressions under pool headroom.
"""
import dataclasses

import jax
import pytest

from repro.api import SamplingParams, Zipage
from repro.configs import get_config
from repro.core.block_manager import BlockManager
from repro.core.request import Request, State
from repro.core.scheduler import Scheduler, SchedulerOutputs, SchedulerParams
from repro.models import lm

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))


# ----------------------------------------------------------------------
# pure-host unit tests (no model, no device steps)


def make_sched(n_blocks=64, block_size=4, **kw):
    base = dict(block_size=block_size, max_batch=4, m_qslots=4, n_max=3,
                window=2, prefill_rows=4, compression_enabled=True,
                budget_blocks=2, prefix_ok=False)
    base.update(kw)
    return Scheduler(SchedulerParams(**base),
                     BlockManager(n_blocks, block_size,
                                  enable_prefix_cache=False))


def running_request(s, rid, *, policy="default", n_blocks=4,
                    redundancy=None, attn_entropy=None):
    """Fabricate a fully-prefilled RUNNING request holding ``n_blocks``
    exactly-full blocks, i.e. compression-eligible modulo its cap."""
    r = Request(rid=rid, prompt=[1, 2, 3], max_new_tokens=8,
                arrival=float(rid),
                sampling=SamplingParams(compression_policy=policy))
    r.blocks = s.bm.allocate(n_blocks)
    r.state = State.RUNNING
    r.slot = s.free_slots.pop()
    r.qslot = s.free_qslots.pop()
    r.seq_len = n_blocks * s.p.block_size
    r.position = r.seq_len
    r.win_count = s.p.window
    r.redundancy = redundancy
    r.attn_entropy = attn_entropy
    s.running.append(r)
    return r


def test_n_max_cap_per_policy():
    s = make_sched(n_blocks=16, quality_aware=True, compression_deferral=2,
                   quality_defer_min_free=8)
    default = running_request(s, 0, n_blocks=1)
    protect = running_request(s, 1, policy="protect", n_blocks=1)
    aggressive = running_request(s, 2, policy="aggressive", n_blocks=1)
    # headroom (13 free >= 8): default defers by compression_deferral
    assert s._n_max_cap(default) == 5
    assert s._n_max_cap(protect) == 7       # n_max + 2*deferral, always
    assert s._n_max_cap(aggressive) == 3    # base rule, always
    # drain the pool below the floor: the default-policy deferral vanishes,
    # the explicit-intent caps don't
    s.bm.allocate(10)
    assert s.bm.num_free < s.p.quality_defer_min_free
    assert s._n_max_cap(default) == 3
    assert s._n_max_cap(protect) == 7
    assert s._n_max_cap(aggressive) == 3
    # the sanitizer audits against the static envelope: headroom-blind
    assert s._n_max_cap(default, worst_case=True) == 5
    assert s._n_max_cap(protect, worst_case=True) == 7
    assert s._n_max_cap(aggressive, worst_case=True) == 3


def test_n_max_cap_quality_off_is_base_rule():
    s = make_sched(n_blocks=16, compression_deferral=2)
    assert s._n_max_cap(running_request(s, 0, n_blocks=1)) == 3
    assert s._n_max_cap(running_request(s, 1, policy="aggressive",
                                        n_blocks=1)) == 3
    # protect is per-request intent — honored even with the planner off
    assert s._n_max_cap(running_request(s, 2, policy="protect",
                                        n_blocks=1)) == 7


def test_compression_due_tracks_effective_cap():
    s = make_sched(n_blocks=64, quality_aware=True, compression_deferral=1,
                   quality_defer_min_free=8)
    at_base = running_request(s, 0, n_blocks=3)     # n_max, deferred
    at_cap = running_request(s, 1, n_blocks=4)      # n_max + deferral
    agg = running_request(s, 2, policy="aggressive", n_blocks=3)
    assert not s._compression_due(at_base)
    assert s._compression_due(at_cap)
    assert s._compression_due(agg)
    # losing the qslot or an unfilled last block disarms the trigger
    at_cap.qslot = -1
    assert not s._compression_due(at_cap)


def test_victim_shielding_matrix():
    s = make_sched(n_blocks=32, quality_aware=True,
                   quality_entropy_threshold=0.8)
    spread = running_request(s, 0, n_blocks=1, attn_entropy=0.9)
    peaked = running_request(s, 1, n_blocks=1, attn_entropy=0.3)
    unmeasured = running_request(s, 2, n_blocks=1)
    volunteer = running_request(s, 3, policy="aggressive", n_blocks=1,
                                attn_entropy=0.95)
    assert s._victim_shielded(spread)
    assert not s._victim_shielded(peaked)
    assert not s._victim_shielded(unmeasured)
    assert not s._victim_shielded(volunteer)      # intent beats telemetry

    off = make_sched(n_blocks=32, quality_entropy_threshold=0.8)
    assert not off._victim_shielded(
        running_request(off, 0, n_blocks=1, attn_entropy=0.9))
    assert off._victim_shielded(
        running_request(off, 1, policy="protect", n_blocks=1))


def test_candidate_order_lowest_redundancy_first():
    s = make_sched(n_blocks=64, quality_aware=True, compression_deferral=1,
                   quality_defer_min_free=8)
    running_request(s, 0, n_blocks=4, redundancy=0.9)
    running_request(s, 1, policy="aggressive", n_blocks=4)
    running_request(s, 2, n_blocks=4, redundancy=0.1)
    running_request(s, 3, policy="protect", n_blocks=5, redundancy=0.0)
    outs = SchedulerOutputs()
    s.plan_compression(outs)
    # aggressive volunteer leads, defaults lowest-redundancy-first,
    # protect trails even at the lowest measured redundancy
    assert [c.request.rid for c in outs.compress] == [1, 2, 0, 3]


def test_candidate_order_unchanged_without_quality():
    s = make_sched(n_blocks=64)
    running_request(s, 0, n_blocks=4, redundancy=0.9)
    running_request(s, 1, policy="aggressive", n_blocks=4, redundancy=0.5)
    running_request(s, 2, n_blocks=4, redundancy=0.1)
    outs = SchedulerOutputs()
    s.plan_compression(outs)
    assert [c.request.rid for c in outs.compress] == [0, 1, 2]


def test_deferral_counter_counts_base_rule_due():
    s = make_sched(n_blocks=64, quality_aware=True, compression_deferral=1,
                   quality_defer_min_free=0)
    running_request(s, 0, n_blocks=3)               # due at 4: deferred
    running_request(s, 1, n_blocks=4)               # at effective cap
    outs = SchedulerOutputs()
    s.plan_compression(outs)
    assert [c.request.rid for c in outs.compress] == [1]
    assert s.n_comp_deferred == 1
    # cumulative across steps, and exposed through stats()
    s.plan_compression(SchedulerOutputs())
    assert s.n_comp_deferred == 2
    assert s.stats(SchedulerOutputs())["n_comp_deferred"] == 2


def test_sampling_params_rejects_unknown_policy():
    with pytest.raises(ValueError, match="compression_policy"):
        SamplingParams(compression_policy="bogus")


# ----------------------------------------------------------------------
# engine-level: policy plumbing api -> engine -> scheduler -> telemetry

ENGINE_KW = dict(block_size=4, n_total_blocks=48, max_batch=2, m_qslots=2,
                 n_max=3, window=2, max_model_len=128, prefill_rows=2,
                 prefill_len=32, dtype="float32")
PROMPT = list(range(1, 13))


def _run_policy(policy, **engine_kw):
    kw = dict(ENGINE_KW, **engine_kw)
    z = Zipage(CFG, PARAMS, **kw)
    sp = SamplingParams(max_new_tokens=40, compression_policy=policy)
    outs = z.generate([PROMPT], [sp], max_steps=400)
    stats = z.scheduler_stats
    (req,) = z.engine.scheduler.finished.values()
    return outs[0].token_ids, req.n_compressions, stats


def test_default_policy_is_the_default():
    """``compression_policy="default"`` must be indistinguishable from
    omitting the field — the pre-PR stream, token for token."""
    z = Zipage(CFG, PARAMS, **ENGINE_KW)
    base = z.generate([PROMPT], [SamplingParams(max_new_tokens=40)],
                      max_steps=400)
    toks, _, stats = _run_policy("default")
    assert toks == base[0].token_ids
    assert stats["quality_aware"] is False
    assert stats["n_comp_deferred"] == 0
    assert stats["n_comp_protect"] == stats["n_comp_aggressive"] == 0
    assert stats["n_comp_default"] > 0


def test_policy_shifts_compression_counts():
    _, n_default, s_default = _run_policy("default")
    _, n_protect, s_protect = _run_policy("protect")
    _, n_aggressive, s_aggressive = _run_policy("aggressive")
    # protect defers to n_max + 2*deferral: measurably fewer compressions
    assert n_protect < n_default
    assert n_aggressive >= n_protect
    assert n_default > 0 and n_protect >= 0
    # and every event lands in its policy's stats bucket
    assert s_protect["n_comp_protect"] == n_protect
    assert s_protect["n_comp_default"] == 0
    assert s_aggressive["n_comp_aggressive"] == n_aggressive
    assert s_aggressive["n_comp_default"] == 0


def test_quality_aware_defers_under_headroom():
    _, n_base, _ = _run_policy("default")
    _, n_qa, s_qa = _run_policy("default", quality_aware=True,
                                quality_defer_min_free=0)
    assert s_qa["quality_aware"] is True
    assert n_qa < n_base                  # effective cap n_max + deferral
    assert s_qa["n_comp_deferred"] > 0
