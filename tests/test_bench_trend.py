"""tools/bench_trend.py: trajectory table + decode-throughput regression
gate over the per-PR bench-smoke JSON artifacts (`make bench-trend`)."""
import importlib.util
import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "bench_trend", REPO / "tools" / "bench_trend.py")
bench_trend = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_trend)


def conc_point(tps, nano=200.0, schema="zipage-bench-concurrency/v2"):
    return {
        "schema": schema, "jax": "0", "platform": "cpu", "smoke": True,
        "results": [
            {"name": "zipage", "tps": tps, "tokens_per_step": 6.0,
             "t_host_ms": 10.0, "t_device_ms": 2.0,
             "mean_decode_horizon": 4.0},
            {"name": "nano_vllm", "tps": nano},
        ],
        "speedup_tps_zipage_vs_nano": round(tps / nano, 3),
    }


def oversub_point(tps, swap_tps, step_speedup=1.05):
    """Schema-v3 point: the base comparison plus the oversubscribed
    preemption-mode rows (ISSUE 5)."""
    pt = conc_point(tps, schema="zipage-bench-concurrency/v3")
    pt["results"] += [
        {"name": "oversub_recompute", "tps": round(swap_tps / 1.1, 2),
         "tokens_per_step": 36.0, "preemptions": 9, "n_swapped_out": 0},
        {"name": "oversub_swap", "tps": swap_tps, "tokens_per_step": 38.0,
         "preemptions": 9, "n_swapped_out": 9, "n_swapped_in": 9,
         "swap_mb": 1.5},
        {"name": "oversub_auto", "tps": swap_tps, "tokens_per_step": 37.5,
         "preemptions": 9, "n_swapped_out": 4},
    ]
    pt["oversub_speedup_tps_swap_vs_recompute"] = 1.1
    pt["oversub_speedup_step_swap_vs_recompute"] = step_speedup
    return pt


def kernels_point():
    return {
        "schema": "zipage-bench-kernels/v1", "jax": "0", "platform": "cpu",
        "smoke": True,
        "results": [{"name": "scoring", "backend": "jnp",
                     "us_per_call": 12.5}],
    }


def write(tmp_path, name, data):
    p = tmp_path / name
    p.write_text(json.dumps(data))
    return str(p)


def test_trend_table_and_pass(tmp_path, capsys):
    files = [write(tmp_path, "pr1-concurrency.json", conc_point(100.0,
                   schema="zipage-bench-concurrency/v1")),
             write(tmp_path, "pr2-concurrency.json", conc_point(150.0)),
             write(tmp_path, "pr2-kernels.json", kernels_point())]
    out = tmp_path / "TREND.md"
    rc = bench_trend.main(files + ["--out", str(out)])
    assert rc == 0
    text = out.read_text()
    assert "pr1-concurrency" in text and "pr2-concurrency" in text
    assert "| 150.0 |" in text            # newest zipage tps in the table
    assert "scoring/jnp" in text          # kernels table rendered too


def test_trend_fails_on_regression(tmp_path):
    files = [write(tmp_path, "a.json", conc_point(100.0)),
             write(tmp_path, "b.json", conc_point(74.0))]   # -26% > 25%
    assert bench_trend.main(files) == 1
    # a 25%-or-less drop passes the default gate
    files = [write(tmp_path, "a.json", conc_point(100.0)),
             write(tmp_path, "c.json", conc_point(76.0))]
    assert bench_trend.main(files) == 0
    # tighter threshold flips it
    assert bench_trend.main(files + ["--max-regression", "0.1"]) == 1


def test_trend_single_point_trivially_green(tmp_path):
    files = [write(tmp_path, "only.json", conc_point(123.0))]
    assert bench_trend.main(files) == 0


def test_trend_v3_history_and_swap_gate(tmp_path):
    """Synthetic 3-point history (pre-swap v2 point + two v3 points): the
    table grows a swap column, mixed-schema rows render, and the gate
    watches the swap-mode series too."""
    files = [write(tmp_path, "000-pr4.json", conc_point(150.0)),   # pre-v3
             write(tmp_path, "001-pr5.json", oversub_point(155.0, 300.0)),
             write(tmp_path, "002-pr6.json", oversub_point(160.0, 310.0))]
    out = tmp_path / "TREND.md"
    assert bench_trend.main(files + ["--out", str(out)]) == 0
    text = out.read_text()
    assert "swap tok/s" in text and "| 310.0 |" in text
    assert text.count("\n| 0") == 3            # one row per point
    # swap-mode collapse fails the gate even with zipage tps healthy
    files[2] = write(tmp_path, "002-pr6.json", oversub_point(160.0, 200.0))
    assert bench_trend.main(files) == 1
    # a single v3 point after v2 history: swap series has <2 points,
    # zipage series still gates across the schema boundary
    assert bench_trend.main(files[:2]) == 0
    assert bench_trend.main([files[0],
                             write(tmp_path, "001b.json",
                                   oversub_point(80.0, 300.0))]) == 1


def test_trend_unknown_schema_skipped(tmp_path):
    bad = write(tmp_path, "bad.json", {"schema": "nope/v9"})
    good = write(tmp_path, "good.json", conc_point(100.0))
    assert bench_trend.main([bad, good]) == 0
    assert bench_trend.main([bad]) == 2   # nothing recognised


def eval_point(full_acc, n4_acc, n4_vs_full=None):
    """A zipage-eval/v1 point (repro.eval --smoke; docs/EVAL.md)."""
    def row(name, acc, **kw):
        return dict({"name": name, "accuracy": acc,
                     "token_accuracy": acc, "agreement_vs_full": 0.9,
                     "tokens_per_step": 5.0, "compressions": 4}, **kw)
    return {
        "schema": "zipage-eval/v1", "model": "tiny-lm", "smoke": True,
        "config": {"seed": 0},
        "results": [
            row("full_kv", full_acc, accuracy_vs_full=1.0, compressions=0),
            row("n2_w4", round(n4_acc - 0.1, 3)),
            row("n3_w4", round(n4_acc - 0.05, 3)),
            row("n4_w4", n4_acc,
                accuracy_vs_full=n4_vs_full
                or (round(n4_acc / full_acc, 3) if full_acc else None)),
            row("n3_w4_qa", round(n4_acc - 0.02, 3)),
        ],
    }


def quality_point(top1):
    return {
        "schema": "zipage-bench-quality/v1", "jax": "0", "platform": "cpu",
        "smoke": True,
        "results": [
            {"name": "full_kv", "top1_agreement": 1.0, "compressions": 0,
             "steps": 40, "tokens": 60, "us_per_step": 100.0},
            {"name": "paper_c8", "top1_agreement": top1, "compressions": 6,
             "steps": 40, "tokens": 60, "us_per_step": 90.0},
        ],
    }


def test_quality_table_renders(tmp_path):
    files = [write(tmp_path, "000-eval.json", eval_point(0.34, 0.30)),
             write(tmp_path, "000-quality.json", quality_point(0.97)),
             write(tmp_path, "001-eval.json", eval_point(0.34, 0.32))]
    out = tmp_path / "TREND.md"
    assert bench_trend.main(files + ["--out", str(out)]) == 0
    text = out.read_text()
    assert "Reasoning-quality trajectory" in text
    assert "| 0.34 |" in text and "| 0.97 |" in text
    # second eval row has no paired quality point: column renders '-'
    assert "000-eval" in text and "001-eval" in text


def test_accuracy_gate_fails_on_drop(tmp_path):
    # full-KV accuracy drops 5 points > the 2-point default ceiling
    files = [write(tmp_path, "000-eval.json", eval_point(0.34, 0.30)),
             write(tmp_path, "001-eval.json", eval_point(0.29, 0.30))]
    assert bench_trend.main(files) == 1
    # the n4 budget series gates independently of the full-KV anchor
    files = [write(tmp_path, "000-eval.json", eval_point(0.34, 0.30)),
             write(tmp_path, "002-eval.json", eval_point(0.34, 0.25))]
    assert bench_trend.main(files) == 1
    # a within-tolerance wiggle passes; a looser ceiling admits the drop
    files = [write(tmp_path, "000-eval.json", eval_point(0.34, 0.30)),
             write(tmp_path, "003-eval.json", eval_point(0.325, 0.285))]
    assert bench_trend.main(files) == 0
    files = [write(tmp_path, "000-eval.json", eval_point(0.34, 0.30)),
             write(tmp_path, "004-eval.json", eval_point(0.29, 0.25))]
    assert bench_trend.main(files + ["--max-accuracy-drop", "0.1"]) == 0


def test_accuracy_gate_single_point_and_mixed_history(tmp_path):
    # one eval point: trivially green, and eval-only input is recognised
    only = [write(tmp_path, "only-eval.json", eval_point(0.34, 0.30))]
    assert bench_trend.main(only) == 0
    # eval history mixes with concurrency history; the tps gate and the
    # accuracy gate fail independently
    files = [write(tmp_path, "000-conc.json", conc_point(100.0)),
             write(tmp_path, "000-eval.json", eval_point(0.34, 0.30)),
             write(tmp_path, "001-conc.json", conc_point(100.0)),
             write(tmp_path, "001-eval.json", eval_point(0.20, 0.30))]
    assert bench_trend.main(files) == 1
    files[3] = write(tmp_path, "001-eval.json", eval_point(0.34, 0.30))
    assert bench_trend.main(files) == 0

def kernels_v2_point(dense_us=5000.0, ragged_us=4000.0):
    """Schema-v2 point: the ragged decode rows plus the long-context DMA
    footprint summary (ISSUE 9)."""
    return {
        "schema": "zipage-bench-kernels/v2", "jax": "0", "platform": "cpu",
        "smoke": True,
        "results": [
            {"name": "paged_attention", "backend": "jnp",
             "us_per_call": 50.0},
            {"name": "ragged_attention", "backend": "jnp",
             "us_per_call": 45.0},
            {"name": "paged_attention_long", "backend": "jnp",
             "us_per_call": dense_us},
            {"name": "ragged_attention_long", "backend": "jnp",
             "us_per_call": ragged_us},
        ],
        "long_context": {"seq_lens": [4096, 512, 64, 0], "block_size": 64,
                         "max_blocks": 64, "pages_visited": 73,
                         "pages_dense": 256, "pages_ratio": 0.2852},
    }


def test_kernels_v2_speedup_column_and_gate(tmp_path):
    """The v2 kernels table grows the derived ragged-vs-dense speedup
    row, and the kernel gate compares the newest two speedup ratios."""
    files = [write(tmp_path, "000-k.json", kernels_v2_point(5000, 4000)),
             write(tmp_path, "001-k.json", kernels_v2_point(5200, 4100))]
    out = tmp_path / "TREND.md"
    assert bench_trend.main(files + ["--out", str(out)]) == 0
    text = out.read_text()
    assert "ragged_attention_long/jnp" in text
    assert "ragged-vs-dense (long, jnp)" in text
    assert "1.25x" in text and "1.27x" in text
    # newest speedup collapsing below the floor fails the gate
    files[1] = write(tmp_path, "001-k.json", kernels_v2_point(5000, 5600))
    assert bench_trend.main(files) == 1
    # looser threshold passes again
    assert bench_trend.main(files + ["--max-regression", "0.5"]) == 0


def serving_point(tps, ttft_p99=150.0):
    """A zipage-bench-serving/v1 point (benchmarks/bench_serving.py —
    Poisson arrivals through the in-process ASGI app, ISSUE 10)."""
    return {
        "schema": "zipage-bench-serving/v1", "jax": "0", "platform": "cpu",
        "smoke": True,
        "results": [
            {"name": "serving_poisson", "n_requests": 12, "rate_rps": 20.0,
             "n_ok": 12, "n_rejected": 0, "tokens": 170, "steps": 15,
             "wall_s": 0.97, "tps": tps, "ttft_p50_ms": 90.0,
             "ttft_p99_ms": ttft_p99, "itl_mean_ms": 30.0,
             "itl_p50_ms": 16.0, "itl_p99_ms": 110.0},
        ],
    }


def test_serving_table_and_gate(tmp_path):
    files = [write(tmp_path, "000-srv.json", serving_point(170.0)),
             write(tmp_path, "001-srv.json", serving_point(180.0, 160.0))]
    out = tmp_path / "TREND.md"
    assert bench_trend.main(files + ["--out", str(out)]) == 0
    text = out.read_text()
    assert "Serving latency trajectory" in text
    assert "| 180.0 |" in text and "| 160.0 |" in text
    assert "12/12" in text
    # a single serving point is trivially green
    assert bench_trend.main(files[:1]) == 0
    # tok/s collapse fails the serving gate
    files[1] = write(tmp_path, "001-srv.json", serving_point(120.0))
    assert bench_trend.main(files) == 1
    # p99-TTFT blow-up fails even with throughput healthy; widening the
    # ceiling admits it
    files[1] = write(tmp_path, "001-srv.json",
                     serving_point(175.0, 400.0))
    assert bench_trend.main(files) == 1
    assert bench_trend.main(files + ["--max-ttft-growth", "2.0"]) == 0
    # serving history mixes with the other kinds; gates are independent
    mixed = [write(tmp_path, "000-c.json", conc_point(100.0)),
             files[0],
             write(tmp_path, "001-c.json", conc_point(100.0)),
             write(tmp_path, "002-srv.json", serving_point(171.0))]
    assert bench_trend.main(mixed) == 0


def test_kernels_v1_history_mixes_with_v2(tmp_path):
    """v1 history (no long-context rows) must neither break the table nor
    trip the kernel gate: the series gates only between points that both
    carry it."""
    files = [write(tmp_path, "000-k.json", kernels_point()),
             write(tmp_path, "001-k.json", kernels_v2_point())]
    out = tmp_path / "TREND.md"
    assert bench_trend.main(files + ["--out", str(out)]) == 0
    text = out.read_text()
    assert "scoring/jnp" in text and "ragged_attention_long/jnp" in text
    assert "trivially OK" in text
