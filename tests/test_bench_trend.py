"""tools/bench_trend.py: trajectory table + decode-throughput regression
gate over the per-PR bench-smoke JSON artifacts (`make bench-trend`)."""
import importlib.util
import json
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
_spec = importlib.util.spec_from_file_location(
    "bench_trend", REPO / "tools" / "bench_trend.py")
bench_trend = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(bench_trend)


def conc_point(tps, nano=200.0, schema="zipage-bench-concurrency/v2"):
    return {
        "schema": schema, "jax": "0", "platform": "cpu", "smoke": True,
        "results": [
            {"name": "zipage", "tps": tps, "tokens_per_step": 6.0,
             "t_host_ms": 10.0, "t_device_ms": 2.0,
             "mean_decode_horizon": 4.0},
            {"name": "nano_vllm", "tps": nano},
        ],
        "speedup_tps_zipage_vs_nano": round(tps / nano, 3),
    }


def oversub_point(tps, swap_tps, step_speedup=1.05):
    """Schema-v3 point: the base comparison plus the oversubscribed
    preemption-mode rows (ISSUE 5)."""
    pt = conc_point(tps, schema="zipage-bench-concurrency/v3")
    pt["results"] += [
        {"name": "oversub_recompute", "tps": round(swap_tps / 1.1, 2),
         "tokens_per_step": 36.0, "preemptions": 9, "n_swapped_out": 0},
        {"name": "oversub_swap", "tps": swap_tps, "tokens_per_step": 38.0,
         "preemptions": 9, "n_swapped_out": 9, "n_swapped_in": 9,
         "swap_mb": 1.5},
        {"name": "oversub_auto", "tps": swap_tps, "tokens_per_step": 37.5,
         "preemptions": 9, "n_swapped_out": 4},
    ]
    pt["oversub_speedup_tps_swap_vs_recompute"] = 1.1
    pt["oversub_speedup_step_swap_vs_recompute"] = step_speedup
    return pt


def kernels_point():
    return {
        "schema": "zipage-bench-kernels/v1", "jax": "0", "platform": "cpu",
        "smoke": True,
        "results": [{"name": "scoring", "backend": "jnp",
                     "us_per_call": 12.5}],
    }


def write(tmp_path, name, data):
    p = tmp_path / name
    p.write_text(json.dumps(data))
    return str(p)


def test_trend_table_and_pass(tmp_path, capsys):
    files = [write(tmp_path, "pr1-concurrency.json", conc_point(100.0,
                   schema="zipage-bench-concurrency/v1")),
             write(tmp_path, "pr2-concurrency.json", conc_point(150.0)),
             write(tmp_path, "pr2-kernels.json", kernels_point())]
    out = tmp_path / "TREND.md"
    rc = bench_trend.main(files + ["--out", str(out)])
    assert rc == 0
    text = out.read_text()
    assert "pr1-concurrency" in text and "pr2-concurrency" in text
    assert "| 150.0 |" in text            # newest zipage tps in the table
    assert "scoring/jnp" in text          # kernels table rendered too


def test_trend_fails_on_regression(tmp_path):
    files = [write(tmp_path, "a.json", conc_point(100.0)),
             write(tmp_path, "b.json", conc_point(74.0))]   # -26% > 25%
    assert bench_trend.main(files) == 1
    # a 25%-or-less drop passes the default gate
    files = [write(tmp_path, "a.json", conc_point(100.0)),
             write(tmp_path, "c.json", conc_point(76.0))]
    assert bench_trend.main(files) == 0
    # tighter threshold flips it
    assert bench_trend.main(files + ["--max-regression", "0.1"]) == 1


def test_trend_single_point_trivially_green(tmp_path):
    files = [write(tmp_path, "only.json", conc_point(123.0))]
    assert bench_trend.main(files) == 0


def test_trend_v3_history_and_swap_gate(tmp_path):
    """Synthetic 3-point history (pre-swap v2 point + two v3 points): the
    table grows a swap column, mixed-schema rows render, and the gate
    watches the swap-mode series too."""
    files = [write(tmp_path, "000-pr4.json", conc_point(150.0)),   # pre-v3
             write(tmp_path, "001-pr5.json", oversub_point(155.0, 300.0)),
             write(tmp_path, "002-pr6.json", oversub_point(160.0, 310.0))]
    out = tmp_path / "TREND.md"
    assert bench_trend.main(files + ["--out", str(out)]) == 0
    text = out.read_text()
    assert "swap tok/s" in text and "| 310.0 |" in text
    assert text.count("\n| 0") == 3            # one row per point
    # swap-mode collapse fails the gate even with zipage tps healthy
    files[2] = write(tmp_path, "002-pr6.json", oversub_point(160.0, 200.0))
    assert bench_trend.main(files) == 1
    # a single v3 point after v2 history: swap series has <2 points,
    # zipage series still gates across the schema boundary
    assert bench_trend.main(files[:2]) == 0
    assert bench_trend.main([files[0],
                             write(tmp_path, "001b.json",
                                   oversub_point(80.0, 300.0))]) == 1


def test_trend_unknown_schema_skipped(tmp_path):
    bad = write(tmp_path, "bad.json", {"schema": "nope/v9"})
    good = write(tmp_path, "good.json", conc_point(100.0))
    assert bench_trend.main([bad, good]) == 0
    assert bench_trend.main([bad]) == 2   # nothing recognised