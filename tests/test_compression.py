"""Compression pipeline tests: structural invariants of score->topk->compact.

Trick: K values carry a position stamp in feature 0 (value = cache position)
so after compaction we can read back exactly which tokens survived and in
what order.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import scoring
from repro.core.compression import CompressOptions, build_compress_fn

RNG = np.random.default_rng(1)


def tiny_cfg(**kw):
    cfg = get_config("tiny-lm")                 # 4 heads, kv 2, d 32
    return dataclasses.replace(cfg, **kw) if kw else cfg


def make_setup(cfg, *, L=2, N_total=16, b=4, max_blocks=8, budget_blocks=3,
               n_req=2, w=2, seed=0):
    rng = np.random.default_rng(seed)
    h, d, hq = cfg.num_kv_heads, cfg.head_dim, cfg.num_heads
    pools = {
        "k": rng.normal(size=(L, N_total, b, h, d)).astype(np.float32),
        "v": rng.normal(size=(L, N_total, b, h, d)).astype(np.float32),
        "f": np.zeros((L, N_total, b, h), np.float32),
    }
    qwin = rng.normal(size=(L, 4, w, hq, d)).astype(np.float32)
    return pools, qwin


def stamp_positions(pools, block_table, b):
    """Write cache-position stamps into K feature 0 for one request."""
    k = pools["k"]
    for ci, blk in enumerate(block_table):
        if blk < 0:
            continue
        for s in range(b):
            k[:, blk, s, :, 0] = ci * b + s
    return pools


def run_compress(cfg, pools, qwin, src_bt, dest_bt, seq_lens, hist_lens,
                 qslots, *, b=4, max_blocks=8, budget_blocks=3, opts=None):
    opts = opts or CompressOptions(window=2, redundancy="lightning",
                                   pooling="none")
    fn = build_compress_fn(cfg, block_size=b, max_blocks=max_blocks,
                           budget_blocks=budget_blocks, opts=opts)
    fn = jax.jit(fn)
    jp = {k: jnp.asarray(v) for k, v in pools.items()}
    req = (jnp.asarray(src_bt), jnp.asarray(dest_bt), jnp.asarray(qslots),
           jnp.asarray(seq_lens), jnp.asarray(hist_lens))
    new_pools, new_seq, _ = fn(jp, jnp.asarray(qwin), req)
    return {k: np.asarray(v) for k, v in new_pools.items()}, np.asarray(new_seq)


def read_dest_stamps(pools, dest_blocks, b, head):
    out = []
    for blk in dest_blocks:
        for s in range(b):
            out.append(pools["k"][0, blk, s, head, 0])
    return np.asarray(out)


def test_compaction_preserves_order_and_window():
    cfg = tiny_cfg()
    b, mb, bb = 4, 8, 3
    pools, qwin = make_setup(cfg, b=b, max_blocks=mb, budget_blocks=bb)
    src_bt = np.full((2, mb), -1, np.int32)
    src_bt[0, :5] = [3, 7, 1, 9, 12]            # 5 blocks, T=20
    src_bt[1, :4] = [0, 2, 4, 5]
    dest_bt = np.stack([src_bt[0, :bb], src_bt[1, :bb]])
    pools = stamp_positions(pools, src_bt[0], b)
    seq_lens = np.array([20, 16], np.int32)
    new_pools, new_seq = run_compress(
        cfg, pools, qwin, src_bt, dest_bt, seq_lens,
        hist_lens=np.zeros(2, np.int32), qslots=np.array([0, 1], np.int32),
        b=b, max_blocks=mb, budget_blocks=bb)
    k_keep = bb * b
    assert (new_seq == k_keep).all()
    for head in range(cfg.num_kv_heads):
        stamps = read_dest_stamps(new_pools, dest_bt[0], b, head)
        # strictly increasing original order, subset of [0, 20)
        assert (np.diff(stamps) > 0).all()
        assert stamps.min() >= 0 and stamps.max() < 20
        # observation window (last w=2) always kept
        assert {18.0, 19.0} <= set(stamps.tolist())


def test_padding_rows_are_noops():
    cfg = tiny_cfg()
    b, mb, bb = 4, 8, 3
    pools, qwin = make_setup(cfg, b=b, max_blocks=mb, budget_blocks=bb)
    src_bt = np.full((2, mb), -1, np.int32)
    src_bt[0, :4] = [3, 7, 1, 9]
    dest_bt = np.full((2, bb), -1, np.int32)
    dest_bt[0] = src_bt[0, :bb]
    before = {k: v.copy() for k, v in pools.items()}
    seq_lens = np.array([16, 0], np.int32)
    new_pools, new_seq = run_compress(
        cfg, pools, qwin, src_bt, dest_bt, seq_lens,
        hist_lens=np.zeros(2, np.int32), qslots=np.array([-1, -1], np.int32),
        b=b, max_blocks=mb, budget_blocks=bb)
    for key in ("k", "v", "f"):
        np.testing.assert_array_equal(new_pools[key], before[key])
    np.testing.assert_array_equal(new_seq, seq_lens)


def test_inplace_vs_fresh_destination_equivalence():
    """Compacting into the request's own first blocks must equal compacting
    into fresh blocks (guards against aliasing bugs in gather/scatter)."""
    cfg = tiny_cfg()
    b, mb, bb = 4, 8, 3
    pools, qwin = make_setup(cfg, b=b, max_blocks=mb, budget_blocks=bb,
                             N_total=20)
    src_bt = np.full((1, mb), -1, np.int32)
    src_bt[0, :5] = [3, 7, 1, 9, 12]
    seq_lens = np.array([20], np.int32)
    qslots = np.array([0], np.int32)
    hist = np.zeros(1, np.int32)

    dest_inplace = src_bt[:, :bb].copy()
    p1, _ = run_compress(cfg, {k: v.copy() for k, v in pools.items()}, qwin,
                         src_bt, dest_inplace, seq_lens, hist, qslots,
                         b=b, max_blocks=mb, budget_blocks=bb)
    dest_fresh = np.array([[15, 16, 17]], np.int32)
    p2, _ = run_compress(cfg, {k: v.copy() for k, v in pools.items()}, qwin,
                         src_bt, dest_fresh, seq_lens, hist, qslots,
                         b=b, max_blocks=mb, budget_blocks=bb)
    for key in ("k", "v", "f"):
        got = np.stack([p1[key][:, blk] for blk in dest_inplace[0]], 1)
        want = np.stack([p2[key][:, blk] for blk in dest_fresh[0]], 1)
        np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_kept_set_matches_topk_of_scores():
    """The survivors must be exactly the top-k of the final combined score."""
    cfg = tiny_cfg()
    b, mb, bb, w = 4, 8, 3, 2
    opts = CompressOptions(window=w, redundancy="lightning", pooling="none",
                           use_global=False)
    pools, qwin = make_setup(cfg, b=b, max_blocks=mb, budget_blocks=bb, w=w)
    src_bt = np.full((1, mb), -1, np.int32)
    src_bt[0, :4] = [3, 7, 1, 9]
    T = mb * b
    seq_len = 16
    pools = stamp_positions(pools, src_bt[0], b)

    # oracle: recompute scores directly from gathered entries
    from repro.core.compression import _score_one
    entries = np.concatenate(
        [pools["k"][0, blk] for blk in src_bt[0][src_bt[0] >= 0]], 0)
    entries = np.concatenate(
        [entries, np.zeros((T - seq_len,) + entries.shape[1:], np.float32)])
    fscore = np.zeros((T, cfg.num_kv_heads), np.float32)
    valid = np.arange(T) < seq_len
    ring = qwin[0, 0]
    order = (seq_len - w + np.arange(w)) % w
    final, _, _ = _score_one(cfg, opts, jnp.asarray(ring[order]),
                          jnp.asarray(entries), jnp.asarray(fscore),
                          jnp.asarray(valid), seq_len, 0, b)
    want_keep = np.asarray(scoring.topk_tag(final, bb * b))

    new_pools, _ = run_compress(
        cfg, pools, qwin, src_bt, src_bt[:, :bb], np.array([seq_len]),
        np.zeros(1, np.int32), np.zeros(1, np.int32),
        b=b, max_blocks=mb, budget_blocks=bb, opts=opts)
    for head in range(cfg.num_kv_heads):
        stamps = read_dest_stamps(new_pools, src_bt[0, :bb], b, head)
        kept = np.zeros(T, bool)
        kept[stamps.astype(int)] = True
        np.testing.assert_array_equal(kept, want_keep[:, head])


@settings(max_examples=15, deadline=None)
@given(n_blocks=st.integers(4, 7), seed=st.integers(0, 10_000),
       redundancy=st.sampled_from(["lightning", "none"]),
       hist=st.integers(0, 1))
def test_property_compression_invariants(n_blocks, seed, redundancy, hist):
    """Hypothesis: for random pools/tables, compaction always (a) keeps
    exactly k entries, (b) preserves order, (c) keeps the window, (d) yields
    seq_len == k."""
    cfg = tiny_cfg()
    b, mb, bb, w = 4, 8, 3, 2
    pools, qwin = make_setup(cfg, b=b, max_blocks=mb, budget_blocks=bb,
                             w=w, seed=seed, N_total=16)
    rng = np.random.default_rng(seed)
    blocks = rng.choice(16, size=n_blocks, replace=False).astype(np.int32)
    src_bt = np.full((1, mb), -1, np.int32)
    src_bt[0, :n_blocks] = blocks
    seq_len = n_blocks * b
    pools = stamp_positions(pools, src_bt[0], b)
    hist_len = (bb * b) if hist else 0
    opts = CompressOptions(window=w, redundancy=redundancy, pooling="none")
    new_pools, new_seq = run_compress(
        cfg, pools, qwin, src_bt, src_bt[:, :bb], np.array([seq_len]),
        np.array([hist_len], np.int32), np.zeros(1, np.int32),
        b=b, max_blocks=mb, budget_blocks=bb, opts=opts)
    assert new_seq[0] == bb * b
    for head in range(cfg.num_kv_heads):
        stamps = read_dest_stamps(new_pools, src_bt[0, :bb], b, head)
        assert len(stamps) == bb * b
        assert (np.diff(stamps) > 0).all()
        assert stamps.max() == seq_len - 1      # newest token always kept
        assert set(range(seq_len - w, seq_len)) <= set(stamps.astype(int))
