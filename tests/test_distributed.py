"""Distributed runtime tests — run in a subprocess with 8 fake CPU devices
(XLA_FLAGS must be set before jax import, so these can't run in-process).

Covers: sharding rules, ZeRO-1 train step on a (2,4) mesh, EF-int8 pod
compression on a (2,2,2) mesh, shard_map decode parity vs per-replica
execution, and elastic checkpoint restore onto a different mesh.
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import lm
from repro.distributed import sharding as shd
from repro.training import optimizer as opt
from repro.training.data import DataConfig, batch_at
from repro.training.train_loop import build_train_step
from repro.training.compress_grads import init_error_state
from repro.training import checkpoint as ckpt
from repro.core import serve_model

assert len(jax.devices()) == 8
CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")

# ---------------------------------------------------------------- rules
llama = get_config("llama3-8b")
mesh24 = jax.make_mesh((2, 4), ("data", "model"))
ps = lm.param_specs(llama)
rows, fallbacks = shd.sharding_summary(llama, ps, mesh24)
by_name = {k: spec for k, spec, *rest in
           [(r[0], r[2]) for r in rows]}
assert any("wq" in k and "model" in str(v) for k, v in by_name.items()), by_name
rg = get_config("recurrentgemma-2b")
mesh16 = jax.make_mesh((1, 8), ("data", "model"))
rows_rg, _ = shd.sharding_summary(rg, lm.param_specs(rg), mesh16)
d_rg = dict((r[0], r[2]) for r in rows_rg)
attn_specs = [v for k, v in d_rg.items() if "/attn/wq" in k]
ffn_specs = [v for k, v in d_rg.items() if k.endswith("ffn/w1")]
assert all("model" not in str(s) for s in attn_specs)   # 10 heads % 8 != 0
assert any("model" in str(s) for s in ffn_specs)        # 7680 % 8 == 0
print("RULES OK")

# ------------------------------------------------------------- train 2x4
DC = DataConfig(seq_len=32, global_batch=8, vocab_size=CFG.vocab_size)
params = lm.init(CFG, jax.random.key(0))
opt_state = opt.init_opt_state(params)
adamw = opt.AdamWConfig(lr=1e-2, warmup_steps=2, total_steps=50)
step = build_train_step(CFG, adamw, vocab_chunk=16)
p_sh = shd.param_shardings(CFG, params, mesh24)
o_sh = shd.zero1_shardings(CFG, params, mesh24)
batch = jax.tree.map(jnp.asarray, batch_at(DC, 0))
b_sh = shd.batch_shardings(mesh24, batch)
params_d = jax.device_put(params, p_sh)
opt_d = jax.device_put(opt_state, o_sh)
batch_d = jax.device_put(batch, b_sh)

def step3(p, o, b):
    pp, oo, _, m = step(p, o, None, b)
    return pp, oo, m

rep = NamedSharding(mesh24, P())
jstep = jax.jit(step3, in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh,
                               {"loss": rep, "grad_norm": rep, "lr": rep}))
losses = []
for i in range(5):
    params_d, opt_d, m = jstep(params_d, opt_d, batch_d)
    losses.append(float(m["loss"]))
assert np.isfinite(losses).all() and losses[-1] < losses[0], losses
# ZeRO-1: moments actually sharded over data
mleaf = jax.tree.leaves(opt_d["m"])[0]
print("TRAIN 2x4 OK", losses[0], "->", losses[-1])

# ------------------------------------------------- pod-compressed (2,2,2)
mesh222 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
stepc = build_train_step(CFG, adamw, vocab_chunk=16, pod_axis="pod")
err0 = init_error_state(params)

from repro.kernels.pallas_compat import shard_map
smap = shard_map(
    stepc, mesh=mesh222,
    in_specs=(jax.tree.map(lambda _: P(), params),
              jax.tree.map(lambda _: P(), opt_state),
              jax.tree.map(lambda _: P(), err0),
              jax.tree.map(lambda _: P("pod"), batch)),
    out_specs=(jax.tree.map(lambda _: P(), params),
               jax.tree.map(lambda _: P(), opt_state),
               jax.tree.map(lambda _: P(), err0),
               {"loss": P(), "grad_norm": P(), "lr": P()}),
    check=False)   # full-manual: the data/model axes are unused inside
jc = jax.jit(smap)
pc, oc, ec, mc = jc(params, opt_state, err0, batch)
# uncompressed reference on same batch
pr, orr, mr = jax.jit(step3)(params, opt_state, batch)
rel = [float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
       for a, b in zip(jax.tree.leaves(pc), jax.tree.leaves(pr))]
assert float(mc["loss"]) == float(mr["loss"]) or \
    abs(float(mc["loss"]) - float(mr["loss"])) < 1e-3
assert max(rel) < 5e-2, max(rel)   # int8 quantization-level agreement
print("POD COMPRESS OK", max(rel))

# --------------------------------------------- shard_map decode parity
spec = serve_model.ServeSpec(n_slots=4, block_size=4, max_blocks=6,
                             n_total_blocks=8, m_qslots=4, window=2,
                             prefill_rows=2, prefill_len=16, dtype="float32")
state = serve_model.make_state(CFG, spec)
rng = np.random.default_rng(0)
# two replicas, each with 2 slots and 4 local blocks; fill pools randomly
pools = {k: jnp.asarray(rng.normal(size=v.shape), v.dtype) * 0.1
         for k, v in state["pools"].items()}
state["pools"] = pools
bt = np.full((4, 6), -1, np.int32)
bt[0, :2] = [0, 1]; bt[1, :2] = [2, 3]
bt[2, :2] = [0, 1]; bt[3, :2] = [2, 3]       # replica-local ids
state["block_tables"] = jnp.asarray(bt)
state["seq_lens"] = jnp.asarray(np.array([7, 5, 6, 8], np.int32))
state["positions"] = jnp.asarray(np.array([7, 5, 6, 8], np.int32))
tokens = jnp.asarray(np.array([3, 7, 11, 13], np.int32))
active = jnp.ones((4,), bool)
step_d = serve_model.build_decode_step(CFG, spec)
mesh2 = jax.make_mesh((2, 4), ("data", "model"))

def st_spec(key_leaf):
    return None
from repro.launch.dryrun import serve_pspecs  # reuse the spec builder
st_p = serve_pspecs(CFG, state, ("data",), False)
pspecs = (jax.tree.map(lambda _: P(), lm.param_specs(CFG)), st_p,
          P("data"), P("data"))
smap_d = shard_map(step_d, mesh=mesh2, in_specs=pspecs,
                   out_specs=(P("data"), st_p),
                   check=False)   # full-manual: "model" is unused inside
logits_mesh, state_mesh = jax.jit(smap_d)(params, state, tokens, active)
# reference: run each replica separately on half the state
def half(tree, lo, hi, table):
    out = {}
    for k, v in tree.items():
        if k == "pools":
            out[k] = {kk: vv[:, lo * 4 // 2:hi * 4 // 2] if False else
                      vv[:, (lo // 2) * 4:(hi // 2) * 4]
                      for kk, vv in v.items()}
        elif k in ("block_tables", "seq_lens", "positions", "qslot"):
            out[k] = v[lo:hi]
        elif k == "qwin":
            out[k] = v[:, lo:hi]
        else:
            out[k] = v
    return out
spec_half = dataclasses.replace(spec, n_slots=2, n_total_blocks=4,
                                m_qslots=2)
step_half = serve_model.build_decode_step(CFG, spec_half)
outs = []
for r in range(2):
    sh = half(state, 2 * r, 2 * r + 2, None)
    lg, _ = jax.jit(step_half)(params, sh, tokens[2 * r:2 * r + 2],
                               active[2 * r:2 * r + 2])
    outs.append(np.asarray(lg))
ref = np.concatenate(outs)
np.testing.assert_allclose(np.asarray(logits_mesh), ref, rtol=2e-4,
                           atol=2e-4)
print("DECODE PARITY OK")

# --------------------------------------------------- elastic restore 4x2
import tempfile
d = tempfile.mkdtemp()
ckpt.save(d, 1, {"params": params_d, "opt": opt_d})
mesh42 = jax.make_mesh((4, 2), ("data", "model"))
p_sh2 = shd.param_shardings(CFG, params, mesh42)
o_sh2 = shd.zero1_shardings(CFG, params, mesh42)
restored, _ = ckpt.restore(d, 1, {"params": params, "opt": opt_state},
                           shardings={"params": p_sh2, "opt": o_sh2})
for a, b in zip(jax.tree.leaves(restored["params"]),
                jax.tree.leaves(params_d)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("ELASTIC OK")
print("ALL_DISTRIBUTED_OK")
"""


@pytest.mark.slow
def test_distributed_suite():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout[-2000:] + "\n" + r.stderr[-4000:]
    assert "ALL_DISTRIBUTED_OK" in r.stdout
