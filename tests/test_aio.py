"""Async facade surface: generate_async/stream parity with the sync path,
the background loop's op serialization, backpressure, drain, and hooks.

Each test runs its own ``asyncio.run`` (no pytest-asyncio in the image);
the shared facade is reused across tests — the AsyncEngineLoop rebinds
to each fresh event loop lazily — so jit recompilation stays minimal.
"""
import asyncio
import dataclasses

import jax
import pytest

from repro.api import (EngineDraining, EngineSaturated, SamplingParams,
                       Zipage)
from repro.api.aio import AsyncEngineLoop
from repro.configs import get_config
from repro.core import invariants
from repro.models import lm

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))
N_BLOCKS = 64

Z = Zipage(CFG, PARAMS, block_size=8, n_total_blocks=N_BLOCKS,
           max_batch=4, m_qslots=4, n_max=3, window=4, max_model_len=128,
           prefill_rows=2, prefill_len=64)
P1, P2 = [1, 2, 3, 4, 5], [9, 8, 7]


def sp(n, seed=0, temperature=0.0):
    return SamplingParams(max_new_tokens=n, seed=seed,
                          temperature=temperature)


def run(coro):
    result = asyncio.run(coro)
    assert Z.num_free_blocks == N_BLOCKS       # every test leaves it clean
    return result


def test_generate_async_matches_sync_generate():
    hot = sp(12, seed=11, temperature=0.9)
    ref, = Z.generate([P1], hot)

    async def main():
        out = await Z.generate_async(P1, hot)
        await Z._aio.drain()
        return out

    out = run(main())
    assert out.token_ids == ref.token_ids
    assert out.finish_reason == "length"
    assert out.usage.total_tokens == len(P1) + 12


def test_stream_chunks_match_sync_generate():
    hot = sp(15, seed=3, temperature=1.1)
    ref, = Z.generate([P1], hot)

    async def main():
        toks, final = [], None
        async for chunk in Z.stream(P1, hot):
            assert chunk.index == len(toks)
            toks.extend(chunk.token_ids)
            final = chunk
        await Z._aio.drain()
        return toks, final

    toks, final = run(main())
    assert toks == ref.token_ids
    assert final.finish_reason == "length"
    assert final.usage.completion_tokens == 15


def test_concurrent_generate_async_batches_together():
    refs = Z.generate([P1, P2, P1], [sp(8), sp(8, seed=2), sp(6)])

    async def main():
        outs = await asyncio.gather(
            Z.generate_async(P1, sp(8)),
            Z.generate_async(P2, sp(8, seed=2)),
            Z.generate_async(P1, sp(6)))
        steps_spent = Z.step_count
        await Z._aio.drain()
        return outs, steps_spent

    outs, _ = run(main())
    for out, ref in zip(outs, refs):
        assert out.token_ids == ref.token_ids


def test_async_abort_mid_flight_reclaims():
    async def main():
        aio = await Z._ensure_aio()
        rid = await aio.add_request(P1, sp(40))
        stream = aio.stream_outputs(rid)
        first = await asyncio.wait_for(stream.__anext__(), 30)
        assert first.chunk.token_ids
        final = await aio.abort(rid)
        assert final.finish_reason == "abort" and final.finished
        # the stream flushes the terminal snapshot, then closes
        tail = [o async for o in stream]
        assert tail and tail[-1].finish_reason == "abort"
        await aio.drain()

    run(main())
    Z.engine._qwin_shadow.clear()          # between-steps check: reset
    invariants.check_engine(Z.engine)


def test_backpressure_saturated_raises_with_retry_after():
    # pre-fill the scheduler's waiting queue synchronously: backpressure
    # must reject before the loop even starts (no timing dependence)
    parked = Z.add_request(P1, sp(30))

    async def main():
        aio = AsyncEngineLoop(Z, max_queued_requests=1)
        with pytest.raises(EngineSaturated) as e:
            await aio.add_request(P2, sp(4))
        assert e.value.retry_after >= 1.0
        assert e.value.backlog == 1 and e.value.limit == 1
        assert not aio.started               # rejected without spin-up

    asyncio.run(main())
    Z.abort(parked)
    assert Z.num_free_blocks == N_BLOCKS


def test_drain_finishes_running_and_rejects_new():
    async def main():
        aio = await Z._ensure_aio()
        rid = await aio.add_request(P1, sp(20))
        drainer = asyncio.create_task(aio.drain())
        await asyncio.sleep(0)                # let drain close intake
        with pytest.raises(EngineDraining):
            await aio.add_request(P2, sp(4))
        final = None
        async for out in aio.stream_outputs(rid):
            final = out
        await drainer
        # running request finished normally despite the drain
        assert final.finished and final.finish_reason == "length"
        assert final.usage.completion_tokens == 20

    run(main())


def test_step_hooks_and_listeners():
    entries, batches = [], []
    Z.engine.step_hooks.append(entries.append)
    Z.add_listener(batches.append)
    try:
        out, = Z.generate([P1], sp(5))
    finally:
        Z.engine.step_hooks.remove(entries.append)
        Z.remove_listener(batches.append)
    assert entries and all("t_total" in e for e in entries)
    streamed = [t for outs in batches for o in outs
                if o.request_id == out.request_id
                for t in o.chunk.token_ids]
    assert streamed == out.token_ids
