"""Host-KV swap preemption tier (ISSUE 5, docs/SCHEDULER.md "Preemption
modes").

Swap-mode preemption must be *invisible in the token streams*: a victim's
KV (and its observation window) is parked in the CPU swap pool and
restored bit-for-bit, so under any preemption pressure the outputs must
match recompute mode — greedy, seeded top-k/top-p, logprobs, compression
and all — while moving blocks instead of re-prefilling. On top of the
parity pins: prefix-cache ref-count safety across the swap cycle
(shared blocks are copy-on-swap), snapshot/restore with a non-empty
swapped queue, the auto mode's cost model, and pool accounting that never
leaks a device or host block.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.api import SamplingParams as ApiSamplingParams, Zipage
from repro.configs import get_config
from repro.core.block_manager import BlockManager
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.core.request import State
from repro.core.sampling import SamplingParams
from repro.core.scheduler import Scheduler, SchedulerParams
from repro.models import lm

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))

PROMPTS = [[1, 2, 3, 4, 5], [9, 8, 7], [10, 11, 12, 13, 14, 15, 16],
           [20, 21]]
# greedy + seeded top-k/top-p + a logprob consumer, long enough that
# compression triggers (n_max=3 * block_size=8 = 24-token budget)
MIXED = [SamplingParams(max_new_tokens=28),
         SamplingParams(max_new_tokens=28, temperature=0.8, top_k=5,
                        seed=7),
         SamplingParams(max_new_tokens=28, temperature=1.1, top_p=0.9,
                        seed=3),
         SamplingParams(max_new_tokens=28, temperature=0.7, seed=11,
                        logprobs=True)]


def make_engine(**kw):
    # 10 blocks for 4 requests wanting ~4 blocks each: preemption fires
    # in every mode (same spec as test_engine's preemption test, so the
    # jitted steps are shared across the suite)
    base = dict(block_size=8, n_total_blocks=10, max_batch=4, m_qslots=4,
                n_max=3, window=4, max_model_len=256, prefill_rows=2,
                prefill_len=64, compress=CompressOptions(window=4))
    base.update(kw)
    return ZipageEngine(CFG, PARAMS, EngineOptions(**base))


def run_tight(mode, **kw):
    swap = 0 if mode == "recompute" else 24
    eng = make_engine(preemption_mode=mode, swap_space_blocks=swap, **kw)
    rids = [eng.add_request(p, sp) for p, sp in zip(PROMPTS, MIXED)]
    done = eng.run(max_steps=2000)
    outs = [(done[r].output, done[r].logprobs) for r in rids]
    return outs, eng


def total(eng, key):
    return sum(m[key] for m in eng.metrics)


REC, REC_ENG = run_tight("recompute")
SWAP, SWAP_ENG = run_tight("swap")


# ----------------------------------------------------------------------
# token-stream parity under forced preemption


def test_recompute_vs_swap_token_stream_parity():
    """The headline pin: under a pool tight enough to force preemption,
    swap mode and recompute mode emit identical tokens *and* logprobs —
    and both actually preempted (otherwise the test proves nothing)."""
    assert total(REC_ENG, "n_preempted") > 0
    assert total(SWAP_ENG, "n_preempted") > 0
    assert total(SWAP_ENG, "n_swapped_out") > 0
    assert total(REC_ENG, "n_swapped_out") == 0
    assert total(SWAP_ENG, "n_swapped_out") == total(SWAP_ENG,
                                                     "n_swapped_in")
    assert REC == SWAP


def test_auto_mode_parity_and_telemetry():
    outs, eng = run_tight("auto")
    assert outs == REC
    assert total(eng, "n_preempted") > 0
    # cumulative swap telemetry is monotone and consistent
    assert eng.metrics[-1]["swap_bytes"] >= 0
    assert 0.0 <= eng.metrics[-1]["swap_util"] <= 1.0


def test_swap_stream_matches_unpressured_run():
    """Swap restores KV bit-for-bit, so the swapped run's streams equal a
    run with an ample pool where nothing is ever preempted."""
    eng = make_engine(n_total_blocks=64)
    rids = [eng.add_request(p, sp) for p, sp in zip(PROMPTS, MIXED)]
    done = eng.run(max_steps=2000)
    assert total(eng, "n_preempted") == 0
    ample = [(done[r].output, done[r].logprobs) for r in rids]
    assert SWAP == ample


def test_swap_accounting_drains_clean():
    """After the swapped run completes, every device and host block is
    back in its pool and the swapped queue is empty."""
    bm = SWAP_ENG.bm
    bm.check_invariants()
    assert bm.num_free == SWAP_ENG.opts.n_total_blocks
    assert len(bm.swap_free) == SWAP_ENG.opts.swap_space_blocks
    assert bm.swapped == {}
    assert not SWAP_ENG.scheduler.swapped
    assert SWAP_ENG._swap_qwin == {}


# ----------------------------------------------------------------------
# pure-host scheduler units (no model, no device steps)


def make_swap_sched(n_blocks=16, block_size=4, swap_blocks=8,
                    prefix_ok=False, **kw):
    base = dict(block_size=block_size, max_batch=4, m_qslots=4, n_max=3,
                window=2, prefill_rows=4, compression_enabled=True,
                budget_blocks=2, prefix_ok=prefix_ok,
                preemption_mode="swap", block_bytes=100)
    base.update(kw)
    s = Scheduler(SchedulerParams(**base),
                  BlockManager(n_blocks, block_size,
                               enable_prefix_cache=prefix_ok,
                               swap_space_blocks=swap_blocks))
    log = []
    s.swap_executor = lambda r, src, dst: log.append(
        ("out", r.rid, list(src), list(dst)))
    s.swap_in_executor = lambda r, src, dst: log.append(
        ("in", r.rid, list(src), list(dst)))
    return s, log


def waiting_request(rid, n_prompt, n_out):
    from repro.core.request import Request
    return Request(rid=rid, prompt=list(range(1, n_prompt + 1)),
                   max_new_tokens=n_out, arrival=float(rid))


def test_preemption_mode_validation():
    with pytest.raises(ValueError, match="preemption_mode"):
        Scheduler(SchedulerParams(preemption_mode="hibernate"),
                  BlockManager(8, 4))
    with pytest.raises(ValueError, match="swap_space_blocks"):
        Scheduler(SchedulerParams(preemption_mode="swap"),
                  BlockManager(8, 4, swap_space_blocks=0))
    # the facade rejects the same contradiction (plumbed through
    # CacheConfig.swap_space_blocks / SchedulerConfig.preemption_mode)
    with pytest.raises(ValueError, match="swap_space_blocks"):
        Zipage(CFG, PARAMS, block_size=8, n_total_blocks=32,
               preemption_mode="swap")


def test_auto_cost_model_picks_per_victim():
    """auto: a compressed victim (few blocks, long history) swaps; a
    short uncompressed one recomputes; and swap degrades to recompute
    when the executor is missing or the host pool is full."""
    s, _log = make_swap_sched(preemption_mode="auto")
    short = waiting_request(0, n_prompt=8, n_out=4)
    short.blocks = s.bm.allocate(2)
    short.state = State.RUNNING
    compressed = waiting_request(1, n_prompt=8, n_out=40)
    compressed.blocks = s.bm.allocate(3)
    compressed.compressed = True
    compressed.output = list(range(30))      # long accumulated history
    compressed.state = State.RUNNING
    # swap cost 2*2*4*0.5 = 8 tokens vs re-prefill 8 -> tie goes recompute
    assert s._preempt_mode(short) == "recompute"
    # swap cost 2*3*4*0.5 = 12 << 38-token re-prefill -> swap
    assert s._preempt_mode(compressed) == "swap"
    s.swap_executor = None
    assert s._preempt_mode(compressed) == "recompute"


def test_swap_mode_always_swaps_when_possible():
    s, _log = make_swap_sched(preemption_mode="swap")
    r = waiting_request(0, n_prompt=8, n_out=4)
    r.blocks = s.bm.allocate(2)
    r.state = State.RUNNING
    assert s._preempt_mode(r) == "swap"
    s.bm.swap_free = []                      # host pool exhausted
    assert s._preempt_mode(r) == "recompute"


def test_swap_cycle_preserves_prefix_cache_refcounts():
    """Shared prefix blocks are copy-on-swap: swapping a sharer out drops
    only its own ref (the peer and the cache keep serving the block), and
    swap-in restores private copies without disturbing the cache."""
    s, log = make_swap_sched(n_blocks=16, prefix_ok=True)
    a = waiting_request(0, n_prompt=8, n_out=20)     # 2 full blocks
    b = waiting_request(1, n_prompt=8, n_out=20)     # same prompt
    s.add_request(a)
    s.add_request(b)
    plan = s.schedule()
    assert len(plan.admitted) == 2 and b.n_shared == 2
    shared = list(a.blocks)
    assert all(s.bm.ref[blk] == 2 for blk in shared)
    for r in (a, b):
        r.n_prefilled = r.prefill_target         # prefill "done"
        r.output = [1]
    s._swap_out(a, None)
    assert a.state == State.SWAPPED and a.blocks == []
    assert all(s.bm.ref[blk] == 1 for blk in shared), \
        "peer's refs must survive the sharer's swap-out"
    assert s.bm.n_swapped_blocks(a.rid) == 2
    assert log[-1][0] == "out" and log[-1][1] == a.rid
    s.bm.check_invariants()
    plan2 = s.schedule()
    assert plan2.swapped_in == [a] and a.state == State.RUNNING
    assert log[-1][0] == "in" and log[-1][1] == a.rid
    # restored blocks are private copies; the cached originals still
    # belong to the peer and the hash chain is untouched
    assert set(a.blocks).isdisjoint(shared)
    assert all(s.bm.ref[blk] == 1 for blk in a.blocks + shared)
    assert all(blk in s.bm.block_hash for blk in shared)
    assert s.bm.swapped == {} and not s.swapped
    assert s.n_swapped_out == 1 and s.n_swapped_in == 1
    assert s.swap_bytes == 4 * 100               # 2 blocks out + 2 back in
    s.bm.check_invariants()


def test_swapped_queue_blocks_fresh_admission():
    """Anti-thrash: while a swapped request cannot come back, fresh
    prompts must not steal the blocks it is waiting for."""
    s, _log = make_swap_sched(n_blocks=8)
    v = waiting_request(0, n_prompt=8, n_out=20)
    s.add_request(v)
    plan = s.schedule()
    assert plan.admitted == [v]
    v.n_prefilled = v.prefill_target
    s._swap_out(v, None)
    s.bm.allocate(s.bm.num_free)                 # someone holds every block
    s.add_request(waiting_request(1, n_prompt=4, n_out=4))
    plan2 = s.schedule()
    assert plan2.admitted == [] and plan2.swapped_in == []
    assert s.has_work()


def test_abort_swapped_request_releases_host_blocks():
    s, _log = make_swap_sched()
    r = waiting_request(0, n_prompt=8, n_out=20)
    s.add_request(r)
    s.schedule()
    r.n_prefilled = r.prefill_target
    s._swap_out(r, None)
    assert s.bm.swap_util > 0
    assert s.abort(r.rid) is r
    assert s.bm.swapped == {} and not s.swapped and s.bm.swap_util == 0.0
    s.bm.check_invariants()


# ----------------------------------------------------------------------
# snapshot/restore + leak property (engine level)


def test_snapshot_restore_with_nonempty_swapped_queue():
    """Fault tolerance across the swap tier: snapshot taken while a
    request sits in the swapped queue (KV parked on host) must restore to
    byte-identical streams."""
    def boot():
        eng = make_engine(preemption_mode="swap", swap_space_blocks=24,
                          prefix_caching=False)
        rids = [eng.add_request([30 + i, 2, 3, 4, 5], sp)
                for i, sp in enumerate(
                    [SamplingParams(max_new_tokens=30)] * 5)]
        return eng, rids

    eng, rids = boot()
    snap = None
    for _ in range(400):
        eng.step()
        if eng.scheduler.swapped:
            snap = eng.snapshot()
            break
    assert snap is not None, "never caught a non-empty swapped queue"
    assert len(snap["requests"]["swapped"]) > 0
    done_a = eng.run(max_steps=2000)
    out_a = [done_a[r].output for r in rids]
    eng2, _ = boot()
    eng2.restore(snap)
    assert eng2.scheduler.swapped
    done_b = eng2.run(max_steps=2000)
    out_b = [done_b[r].output for r in rids]
    assert out_a == out_b
    eng2.bm.check_invariants()
    assert len(eng2.bm.swap_free) == eng2.opts.swap_space_blocks


def test_restore_swap_snapshot_without_swap_tier_degrades():
    """A swap-mode snapshot with a non-empty swapped queue restored into
    an engine without a swap tier must not crash: the parked KV is
    unreachable there, so those requests demote to recompute
    re-admission and still finish."""
    eng = make_engine(preemption_mode="swap", swap_space_blocks=24,
                      prefix_caching=False)
    rids = [eng.add_request([40 + i, 2, 3, 4, 5],
                            SamplingParams(max_new_tokens=30))
            for i in range(5)]
    snap = None
    for _ in range(400):
        eng.step()
        if eng.scheduler.swapped:
            snap = eng.snapshot()
            break
    assert snap is not None
    plain = make_engine(prefix_caching=False)    # no swap tier at all
    plain.restore(snap)
    assert plain.scheduler.swapped
    done = plain.run(max_steps=2000)
    assert sorted(done) == sorted(rids)
    assert all(len(done[r].output) == 30 for r in rids)
    plain.bm.check_invariants()
    assert plain.bm.num_free == plain.opts.n_total_blocks


def test_swap_cost_per_token_is_public_config():
    """The auto cost model's exchange rate rides the facade config path
    (docs/SCHEDULER.md documents the formula, so the knob must be
    reachable)."""
    from repro.api.config import build_engine_options, route_overrides
    cache, sched, runner = route_overrides(preemption_mode="auto",
                                           swap_space_blocks=8,
                                           swap_cost_per_token=0.125)
    assert sched.swap_cost_per_token == 0.125
    opts = build_engine_options(cache, sched, runner)
    assert opts.swap_cost_per_token == 0.125
    assert opts.swap_space_blocks == 8


@pytest.mark.parametrize("seed", [0, 1])
def test_swap_pool_accounting_never_leaks(seed):
    """Property: random oversubscribed workloads under auto mode leave
    both pools exactly full and every queue empty."""
    rng = np.random.default_rng(seed)
    eng = make_engine(preemption_mode="auto", swap_space_blocks=16,
                      prefix_caching=bool(seed % 2))
    rids = []
    for _i in range(6):
        p = rng.integers(1, 50, size=int(rng.integers(2, 9))).tolist()
        sp = SamplingParams(max_new_tokens=int(rng.integers(8, 30)),
                            temperature=float(rng.choice([0.0, 0.9])),
                            seed=int(rng.integers(0, 100)))
        rids.append(eng.add_request(p, sp))
    done = eng.run(max_steps=3000)
    assert sorted(done) == sorted(rids)
    bm = eng.bm
    bm.check_invariants()
    assert bm.num_free == eng.opts.n_total_blocks
    assert len(bm.swap_free) == eng.opts.swap_space_blocks
    assert bm.swapped == {} and not eng.scheduler.swapped
    assert eng._swap_qwin == {}


def test_facade_surfaces_swap_telemetry():
    z = Zipage(CFG, PARAMS, block_size=8, n_total_blocks=10, max_batch=4,
               m_qslots=4, n_max=3, window=4, max_model_len=256,
               prefill_rows=2, prefill_len=64,
               preemption_mode="swap", swap_space_blocks=24)
    outs = z.generate(PROMPTS, [ApiSamplingParams(max_new_tokens=24)] * 4,
                      max_steps=2000)
    assert all(o.usage.completion_tokens == 24 for o in outs)
    stats = z.scheduler_stats
    for key in ("preemption_mode", "n_swapped_out", "n_swapped_in",
                "n_swapped", "swap_bytes", "swap_util"):
        assert key in stats
    assert stats["preemption_mode"] == "swap"
    assert sum(m["n_swapped_out"] for m in z.metrics) > 0
    assert max(m["swap_bytes"] for m in z.metrics) > 0
