"""Additional coverage: KV-head replication parity, straggler-aware
admission, randomized workload property test, memory-planner integration,
grouped-MoE dispatch parity."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import serve_model
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.core.memory_planner import plan_memory
from repro.models import lm
from engine_utils import submit
from repro.models import layers as L

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))


def test_kv_replication_decode_parity():
    """h_store = h_kv * r (repeat-consecutive) must give identical logits —
    the TP>h_kv serving layout (DESIGN.md §5) is math-neutral."""
    S_prompt, n_dec = 6, 5
    toks = np.asarray(jax.random.randint(jax.random.key(1),
                                         (S_prompt + n_dec,), 0,
                                         CFG.vocab_size))
    outs = {}
    for rep in (1, 2):
        spec = serve_model.ServeSpec(
            n_slots=1, block_size=4, max_blocks=8, n_total_blocks=16,
            m_qslots=1, window=4, prefill_rows=1, prefill_len=16,
            dtype="float32", kv_replication=rep)
        state = serve_model.make_state(CFG, spec)
        bt = np.full((1, 8), -1, np.int32)
        bt[0] = np.arange(8)
        state["block_tables"] = jnp.asarray(bt)
        state["seq_lens"] = jnp.asarray([S_prompt], jnp.int32)
        state["positions"] = jnp.asarray([S_prompt], jnp.int32)
        prefill = jax.jit(serve_model.build_prefill_step(CFG, spec))
        decode = jax.jit(serve_model.build_decode_step(CFG, spec))
        pt = np.zeros((1, 16), np.int32)
        pt[0, :S_prompt] = toks[:S_prompt]
        logits, state = prefill(PARAMS, state, jnp.asarray(pt),
                                jnp.asarray([0], jnp.int32),
                                jnp.asarray([S_prompt], jnp.int32),
                                jnp.asarray([0], jnp.int32))
        got = [np.asarray(logits[0])]
        for t in range(S_prompt, S_prompt + n_dec - 1):
            logits, state = decode(PARAMS, state,
                                   jnp.asarray([toks[t]], jnp.int32),
                                   jnp.ones((1,), bool))
            got.append(np.asarray(logits[0]))
        outs[rep] = np.stack(got)
    np.testing.assert_allclose(outs[1], outs[2], rtol=1e-5, atol=1e-5)


def test_chunked_attn_backend_engine_parity():
    """Engine outputs identical under gather vs chunked decode attention.
    ``kernel_backend`` drives the spec at construction, so the fused
    decode path (the default) traces with the right backend too."""
    outs = {}
    for backend in ("jnp", "chunked"):
        eng = ZipageEngine(CFG, PARAMS, EngineOptions(
            block_size=8, n_total_blocks=64, max_batch=4, m_qslots=4,
            n_max=3, window=4, compress=CompressOptions(window=4),
            max_model_len=128, prefill_rows=2, prefill_len=32,
            temperature=0.0, kernel_backend=backend))
        assert eng.spec.attn_backend == backend
        rids = [submit(eng, [1, 2, 3], 30), submit(eng, [5, 6], 30)]
        done = eng.run(max_steps=300)
        outs[backend] = [done[r].output for r in rids]
    assert outs["jnp"] == outs["chunked"]


def test_straggler_admission_backoff():
    eng = ZipageEngine(CFG, PARAMS, EngineOptions(
        block_size=8, n_total_blocks=64, max_batch=8, m_qslots=4, n_max=3,
        window=4, compress=CompressOptions(window=4), max_model_len=128,
        prefill_rows=4, prefill_len=32))
    eng._ewma = 0.001                        # pretend steps were fast
    for i in range(6):
        submit(eng, [1 + i], 4)
    eng.step()                               # real step is far slower => 3x
    assert eng.admission_scale < 1.0         # backoff engaged
    for _ in range(60):
        if not (eng.waiting or eng.running):
            break
        eng.step()
    assert eng.admission_scale <= 1.0
    assert not eng.running and not eng.waiting


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 100), n=st.integers(2, 6),
       scheduling=st.sampled_from(["hybrid", "constrained"]))
def test_property_random_workload_completes_cleanly(seed, n, scheduling):
    """Any random workload completes with exact block accounting."""
    rng = np.random.default_rng(seed)
    eng = ZipageEngine(CFG, PARAMS, EngineOptions(
        block_size=8, n_total_blocks=48, max_batch=4, m_qslots=2, n_max=3,
        window=4, compress=CompressOptions(window=4), max_model_len=128,
        prefill_rows=2, prefill_len=32, temperature=0.0,
        scheduling=scheduling,
        prefix_caching=bool(seed % 2)))
    rids = []
    for _i in range(n):
        p = rng.integers(0, CFG.vocab_size,
                         size=int(rng.integers(2, 20))).tolist()
        rids.append(submit(eng, p, int(rng.integers(2, 40))))
    done = eng.run(max_steps=2000)
    assert set(rids) <= set(done)
    eng.bm.check_invariants()
    assert eng.bm.num_free == 48
    assert sorted(eng.free_slots) == list(range(4))


def test_memory_planner_drives_engine():
    """Eq. 1 plan feeds a working engine configuration."""
    plan = plan_memory(CFG, 8 * 1024 * 1024, n_max=3, block_size=8, window=4)
    assert plan.M >= 1 and plan.N_total >= plan.M * 3
    eng = ZipageEngine(CFG, PARAMS, EngineOptions(
        block_size=8, n_total_blocks=min(plan.N_total, 128),
        max_batch=4, m_qslots=min(plan.M, 4), n_max=3, window=4,
        compress=CompressOptions(window=4), max_model_len=128,
        prefill_rows=2, prefill_len=32))
    r = submit(eng, [1, 2, 3], 30)
    done = eng.run(max_steps=300)
    assert len(done[r].output) == 30


def test_moe_grouped_dispatch_parity():
    cfg = dataclasses.replace(get_config("dbrx-132b").reduced(),
                              dtype="float32", moe_capacity_factor=8.0)
    params = lm.init(cfg, jax.random.key(0))
    x = jax.random.normal(jax.random.key(1), (2, 16, cfg.d_model)) * 0.1
    moe_p = jax.tree.map(lambda a: a[0], params["main"])["0"]["moe"]
    y1 = L.moe_forward(cfg, moe_p, x, groups=1)
    y2 = L.moe_forward(cfg, moe_p, x, groups=4)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-5, atol=2e-6)
