"""Shared test helper replacing the retired ``ZipageEngine.submit()`` shim.

``submit(eng, prompt, n)`` reproduces exactly what the old shim did —
engine-default temperature plus the engine's per-request derived seed —
so the pinned token streams in the test suite are unchanged by the
API retirement. New code should construct ``SamplingParams`` explicitly
and call ``add_request``.
"""
from repro.core.sampling import SamplingParams


def submit(eng, prompt, max_new_tokens):
    return eng.add_request(prompt, SamplingParams(
        temperature=eng.opts.temperature,
        seed=eng._default_seed(),
        max_new_tokens=max_new_tokens))
