"""Tests for the extracted scheduling subsystem (repro.core.scheduler).

Three layers:
  * pure-host unit tests driving ``Scheduler`` directly (no device work) —
    compression-aware admission margins and policy-ordered preemption;
  * engine-level tests for the new knobs (token budget, priority/srpt
    policies, telemetry) through the tiny LM;
  * the old-vs-new parity test: the refactored engine with the default
    FCFS policy must reproduce the frozen pre-extraction engine
    (tests/_legacy_engine.py) token-for-token on a mixed concurrent
    workload that exercises compression, prefix sharing and preemption.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SamplingParams, Zipage
from repro.configs import get_config
from repro.core.block_manager import BlockManager
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.core.request import Request, State
from repro.core.scheduler import (POLICIES, PrefillChunk, Scheduler,
                                  SchedulerOutputs, SchedulerParams,
                                  make_policy)
from repro.models import lm

from _legacy_engine import LegacyZipageEngine
from engine_utils import submit

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))


def ref_generate(prompt, n_new):
    toks = list(prompt)
    for _ in range(n_new):
        logits = lm.forward(CFG, PARAMS, jnp.asarray([toks]))
        toks.append(int(jnp.argmax(logits[0, -1])))
    return toks[len(prompt):]


# ----------------------------------------------------------------------
# pure-host unit tests (no model, no device steps)


def make_sched(n_blocks=16, block_size=4, **kw):
    base = dict(block_size=block_size, max_batch=4, m_qslots=4, n_max=3,
                window=2, prefill_rows=4, compression_enabled=True,
                budget_blocks=2, prefix_ok=False)
    base.update(kw)
    p = SchedulerParams(**base)
    return Scheduler(p, BlockManager(n_blocks, block_size,
                                     enable_prefix_cache=False))


def waiting_request(rid, n_prompt, n_out, priority=0):
    return Request(rid=rid, prompt=list(range(1, n_prompt + 1)),
                   max_new_tokens=n_out, priority=priority, arrival=float(rid))


def test_admission_honors_post_compression_footprint():
    """The paper's lever: with compression on, a running request's projected
    growth is capped at n_max blocks, so a margin-guarded admission still
    packs the batch; the full-KV baseline must reserve for the raw
    generation length and stalls after one request."""
    # each request: 8-token prompt (2 blocks) + 56 new tokens
    # => raw final footprint 16 blocks, post-compression footprint n_max=3
    compressed = make_sched(n_blocks=16, admission_margin=1.0)
    baseline = make_sched(n_blocks=16, admission_margin=1.0,
                          compression_enabled=False, n_max=None,
                          budget_blocks=0)
    for s in (compressed, baseline):
        for rid in range(3):
            s.add_request(waiting_request(rid, n_prompt=8, n_out=56))
    plan_c = compressed.schedule()
    plan_b = baseline.schedule()
    assert len(plan_c.admitted) >= 2, \
        "compression-aware admission should pack the batch"
    assert len(plan_b.admitted) == 1, \
        "full-KV projections must hold the margin back"


def test_admission_margin_zero_is_greedy():
    s = make_sched(n_blocks=16, admission_margin=0.0)
    for rid in range(4):
        s.add_request(waiting_request(rid, n_prompt=8, n_out=56))
    plan = s.schedule()
    # greedy: admits until slots/blocks run out (4 slots, 2 blocks each)
    assert len(plan.admitted) == 4


def running_request(sched, rid, n_blocks, priority=0, max_new=20,
                    qslot=-1):
    r = waiting_request(rid, n_prompt=n_blocks * sched.p.block_size,
                        n_out=max_new, priority=priority)
    r.blocks = sched.bm.allocate(n_blocks)
    r.slot = sched.free_slots.pop()
    r.qslot = qslot
    r.state = State.RUNNING
    r.seq_len = r.position = len(r.prompt)
    r.n_prefilled = r.prefill_target = len(r.prompt)
    sched.running.append(r)
    return r


def test_quiescent_horizon_per_row_caps():
    """Pure-host horizon planning (docs/PERF.md): each active row's cap is
    its host-free decode budget — block capacity, remaining length, the
    hybrid slotless boundary or stop-sequence matching — and the scan
    length is the max (rows below it sit out, they are not a global min)."""
    s = make_sched(n_blocks=32, block_size=8, window=4, n_max=3,
                   decode_steps=8)
    # plain qslot-holder: 2 blocks allocated, 10/16 tokens used -> 6 steps
    r_cap = running_request(s, 0, n_blocks=2, max_new=100, qslot=0)
    r_cap.seq_len = r_cap.position = 10
    # length-bound: only 3 tokens of budget left
    r_len = running_request(s, 1, n_blocks=2, max_new=20, qslot=1)
    r_len.seq_len = r_len.position = 9
    r_len.output = list(range(17))
    # slotless at 1 token into its n_max-th block: b - w = 4 boundary
    # allows tokens while tokens_in_last_block < 4 -> 3 steps
    r_slotless = running_request(s, 2, n_blocks=3, max_new=100, qslot=-1)
    r_slotless.seq_len = r_slotless.position = 17
    # stop sequences need per-token host matching -> cap 1
    r_stop = running_request(s, 3, n_blocks=2, max_new=100, qslot=2)
    r_stop.seq_len = r_stop.position = 9
    r_stop.sampling = SamplingParams(max_new_tokens=100, stop=((5, 6),))
    active = [r_cap, r_len, r_stop, r_slotless]
    K, caps = s.quiescent_horizon(active)
    assert caps == [6, 3, 1, 3]
    assert K == 6


def test_quiescent_horizon_respects_token_budget():
    """Multi-step caps must keep n_prefill_tokens + n_decode within the
    per-step token budget: each row gets its even share of what the
    step's prefill chunks left over."""
    s = make_sched(n_blocks=32, block_size=8, window=4, n_max=3,
                   decode_steps=8, token_budget=28, max_batch=4)
    rows = []
    for rid in range(4):
        r = running_request(s, rid, n_blocks=2, max_new=100, qslot=-1)
        r.seq_len = r.position = 9
        rows.append(r)
    outs = SchedulerOutputs()
    outs.prefill_chunks.append(PrefillChunk(waiting_request(9, 8, 10),
                                            0, 12, is_final=False))
    K, caps = s.quiescent_horizon(rows, outs)
    # (28 budget - 12 prefill) // 4 rows = 4 tokens per row
    assert caps == [4, 4, 4, 4] and K == 4
    assert 12 + sum(caps) <= 28
    # without prefill this step, decode may fill the whole budget share
    K2, caps2 = s.quiescent_horizon(rows, SchedulerOutputs())
    assert caps2 == [7, 7, 7, 7]       # 28 // 4, block capacity allows it
    assert sum(caps2) <= 28


def test_quiescent_horizon_single_step_mode():
    s = make_sched(n_blocks=32, block_size=8, decode_steps=1)
    r = running_request(s, 0, n_blocks=2, max_new=100, qslot=0)
    assert s.quiescent_horizon([r]) == (1, [1])
    assert s.quiescent_horizon([]) == (1, [])


def test_scheduler_version_tracks_device_table_mutations():
    """The engine's dirty-push gate: the version must move whenever slot /
    qslot / block state changes, and stay put across decision-free steps."""
    s = make_sched(n_blocks=16)          # block_size 4
    v0 = s.version
    s.add_request(waiting_request(0, n_prompt=6, n_out=30))
    plan = s.schedule()
    assert len(plan.admitted) == 1 and s.version > v0
    r = plan.admitted[0]
    r.n_prefilled = r.prefill_target     # prefill "done"
    r.output = [1]
    v1 = s.version
    # mid-stream decode with room in the last block (seq 6 of 8): no
    # device-table mutation, so the version must not move
    s.schedule_decode(plan)
    assert s.version == v1
    # block boundary -> allocation bumps the version
    r.seq_len = r.position = 8
    plan2 = SchedulerOutputs()
    s.schedule_decode(plan2)
    assert s.version > v1


@pytest.mark.parametrize("policy,expect_victim", [
    ("fcfs", 3),       # LIFO: newest admitted first
    ("priority", 2),   # lowest priority first (r2 has priority 0)
    ("srpt", 1),       # longest remaining work first (r1 wants 60 tokens)
])
def test_preemption_order_matches_policy(policy, expect_victim):
    # m_qslots=0 keeps every request slotless, so the hybrid victim tier
    # applies to all of them and the policy order alone decides
    s = make_sched(n_blocks=8, preemption=policy, m_qslots=0)
    requester = running_request(s, 0, n_blocks=2, priority=9, max_new=10)
    running_request(s, 1, n_blocks=2, priority=5, max_new=60)
    running_request(s, 2, n_blocks=2, priority=0, max_new=20)
    running_request(s, 3, n_blocks=2, priority=5, max_new=30)
    assert s.bm.num_free == 0
    outs = SchedulerOutputs()
    assert s._preempt_for_blocks(1, requester, outs)
    assert [r.rid for r in outs.preempted] == [expect_victim]
    victim = outs.preempted[0]
    assert victim.state == State.WAITING and s.waiting[0] is victim
    assert victim.preempt_count == 1
    s.bm.check_invariants()


def test_policy_admission_order():
    fcfs, prio, srpt = (make_policy(n) for n in ("fcfs", "priority", "srpt"))
    reqs = [waiting_request(0, 10, 40, priority=0),
            waiting_request(1, 4, 4, priority=2),
            waiting_request(2, 30, 20, priority=1)]
    assert [r.rid for r in fcfs.admission_order(reqs)] == [0, 1, 2]
    assert [r.rid for r in prio.admission_order(reqs)] == [1, 2, 0]
    assert [r.rid for r in srpt.admission_order(reqs)] == [1, 0, 2]
    assert set(POLICIES) == {"fcfs", "priority", "srpt", "cache_aware"}


def test_token_budget_plans_partial_prefill():
    s = make_sched(n_blocks=32, block_size=4, token_budget=10,
                   max_prefill_chunk=None)
    s.add_request(waiting_request(0, n_prompt=16, n_out=8))
    s.add_request(waiting_request(1, n_prompt=16, n_out=8))
    plan = s.schedule()
    assert plan.n_scheduled_tokens <= 10
    assert len(plan.admitted) == 1           # budget stops the second admit
    (chunk,) = plan.prefill_chunks
    assert chunk.n_tokens == 10 and not chunk.is_final
    # simulate the engine executing the chunk, then the next step finishes
    # it (a final chunk reserves +1 budget for its same-step decode)
    chunk.request.n_prefilled += chunk.n_tokens
    plan2 = s.schedule()
    carried = [c for c in plan2.prefill_chunks if c.request.rid == 0]
    assert carried and carried[0].start == 10 and carried[0].n_tokens == 6 \
        and carried[0].is_final
    assert plan2.n_prefill_tokens + 1 <= 10  # decode reservation respected


def test_scheduler_config_validation():
    with pytest.raises(ValueError, match="policy"):
        Zipage(CFG, PARAMS, block_size=8, n_total_blocks=32,
               policy="round-robin")
    with pytest.raises(ValueError, match="token_budget"):
        Zipage(CFG, PARAMS, block_size=8, n_total_blocks=32,
               max_batch=8, token_budget=4)
    with pytest.raises(ValueError):
        Scheduler(SchedulerParams(admission_margin=-0.5),
                  BlockManager(8, 4))


# ----------------------------------------------------------------------
# engine-level tests through the tiny LM


def make_engine(**kw):
    base = dict(block_size=8, n_total_blocks=64, max_batch=4, m_qslots=2,
                n_max=3, window=4, max_model_len=256, prefill_rows=2,
                prefill_len=64, compress=CompressOptions(window=4),
                temperature=0.0)
    base.update(kw)
    return ZipageEngine(CFG, PARAMS, EngineOptions(**base))


def test_token_budget_never_exceeded_and_exact():
    """Chunked prefill under a shared prefill+decode token budget: the
    per-step scheduled tokens never exceed the budget, and (with the
    full-KV baseline, whose paged cache is exact) the token streams still
    match the naive reference."""
    budget = 16
    eng = make_engine(n_max=None, token_budget=budget, prefill_len=32,
                      max_model_len=128)
    prompts = [list(range(1, 41)), list(range(3, 40)),
               list(range(5, 35)), [7, 8, 9]]
    rids = [submit(eng, p, 8) for p in prompts]
    done = eng.run(max_steps=400)
    for m in eng.metrics:
        assert m["n_scheduled_tokens"] <= budget, m
        assert m["n_prefill_tokens"] + m["n_active"] <= budget
    # prefill genuinely spread over multiple steps
    assert sum(1 for m in eng.metrics if 0 < m["n_prefill_tokens"]) >= 2
    for rid, p in zip(rids, prompts):
        assert done[rid].output == ref_generate(p, 8)
    assert eng.bm.num_free == eng.opts.n_total_blocks


def test_max_prefill_chunk_caps_per_request_chunks():
    eng = make_engine(n_max=None, token_budget=24, max_prefill_chunk=8,
                      prefill_len=32, max_model_len=128)
    rid = submit(eng, list(range(1, 41)), 4)
    done = eng.run(max_steps=100)
    assert len(done[rid].output) == 4
    # 40-token prompt at <=8 tokens/step => at least 5 prefill steps
    assert sum(1 for m in eng.metrics if m["n_prefill_tokens"] > 0) >= 5
    assert max(m["n_prefill_tokens"] for m in eng.metrics) <= 8


def test_priority_policy_admits_high_priority_first():
    z = Zipage(CFG, PARAMS, block_size=8, n_total_blocks=64, max_batch=1,
               m_qslots=1, n_max=3, window=4, max_model_len=128,
               prefill_rows=4, prefill_len=32, policy="priority")
    lo = z.add_request([1, 2, 3], SamplingParams(max_new_tokens=6),
                       priority=0)
    hi = z.add_request([4, 5, 6], SamplingParams(max_new_tokens=6),
                       priority=5)
    z.step()
    running = z.engine.scheduler.running
    assert [r.rid for r in running] == [hi]
    while z.has_unfinished():
        z.step()
    lo_out, hi_out = z.output(lo), z.output(hi)
    assert hi_out.metrics.t_finish <= lo_out.metrics.t_finish


def test_srpt_policy_prefers_short_requests():
    eng = make_engine(max_batch=1, m_qslots=1, policy="srpt")
    long_rid = submit(eng, [1, 2, 3], 40)
    short_rid = submit(eng, [4, 5, 6], 4)
    eng.step()
    assert [r.rid for r in eng.running] == [short_rid]
    done = eng.run(max_steps=400)
    assert len(done[long_rid].output) == 40
    assert len(done[short_rid].output) == 4


def test_scheduler_telemetry_in_metrics_and_facade():
    z = Zipage(CFG, PARAMS, block_size=8, n_total_blocks=64, max_batch=4,
               m_qslots=2, n_max=3, window=4, max_model_len=128,
               prefill_rows=2, prefill_len=32)
    assert z.scheduler_stats is None
    z.generate([[1, 2, 3, 4]], SamplingParams(max_new_tokens=6))
    m = z.metrics[0]
    for key in ("policy", "n_admitted", "n_preempted", "n_blocked",
                "n_finished", "n_prefill_tokens", "n_scheduled_tokens",
                "token_budget", "budget_util", "free_blocks",
                "admission_scale"):
        assert key in m, key
    assert m["policy"] == "fcfs" and m["n_admitted"] == 1
    assert m["n_prefill_tokens"] == 4
    stats = z.scheduler_stats
    assert stats["free_blocks"] == z.num_free_blocks
    assert stats["policy"] == "fcfs"


# ----------------------------------------------------------------------
# old-vs-new parity


def _mixed_workload(rng, n=10):
    """Mixed concurrent workload: short/long prompts, short/long decodes,
    a shared prefix pair (prefix-cache path), enough volume for
    compression and block-pressure preemption on a 48-block pool."""
    reqs = []
    shared = list(range(100, 124))           # 3 full blocks of 8
    for i in range(n):
        kind = i % 4
        if kind == 0:      # amc-like: short in, long out
            p = rng.integers(1, 64, size=int(rng.integers(4, 12))).tolist()
            o = int(rng.integers(30, 48))
        elif kind == 1:    # short in, short out
            p = rng.integers(1, 64, size=int(rng.integers(4, 12))).tolist()
            o = int(rng.integers(4, 10))
        elif kind == 2:    # long in, short out
            p = rng.integers(1, 64, size=int(rng.integers(40, 80))).tolist()
            o = int(rng.integers(4, 12))
        else:              # shared-prefix long decode
            p = shared + [int(200 + i)]
            o = int(rng.integers(24, 40))
        reqs.append((p, o))
    return reqs


def test_fcfs_parity_with_legacy_engine():
    """Acceptance gate for the extraction: the scheduler-driven engine with
    the default FCFS policy reproduces the frozen pre-refactor engine
    token-for-token (and step-for-step) on a mixed concurrent workload.

    The straggler-aware admission backoff keys off wall-clock EWMAs, which
    jit-compilation spikes make nondeterministic — it is pinned to neutral
    on both engines so the comparison is purely about scheduling logic.
    """
    kw = dict(block_size=8, n_total_blocks=48, max_batch=6, m_qslots=3,
              n_max=3, window=4, scheduling="hybrid", prefix_caching=True,
              async_compression=True, max_model_len=256, prefill_rows=2,
              prefill_len=32, compress=CompressOptions(window=4),
              temperature=0.0,
              # the frozen engine predates the radix cache and always
              # builds a flat-policy BlockManager; pin the new engine to
              # flat so the comparison is byte-for-byte legacy semantics
              prefix_cache_policy="flat")
    reqs = _mixed_workload(np.random.default_rng(7))
    old = LegacyZipageEngine(CFG, PARAMS, EngineOptions(**kw))
    new = ZipageEngine(CFG, PARAMS, EngineOptions(**kw))
    rids_old = [old.submit(p, o) for p, o in reqs]
    rids_new = [submit(new, p, o) for p, o in reqs]
    assert rids_old == rids_new
    for _ in range(2000):
        if not (old.waiting or old.running) \
                and not (new.waiting or new.running):
            break
        if old.waiting or old.running:
            old.step()
        if new.waiting or new.running:
            new.step()
        # neutralize the wall-clock-driven admission backoff on both sides
        old.admission_scale = 1.0
        old._ewma = None
        new.scheduler.admission_scale = 1.0
        new.scheduler.ewma = None
    else:
        raise AssertionError("workload did not finish")
    done_old = {r.rid: r for r in old.finished.values()}
    done_new = {r.rid: r for r in new.finished.values()}
    for rid in rids_old:
        assert done_old[rid].output == done_new[rid].output, f"rid {rid}"
        assert done_old[rid].finish_reason == done_new[rid].finish_reason
    # structural parity: same step count, same compression volume, same
    # preemption pressure, clean pool on both sides
    assert old.step_count == new.step_count
    assert sum(m["n_compressing"] for m in old.metrics) \
        == sum(m["n_compressing"] for m in new.metrics)
    assert sum(m["n_compressing"] for m in new.metrics) > 0, \
        "workload never compressed — parity test lost its teeth"
    assert [m["n_running"] for m in old.metrics] \
        == [m["n_running"] for m in new.metrics]
    assert sum(r.preempt_count for r in done_old.values()) \
        == sum(r.preempt_count for r in done_new.values())
    old.bm.check_invariants()
    new.bm.check_invariants()
    assert old.bm.num_free == new.bm.num_free == kw["n_total_blocks"]
