"""tools/docs_check.py link-gate tests: the real repo's docs resolve,
the docs/*.md glob auto-enrolls new pages (so docs/CACHING.md is gated
without touching the tool), and a broken link actually fails."""
import importlib.util
import sys
from pathlib import Path

import pytest

_TOOL = Path(__file__).resolve().parent.parent / "tools" / "docs_check.py"
_spec = importlib.util.spec_from_file_location("docs_check", _TOOL)
dc = importlib.util.module_from_spec(_spec)
sys.modules["docs_check"] = dc
_spec.loader.exec_module(dc)


def test_repo_links_resolve():
    # the same gate CI runs via `make docs-check`
    assert dc.check_links() == []


def test_docs_glob_auto_enrolls_new_pages():
    names = {p.name for p in dc.DOC_FILES}
    assert {"README.md", "ROADMAP.md", "CACHING.md",
            "SCHEDULER.md"} <= names


def test_broken_link_is_caught(monkeypatch, tmp_path):
    bad = tmp_path / "BAD.md"
    bad.write_text("see [missing](no/such/page.md) "
                   "and [ok](OK.md#some-anchor)\n")
    (tmp_path / "OK.md").write_text("fine\n")
    monkeypatch.setattr(dc, "REPO", tmp_path)
    monkeypatch.setattr(dc, "DOC_FILES", [bad])
    errors = dc.check_links()
    assert len(errors) == 1
    assert "no/such/page.md" in errors[0] and "BAD.md:1" in errors[0]


def test_external_urls_and_anchors_skipped(monkeypatch, tmp_path):
    md = tmp_path / "DOC.md"
    md.write_text("[ci](https://example.com/x) [top](#anchor)\n")
    monkeypatch.setattr(dc, "REPO", tmp_path)
    monkeypatch.setattr(dc, "DOC_FILES", [md])
    assert dc.check_links() == []


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
