"""Per-arch smoke tests: reduced config, one forward + one train grad step on
CPU, asserting output shapes and finiteness. The FULL configs are exercised
only via the dry-run (abstract lowering)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.models import lm

ARCHS = [
    "recurrentgemma-2b", "deepseek-v2-lite-16b", "dbrx-132b", "llama3-8b",
    "nemotron-4-15b", "olmo-1b", "qwen2.5-3b", "rwkv6-3b", "whisper-tiny",
    "internvl2-26b",
]


def make_batch(cfg, key, B=2, S=16):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size),
    }
    if cfg.frontend == "vision_stub":
        batch["prefix_embeds"] = jax.random.normal(
            ks[2], (B, cfg.num_prefix_embeds, cfg.d_model), jnp.float32) * 0.02
    if cfg.frontend == "audio_stub":
        batch["frame_embeds"] = jax.random.normal(
            ks[2], (B, cfg.cross_seq_len, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_shapes(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.key(0)
    params = lm.init(cfg, key)
    batch = make_batch(cfg, jax.random.key(1))
    logits = lm.forward(cfg, params, batch["tokens"],
                        prefix_embeds=batch.get("prefix_embeds"),
                        frame_embeds=batch.get("frame_embeds"))
    B, S = batch["tokens"].shape
    P = cfg.num_prefix_embeds if cfg.frontend == "vision_stub" else 0
    assert logits.shape == (B, S + P, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch).reduced()
    params = lm.init(cfg, jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))

    @jax.jit
    def loss_fn(p):
        return lm.lm_loss(cfg, p, batch, vocab_chunk=8)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert np.isfinite(np.asarray(g, np.float32)).all()


def test_all_assigned_archs_registered():
    names = set(all_arch_names())
    for a in ARCHS:
        assert a in names
