"""Scoring-function unit tests vs independent numpy oracles."""
import jax.numpy as jnp
import numpy as np

from repro.core import scoring

RNG = np.random.default_rng(0)


def naive_attention_scores(q_win, entries, valid, seq_len):
    """Independent oracle: softmax(qk/sqrt d) with causal+valid mask, GQA max
    over group, mean over window."""
    w, hq, d = q_win.shape
    T, h, _ = entries.shape
    g = hq // h
    out = np.zeros((T, h))
    probs = np.zeros((w, hq, T))
    for u in range(w):
        qpos = seq_len - w + u
        for qh in range(hq):
            s = entries[:, qh // g, :].astype(np.float64) @ \
                q_win[u, qh].astype(np.float64) / np.sqrt(d)
            mask = (np.arange(T) <= qpos) & valid
            s = np.where(mask, s, -np.inf)
            e = np.exp(s - s.max())
            probs[u, qh] = e / e.sum()
    for kh in range(h):
        grp = probs[:, kh * g:(kh + 1) * g]       # (w, g, T)
        out[:, kh] = grp.max(axis=1).mean(axis=0)
    return out


def test_attention_scores_vs_oracle():
    w, hq, h, d, T = 4, 4, 2, 8, 16
    seq_len = 13
    q = RNG.normal(size=(w, hq, d)).astype(np.float32)
    k = RNG.normal(size=(T, h, d)).astype(np.float32)
    valid = np.arange(T) < seq_len
    got = np.asarray(scoring.attention_scores(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(valid), seq_len))
    want = naive_attention_scores(q, k, valid, seq_len)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)


def test_attention_scores_causal_mask():
    """Keys after a query position must get zero probability from it; the
    last key overall can only be scored by the last query."""
    w, hq, h, d, T = 4, 2, 2, 8, 8
    seq_len = 8
    q = RNG.normal(size=(w, hq, d)).astype(np.float32)
    k = RNG.normal(size=(T, h, d)).astype(np.float32)
    valid = np.ones(T, bool)
    s = np.asarray(scoring.attention_scores(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(valid), seq_len))
    assert (s > 0).all()  # every key precedes at least the last query


def test_global_score_update():
    T, h = 8, 2
    s = jnp.asarray(RNG.normal(size=(T, h)).astype(np.float32))
    f = jnp.asarray(RNG.normal(size=(T, h)).astype(np.float32))
    out = np.asarray(scoring.global_score_update(s, f, hist_len=5, alpha=0.8))
    want = np.asarray(s).copy()
    want[:5] = np.maximum(0.8 * np.asarray(f)[:5], want[:5])
    np.testing.assert_allclose(out, want)


def naive_redundancy(entries, valid, p):
    T, h, d = entries.shape
    out = np.zeros((T, h))
    n = max(valid.sum(), 1)
    for kh in range(h):
        e = entries[:, kh].astype(np.float64)
        e = e / np.maximum(np.linalg.norm(e, axis=-1, keepdims=True), 1e-12)
        c = e @ e.T
        c[~valid, :] = 0
        c[:, ~valid] = 0
        np.fill_diagonal(c, 0)
        for j in range(T):
            above = np.nonzero(c[:, j] > p)[0]
            if len(above):
                c[above[-1], j] = 0
        out[:, kh] = c.sum(axis=1) / n
    return out


def test_redundancy_full_vs_oracle():
    T, h, d = 12, 2, 8
    entries = RNG.normal(size=(T, h, d)).astype(np.float32)
    entries[7, 0] = entries[3, 0] * 1.5          # force a high-similarity pair
    valid = np.arange(T) < 10
    got = np.asarray(scoring.redundancy_full(
        jnp.asarray(entries), jnp.asarray(valid), p_thresh=0.8))
    want = naive_redundancy(entries, valid, 0.8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_lightning_equals_full_for_single_block():
    """With one block and matching normalization the two scores agree."""
    T, h, d = 8, 2, 8
    entries = RNG.normal(size=(T, h, d)).astype(np.float32)
    valid = np.ones(T, bool)
    full = np.asarray(scoring.redundancy_full(
        jnp.asarray(entries), jnp.asarray(valid), p_thresh=0.8))
    light = np.asarray(scoring.redundancy_lightning(
        jnp.asarray(entries), jnp.asarray(valid), block_size=T, p_thresh=0.8))
    np.testing.assert_allclose(light, full, rtol=1e-5, atol=1e-6)


def test_lightning_blocks_are_local():
    """Changing one block's keys must not change other blocks' scores."""
    T, h, d, b = 16, 1, 4, 4
    e1 = RNG.normal(size=(T, h, d)).astype(np.float32)
    e2 = e1.copy()
    e2[:b] = RNG.normal(size=(b, h, d))
    valid = np.ones(T, bool)
    r1 = np.asarray(scoring.redundancy_lightning(
        jnp.asarray(e1), jnp.asarray(valid), block_size=b))
    r2 = np.asarray(scoring.redundancy_lightning(
        jnp.asarray(e2), jnp.asarray(valid), block_size=b))
    np.testing.assert_allclose(r1[b:], r2[b:], rtol=1e-6)
    assert not np.allclose(r1[:b], r2[:b])


def test_max_pool_scores():
    T, h = 8, 1
    s = jnp.asarray(np.array([[0, 0, 5, 0, 0, 0, 1, 0]], np.float32).T)
    valid = np.ones(T, bool)
    out = np.asarray(scoring.max_pool_scores(s, jnp.asarray(valid), kernel=3))
    np.testing.assert_allclose(out[:, 0], [0, 5, 5, 5, 0, 1, 1, 1])


def test_combine_and_topk():
    T, h = 16, 2
    s = jnp.asarray(RNG.normal(size=(T, h)).astype(np.float32))
    red = jnp.zeros((T, h))
    valid = jnp.asarray(np.arange(T) < 12)
    final = scoring.combine_scores(s, red, valid, win_len=2, seq_len=12,
                                   lam=0.2)
    tag = np.asarray(scoring.topk_tag(final, 6))
    assert (tag.sum(axis=0) == 6).all()
    assert tag[10:12].all()              # observation window pinned
    assert not tag[12:].any()            # invalid region never kept
