"""Paged prefill+decode must reproduce the training-path forward logits.

This is the strongest single correctness check in the system: it exercises
paged writes, paged attention (GQA/MLA/ring), recurrent decode states,
observation-window bookkeeping and the stage/scan machinery at once.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import serve_model
from repro.models import lm

ARCHS = ["tiny-lm", "qwen2.5-3b", "deepseek-v2-lite-16b", "recurrentgemma-2b",
         "rwkv6-3b", "whisper-tiny", "olmo-1b"]


def run_roundtrip(arch, S_prompt=7, n_decode=6, block_size=4):
    cfg = get_config(arch)
    if arch != "tiny-lm":
        cfg = cfg.reduced()
    # fp32 + drop-free MoE so the two execution paths are bit-comparable
    cfg = dataclasses.replace(cfg, dtype="float32", moe_capacity_factor=8.0)
    key = jax.random.key(0)
    params = lm.init(cfg, key)
    S_total = S_prompt + n_decode
    tokens = jax.random.randint(jax.random.key(1), (1, S_total), 0,
                                cfg.vocab_size)
    fkw = {}
    if cfg.is_enc_dec:
        fkw["frame_embeds"] = 0.02 * jax.random.normal(
            jax.random.key(2), (1, cfg.cross_seq_len, cfg.d_model))
    ref = lm.forward(cfg, params, tokens, **fkw)          # (1, S, V)

    spec = serve_model.ServeSpec(
        n_slots=2, block_size=block_size,
        max_blocks=max(8, -(-S_total // block_size) + 1),
        n_total_blocks=64, m_qslots=2, window=4,
        prefill_rows=2, prefill_len=16, dtype="float32")
    state = serve_model.make_state(cfg, spec)
    # host-side: give slot 0 enough blocks
    if cfg.local_window:
        nblk = spec.ring_blocks(cfg)
    else:
        nblk = spec.max_blocks
    bt = np.full((2, spec.max_blocks), -1, np.int32)
    bt[0, :nblk] = np.arange(nblk)
    state["block_tables"] = jnp.asarray(bt)
    state["qslot"] = jnp.asarray(np.array([0, -1], np.int32))

    prefill = jax.jit(serve_model.build_prefill_step(cfg, spec))
    decode = jax.jit(serve_model.build_decode_step(cfg, spec))

    ptoks = np.zeros((spec.prefill_rows, spec.prefill_len), np.int32)
    ptoks[0, :S_prompt] = np.asarray(tokens[0, :S_prompt])
    pf_kw = {}
    if cfg.is_enc_dec:
        fe = np.zeros((spec.prefill_rows, cfg.cross_seq_len, cfg.d_model),
                      np.float32)
        fe[0] = np.asarray(fkw["frame_embeds"][0])
        pf_kw["frame_embeds"] = jnp.asarray(fe)
    state["seq_lens"] = jnp.asarray(
        np.array([min(S_prompt, cfg.local_window or 10**9), 0], np.int32))
    state["positions"] = jnp.asarray(np.array([S_prompt, 0], np.int32))
    logits, state = prefill(
        params, state, jnp.asarray(ptoks),
        jnp.asarray(np.array([0, -1], np.int32)),
        jnp.asarray(np.array([S_prompt, 0], np.int32)),
        jnp.asarray(np.array([0, 0], np.int32)), **pf_kw)
    got = [np.asarray(logits[0])]
    active = jnp.asarray(np.array([True, False]))
    for t in range(S_prompt, S_total - 1):
        tok = jnp.asarray(np.array([tokens[0, t], 0], np.int32))
        logits, state = decode(params, state, tok, active)
        got.append(np.asarray(logits[0]))
    got = np.stack(got)                                    # (n_decode, V)
    want = np.asarray(ref[0, S_prompt - 1:S_total - 1], np.float32)
    return got, want


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    got, want = run_roundtrip(arch)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_crossing_blocks():
    """Long enough to span several pages and trigger block-boundary paths."""
    got, want = run_roundtrip("tiny-lm", S_prompt=5, n_decode=13,
                              block_size=4)
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)
