"""HTTP serving tier: protocol validation, SSE parity with the sync
engine path, backpressure, fairness, disconnect-abort and graceful drain
— all over the in-process ASGI client (no sockets, CI-safe).
"""
import asyncio
import dataclasses

import jax
import pytest

from repro.api import SamplingParams, Zipage
from repro.configs import get_config
from repro.core import invariants
from repro.models import lm
from repro.serve import ServeConfig, create_app
from repro.serve.cli import build_parser, config_from_args
from repro.serve.fairness import ClientFairness
from repro.serve.protocol import (CompletionRequest, ProtocolError,
                                  parse_token_ids, render_text)
from repro.serve.testing import ASGIClient

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))
N_BLOCKS = 64

# the "priority" policy is what per-client fairness maps onto
Z = Zipage(CFG, PARAMS, block_size=8, n_total_blocks=N_BLOCKS,
           max_batch=4, m_qslots=4, n_max=3, window=4, max_model_len=128,
           prefill_rows=2, prefill_len=64, policy="priority")
P1 = [1, 2, 3, 4, 5]


def make_client(**cfg):
    """Fresh app (own AsyncEngineLoop) on the shared warm facade."""
    app = create_app(ServeConfig(**cfg), zipage=Z)
    return app, ASGIClient(app)


def run(coro):
    result = asyncio.run(coro)
    assert Z.num_free_blocks == N_BLOCKS
    # whole-engine sanitizer audit post-test; the qwin-ownership shadow
    # is a between-steps check (stale across sporadic audits) — reset it
    Z.engine._qwin_shadow.clear()
    invariants.check_engine(Z.engine)
    return result


# ----------------------------------------------------------------------
# protocol layer (no engine)

def test_token_codec_roundtrip():
    assert parse_token_ids("1 2 3", "prompt") == [1, 2, 3]
    assert parse_token_ids([4, 5], "prompt") == [4, 5]
    assert render_text([1, 2, 3]) == "1 2 3"
    with pytest.raises(ProtocolError, match="must not be empty"):
        parse_token_ids("", "prompt")
    with pytest.raises(ProtocolError, match="token ids"):
        parse_token_ids("one two", "prompt")
    with pytest.raises(ProtocolError, match="token ids"):
        parse_token_ids([1, "2"], "prompt")


def test_request_validation_did_you_mean():
    with pytest.raises(ProtocolError, match="did you mean 'prompt'"):
        CompletionRequest.from_body({"promt": "1 2"}, chat=False)
    with pytest.raises(ProtocolError, match="did you mean 'messages'"):
        CompletionRequest.from_body({"message": []}, chat=True)
    # SamplingParams-level errors surface as 400s too
    with pytest.raises(ProtocolError, match="n separate requests"):
        CompletionRequest.from_body({"prompt": "1", "n": 3}, chat=False)


def test_capacity_validation_before_admission():
    req = CompletionRequest.from_body(
        {"prompt": "1 2 3", "max_tokens": 1000}, chat=False)
    with pytest.raises(ProtocolError, match="max_model_len"):
        req.check_capacity(vocab_size=256, max_model_len=128,
                           max_tokens_limit=None)
    with pytest.raises(ProtocolError, match="server's limit"):
        req.check_capacity(vocab_size=256, max_model_len=4096,
                           max_tokens_limit=512)
    req = CompletionRequest.from_body({"prompt": "999999 1"}, chat=False)
    with pytest.raises(ProtocolError, match="vocabulary"):
        req.check_capacity(vocab_size=256, max_model_len=128,
                           max_tokens_limit=None)


def test_fairness_ledger():
    f = ClientFairness()
    assert f.admit("a") == 0 and f.admit("a") == -1 and f.admit("a") == -2
    assert f.admit("b") == 0                 # other clients unaffected
    f.release("a")
    assert f.admit("a") == -2
    for _ in range(3):
        f.release("a")
    f.release("b")
    assert f.snapshot() == {}


def test_cli_arg_parsing():
    args = build_parser().parse_args(
        ["--model", "tiny-lm", "--port", "9000", "--no-fairness",
         "--max-queued-requests", "7",
         "--override", "n_total_blocks=128", "--override", "n_max=none"])
    cfg = config_from_args(args)
    assert cfg.port == 9000 and not cfg.fairness
    assert cfg.max_queued_requests == 7
    assert cfg.engine_overrides == {"n_total_blocks": 128, "n_max": None}


# ----------------------------------------------------------------------
# end-to-end over the in-process ASGI app

def test_unary_completion_matches_generate():
    hot = SamplingParams(max_new_tokens=10, seed=7, temperature=0.8)
    ref, = Z.generate([P1], hot)
    _, client = make_client()

    async def main():
        r = await client.request("POST", "/v1/completions", json={
            "prompt": render_text(P1), "max_tokens": 10, "seed": 7,
            "temperature": 0.8})
        await client.app.state.drain()
        return r

    r = run(main())
    assert r.status == 200
    choice = r.json()["choices"][0]
    assert choice["token_ids"] == ref.token_ids
    assert choice["text"] == render_text(ref.token_ids)
    assert choice["finish_reason"] == "length"
    assert r.json()["usage"] == {"prompt_tokens": len(P1),
                                 "completion_tokens": 10,
                                 "total_tokens": len(P1) + 10}


def test_sse_stream_token_identical_to_generate():
    """Acceptance pin: the SSE-streamed completion is token-for-token
    identical to an in-process generate() of the same seeded request."""
    hot = SamplingParams(max_new_tokens=14, seed=21, temperature=1.0)
    ref, = Z.generate([P1], hot)
    _, client = make_client()

    async def main():
        async with client.stream("POST", "/v1/completions", json={
                "prompt": render_text(P1), "max_tokens": 14, "seed": 21,
                "temperature": 1.0, "stream": True,
                "stream_options": {"include_usage": True}}) as h:
            await h.started()
            assert h.status == 200
            assert h.headers["content-type"].startswith(
                "text/event-stream")
            events = [e async for e in h.events()]
        await client.app.state.drain()
        return events

    events = run(main())
    assert events[-1] == "[DONE]"
    usage = events[-2]["usage"]
    data = [e for e in events[:-2] if e["choices"]]
    toks = [t for e in data for t in e["choices"][0]["token_ids"]]
    assert toks == ref.token_ids             # the tentpole guarantee
    reasons = [e["choices"][0]["finish_reason"] for e in data]
    assert reasons[-1] == "length"
    assert all(r is None for r in reasons[:-1])
    assert usage == {"prompt_tokens": len(P1), "completion_tokens": 14,
                     "total_tokens": len(P1) + 14}


def test_chat_stream_matches_completions():
    ref, = Z.generate([P1], SamplingParams(max_new_tokens=8))
    _, client = make_client()

    async def main():
        async with client.stream("POST", "/v1/chat/completions", json={
                "messages": [{"role": "system", "content": "1 2"},
                             {"role": "user", "content": "3 4 5"}],
                "max_tokens": 8, "stream": True}) as h:
            events = [e async for e in h.events()]
        await client.app.state.drain()
        return events

    events = run(main())
    data = [e for e in events if e != "[DONE]" and e["choices"]]
    assert data[0]["choices"][0]["delta"]["role"] == "assistant"
    toks = [t for e in data
            for t in e["choices"][0]["delta"].get("token_ids", [])]
    assert toks == ref.token_ids             # same concatenated prompt
    assert data[0]["object"] == "chat.completion.chunk"


def test_disconnect_mid_stream_aborts_and_reclaims():
    """Client goes away mid-stream -> abort(), slots and blocks return
    to the pool; the whole-engine sanitizer audits the result."""
    _, client = make_client()

    async def main():
        async with client.stream("POST", "/v1/completions", json={
                "prompt": render_text(P1), "max_tokens": 100,
                "stream": True}) as h:
            ev = await h.events().__anext__()   # at least one token out
            assert ev["choices"][0]["token_ids"]
            h.disconnect()
        # context exit waited for the handler: abort has been applied
        assert not Z.has_unfinished()
        await client.app.state.drain()

    run(main())
    aborted = [r for r in Z.engine.finished.values()
               if r.finish_reason == "abort"]
    assert aborted


def test_disconnect_before_response_aborts_unary():
    _, client = make_client()

    async def main():
        async with client.stream("POST", "/v1/completions", json={
                "prompt": render_text(P1), "max_tokens": 100}) as h:
            # handle used for its disconnect control; unary response
            # won't arrive before we hang up
            await asyncio.sleep(0.05)
            h.disconnect()
        assert not Z.has_unfinished()
        await client.app.state.drain()

    run(main())


def test_backpressure_429_with_retry_after():
    parked = Z.add_request(P1, SamplingParams(max_new_tokens=30))
    _, client = make_client(max_queued_requests=1)

    async def main():
        r = await client.request("POST", "/v1/completions", json={
            "prompt": "1 2", "max_tokens": 4})
        return r

    r = asyncio.run(main())
    assert r.status == 429
    assert int(r.headers["retry-after"]) >= 1
    assert r.json()["error"]["code"] == "engine_saturated"
    Z.abort(parked)
    assert Z.num_free_blocks == N_BLOCKS


def test_graceful_drain_finishes_running_rejects_new():
    _, client = make_client()

    async def main():
        async with client.stream("POST", "/v1/completions", json={
                "prompt": render_text(P1), "max_tokens": 12,
                "stream": True}) as h:
            await h.events().__anext__()        # request is running
            drainer = asyncio.create_task(client.app.state.drain())
            await asyncio.sleep(0)              # drain closes intake
            r = await client.request("POST", "/v1/completions", json={
                "prompt": "1 2", "max_tokens": 4})
            assert r.status == 503
            assert r.json()["error"]["code"] == "draining"
            # ... but the running stream finishes and flushes
            rest = [e async for e in h.events()]
            await drainer
        health = await client.request("GET", "/health")
        assert health.status == 503             # still draining: no intake
        return rest

    rest = run(main())
    assert rest[-1] == "[DONE]"
    data = [e for e in rest[:-1] if e != "[DONE]" and e["choices"]]
    assert data[-1]["choices"][0]["finish_reason"] == "length"


def test_fairness_tags_priorities_per_client():
    _, client = make_client()

    async def main():
        streams = []
        for i, key in enumerate(["alice", "alice", "bob"]):
            h = client.stream("POST", "/v1/completions", json={
                "prompt": render_text(P1), "max_tokens": 30,
                "stream": True},
                headers={"authorization": f"Bearer {key}"})
            await h.__aenter__()
            await h.events().__anext__()
            streams.append(h)
        # alice's second request sorts behind bob's first
        prios = {r.rid: r.priority
                 for r in Z.engine.running + list(Z.engine.waiting)}
        for h in streams:
            h.disconnect()
            await h.__aexit__(None, None, None)
        await client.app.state.drain()
        return sorted(prios.values(), reverse=True)

    assert run(main()) == [0, 0, -1]


def test_misc_endpoints_and_errors():
    _, client = make_client()

    async def main():
        health = await client.request("GET", "/health")
        models = await client.request("GET", "/v1/models")
        missing = await client.request("GET", "/v1/nope")
        wrong = await client.request("GET", "/v1/completions")
        bad_json = await client.request("POST", "/v1/completions",
                                        body=b"{nope")
        bad_field = await client.request("POST", "/v1/completions", json={
            "prompt": "1 2", "max_token": 4})
        too_long = await client.request("POST", "/v1/completions", json={
            "prompt": "1 2", "max_tokens": 127})
        await client.app.state.drain()
        return health, models, missing, wrong, bad_json, bad_field, \
            too_long

    health, models, missing, wrong, bad_json, bad_field, too_long = \
        run(main())
    assert health.status == 200 and health.json()["backlog"] == 0
    assert models.json()["data"][0]["id"] == "tiny-lm"
    assert missing.status == 404
    assert wrong.status == 405
    assert bad_json.status == 400
    assert bad_field.status == 400
    assert "did you mean 'max_tokens'" in \
        bad_field.json()["error"]["message"]
    assert too_long.status == 400
    assert "max_model_len" in too_long.json()["error"]["message"]
