"""Serving-facade tests: per-request SamplingParams, streaming chunks,
abort lifecycle, stop handling, and the config split.

One shared facade instance (same device-step shapes) keeps jit
recompilation to a minimum on CPU.
"""
import dataclasses
import warnings

import jax
import pytest

from repro.api import (CacheConfig, ModelRunnerConfig, SamplingParams,
                       SchedulerConfig, Zipage)
from repro.configs import get_config
from repro.models import lm

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))
N_BLOCKS = 64


def make_facade(**kw):
    base = dict(block_size=8, n_total_blocks=N_BLOCKS, max_batch=4,
                m_qslots=4, n_max=3, window=4, max_model_len=128,
                prefill_rows=2, prefill_len=64)
    base.update(kw)
    return Zipage(CFG, PARAMS, **base)


Z = make_facade()
P1, P2 = [1, 2, 3, 4, 5], [9, 8, 7]


def greedy(n):
    return SamplingParams(max_new_tokens=n)


def test_generate_batch_and_pool_accounting():
    outs = Z.generate([P1, P2], greedy(8))
    assert [o.usage.completion_tokens for o in outs] == [8, 8]
    assert all(o.finished and o.finish_reason == "length" for o in outs)
    assert outs[0].prompt_token_ids == P1
    assert Z.num_free_blocks == N_BLOCKS
    Z.bm.check_invariants()


def test_per_request_seed_reproducibility():
    sp = SamplingParams(temperature=0.9, seed=42, max_new_tokens=10)
    # identical (prompt, seed) side by side in ONE continuous batch
    a, b = Z.generate([P1, P1], [sp, sp])
    assert a.token_ids == b.token_ids
    # and across a fresh run of the same engine
    c, = Z.generate([P1], sp)
    assert c.token_ids == a.token_ids
    # a different seed diverges
    d, = Z.generate([P1], dataclasses.replace(sp, seed=7))
    assert d.token_ids != a.token_ids


def test_mixed_temperatures_independent_of_batch_mates():
    """A greedy request must be unaffected by a stochastic batch mate —
    per-slot PRNG state, not an engine-global key."""
    base, = Z.generate([P1], greedy(10))
    hot = SamplingParams(temperature=1.1, top_k=50, seed=3,
                         max_new_tokens=10)
    mixed = Z.generate([P1, P2], [greedy(10), hot])
    assert mixed[0].token_ids == base.token_ids


def test_stop_sequence_truncation():
    base, = Z.generate([P1], greedy(10))
    stop = tuple(base.token_ids[3:5])
    out, = Z.generate([P1], SamplingParams(max_new_tokens=10,
                                           stop=(stop,)))
    assert out.finish_reason == "stop"
    assert out.token_ids == base.token_ids[:3]     # stop tokens truncated
    assert Z.num_free_blocks == N_BLOCKS


def test_eos_ids_kept_in_output():
    base, = Z.generate([P1], greedy(10))
    eos = base.token_ids[4]
    out, = Z.generate([P1], SamplingParams(max_new_tokens=10,
                                           eos_ids=(eos,)))
    assert out.finish_reason == "stop"
    first = base.token_ids.index(eos)              # eos itself kept
    assert out.token_ids == base.token_ids[:first + 1]


def test_eos_on_first_prefill_token():
    """The token sampled at the end of prefill must be eos/stop-checked
    before the same step's decode buries it."""
    base, = Z.generate([P1], greedy(10))
    first = base.token_ids[0]
    out, = Z.generate([P1], SamplingParams(max_new_tokens=10,
                                           eos_ids=(first,)))
    assert out.finish_reason == "stop" and out.token_ids == [first]
    out, = Z.generate([P1], SamplingParams(max_new_tokens=10,
                                           stop=((first,),)))
    assert out.finish_reason == "stop" and out.token_ids == []
    assert Z.num_free_blocks == N_BLOCKS


def test_generate_max_steps_aborts_orphans():
    with pytest.raises(RuntimeError, match="aborted unfinished"):
        Z.generate([P1], greedy(30), max_steps=3)
    assert not Z.has_unfinished()           # no orphans left running
    assert Z.num_free_blocks == N_BLOCKS


def test_abort_returns_all_blocks_mid_flight():
    r1 = Z.add_request(P1, greedy(30))
    r2 = Z.add_request(P2, greedy(30))
    while not Z.output(r2).token_ids:
        Z.step()                                    # r2 is mid-flight now
    aborted = Z.abort(r2)
    assert aborted.finished and aborted.finish_reason == "abort"
    while Z.has_unfinished():
        Z.step()
    assert Z.output(r1).usage.completion_tokens == 30
    assert Z.output(r1).finish_reason == "length"
    assert Z.num_free_blocks == N_BLOCKS
    Z.bm.check_invariants()
    # aborting an unknown/finished id is a no-op
    assert Z.abort(r2) is None
    assert Z.abort(10_000) is None


def test_abort_waiting_request():
    rid = Z.add_request(P1, greedy(5))
    out = Z.abort(rid)                              # never admitted
    assert out.finish_reason == "abort" and out.token_ids == []
    assert not Z.has_unfinished()
    assert Z.num_free_blocks == N_BLOCKS


def test_streaming_chunks_match_batch_generate():
    batch, = Z.generate([P1], greedy(20))
    rid = Z.add_request(P1, greedy(20))
    chunks, finals = [], []
    while Z.has_unfinished():
        for out in Z.step():
            assert out.chunk.index == sum(len(c) for c in chunks)
            chunks.append(out.chunk.token_ids)
            if out.finished:
                finals.append(out)
    streamed = [t for c in chunks for t in c]
    assert streamed == batch.token_ids              # ordering + content
    assert len(finals) == 1 and finals[0].request_id == rid
    assert finals[0].token_ids == batch.token_ids


def test_generate_interleaved_with_streaming_loses_no_chunks():
    """generate() steps the shared engine; chunks of a concurrently
    streaming request must be re-queued, not swallowed."""
    rid = Z.add_request(P1, greedy(20))
    got, finished_seen = [], False

    def collect(outs):
        nonlocal finished_seen
        for o in outs:
            if o.request_id == rid:
                got.extend(o.chunk.token_ids)
                finished_seen |= o.finished

    collect(Z.step())
    collect(Z.step())
    batch, = Z.generate([P2], greedy(30))   # rid finishes inside here
    assert batch.usage.completion_tokens == 30
    while True:
        outs = Z.step()
        collect(outs)
        if not outs and not Z.has_unfinished():
            break
    assert finished_seen
    assert got == Z.output(rid).token_ids
    assert len(got) == 20
    assert Z.num_free_blocks == N_BLOCKS


def test_logprobs_flag():
    on, off = Z.generate(
        [P1, P1], [SamplingParams(max_new_tokens=6, logprobs=True),
                   SamplingParams(max_new_tokens=6)])
    assert off.logprobs is None
    assert len(on.logprobs) == 6
    assert all(lp <= 0.0 for lp in on.logprobs)


def test_compression_metrics_surface():
    out, = Z.generate([P1], greedy(40))             # long enough to compress
    m = out.metrics.compression
    assert m.kv_budget_tokens == 16                 # (n_max-1)*block_size
    assert m.n_compressions >= 1
    # without prefix sharing, compression caps growth rather than releasing
    # already-held blocks, so freed-count is >= 0 but held KV stays bounded
    assert m.blocks_freed >= 0
    assert m.kv_tokens_held <= 3 * 8                # n_max blocks
    assert Z.num_free_blocks == N_BLOCKS


def test_config_split_routing():
    z = Zipage(CFG, PARAMS,
               cache=CacheConfig(block_size=8, n_total_blocks=32,
                                 max_model_len=64),
               scheduler=SchedulerConfig(max_batch=2, m_qslots=2),
               runner=ModelRunnerConfig(prefill_rows=2, prefill_len=32),
               n_max=None)                          # override rides on base
    assert z.engine.opts.n_total_blocks == 32
    assert z.engine.opts.n_max is None
    assert z.kv_budget_tokens is None
    with pytest.raises(TypeError, match="per-request"):
        make_facade(temperature=0.5)
    with pytest.raises(TypeError, match="unknown"):
        make_facade(blocksize=8)
    from repro.core.compression import CompressOptions
    with pytest.raises(ValueError, match="window"):
        make_facade(window=4, compress=CompressOptions(window=2))


def test_engine_submit_shim_retired():
    """The PR-1 ``submit()`` shim is gone; ``add_request`` + the facade
    are the only entry points."""
    assert not hasattr(Z.engine, "submit")


def test_usage_record_and_final_chunk_markers():
    """RequestOutput.usage carries OpenAI-shaped accounting; the chunk
    that finishes a streamed request carries finish_reason + usage so an
    SSE layer needs no second lookup."""
    out, = Z.generate([P1], greedy(8))
    assert out.usage.prompt_tokens == len(P1)
    assert out.usage.completion_tokens == 8
    assert out.usage.total_tokens == len(P1) + 8
    Z.add_request(P2, greedy(5))
    finals, intermediates = [], []
    while Z.has_unfinished():
        for o in Z.step():
            (finals if o.finished else intermediates).append(o.chunk)
    final, = finals
    assert final.finish_reason == "length"
    assert final.usage.completion_tokens == 5
    assert all(c.finish_reason is None and c.usage is None
               for c in intermediates)
    # one-release deprecation shim: n_tokens still answers, but warns
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        assert out.n_tokens == 8
    assert sum(issubclass(w.category, DeprecationWarning)
               for w in rec) == 1


def test_sampling_params_validation():
    with pytest.raises(ValueError):
        SamplingParams(max_new_tokens=0)
    with pytest.raises(ValueError):
        SamplingParams(top_p=0.0)
    sp = SamplingParams(stop=[[1, 2]], eos_ids=[3])
    assert sp.stop == ((1, 2),) and sp.eos_ids == (3,)


def test_sampling_params_openai_spellings():
    # max_tokens is a validated alias of max_new_tokens
    assert SamplingParams(max_tokens=12).max_new_tokens == 12
    assert SamplingParams(max_tokens=12) == SamplingParams(max_new_tokens=12)
    with pytest.raises(ValueError, match="alias"):
        SamplingParams(max_tokens=12, max_new_tokens=13)
    # n is accepted but only n=1 is supported
    assert SamplingParams(n=1).n == 1
    with pytest.raises(ValueError, match="n separate requests"):
        SamplingParams(n=4)
    # unknown kwargs get a did-you-mean error, not silent acceptance
    with pytest.raises(TypeError, match="did you mean 'temperature'"):
        SamplingParams(temprature=0.7)
    with pytest.raises(TypeError, match="unknown SamplingParams field"):
        SamplingParams(banana=1)
