"""Randomized engine soak under the runtime sanitizer (``make test-soak``).

Seeded fuzz workloads — mixed prompt lengths (some sharing prefixes),
mixed output lengths, mixed sampling params and compression policies,
mid-flight aborts — served across the scheduler-policy × preemption-mode
× fused-decode-horizon matrix with ``ZIPAGE_SANITIZE=1`` armed, so every
step runs the whole-engine invariant audit (repro.core.invariants). At
drain the pool must be byte-clean: no leaked blocks, slots, qslots or
swap reservations. One combo additionally snapshots mid-soak and checks
the restore replays to identical outputs.

Small pool + tiny blocks + window=2 on purpose: maximum churn per step
(compression, preemption, swap, prefix eviction all fire) at CPU-CI
cost. The tests arm the sanitizer themselves (monkeypatch, before engine
construction), so they audit under plain ``make test`` too; ``make
test-soak`` runs just this module for a focused loop."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import invariants
from repro.core.compression import CompressOptions
from repro.core.engine import EngineOptions, ZipageEngine
from repro.core.sampling import SamplingParams
from repro.models import lm

CFG = dataclasses.replace(get_config("tiny-lm"), dtype="float32")
PARAMS = lm.init(CFG, jax.random.key(0))

#: (id, engine-option overrides) — one row per scheduler-policy ×
#: preemption-mode × decode-horizon × cache-structure combination
COMBOS = [
    ("fcfs_recompute_h1_flat", dict(
        policy="fcfs", preemption_mode="recompute", decode_steps=1,
        prefix_cache_policy="flat")),
    ("priority_swap_h4_radix", dict(
        policy="priority", preemption_mode="swap", decode_steps=4,
        prefix_cache_policy="radix", swap_space_blocks=16)),
    ("srpt_auto_h8_watermark", dict(
        policy="srpt", preemption_mode="auto", decode_steps=8,
        prefix_cache_policy="radix", prefix_cache_watermark=0.5,
        swap_space_blocks=16)),
    ("cache_aware_auto_h4_segments", dict(
        policy="cache_aware", preemption_mode="auto", decode_steps=4,
        prefix_cache_policy="radix", cache_compressed_prefixes=True,
        token_budget=48, swap_space_blocks=16, quality_aware=True,
        quality_defer_min_free=4)),
]


def make_engine(**kw):
    base = dict(block_size=4, n_total_blocks=40, max_batch=8, m_qslots=4,
                n_max=3, window=2, compress=CompressOptions(window=2),
                max_model_len=128, prefill_rows=2, prefill_len=32,
                fuse_sampling=True, async_compression=True, dtype="float32")
    base.update(kw)
    return ZipageEngine(CFG, PARAMS, EngineOptions(**base))


def fuzz_params(rng):
    """Random per-request sampling: greedy / seeded top-k / seeded
    top-p, random compression policy, occasional eos."""
    style = int(rng.integers(0, 3))
    kw = dict(
        max_new_tokens=int(rng.integers(4, 25)),
        seed=int(rng.integers(0, 2**31 - 1)),
        compression_policy=("default", "protect",
                           "aggressive")[int(rng.integers(0, 3))])
    if style == 1:
        kw.update(temperature=0.8, top_k=8)
    elif style == 2:
        kw.update(temperature=1.0, top_p=0.9)
    return SamplingParams(**kw)


def fuzz_prompt(rng):
    """Random prompt, ~1/3 extending one of a few shared stems so the
    prefix cache and cache_aware admission have something to chew on."""
    stems = {0: [3, 1, 4, 1, 5, 9, 2, 6], 1: [2, 7, 1, 8, 2, 8]}
    tail = [int(t) for t in rng.integers(1, 50, size=rng.integers(1, 12))]
    pick = int(rng.integers(0, 3))
    return stems.get(pick, []) + tail


def drain_and_audit(eng, rids):
    done = eng.run(max_steps=4000)
    leaked = [rid for rid in rids if rid not in done]
    assert not leaked, f"requests never finished: {leaked}"
    assert not eng.scheduler.running and not eng.scheduler.waiting
    assert not eng.scheduler.swapped
    assert eng.bm.num_free == eng.opts.n_total_blocks
    assert len(eng.scheduler.free_slots) == eng.opts.max_batch
    assert len(eng.scheduler.free_qslots) == eng.opts.m_qslots
    assert not eng.bm.swapped and eng.bm.swap_util == 0.0
    eng.bm.check_invariants()
    assert invariants.audit_engine(eng) == []
    return done


@pytest.mark.parametrize("combo_id,overrides", COMBOS,
                         ids=[c[0] for c in COMBOS])
def test_soak_fuzz_matrix(monkeypatch, combo_id, overrides):
    monkeypatch.setenv("ZIPAGE_SANITIZE", "1")   # before construction
    eng = make_engine(**overrides)
    assert eng.sanitize is True
    rng = np.random.default_rng(abs(hash(combo_id)) % (2**31))
    rids = []
    # three admission waves with interleaved stepping + one mid-wave abort
    for wave in range(3):
        for _ in range(5):
            rids.append(eng.add_request(
                fuzz_prompt(rng), fuzz_params(rng),
                priority=int(rng.integers(0, 3))))
        for _ in range(int(rng.integers(2, 6))):
            eng.step()
        if wave == 1:
            victim = rids[int(rng.integers(0, len(rids)))]
            if eng.abort(victim):
                rids.remove(victim)
    drain_and_audit(eng, rids)


def test_soak_snapshot_restore_roundtrip(monkeypatch):
    """Mid-soak snapshot under the sanitizer: restoring into a fresh
    engine and draining must reproduce the original outputs exactly."""
    monkeypatch.setenv("ZIPAGE_SANITIZE", "1")
    overrides = COMBOS[1][1]
    eng = make_engine(**overrides)
    rng = np.random.default_rng(7)
    rids = [eng.add_request(fuzz_prompt(rng), fuzz_params(rng),
                            priority=int(rng.integers(0, 3)))
            for _ in range(10)]
    for _ in range(6):
        eng.step()
    snap = eng.snapshot()
    done_a = drain_and_audit(eng, rids)
    out_a = {rid: done_a[rid].output for rid in rids}

    eng2 = make_engine(**overrides)
    eng2.restore(snap)
    done_b = drain_and_audit(eng2, rids)
    out_b = {rid: done_b[rid].output for rid in rids}
    assert out_a == out_b
